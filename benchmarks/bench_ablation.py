"""Fig. 16 reproduction: ablating the three throughput-oriented strategies
(R = routing, S = synchronization, M = migration) against their vanilla
counterparts. Expected: all-vanilla ~= the in-flight-limit baseline; each
staleflow strategy added improves throughput; all three together best."""
from __future__ import annotations

import dataclasses

from benchmarks.common import emit, note, sim_cfg
from repro.core import StrategySuite
from repro.core.strategies import (
    migration_strategy,
    routing_strategy,
    synchronization_strategy,
    vanilla_migration,
    vanilla_routing,
    vanilla_synchronization,
)
from repro.core.types import reset_traj_ids
from repro.sim.engine import StaleFlowSim

GRID = {
    "vanilla": (vanilla_routing, vanilla_synchronization, vanilla_migration),
    "R": (routing_strategy, vanilla_synchronization, vanilla_migration),
    "RS": (routing_strategy, synchronization_strategy, vanilla_migration),
    "RM": (routing_strategy, vanilla_synchronization, migration_strategy),
    "SM": (vanilla_routing, synchronization_strategy, migration_strategy),
    "RSM": (routing_strategy, synchronization_strategy, migration_strategy),
}


def run(quick: bool = False) -> dict:
    note("bench_ablation (Fig. 16): R/S/M strategy grid")
    out = {}
    combos = ("vanilla", "R", "RS", "RSM") if quick else tuple(GRID)
    base = sim_cfg(eta=3, total_steps=4 if quick else 6)
    for name in combos:
        r, s, m = GRID[name]
        cfg = dataclasses.replace(
            base, suite=StrategySuite(routing=r, synchronization=s, migration=m)
        )
        reset_traj_ids()
        res = StaleFlowSim(cfg).run()
        emit("ablation", f"{name}_tokens_per_s", res.throughput)
        out[name] = res.throughput
    emit("ablation", "RSM_over_vanilla", out["RSM"] / out["vanilla"])
    return out


if __name__ == "__main__":
    run()
