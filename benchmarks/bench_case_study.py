"""Fig. 17 reproduction: per-instance rollout load over time, staleflow
strategies vs all-vanilla. Expected qualitative shapes: vanilla dumps every
assignable trajectory onto instances immediately (high initial load, long
idle tails); staleflow routes incrementally against the marginal-gain
threshold and rebalances via migration (flatter, denser load)."""
from __future__ import annotations

import dataclasses
import json
import os

import numpy as np

from benchmarks.common import emit, note, sim_cfg
from repro.core import StrategySuite
from repro.core.types import reset_traj_ids
from repro.sim.engine import StaleFlowSim


def run(quick: bool = False, out_dir: str = "results") -> dict:
    note("bench_case_study (Fig. 17): per-instance load timelines")
    base = sim_cfg(eta=3, total_steps=3 if quick else 5)
    out = {}
    os.makedirs(out_dir, exist_ok=True)
    for name, suite in (
        ("staleflow", StrategySuite.staleflow()),
        ("vanilla", StrategySuite.vanilla()),
    ):
        reset_traj_ids()
        res = StaleFlowSim(dataclasses.replace(base, suite=suite)).run()
        # load imbalance: mean over time of (max - min) run count
        gaps = [max(l.values()) - min(l.values()) for _, l in res.instance_load]
        # idleness: fraction of (instance, sample) pairs with zero running
        idle = np.mean(
            [1.0 if v == 0 else 0.0 for _, l in res.instance_load for v in l.values()]
        )
        emit("case_study", f"{name}_mean_load_gap", float(np.mean(gaps)))
        emit("case_study", f"{name}_idle_fraction", float(idle))
        emit("case_study", f"{name}_syncs", len(res.sync_events))
        path = os.path.join(out_dir, f"case_study_load_{name}.json")
        with open(path, "w") as f:
            json.dump(
                {
                    "timeline": [
                        {"t": t, "load": {str(k): v for k, v in l.items()}}
                        for t, l in res.instance_load
                    ],
                    "sync_events": res.sync_events,
                },
                f,
            )
        out[name] = {"gap": float(np.mean(gaps)), "idle": float(idle)}
    return out


if __name__ == "__main__":
    run()
