"""Fig. 3/14 reproduction: RL convergence vs staleness bound, on the REAL
async runtime (tiny model, arithmetic verifiable reward).

Expected: eta in {0..3} converges (reward climbs); very large eta trains on
badly stale data — mean IS ratios drift from 1 and learning degrades. At
toy scale we report reward trajectories + IS-ratio drift rather than a
full collapse (the paper uses 100+ steps on 32B models)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, note
from repro.configs import get_arch
from repro.core.types import reset_traj_ids
from repro.runtime.async_runtime import AsyncRLRuntime, RuntimeConfig


def run(quick: bool = False) -> dict:
    note("bench_convergence (Fig. 3/14): reward & IS drift vs eta")
    arch = get_arch("qwen2-1.5b").reduced()
    steps = 4 if quick else 10
    out = {}
    for eta in (0, 1, 3):
        reset_traj_ids()
        rt = AsyncRLRuntime(
            arch,
            RuntimeConfig(
                eta=eta, batch_size=4, group_size=2, n_instances=2,
                max_slots=4, max_len=48, max_new_tokens=8,
                total_steps=steps, lr=3e-3, temperature=1.0, seed=0,
            ),
        )
        hist = rt.run(max_ticks=20000)
        rewards = [h.mean_reward for h in hist]
        ratios = [h.mean_is_ratio for h in hist]
        stal = [s for h in hist for s in h.staleness_hist]
        emit("convergence", f"eta{eta}_steps", len(hist))
        emit("convergence", f"eta{eta}_final_reward", rewards[-1] if rewards else 0)
        emit("convergence", f"eta{eta}_mean_reward", float(np.mean(rewards)))
        emit("convergence", f"eta{eta}_is_ratio_drift",
             float(np.mean(np.abs(np.asarray(ratios) - 1.0))))
        emit("convergence", f"eta{eta}_max_staleness", max(stal) if stal else 0)
        out[f"eta{eta}"] = {
            "rewards": rewards, "ratios": ratios,
            "max_staleness": max(stal) if stal else 0,
        }
        assert all(s <= eta for s in stal), "protocol violation!"
    return out


if __name__ == "__main__":
    run()
