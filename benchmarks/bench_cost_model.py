"""Fig. 24 + Table 4 reproduction: cost-model fit & accuracy.

Profiles OUR real JAX rollout engine (tiny model on CPU): decode step
latency across (kv_cache bytes, n_running) grid points, fits k1..k4 by the
piecewise least squares of Appendix B, and reports the relative estimation
error on held-out points. Paper reports 10.52% mean error on H20; we
expect the same order on a totally different backend because the model's
FORM (linear in KV + max(memory floor, compute slope)) is
hardware-agnostic."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, note
from repro.configs import get_arch
from repro.core.cost_model import fit_coefficients
from repro.models import model as M


def _profile_point(cfg, params, decode, b_active, seq_len, reps=3):
    """Median decode-step latency with b_active rows at seq_len cache fill."""
    cache = M.init_cache(cfg, b_active, max_len=seq_len + 8)
    cache["pos"] = jnp.full((b_active,), seq_len, jnp.int32)
    tokens = jnp.zeros((b_active,), jnp.int32)
    logits, cache = decode(params, tokens, cache)  # compile + warm
    jax.block_until_ready(logits)
    times = []
    for _ in range(reps):
        t0 = time.perf_counter()
        logits, cache = decode(params, tokens, cache)
        jax.block_until_ready(logits)
        times.append(time.perf_counter() - t0)
    return float(np.median(times))


def run(quick: bool = False) -> dict:
    note("bench_cost_model (Fig. 24 / Table 4): fit k1..k4, report error")
    cfg = get_arch("qwen2-1.5b").reduced()
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    from functools import partial

    decode = jax.jit(partial(M.decode_step, cfg))
    k5 = 2.0 * cfg.n_layers * cfg.n_kv_heads * cfg.hd * 4

    ns = (1, 2, 4) if quick else (1, 2, 4, 8, 16)
    lens = (64, 256) if quick else (64, 128, 256, 512)
    samples = []
    for n in ns:
        for s in lens:
            lat = _profile_point(cfg, params, decode, n, s)
            samples.append((k5 * n * s, n, lat))
    cm = fit_coefficients(samples, k5=k5, kv_budget=1e12)
    emit("cost_model", "k1", cm.k1)
    emit("cost_model", "k2", cm.k2)
    emit("cost_model", "k3", cm.k3)
    emit("cost_model", "k4", cm.k4)

    errs = []
    for kv, n, lat in samples:
        pred = cm.step_latency(kv, n)
        errs.append(abs(pred - lat) / lat)
    mean_err = float(np.mean(errs))
    emit("cost_model", "mean_rel_error", mean_err)
    emit("cost_model", "paper_reported_error", 0.1052)
    return {"coeffs": (cm.k1, cm.k2, cm.k3, cm.k4), "mean_err": mean_err}


if __name__ == "__main__":
    run()
