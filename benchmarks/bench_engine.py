"""Rollout-engine data-plane benchmark: batched admission + compacted decode.

Tracks the speedup the prefill/decode runner split buys over the seed
engine's single-row path:

* **admission latency** — time to admit a full wave of waiting
  trajectories (the migration/re-prefill burst after an Interrupt storm):
  seed = one forward + tensor-by-tensor scatter per trajectory; batched =
  one padded forward + one fused scatter per length bucket.
* **decode tokens/s vs active fraction** — seed decodes all ``max_slots``
  rows every step regardless of occupancy; compacted decode gathers the
  active slots into a power-of-two bucket, so cost scales with occupancy.

Acceptance tracked in the bench trajectory: admission latency no worse
than seed; decode tokens/s strictly better when <50% of slots are active.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict

import jax
import numpy as np

from benchmarks.common import emit, note
from repro.configs import get_arch
from repro.core.types import Trajectory, reset_traj_ids
from repro.models import model as M
from repro.rollout.backend import create_backend

NO_EOS = -1  # no sampled token ever matches: trajectories never self-finish


def _bench_arch():
    """Mid-size config: big enough that per-row decode compute dominates
    dispatch overhead on CPU (the tiny smoke config measures only the
    latter), small enough that the bench stays in seconds."""
    return dataclasses.replace(
        get_arch("qwen2-1.5b").reduced(),
        d_model=256, n_layers=8, n_heads=8, n_kv_heads=2, head_dim=32,
        d_ff=512, vocab_size=4096,
    )


def _mk_instance(params, cfg, *, legacy: bool, slots: int, max_len: int, **kw):
    return create_backend(
        "jax", 0, cfg=cfg, params=params, version=0,
        max_slots=slots, max_len=max_len, temperature=1.0, eos_id=NO_EOS,
        batched_prefill=not legacy, compact_decode=not legacy, **kw,
    )


def _mk_trajs(n, prompt_len, max_new=10_000, base=0):
    return [
        Trajectory(
            traj_id=base + i,
            prompt=list(np.random.RandomState(base + i).randint(3, 200, prompt_len)),
            max_new_tokens=max_new,
        )
        for i in range(n)
    ]


def _bench_admission(params, cfg, *, legacy, slots=8, prompt_len=8, repeats=5):
    """Median wall time to admit ``slots`` waiting trajectories at once."""
    inst = _mk_instance(params, cfg, legacy=legacy, slots=slots, max_len=64)
    trajs = _mk_trajs(slots, prompt_len, base=1000)
    ids = [t.traj_id for t in trajs]
    # warm-up: compiles the prefill/scatter shapes for this wave
    inst.route_many(trajs)
    times = []
    for _ in range(repeats):
        out = inst.interrupt(ids)
        assert len(out) == slots
        # keep re-prefill shapes identical across repeats: drop the token
        # each admission samples
        for t in trajs:
            t.response.pop()
            t.behavior_logprobs.pop()
            t.finished = False
        t0 = time.perf_counter()
        inst.route_many(trajs)  # one wave, as execute_commands delivers it
        times.append(time.perf_counter() - t0)
    assert inst.n_active() == slots
    return float(np.median(times))


def _bench_decode(
    params, cfg, *, legacy, n_active, slots=16, steps=20, reps=5
):
    """Steady-state decode tokens/s with ``n_active`` occupied slots."""
    inst = _mk_instance(params, cfg, legacy=legacy, slots=slots, max_len=128)
    for t in _mk_trajs(n_active, 8, base=2000):
        inst.route(t)
    assert inst.n_active() == n_active
    for _ in range(5):  # warm-up: compiles this occupancy's decode bucket
        inst.step()
    best = float("inf")
    for _ in range(reps):  # min-of-reps to shrug off scheduler noise
        t0 = time.perf_counter()
        for _ in range(steps):
            inst.step()
        best = min(best, time.perf_counter() - t0)
    assert inst.n_active() == n_active, "occupancy changed mid-measurement"
    return n_active * steps / best


def _bench_paged_capacity(
    params, cfg, *, paged: bool, budget_slots: int, max_len: int = 128,
    block_size: int = 16, steps: int = 20,
):
    """Concurrency + decode tokens/s at one fixed HBM budget.

    The budget holds ``budget_slots`` dense worst-case rows. The dense
    engine physically reserves ``max_len`` rows per slot, so its slot count
    IS the budget; the paged engine shares the same bytes as a block pool
    and admits by actual allocation, so a mixed short/long workload packs
    strictly more concurrent trajectories into the same memory.
    """
    k5 = 2 * cfg.n_layers * cfg.n_kv_heads * cfg.hd * 4
    budget = float(k5 * max_len * budget_slots)
    inst = _mk_instance(
        params, cfg, legacy=False,
        slots=(4 * budget_slots) if paged else budget_slots,
        max_len=max_len, kv_budget=budget,
        **(dict(paged=True, kv_block_size=block_size) if paged else {}),
    )
    # heavy-tail mix: mostly short prompts, a few long ones (Fig. 4 skew)
    lengths = [8, 8, 8, 16, 8, 8, 32, 8] * budget_slots
    trajs = [
        Trajectory(
            traj_id=3000 + i,
            prompt=list(np.random.RandomState(3000 + i).randint(3, 200, pl)),
            max_new_tokens=10_000,
        )
        for i, pl in enumerate(lengths)
    ]
    inst.route_many(trajs)
    admitted = inst.n_active()
    for _ in range(3):  # warm-up this occupancy's decode shapes
        inst.step()
    t0 = time.perf_counter()
    tok0 = inst.decode_tokens
    for _ in range(steps):
        inst.step()
    dt = time.perf_counter() - t0
    # decode_tokens counts rows actually decoded (post-preemption), so the
    # paged number is not inflated by slots evicted before the dispatch
    return admitted, (inst.decode_tokens - tok0) / dt, inst.kv_bytes() / budget


def _bench_prefix_capacity(
    params, cfg, *, share: bool, group_size: int, prompt_len: int,
    budget_slots: int = 2, max_len: int = 128, block_size: int = 16,
):
    """Group-sampling capacity at one fixed HBM budget, with and without
    prefix sharing.

    Routes waves of ``group_size``-member groups (identical prompt per
    group) at a paged engine whose pool holds ``budget_slots`` dense
    worst-case rows. Sharing stores each admitted group's full prompt
    blocks once and prefills the prompt once, so at the same budget it
    admits up to ~group_size x more members on prompt-heavy workloads
    while running a fraction of the prefill tokens.

    Returns (admitted members, HBM fill fraction, prefill tokens run,
    prefill tokens saved by sharing).
    """
    k5 = 2 * cfg.n_layers * cfg.n_kv_heads * cfg.hd * 4
    budget = float(k5 * max_len * budget_slots)
    inst = _mk_instance(
        params, cfg, legacy=False,
        slots=8 * budget_slots * max(1, group_size // 2),
        max_len=max_len, kv_budget=budget,
        paged=True, kv_block_size=block_size, share_prefix=share,
    )
    n_groups = 4 * budget_slots
    for gid in range(n_groups):
        prompt = list(
            np.random.RandomState(7000 + gid).randint(3, 200, prompt_len)
        )
        inst.route_many([
            Trajectory(
                traj_id=7000 + gid * 100 + i, prompt=list(prompt),
                group_id=gid, max_new_tokens=10_000,
            )
            for i in range(group_size)
        ])
    return (
        inst.n_active(),
        inst.kv_bytes() / budget,
        inst.prefill_tokens,
        inst.prefill_tokens_saved,
    )


def _bench_fork_admission(
    params, cfg, *, share: bool, lazy: bool = True, group_size: int = 4,
    prompt_len: int = 37, block_size: int = 16, max_len: int = 64,
    run_slots: int = 2,
):
    """Straggler-fork admission cost: a group wider than the slot count
    admits ``run_slots`` members up front; the rest fork the still-resident
    prefix one by one as early finishers free slots. With suffix prefill a
    fork forwards only the prompt's partial-tail tokens (the full prefix
    blocks are resident), so the tokens forwarded per fork drop from
    ``prompt_len`` to ``prompt_len mod block_size``-ish.

    Returns (wave prefill tokens, fork prefill tokens, pool block copies,
    completed trajectories).
    """
    inst = _mk_instance(
        params, cfg, legacy=False, slots=run_slots, max_len=max_len,
        paged=True, kv_block_size=block_size, share_prefix=share,
        lazy_cow=lazy,
    )
    prompt = list(np.random.RandomState(7777).randint(3, 200, prompt_len))
    group = [
        Trajectory(
            traj_id=7700 + i, prompt=list(prompt), group_id=77,
            # staggered budgets: finishers free slots while siblings still
            # hold the prefix, so every straggler admission is a fork
            max_new_tokens=4 + 2 * i,
        )
        for i in range(group_size)
    ]
    inst.route_many(group)
    wave_tokens = inst.prefill_tokens
    done = []
    for _ in range(100 * group_size):
        done.extend(inst.step())
        if len(done) == group_size:
            break
    return (
        wave_tokens,
        inst.prefill_tokens - wave_tokens,
        inst.block_copies,
        len(done),
    )


def _bench_cow_traffic(
    params, cfg, *, lazy: bool, group_size: int = 4, prompt_len: int = 21,
    block_size: int = 16,
):
    """Pool block copies for a group whose members partly never decode:
    half the members are interrupted between admission and their first
    step (rebalancing storms do exactly this). Eager CoW has already
    copied every member's tail at admission; lazy CoW copies only at
    first divergence, so the interrupted members' copies never happen."""
    inst = _mk_instance(
        params, cfg, legacy=False, slots=group_size, max_len=64,
        paged=True, kv_block_size=block_size, share_prefix=True,
        lazy_cow=lazy,
    )
    group = [
        Trajectory(
            traj_id=7900 + i,
            prompt=list(
                np.random.RandomState(7900).randint(3, 200, prompt_len)
            ),
            group_id=79, max_new_tokens=4,
        )
        for i in range(group_size)
    ]
    inst.route_many(group)
    inst.interrupt([7900 + i for i in range(group_size // 2)])
    for _ in range(20):
        if not inst.n_active():
            break
        inst.step()
    return inst.block_copies


def run(quick: bool = False) -> Dict[str, float]:
    reset_traj_ids()
    cfg = _bench_arch()
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    slots = 16
    out: Dict[str, float] = {}

    note("engine: admission latency (one wave fills all slots)")
    for mode, legacy in (("seed", True), ("batched", False)):
        lat = _bench_admission(
            params, cfg, legacy=legacy, slots=8,
            repeats=3 if quick else 5,
        )
        out[f"admission_latency_{mode}_s"] = lat
        emit("engine", f"admission_latency_{mode}_s", lat)
    emit(
        "engine", "admission_speedup",
        out["admission_latency_seed_s"] / out["admission_latency_batched_s"],
    )

    note("engine: decode tokens/s vs active slots (of %d)" % slots)
    for n_active in (1, 2, 4, 8, 16):
        for mode, legacy in (("seed", True), ("compact", False)):
            tps = _bench_decode(
                params, cfg, legacy=legacy, n_active=n_active, slots=slots,
                steps=10 if quick else 20, reps=3 if quick else 5,
            )
            out[f"decode_tps_{mode}_active{n_active}"] = tps
            emit("engine", f"decode_tps_{mode}_active{n_active}", tps)
        emit(
            "engine", f"decode_speedup_active{n_active}",
            out[f"decode_tps_compact_active{n_active}"]
            / out[f"decode_tps_seed_active{n_active}"],
        )

    note("engine: paged vs dense at a fixed HBM budget (mixed lengths)")
    for budget_slots in (2, 4) if quick else (2, 4, 8):
        for mode, paged in (("dense", False), ("paged", True)):
            adm, tps, fill = _bench_paged_capacity(
                params, cfg, paged=paged, budget_slots=budget_slots,
                steps=10 if quick else 20,
            )
            out[f"kvfit_{mode}_budget{budget_slots}_admitted"] = adm
            out[f"kvfit_{mode}_budget{budget_slots}_tps"] = tps
            emit("engine", f"kvfit_{mode}_budget{budget_slots}_admitted", adm)
            emit("engine", f"kvfit_{mode}_budget{budget_slots}_tps", tps)
            emit("engine", f"kvfit_{mode}_budget{budget_slots}_fill", fill)
        emit(
            "engine", f"kvfit_concurrency_gain_budget{budget_slots}",
            out[f"kvfit_paged_budget{budget_slots}_admitted"]
            / out[f"kvfit_dense_budget{budget_slots}_admitted"],
        )

    note("engine: prefix sharing — group capacity at a fixed HBM budget")
    gs_sweep = (4,) if quick else (2, 4, 8)
    pl_sweep = (48,) if quick else (16, 48, 96)
    for group_size in gs_sweep:
        for prompt_len in pl_sweep:
            cell = f"g{group_size}_p{prompt_len}"
            for mode, share in (("noshare", False), ("share", True)):
                adm, fill, ptoks, _ = _bench_prefix_capacity(
                    params, cfg, share=share,
                    group_size=group_size, prompt_len=prompt_len,
                )
                out[f"prefixfit_{mode}_{cell}_admitted"] = adm
                # prefill work is per-member: sharing admits more members
                # off the same prompt passes, so tokens/member is the
                # comparable cost (raw totals are budget-bounded alike)
                out[f"prefixfit_{mode}_{cell}_prefill_per_member"] = (
                    ptoks / max(adm, 1)
                )
                emit("engine", f"prefixfit_{mode}_{cell}_admitted", adm)
                emit("engine", f"prefixfit_{mode}_{cell}_fill", fill)
                emit(
                    "engine", f"prefixfit_{mode}_{cell}_prefill_per_member",
                    ptoks / max(adm, 1),
                )
            emit(
                "engine", f"prefixfit_member_gain_{cell}",
                out[f"prefixfit_share_{cell}_admitted"]
                / max(out[f"prefixfit_noshare_{cell}_admitted"], 1),
            )
            emit(
                "engine", f"prefixfit_prefill_saved_frac_{cell}",
                1.0
                - out[f"prefixfit_share_{cell}_prefill_per_member"]
                / max(
                    out[f"prefixfit_noshare_{cell}_prefill_per_member"], 1e-9
                ),
            )

    note("engine: suffix prefill — tokens forwarded at straggler forks")
    for group_size in gs_sweep:
        if group_size < 4:               # need stragglers beyond the slots
            continue
        for mode, share in (("noshare", False), ("share", True)):
            _, fork_toks, _, finished = _bench_fork_admission(
                params, cfg, share=share, group_size=group_size,
            )
            assert finished == group_size
            out[f"forkfit_{mode}_g{group_size}_fork_tokens"] = fork_toks
            emit(
                "engine", f"forkfit_{mode}_g{group_size}_fork_tokens",
                fork_toks,
            )
        emit(
            "engine", f"forkfit_fork_token_gain_g{group_size}",
            out[f"forkfit_noshare_g{group_size}_fork_tokens"]
            / max(out[f"forkfit_share_g{group_size}_fork_tokens"], 1),
        )

    note("engine: CoW traffic — lazy copy-at-first-divergence vs eager")
    copies_lazy = _bench_cow_traffic(params, cfg, lazy=True)
    copies_eager = _bench_cow_traffic(params, cfg, lazy=False)
    emit("engine", "cow_copies_lazy", copies_lazy)
    emit("engine", "cow_copies_eager", copies_eager)
    return out


def run_memfit_smoke() -> Dict[str, int]:
    """CI smoke: the kvfit and prefixfit sweeps at a tiny config.

    Exercises the real admission/allocation paths (dense vs paged, shared
    vs unshared) end-to-end in seconds and asserts the headline
    inequalities, so the benchmarks cannot silently rot.

    Returns the sweeps' *deterministic* counters (admission counts and
    prefill-token totals are pure functions of the seeded workload and
    the block-exact accounting — no timing anywhere). CI pins them
    against ``benchmarks/smoke_baseline.json`` so an accounting
    regression fails the build instead of silently shifting every sweep.
    """
    reset_traj_ids()
    cfg = get_arch("qwen2-1.5b").reduced()  # tiny smoke arch, CPU-fast
    params = M.init_params(cfg, jax.random.PRNGKey(0))

    note("smoke: kvfit (paged vs dense at one fixed budget)")
    dense_adm, _, dense_fill = _bench_paged_capacity(
        params, cfg, paged=False, budget_slots=2, max_len=64, steps=2,
    )
    paged_adm, _, paged_fill = _bench_paged_capacity(
        params, cfg, paged=True, budget_slots=2, max_len=64, steps=2,
    )
    emit("engine", "smoke_kvfit_dense_admitted", dense_adm)
    emit("engine", "smoke_kvfit_paged_admitted", paged_adm)
    assert paged_adm > dense_adm, "paged must out-admit dense"
    assert dense_fill <= 1.0 and paged_fill <= 1.0, "budget overrun"

    note("smoke: prefixfit (shared vs unshared group admission)")
    reset_traj_ids()
    no_adm, no_fill, no_ptoks, no_saved = _bench_prefix_capacity(
        params, cfg, share=False, group_size=4, prompt_len=24, max_len=64,
    )
    reset_traj_ids()
    sh_adm, sh_fill, sh_ptoks, sh_saved = _bench_prefix_capacity(
        params, cfg, share=True, group_size=4, prompt_len=24, max_len=64,
    )
    emit("engine", "smoke_prefixfit_noshare_admitted", no_adm)
    emit("engine", "smoke_prefixfit_share_admitted", sh_adm)
    emit("engine", "smoke_prefixfit_prefill_per_member_noshare",
         no_ptoks / max(no_adm, 1))
    emit("engine", "smoke_prefixfit_prefill_per_member_share",
         sh_ptoks / max(sh_adm, 1))
    assert sh_adm >= no_adm, "sharing must not admit fewer members"
    assert sh_ptoks / max(sh_adm, 1) < no_ptoks / max(no_adm, 1), (
        "sharing must cut prefill tokens per admitted member"
    )
    assert no_saved == 0, "unshared sweep cannot save prefill tokens"
    assert sh_saved > 0, "shared sweep must save prefill tokens"
    assert no_fill <= 1.0 and sh_fill <= 1.0, "budget overrun"

    note("smoke: forkfit (suffix prefill at straggler-fork admission)")
    reset_traj_ids()
    _, fork_no, _, fin_no = _bench_fork_admission(params, cfg, share=False)
    reset_traj_ids()
    _, fork_sh, _, fin_sh = _bench_fork_admission(params, cfg, share=True)
    emit("engine", "smoke_forkfit_noshare_fork_tokens", fork_no)
    emit("engine", "smoke_forkfit_share_fork_tokens", fork_sh)
    assert fin_no == fin_sh, "fork sweeps must complete the same workload"
    assert fork_no >= 5 * fork_sh, (
        "suffix prefill must forward >= 5x fewer prompt tokens at "
        "straggler-fork admission"
    )

    note("smoke: CoW traffic (lazy copy-at-first-divergence vs eager)")
    reset_traj_ids()
    copies_lazy = _bench_cow_traffic(params, cfg, lazy=True)
    reset_traj_ids()
    copies_eager = _bench_cow_traffic(params, cfg, lazy=False)
    emit("engine", "smoke_cow_copies_lazy", copies_lazy)
    emit("engine", "smoke_cow_copies_eager", copies_eager)
    assert copies_lazy < copies_eager, (
        "lazy CoW must copy strictly fewer blocks than eager CoW"
    )
    note("smoke: OK")
    return {
        "kvfit_dense_admitted": int(dense_adm),
        "kvfit_paged_admitted": int(paged_adm),
        "prefixfit_noshare_admitted": int(no_adm),
        "prefixfit_share_admitted": int(sh_adm),
        "prefixfit_noshare_prefill_tokens": int(no_ptoks),
        "prefixfit_share_prefill_tokens": int(sh_ptoks),
        "prefixfit_share_prefill_tokens_saved": int(sh_saved),
        "forkfit_noshare_fork_prefill_tokens": int(fork_no),
        "forkfit_share_fork_prefill_tokens": int(fork_sh),
        "cow_block_copies_lazy": int(copies_lazy),
        "cow_block_copies_eager": int(copies_eager),
    }


def _check_baseline(counters: Dict[str, int], baseline_path: str) -> None:
    """Exact comparison against the committed smoke baseline; any drift
    is an accounting change that must be reviewed (and the baseline
    regenerated with --json)."""
    import json

    with open(baseline_path) as f:
        baseline = json.load(f)
    diffs = {
        key: (baseline.get(key), counters.get(key))
        for key in sorted(set(baseline) | set(counters))
        if baseline.get(key) != counters.get(key)
    }
    if diffs:
        raise SystemExit(
            f"smoke counters drifted from {baseline_path} "
            f"(baseline, got): {diffs}\n"
            "If the change is intentional, regenerate the baseline:\n"
            "  python -m benchmarks.bench_engine --smoke "
            "--json benchmarks/smoke_baseline.json"
        )
    note(f"smoke: counters match {baseline_path}")


if __name__ == "__main__":
    import json
    import sys

    print("bench,metric,value")
    if "--smoke" in sys.argv:
        counters = run_memfit_smoke()
        if "--json" in sys.argv:
            path = sys.argv[sys.argv.index("--json") + 1]
            with open(path, "w") as f:
                json.dump(counters, f, indent=2, sort_keys=True)
                f.write("\n")
            note(f"smoke: counters written to {path}")
        if "--check-baseline" in sys.argv:
            _check_baseline(
                counters, sys.argv[sys.argv.index("--check-baseline") + 1]
            )
    else:
        run(quick="--quick" in sys.argv)
