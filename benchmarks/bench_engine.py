"""Rollout-engine data-plane benchmark: batched admission + compacted decode.

Tracks the speedup the prefill/decode runner split buys over the seed
engine's single-row path:

* **admission latency** — time to admit a full wave of waiting
  trajectories (the migration/re-prefill burst after an Interrupt storm):
  seed = one forward + tensor-by-tensor scatter per trajectory; batched =
  one padded forward + one fused scatter per length bucket.
* **decode tokens/s vs active fraction** — seed decodes all ``max_slots``
  rows every step regardless of occupancy; compacted decode gathers the
  active slots into a power-of-two bucket, so cost scales with occupancy.

Acceptance tracked in the bench trajectory: admission latency no worse
than seed; decode tokens/s strictly better when <50% of slots are active.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict

import jax
import numpy as np

from benchmarks.common import emit, note
from repro.configs import get_arch
from repro.core.types import Trajectory, reset_traj_ids
from repro.models import model as M
from repro.rollout.backend import create_backend

NO_EOS = -1  # no sampled token ever matches: trajectories never self-finish


def _bench_arch():
    """Mid-size config: big enough that per-row decode compute dominates
    dispatch overhead on CPU (the tiny smoke config measures only the
    latter), small enough that the bench stays in seconds."""
    return dataclasses.replace(
        get_arch("qwen2-1.5b").reduced(),
        d_model=256, n_layers=8, n_heads=8, n_kv_heads=2, head_dim=32,
        d_ff=512, vocab_size=4096,
    )


def _mk_instance(params, cfg, *, legacy: bool, slots: int, max_len: int, **kw):
    return create_backend(
        "jax", 0, cfg=cfg, params=params, version=0,
        max_slots=slots, max_len=max_len, temperature=1.0, eos_id=NO_EOS,
        batched_prefill=not legacy, compact_decode=not legacy, **kw,
    )


def _mk_trajs(n, prompt_len, max_new=10_000, base=0):
    return [
        Trajectory(
            traj_id=base + i,
            prompt=list(np.random.RandomState(base + i).randint(3, 200, prompt_len)),
            max_new_tokens=max_new,
        )
        for i in range(n)
    ]


def _bench_admission(params, cfg, *, legacy, slots=8, prompt_len=8, repeats=5):
    """Median wall time to admit ``slots`` waiting trajectories at once."""
    inst = _mk_instance(params, cfg, legacy=legacy, slots=slots, max_len=64)
    trajs = _mk_trajs(slots, prompt_len, base=1000)
    ids = [t.traj_id for t in trajs]
    # warm-up: compiles the prefill/scatter shapes for this wave
    inst.route_many(trajs)
    times = []
    for _ in range(repeats):
        out = inst.interrupt(ids)
        assert len(out) == slots
        # keep re-prefill shapes identical across repeats: drop the token
        # each admission samples
        for t in trajs:
            t.response.pop()
            t.behavior_logprobs.pop()
            t.finished = False
        t0 = time.perf_counter()
        inst.route_many(trajs)  # one wave, as execute_commands delivers it
        times.append(time.perf_counter() - t0)
    assert inst.n_active() == slots
    return float(np.median(times))


def _bench_decode(
    params, cfg, *, legacy, n_active, slots=16, steps=20, reps=5
):
    """Steady-state decode tokens/s with ``n_active`` occupied slots."""
    inst = _mk_instance(params, cfg, legacy=legacy, slots=slots, max_len=128)
    for t in _mk_trajs(n_active, 8, base=2000):
        inst.route(t)
    assert inst.n_active() == n_active
    for _ in range(5):  # warm-up: compiles this occupancy's decode bucket
        inst.step()
    best = float("inf")
    for _ in range(reps):  # min-of-reps to shrug off scheduler noise
        t0 = time.perf_counter()
        for _ in range(steps):
            inst.step()
        best = min(best, time.perf_counter() - t0)
    assert inst.n_active() == n_active, "occupancy changed mid-measurement"
    return n_active * steps / best


def _bench_paged_capacity(
    params, cfg, *, paged: bool, budget_slots: int, max_len: int = 128,
    block_size: int = 16, steps: int = 20,
):
    """Concurrency + decode tokens/s at one fixed HBM budget.

    The budget holds ``budget_slots`` dense worst-case rows. The dense
    engine physically reserves ``max_len`` rows per slot, so its slot count
    IS the budget; the paged engine shares the same bytes as a block pool
    and admits by actual allocation, so a mixed short/long workload packs
    strictly more concurrent trajectories into the same memory.
    """
    k5 = 2 * cfg.n_layers * cfg.n_kv_heads * cfg.hd * 4
    budget = float(k5 * max_len * budget_slots)
    inst = _mk_instance(
        params, cfg, legacy=False,
        slots=(4 * budget_slots) if paged else budget_slots,
        max_len=max_len, kv_budget=budget,
        **(dict(paged=True, kv_block_size=block_size) if paged else {}),
    )
    # heavy-tail mix: mostly short prompts, a few long ones (Fig. 4 skew)
    lengths = [8, 8, 8, 16, 8, 8, 32, 8] * budget_slots
    trajs = [
        Trajectory(
            traj_id=3000 + i,
            prompt=list(np.random.RandomState(3000 + i).randint(3, 200, pl)),
            max_new_tokens=10_000,
        )
        for i, pl in enumerate(lengths)
    ]
    inst.route_many(trajs)
    admitted = inst.n_active()
    for _ in range(3):  # warm-up this occupancy's decode shapes
        inst.step()
    t0 = time.perf_counter()
    tok0 = inst.decode_tokens
    for _ in range(steps):
        inst.step()
    dt = time.perf_counter() - t0
    # decode_tokens counts rows actually decoded (post-preemption), so the
    # paged number is not inflated by slots evicted before the dispatch
    return admitted, (inst.decode_tokens - tok0) / dt, inst.kv_bytes() / budget


def run(quick: bool = False) -> Dict[str, float]:
    reset_traj_ids()
    cfg = _bench_arch()
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    slots = 16
    out: Dict[str, float] = {}

    note("engine: admission latency (one wave fills all slots)")
    for mode, legacy in (("seed", True), ("batched", False)):
        lat = _bench_admission(
            params, cfg, legacy=legacy, slots=8,
            repeats=3 if quick else 5,
        )
        out[f"admission_latency_{mode}_s"] = lat
        emit("engine", f"admission_latency_{mode}_s", lat)
    emit(
        "engine", "admission_speedup",
        out["admission_latency_seed_s"] / out["admission_latency_batched_s"],
    )

    note("engine: decode tokens/s vs active slots (of %d)" % slots)
    for n_active in (1, 2, 4, 8, 16):
        for mode, legacy in (("seed", True), ("compact", False)):
            tps = _bench_decode(
                params, cfg, legacy=legacy, n_active=n_active, slots=slots,
                steps=10 if quick else 20, reps=3 if quick else 5,
            )
            out[f"decode_tps_{mode}_active{n_active}"] = tps
            emit("engine", f"decode_tps_{mode}_active{n_active}", tps)
        emit(
            "engine", f"decode_speedup_active{n_active}",
            out[f"decode_tps_compact_active{n_active}"]
            / out[f"decode_tps_seed_active{n_active}"],
        )

    note("engine: paged vs dense at a fixed HBM budget (mixed lengths)")
    for budget_slots in (2, 4) if quick else (2, 4, 8):
        for mode, paged in (("dense", False), ("paged", True)):
            adm, tps, fill = _bench_paged_capacity(
                params, cfg, paged=paged, budget_slots=budget_slots,
                steps=10 if quick else 20,
            )
            out[f"kvfit_{mode}_budget{budget_slots}_admitted"] = adm
            out[f"kvfit_{mode}_budget{budget_slots}_tps"] = tps
            emit("engine", f"kvfit_{mode}_budget{budget_slots}_admitted", adm)
            emit("engine", f"kvfit_{mode}_budget{budget_slots}_tps", tps)
            emit("engine", f"kvfit_{mode}_budget{budget_slots}_fill", fill)
        emit(
            "engine", f"kvfit_concurrency_gain_budget{budget_slots}",
            out[f"kvfit_paged_budget{budget_slots}_admitted"]
            / out[f"kvfit_dense_budget{budget_slots}_admitted"],
        )
    return out


if __name__ == "__main__":
    print("bench,metric,value")
    run()
