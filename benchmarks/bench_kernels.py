"""Kernel substrate microbenchmark: per-op wall time of the reference
execution path (what CPU actually runs) + one interpret-mode correctness
probe per Pallas kernel (the TPU-target code path). The TPU kernels
themselves can only be timed on TPU; their roofline behavior is covered by
the dry-run cost analysis instead."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, note
from repro.kernels import ops, ref


def _time(fn, *args, reps=5, **kw):
    out = fn(*args, **kw)
    jax.block_until_ready(out)
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        out = fn(*args, **kw)
        jax.block_until_ready(out)
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts)) * 1e6  # us


def run(quick: bool = False) -> dict:
    note("bench_kernels: ref-path us/call + interpret-mode correctness")
    key = jax.random.PRNGKey(0)
    out = {}

    b, s, h, hkv, hd = 2, 512, 8, 2, 64
    q = jax.random.normal(key, (b, s, h, hd))
    k = jax.random.normal(key, (b, s, hkv, hd))
    v = jax.random.normal(key, (b, s, hkv, hd))
    fa = jax.jit(lambda q, k, v: ops.flash_attention(q, k, v, impl="ref"))
    out["flash_attention_us"] = _time(fa, q, k, v)
    emit("kernels", "flash_attention_ref_us", out["flash_attention_us"])

    qd = jax.random.normal(key, (b, h, hd))
    lengths = jnp.full((b,), s, jnp.int32)
    da = jax.jit(
        lambda q, k, v, l: ops.decode_attention(q, k, v, l, impl="ref")
    )
    out["decode_attention_us"] = _time(da, qd, k, v, lengths)
    emit("kernels", "decode_attention_ref_us", out["decode_attention_us"])

    e, c, d, f = 8, 128, 256, 512
    x = jax.random.normal(key, (e, c, d)) * 0.1
    wg = jax.random.normal(key, (e, d, f)) * 0.05
    wu = jax.random.normal(key, (e, d, f)) * 0.05
    wd = jax.random.normal(key, (e, f, d)) * 0.05
    gm = jax.jit(
        lambda x, a, b2, c2: ops.moe_expert_ffn(x, a, b2, c2, impl="ref")
    )
    out["moe_ffn_us"] = _time(gm, x, wg, wu, wd)
    emit("kernels", "moe_expert_ffn_ref_us", out["moe_ffn_us"])

    bt, tt = 16, 1024
    lp = jax.random.normal(key, (bt, tt)) * 0.1 - 2.0
    olp = lp + 0.01
    adv = jax.random.normal(key, (bt,))
    mask = jnp.ones((bt, tt))
    dl = jax.jit(lambda a, b2, c2, d2: ops.dapo_loss(a, b2, c2, d2, impl="ref"))
    out["dapo_loss_us"] = _time(dl, lp, olp, adv, mask)
    emit("kernels", "dapo_loss_ref_us", out["dapo_loss_us"])

    # interpret-mode correctness probes (TPU-target kernel bodies)
    if not quick:
        o1 = ops.flash_attention(q[:1, :128], k[:1, :128], v[:1, :128],
                                 impl="interpret")
        r1 = ref.flash_attention_ref(q[:1, :128], k[:1, :128], v[:1, :128])
        err = float(jnp.abs(o1 - r1).max())
        emit("kernels", "flash_attention_interpret_max_err", err)
        assert err < 1e-4
    return out


if __name__ == "__main__":
    run()
