"""Fig. 25 reproduction: redundant rollout ablation (batch-level and
group-level). Expected: redundancy drops long-tail trajectories -> max/mean
response length of *consumed* data falls, per-step time falls, throughput
improves modestly; batch-level cuts deeper than group-level at the same
redundant ratio (it can discard whole long groups)."""
from __future__ import annotations

import dataclasses


from benchmarks.common import emit, note, sim_cfg
from repro.core.types import reset_traj_ids
from repro.sim.engine import StaleFlowSim


def _run(cfg):
    reset_traj_ids()
    return StaleFlowSim(cfg).run()


def run(quick: bool = False) -> dict:
    note("bench_redundancy (Fig. 25): none vs batch-level vs group-level")
    base = sim_cfg(eta=3, total_steps=3 if quick else 5, response_sigma=1.6)
    out = {}
    variants = {
        "none": base,
        "batch_1_16": dataclasses.replace(
            base, batch_redundancy=max(1, base.batch_size // 16)
        ),
        "group_1_16": dataclasses.replace(
            base, group_redundancy=max(1, base.group_size // 16)
        ),
    }
    for name, cfg in variants.items():
        res = _run(cfg)
        tokens_per_step = res.total_tokens / max(res.steps, 1)
        time_per_step = res.total_time / max(res.steps, 1)
        emit("redundancy", f"{name}_tokens_per_step", tokens_per_step)
        emit("redundancy", f"{name}_time_per_step_s", time_per_step)
        emit("redundancy", f"{name}_throughput", res.throughput)
        out[name] = {
            "tokens_per_step": tokens_per_step,
            "time_per_step": time_per_step,
            "throughput": res.throughput,
        }
    return out


if __name__ == "__main__":
    run()
