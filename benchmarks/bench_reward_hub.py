"""Reward-hub integration smoke: the hermetic scenarios the ``reward-hub``
CI job gates on.

Everything runs against the stdlib :class:`StubJudge` on the loopback
interface or a local subprocess — **no external network**. Five
scenarios, each with explicit pass conditions:

* **happy** — submit-then-poll against a healthy judge: all scores land,
  the poll loop actually polled.
* **retry** — the judge 500s the first N submits: the client backs off,
  retries, and still lands every score; retry counters prove it.
* **breaker** — the judge is gone (connection refused): consecutive
  failures trip the breaker open, later calls fail fast (no socket
  touched), and the hub resolves every failure to the deterministic
  fallback score.
* **sandbox** — a scoring program that loops forever is SIGKILLed at the
  wall deadline (kill counted); a healthy program scores fine.
* **threaded** — the full stack under the RewardServer worker pool with
  seeded fault injection: every submitted completion reaches exactly one
  disposition, no worker dies, and the faults demonstrably fired.

Writes ``BENCH_reward_hub.json`` (the CI artifact) and exits non-zero on
any violated condition.

    PYTHONPATH=src python -m benchmarks.bench_reward_hub \
        --json BENCH_reward_hub.json
"""
from __future__ import annotations

import argparse
import json
import sys
import time

from benchmarks.common import emit, note
from repro.core import (
    FnVerifier,
    RewardServer,
    RewardServerConfig,
    TrajectoryLifecycle,
)
from repro.core.types import Trajectory, next_traj_id, reset_traj_ids
from repro.reward import (
    BreakerState,
    CircuitBreaker,
    FaultInjectingVerifier,
    FaultSchedule,
    HttpVerifier,
    RetryPolicy,
    RewardHub,
    SandboxVerifier,
    StubJudge,
)

FAST = RetryPolicy(
    max_attempts=3, request_timeout_s=2.0,
    backoff_base_s=0.002, backoff_cap_s=0.02,
)


def scenario_happy(failures: list) -> dict:
    with StubJudge(score_fn=lambda p, r, task: float(len(r)),
                   pending_polls=2) as judge:
        v = HttpVerifier(judge.url, policy=FAST, total_timeout_s=5.0,
                         poll_interval_s=0.002)
        scores = [v.score([1, 2], [3] * (i + 1)) for i in range(8)]
    if scores != [float(i + 1) for i in range(8)]:
        failures.append(f"happy: wrong scores {scores}")
    if judge.polls < 8 * 3:  # 2 pendings + 1 done per job
        failures.append(f"happy: poll loop did not poll ({judge.polls})")
    return {"scores": len(scores), "polls": judge.polls,
            "requests": v.requests}


def scenario_retry(failures: list) -> dict:
    with StubJudge(fail_first=2, inline=True) as judge:
        v = HttpVerifier(judge.url, policy=FAST, total_timeout_s=5.0)
        score = v.score([1], [2])
    if score != 1.0:
        failures.append(f"retry: expected 1.0 after retries, got {score}")
    if v.retries < 2:
        failures.append(f"retry: client did not retry ({v.retries})")
    if judge.errors_served != 2:
        failures.append(f"retry: judge served {judge.errors_served} errors")
    return {"score": score, "retries": v.retries,
            "errors_served": judge.errors_served}


def scenario_breaker(failures: list) -> dict:
    # a judge that is not there: connection refused on every request
    judge = StubJudge()  # never started; grab a port that refuses
    dead_url = judge.url
    judge._server.server_close()
    breaker = CircuitBreaker(failure_threshold=4, reset_timeout_s=60.0)
    v = HttpVerifier(
        dead_url,
        policy=RetryPolicy(max_attempts=2, request_timeout_s=0.2,
                           backoff_base_s=0.001, backoff_cap_s=0.005),
        breaker=breaker, total_timeout_s=2.0,
    )
    hub = RewardHub(on_failure="fallback", fallback_score=-1.0)
    hub.register("remote", v)
    hub.register("", v)
    scores = [hub.score([1], [2]) for _ in range(12)]
    if any(s != -1.0 for s in scores):
        failures.append(f"breaker: non-fallback score in {scores}")
    if breaker.state is not BreakerState.OPEN:
        failures.append(f"breaker: state {breaker.state} after dead judge")
    if breaker.fast_failures == 0:
        failures.append("breaker: never failed fast (open gate untested)")
    route = hub.stats()["routes"]["default"]
    if route["fallbacks"] != 12:
        failures.append(f"breaker: {route['fallbacks']} fallbacks != 12")
    return {
        "fallbacks": route["fallbacks"],
        "breaker_opened": breaker.opened,
        "fast_failures": breaker.fast_failures,
        "requests": v.requests,
    }


def scenario_sandbox(failures: list) -> dict:
    good = SandboxVerifier(
        "def score(p, r):\n    return float(len(r))", timeout_s=5.0
    )
    if good.score([1], [2, 3]) != 2.0:
        failures.append("sandbox: healthy program scored wrong")
    hang = SandboxVerifier(
        "import time\n"
        "def score(p, r):\n"
        "    time.sleep(3600)\n"
        "    return 0.0",
        timeout_s=0.5,
    )
    hub = RewardHub(on_failure="fallback", fallback_score=0.0)
    hub.register("code", hang)
    t0 = time.perf_counter()
    t = Trajectory(traj_id=next_traj_id(), prompt=[1], task="code")
    t.response = [2]
    score = hub.score_trajectory(t)
    wall = time.perf_counter() - t0
    if score != 0.0:
        failures.append(f"sandbox: hung program scored {score}")
    if hang.kills != 1:
        failures.append(f"sandbox: kill not counted ({hang.kills})")
    if wall > 5.0:
        failures.append(f"sandbox: kill took {wall:.1f}s (deadline 0.5s)")
    return {"good_calls": good.calls, "kills": hang.kills,
            "kill_wall_s": round(wall, 3)}


def scenario_threaded(failures: list) -> dict:
    n = 64
    with StubJudge(pending_polls=1) as judge:
        remote = HttpVerifier(judge.url, policy=FAST, total_timeout_s=5.0,
                              poll_interval_s=0.002)
        faulty = FaultInjectingVerifier(
            FnVerifier(lambda p, r: 1.0),
            FaultSchedule(seed=3, error_rate=0.2, crash_rate=0.1,
                          drop_rate=0.05, delay_rate=0.2, delay_s=0.002),
            drop_hang_s=0.002,
        )
        hub = RewardHub(
            default=FnVerifier(lambda p, r: 1.0),
            on_failure="fallback", fallback_score=0.0,
        )
        hub.register("remote", remote)
        hub.register("faulty", faulty)
        lifecycle = TrajectoryLifecycle()
        server = RewardServer(
            hub, lifecycle, RewardServerConfig(n_workers=4)
        )
        server.start()
        tags = ["remote", "faulty", "math-ish"]  # third tag -> default route
        for i in range(n):
            t = Trajectory(traj_id=next_traj_id(), prompt=[1, i],
                           task=tags[i % 3])
            t.response = [2]
            lifecycle.completed(t)
        drained = server.drain(timeout=60.0)
        workers_alive = server.alive_workers()
        server.stop()
    if not drained:
        failures.append("threaded: drain timed out (stuck completion)")
    disposed = server.scored + server.dropped + server.aborted
    if disposed != server.submitted:
        failures.append(
            f"threaded: {disposed} dispositions != {server.submitted} "
            f"submitted"
        )
    if workers_alive != 4:
        failures.append(f"threaded: {workers_alive}/4 workers alive")
    if faulty.injected() == 0:
        failures.append("threaded: no faults fired — scenario proves nothing")
    return {
        "submitted": server.submitted,
        "scored": server.scored,
        "workers_alive": workers_alive,
        "worker_errors": server.worker_errors,
        "injected_faults": faulty.injected(),
        "fault_counts": dict(faulty.counts),
        "hub": hub.stats(),
    }


def run(json_path: str = "BENCH_reward_hub.json") -> int:
    note("bench_reward_hub: hermetic verifier-fault scenarios "
         "(loopback + subprocess only)")
    reset_traj_ids()
    failures: list = []
    results = {}
    for name, fn in (
        ("happy", scenario_happy),
        ("retry", scenario_retry),
        ("breaker", scenario_breaker),
        ("sandbox", scenario_sandbox),
        ("threaded", scenario_threaded),
    ):
        t0 = time.perf_counter()
        results[name] = fn(failures)
        results[name]["wall_s"] = round(time.perf_counter() - t0, 3)
        emit("reward_hub", f"{name}_wall_s", results[name]["wall_s"])
    emit("reward_hub", "failures", len(failures))
    results["failures"] = failures
    with open(json_path, "w", encoding="utf-8") as f:
        json.dump(results, f, indent=2, sort_keys=True)
    note(f"wrote {json_path}")
    if failures:
        for msg in failures:
            note(f"FAIL: {msg}")
        return 1
    note("reward hub smoke OK")
    return 0


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default="BENCH_reward_hub.json",
                    help="results path (also the CI artifact)")
    args = ap.parse_args()
    sys.exit(run(json_path=args.json))
