"""Fig. 15 reproduction: throughput scaling with response length, batch
size, and instance count (staleflow vs the in-flight-limit baseline).
Expected: staleflow holds the highest absolute throughput and its relative
advantage grows with response length (long-tail skew)."""
from __future__ import annotations

import dataclasses

from benchmarks.common import emit, note, sim_cfg
from repro.core import StrategySuite
from repro.core.types import reset_traj_ids
from repro.sim.engine import StaleFlowSim


def _pair(cfg):
    reset_traj_ids()
    sf = StaleFlowSim(cfg).run().throughput
    reset_traj_ids()
    inf = StaleFlowSim(
        dataclasses.replace(cfg, suite=StrategySuite.vanilla())
    ).run().throughput
    return sf, inf


def run(quick: bool = False) -> dict:
    note("bench_scalability (Fig. 15): sweeps of len/batch/instances")
    out = {}
    base = sim_cfg(eta=3, total_steps=3 if quick else 5)

    for mean_len in (2000, 4000) if quick else (2000, 4000, 8000):
        cfg = dataclasses.replace(
            base, response_mean=float(mean_len), response_cap=mean_len * 10
        )
        sf, inf = _pair(cfg)
        emit("scalability", f"len{mean_len}_staleflow", sf)
        emit("scalability", f"len{mean_len}_ratio", sf / inf)
        out[f"len{mean_len}"] = (sf, inf)

    for bs in (8, 16) if quick else (8, 16, 32):
        cfg = dataclasses.replace(base, batch_size=bs)
        sf, inf = _pair(cfg)
        emit("scalability", f"batch{bs}_staleflow", sf)
        emit("scalability", f"batch{bs}_ratio", sf / inf)
        out[f"batch{bs}"] = (sf, inf)

    for n in (4, 8) if quick else (4, 8, 16):
        cfg = dataclasses.replace(base, n_instances=n)
        sf, inf = _pair(cfg)
        emit("scalability", f"inst{n}_staleflow", sf)
        emit("scalability", f"inst{n}_ratio", sf / inf)
        out[f"inst{n}"] = (sf, inf)
    return out


if __name__ == "__main__":
    run()
