"""Fig. 18 reproduction: trajectory staleness distribution across consumed
staleness buffers at eta=3. Expected: (1) no trajectory ever exceeds 3;
(2) as training proceeds the system exploits the full bound (mass shifts
toward staleness == eta)."""
from __future__ import annotations

import collections

from benchmarks.common import emit, note, sim_cfg
from repro.core.types import reset_traj_ids
from repro.sim.engine import StaleFlowSim


def run(quick: bool = False) -> dict:
    note("bench_staleness_dist (Fig. 18): per-buffer staleness histogram")
    cfg = sim_cfg(eta=3, total_steps=4 if quick else 8)
    reset_traj_ids()
    res = StaleFlowSim(cfg).run()
    out = {}
    overall = collections.Counter()
    for step, hist in enumerate(res.staleness_hists):
        c = collections.Counter(hist)
        overall.update(c)
        emit(
            "staleness_dist", f"buffer{step}",
            "|".join(f"s{k}:{v}" for k, v in sorted(c.items())),
        )
        out[step] = dict(c)
    max_s = max(overall)
    emit("staleness_dist", "max_staleness", max_s)
    emit("staleness_dist", "bound_satisfied", int(max_s <= cfg.eta))
    late = res.staleness_hists[-1]
    frac_at_bound = sum(1 for s in late if s == cfg.eta) / len(late)
    emit("staleness_dist", "final_buffer_frac_at_eta", frac_at_bound)
    assert max_s <= cfg.eta
    return out


if __name__ == "__main__":
    run()
