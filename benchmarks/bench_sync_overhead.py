"""Fig. 19 + Table 3 reproduction: command/time breakdown.

Two evidence classes:
1. REAL runtime timers (tiny model): share of decode / prefill / pull /
   route / interrupt / coordinator time. Expected: decode dominates,
   coordination overhead < a few % (Table 3: commands < 3%, Alg. 1 < 0.1s).
2. PS communication plans (Appendix A): push (cross-DCN, load-balanced
   greedy planner) vs pull (replicated co-located PS, PCIe-local) makespans
   for the paper's Qwen3-30B-A3B sharding, at 16..128 workers — expected
   flat with scale (Fig. 23).
"""
from __future__ import annotations


from benchmarks.common import Timer, emit, note
from repro.configs import get_arch
from repro.core.parameter_server import replicated_pull_plan, sharded_push_plan
from repro.core.types import reset_traj_ids
from repro.runtime.async_runtime import AsyncRLRuntime, RuntimeConfig


def run(quick: bool = False) -> dict:
    note("bench_sync_overhead (Fig. 19 / Table 3): time breakdown")
    reset_traj_ids()
    arch = get_arch("qwen2-1.5b").reduced()
    rt = AsyncRLRuntime(
        arch,
        RuntimeConfig(
            eta=1, batch_size=4, group_size=2, n_instances=2, max_slots=4,
            max_len=48, max_new_tokens=10, total_steps=2 if quick else 4,
        ),
    )
    with Timer() as t:
        rt.run(max_ticks=20000)
    total = sum(rt.timers.values())
    out = {"timers": dict(rt.timers)}
    for k, v in sorted(rt.timers.items()):
        emit("sync_overhead", f"time_{k}_s", v)
        emit("sync_overhead", f"share_{k}", v / total if total else 0.0)
    cmd = rt.timers["pull"] + rt.timers["route"] + rt.timers["interrupt"] \
        + rt.timers["coordinator"]
    emit("sync_overhead", "command_share", cmd / total if total else 0.0)

    # --- PS plans across scale (Appendix A.3 / Fig. 23)
    cfg = get_arch("qwen3-30b-a3b")
    param_bytes = int(cfg.n_params * 2)  # bf16
    n_slices = 64
    slices = {f"slice{i}": param_bytes // n_slices for i in range(n_slices)}
    for n_hosts in (2, 4) if quick else (2, 4, 8, 16):
        pull = replicated_pull_plan(slices, n_rollout_hosts=n_hosts)
        holders = {
            name: [f"train{j}" for j in range(4)] for name in slices
        }
        push = sharded_push_plan(slices, holders, n_ps_workers=n_hosts)
        emit("sync_overhead", f"pull_makespan_{n_hosts}hosts_s", pull.makespan)
        emit("sync_overhead", f"push_makespan_{n_hosts}hosts_s", push.makespan)
        out[f"plan_{n_hosts}"] = (pull.makespan, push.makespan)
    return out


if __name__ == "__main__":
    run()
