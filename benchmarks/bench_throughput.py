"""Fig. 13 reproduction: end-to-end throughput across systems x staleness
bounds. Expected: staleflow >= inflight(VeRL-Async) > onestep(VeRL-Pipeline)
> sync(VeRL), with the staleflow/inflight gap widening as eta grows.

Live scheduler comparison (``--scheduler {tick,threaded,both}``): the SAME
tiny runtime driven by the cooperative tick loop vs the threaded service
scheduler, reporting wall time, trainer/rollout overlap fraction
(busy-seconds beyond the wall clock — 0 for a serialized loop), and
reward-queue latency percentiles from the reward server."""
from __future__ import annotations

import argparse
import dataclasses
import json
import time

from benchmarks.common import emit, note, sim_cfg
from repro.core import StrategySuite
from repro.core.types import reset_traj_ids
from repro.obs.stats import percentile
from repro.sim.baselines import OneStepSim, SyncSim
from repro.sim.engine import StaleFlowSim


def _once(cls, cfg):
    reset_traj_ids()
    return cls(cfg).run()


def run(quick: bool = False) -> dict:
    note("bench_throughput (Fig. 13): tokens/s by system and eta")
    etas = (1, 3) if quick else (1, 2, 3)
    steps = 4 if quick else 6
    out = {}
    base = sim_cfg(total_steps=steps)

    r_sync = _once(SyncSim, base)
    r_os = _once(OneStepSim, base)
    emit("throughput", "sync_tokens_per_s", r_sync.throughput)
    emit("throughput", "onestep_tokens_per_s", r_os.throughput)
    out["sync"] = r_sync.throughput
    out["onestep"] = r_os.throughput

    for eta in etas:
        cfg = dataclasses.replace(base, eta=eta)
        r_sf = _once(StaleFlowSim, cfg)
        r_if = _once(
            StaleFlowSim, dataclasses.replace(cfg, suite=StrategySuite.vanilla())
        )
        emit("throughput", f"staleflow_eta{eta}_tokens_per_s", r_sf.throughput)
        emit("throughput", f"inflight_eta{eta}_tokens_per_s", r_if.throughput)
        emit("throughput", f"gain_vs_inflight_eta{eta}",
             r_sf.throughput / r_if.throughput)
        emit("throughput", f"gain_vs_sync_eta{eta}",
             r_sf.throughput / r_sync.throughput)
        out[f"staleflow_eta{eta}"] = r_sf.throughput
        out[f"inflight_eta{eta}"] = r_if.throughput
    return out


# -------------------------------------------------- live scheduler compare
def _run_live(
    scheduler: str,
    *,
    total_steps: int,
    reward_latency: float,
    streaming: bool = False,
    probe: bool = False,
    **rcfg_kw,
):
    from repro.configs import get_arch
    from repro.runtime.async_runtime import AsyncRLRuntime, RuntimeConfig

    reset_traj_ids()
    cfg = dict(
        eta=1, batch_size=2, group_size=2, n_instances=2, max_slots=4,
        max_len=48, max_new_tokens=10, total_steps=total_steps, seed=0,
        scheduler=scheduler, reward_latency=reward_latency,
        streaming=streaming, stream_min_fill=1,
        # pipeline latencies now come from the unified observability
        # plane (the tracer's rings replaced the old private bus probe)
        observability=probe,
    )
    cfg.update(rcfg_kw)
    rt = AsyncRLRuntime(get_arch("qwen2-1.5b").reduced(), RuntimeConfig(**cfg))
    t0 = time.perf_counter()
    rt.run(max_ticks=20000)
    wall = time.perf_counter() - t0

    reward = rt.reward_server
    if scheduler == "threaded":
        busy = dict(rt.scheduler.busy)
        busy["reward"] = reward.score_time
    else:
        busy = {
            "decode": rt.timers["decode"],
            "train": rt.timers["train"],
            "reward": reward.score_time,
        }
    overlap = max(0.0, (sum(busy.values()) - wall) / wall) if wall else 0.0
    pct = reward.latency_percentiles((0.5, 0.95, 0.99))
    metrics = {
        "wall_s": wall,
        "steps": rt.model_version,
        "steps_per_s": rt.model_version / wall if wall else 0.0,
        "overlap_fraction": overlap,
        "reward_scored": reward.scored,
        "reward_p50_s": pct[0.5] or 0.0,
        "reward_p95_s": pct[0.95] or 0.0,
        "reward_p99_s": pct[0.99] or 0.0,
        "max_staleness": rt.manager.max_consumed_staleness(),
    }
    if probe:
        from repro.core.lifecycle import LifecycleEventKind as K

        route_lat = rt.tracer.route_lat.values()
        consume_lat = rt.tracer.consume_lat.values()
        stats = rt.coordinator.stats
        consumed = rt.lifecycle.counts[K.CONSUMED]
        metrics.update({
            "route_p50_s": percentile(route_lat, 0.5, default=0.0),
            "route_p95_s": percentile(route_lat, 0.95, default=0.0),
            "consume_p50_s": percentile(consume_lat, 0.5, default=0.0),
            "consume_p95_s": percentile(consume_lat, 0.95, default=0.0),
            "route_samples": len(route_lat),
            "stream_cycles": stats.stream_cycles,
            "stream_routes": stats.stream_routes,
            # full-barrier cycles paid per consumed trajectory: streaming
            # should push routing into the cheap fast path instead
            "cycles_per_traj": stats.cycles / consumed if consumed else 0.0,
        })
    assert metrics["max_staleness"] <= rt.rcfg.eta
    return metrics


def run_schedulers(
    schedulers=("tick", "threaded"),
    quick: bool = False,
    reward_latency: float = 0.002,
) -> dict:
    """Live tick-vs-threaded comparison on the real runtime.

    ``reward_latency`` simulates a slow verifier so the threaded reward
    pool has something to hide; the cooperative loop pays it inline.
    """
    note("bench_throughput --scheduler: live runtime, tick vs threaded")
    steps = 2 if quick else 3
    out = {}
    for sched in schedulers:
        m = _run_live(sched, total_steps=steps,
                      reward_latency=reward_latency)
        out[sched] = m
        for k, v in m.items():
            emit("throughput", f"live_{sched}_{k}", v)
    if "tick" in out and "threaded" in out:
        emit(
            "throughput", "live_overlap_gain",
            out["threaded"]["overlap_fraction"]
            - out["tick"]["overlap_fraction"],
        )
    return out


# ------------------------------------------------ streaming vs barrier
def run_streaming(
    quick: bool = False,
    reward_latency: float = 0.002,
    json_path: str = "BENCH_throughput.json",
) -> dict:
    """Cycle-barrier vs streaming pipeline on the SAME threaded workload.

    The streaming run admits per event (``route_instance``), consumes
    partial batches, and wakes services off lifecycle events; the barrier
    run is the seed threaded scheduler (all-instance-locks snapshot every
    coordinator interval). Reported: overlap fraction, route latency
    (capacity freed -> next Route on that instance), consume latency
    (REWARDED -> CONSUMED), and full cycles paid per consumed trajectory.
    The eta bound is asserted inside each run.
    """
    note("bench_throughput --streaming: threaded barrier vs streaming")
    steps = 2 if quick else 3
    # queue-pressured shape: protocol capacity ((eta+1)*batch_size groups)
    # well above resident slots, so completions always have waiting work
    # to admit — the regime where admission latency is the bottleneck and
    # the cycle barrier actually costs something
    shape = dict(eta=2, batch_size=4, group_size=2, n_instances=2, max_slots=4)
    barrier = _run_live("threaded", total_steps=steps,
                        reward_latency=reward_latency, probe=True, **shape)
    stream = _run_live("threaded", total_steps=steps,
                       reward_latency=reward_latency, streaming=True,
                       probe=True, **shape)
    comparison = {
        "overlap_gain": stream["overlap_fraction"] - barrier["overlap_fraction"],
        "route_p50_speedup": (
            barrier["route_p50_s"] / stream["route_p50_s"]
            if stream["route_p50_s"] else 0.0
        ),
        "consume_p50_speedup": (
            barrier["consume_p50_s"] / stream["consume_p50_s"]
            if stream["consume_p50_s"] else 0.0
        ),
        "cycles_per_traj_ratio": (
            stream["cycles_per_traj"] / barrier["cycles_per_traj"]
            if barrier["cycles_per_traj"] else 0.0
        ),
    }
    out = {"barrier": barrier, "streaming": stream, "comparison": comparison}
    for name, m in (("barrier", barrier), ("streaming", stream)):
        for k, v in m.items():
            emit("throughput", f"{name}_{k}", v)
    for k, v in comparison.items():
        emit("throughput", k, v)
    if json_path:
        with open(json_path, "w") as f:
            json.dump(out, f, indent=2, sort_keys=True)
        note(f"wrote {json_path}")
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument(
        "--scheduler", choices=("tick", "threaded", "both"), default=None,
        help="run the LIVE runtime under this scheduler (both: compare) "
             "instead of the simulator sweep",
    )
    ap.add_argument(
        "--streaming", action="store_true",
        help="compare the threaded cycle-barrier scheduler against the "
             "streaming pipeline (incremental admission + partial-batch "
             "consumption + event-driven wakeups) on the live runtime",
    )
    ap.add_argument("--quick", action="store_true")
    ap.add_argument(
        "--reward-latency", type=float, default=0.002,
        help="simulated per-score verifier latency (seconds) for the live "
             "comparison",
    )
    ap.add_argument(
        "--json", default="BENCH_throughput.json",
        help="path for the --streaming comparison JSON ('' disables)",
    )
    args = ap.parse_args()
    if args.streaming:
        run_streaming(quick=args.quick, reward_latency=args.reward_latency,
                      json_path=args.json)
    elif args.scheduler is None:
        run(quick=args.quick)
    else:
        scheds = (
            ("tick", "threaded") if args.scheduler == "both"
            else (args.scheduler,)
        )
        run_schedulers(scheds, quick=args.quick,
                       reward_latency=args.reward_latency)
