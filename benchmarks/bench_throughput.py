"""Fig. 13 reproduction: end-to-end throughput across systems x staleness
bounds. Expected: staleflow >= inflight(VeRL-Async) > onestep(VeRL-Pipeline)
> sync(VeRL), with the staleflow/inflight gap widening as eta grows.

Live scheduler comparison (``--scheduler {tick,threaded,both}``): the SAME
tiny runtime driven by the cooperative tick loop vs the threaded service
scheduler, reporting wall time, trainer/rollout overlap fraction
(busy-seconds beyond the wall clock — 0 for a serialized loop), and
reward-queue latency percentiles from the reward server."""
from __future__ import annotations

import argparse
import dataclasses
import time

from benchmarks.common import emit, note, sim_cfg
from repro.core import StrategySuite
from repro.core.types import reset_traj_ids
from repro.sim.baselines import OneStepSim, SyncSim
from repro.sim.engine import StaleFlowSim


def _once(cls, cfg):
    reset_traj_ids()
    return cls(cfg).run()


def run(quick: bool = False) -> dict:
    note("bench_throughput (Fig. 13): tokens/s by system and eta")
    etas = (1, 3) if quick else (1, 2, 3)
    steps = 4 if quick else 6
    out = {}
    base = sim_cfg(total_steps=steps)

    r_sync = _once(SyncSim, base)
    r_os = _once(OneStepSim, base)
    emit("throughput", "sync_tokens_per_s", r_sync.throughput)
    emit("throughput", "onestep_tokens_per_s", r_os.throughput)
    out["sync"] = r_sync.throughput
    out["onestep"] = r_os.throughput

    for eta in etas:
        cfg = dataclasses.replace(base, eta=eta)
        r_sf = _once(StaleFlowSim, cfg)
        r_if = _once(
            StaleFlowSim, dataclasses.replace(cfg, suite=StrategySuite.vanilla())
        )
        emit("throughput", f"staleflow_eta{eta}_tokens_per_s", r_sf.throughput)
        emit("throughput", f"inflight_eta{eta}_tokens_per_s", r_if.throughput)
        emit("throughput", f"gain_vs_inflight_eta{eta}",
             r_sf.throughput / r_if.throughput)
        emit("throughput", f"gain_vs_sync_eta{eta}",
             r_sf.throughput / r_sync.throughput)
        out[f"staleflow_eta{eta}"] = r_sf.throughput
        out[f"inflight_eta{eta}"] = r_if.throughput
    return out


# -------------------------------------------------- live scheduler compare
def _run_live(scheduler: str, *, total_steps: int, reward_latency: float):
    from repro.configs import get_arch
    from repro.runtime.async_runtime import AsyncRLRuntime, RuntimeConfig

    reset_traj_ids()
    rt = AsyncRLRuntime(
        get_arch("qwen2-1.5b").reduced(),
        RuntimeConfig(
            eta=1, batch_size=2, group_size=2, n_instances=2, max_slots=4,
            max_len=48, max_new_tokens=10, total_steps=total_steps, seed=0,
            scheduler=scheduler, reward_latency=reward_latency,
        ),
    )
    t0 = time.perf_counter()
    rt.run(max_ticks=20000)
    wall = time.perf_counter() - t0

    reward = rt.reward_server
    if scheduler == "threaded":
        busy = dict(rt.scheduler.busy)
        busy["reward"] = reward.score_time
    else:
        busy = {
            "decode": rt.timers["decode"],
            "train": rt.timers["train"],
            "reward": reward.score_time,
        }
    overlap = max(0.0, (sum(busy.values()) - wall) / wall) if wall else 0.0
    pct = reward.latency_percentiles((0.5, 0.95, 0.99))
    metrics = {
        "wall_s": wall,
        "steps": rt.model_version,
        "steps_per_s": rt.model_version / wall if wall else 0.0,
        "overlap_fraction": overlap,
        "reward_scored": reward.scored,
        "reward_p50_s": pct[0.5] or 0.0,
        "reward_p95_s": pct[0.95] or 0.0,
        "reward_p99_s": pct[0.99] or 0.0,
        "max_staleness": rt.manager.max_consumed_staleness(),
    }
    assert metrics["max_staleness"] <= rt.rcfg.eta
    return metrics


def run_schedulers(
    schedulers=("tick", "threaded"),
    quick: bool = False,
    reward_latency: float = 0.002,
) -> dict:
    """Live tick-vs-threaded comparison on the real runtime.

    ``reward_latency`` simulates a slow verifier so the threaded reward
    pool has something to hide; the cooperative loop pays it inline.
    """
    note("bench_throughput --scheduler: live runtime, tick vs threaded")
    steps = 2 if quick else 3
    out = {}
    for sched in schedulers:
        m = _run_live(sched, total_steps=steps,
                      reward_latency=reward_latency)
        out[sched] = m
        for k, v in m.items():
            emit("throughput", f"live_{sched}_{k}", v)
    if "tick" in out and "threaded" in out:
        emit(
            "throughput", "live_overlap_gain",
            out["threaded"]["overlap_fraction"]
            - out["tick"]["overlap_fraction"],
        )
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument(
        "--scheduler", choices=("tick", "threaded", "both"), default=None,
        help="run the LIVE runtime under this scheduler (both: compare) "
             "instead of the simulator sweep",
    )
    ap.add_argument("--quick", action="store_true")
    ap.add_argument(
        "--reward-latency", type=float, default=0.002,
        help="simulated per-score verifier latency (seconds) for the live "
             "comparison",
    )
    args = ap.parse_args()
    if args.scheduler is None:
        run(quick=args.quick)
    else:
        scheds = (
            ("tick", "threaded") if args.scheduler == "both"
            else (args.scheduler,)
        )
        run_schedulers(scheds, quick=args.quick,
                       reward_latency=args.reward_latency)
