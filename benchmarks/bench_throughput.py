"""Fig. 13 reproduction: end-to-end throughput across systems x staleness
bounds. Expected: staleflow >= inflight(VeRL-Async) > onestep(VeRL-Pipeline)
> sync(VeRL), with the staleflow/inflight gap widening as eta grows."""
from __future__ import annotations

import dataclasses

from benchmarks.common import emit, note, sim_cfg
from repro.core import StrategySuite
from repro.core.types import reset_traj_ids
from repro.sim.baselines import OneStepSim, SyncSim
from repro.sim.engine import StaleFlowSim


def _once(cls, cfg):
    reset_traj_ids()
    return cls(cfg).run()


def run(quick: bool = False) -> dict:
    note("bench_throughput (Fig. 13): tokens/s by system and eta")
    etas = (1, 3) if quick else (1, 2, 3)
    steps = 4 if quick else 6
    out = {}
    base = sim_cfg(total_steps=steps)

    r_sync = _once(SyncSim, base)
    r_os = _once(OneStepSim, base)
    emit("throughput", "sync_tokens_per_s", r_sync.throughput)
    emit("throughput", "onestep_tokens_per_s", r_os.throughput)
    out["sync"] = r_sync.throughput
    out["onestep"] = r_os.throughput

    for eta in etas:
        cfg = dataclasses.replace(base, eta=eta)
        r_sf = _once(StaleFlowSim, cfg)
        r_if = _once(
            StaleFlowSim, dataclasses.replace(cfg, suite=StrategySuite.vanilla())
        )
        emit("throughput", f"staleflow_eta{eta}_tokens_per_s", r_sf.throughput)
        emit("throughput", f"inflight_eta{eta}_tokens_per_s", r_if.throughput)
        emit("throughput", f"gain_vs_inflight_eta{eta}",
             r_sf.throughput / r_if.throughput)
        emit("throughput", f"gain_vs_sync_eta{eta}",
             r_sf.throughput / r_sync.throughput)
        out[f"staleflow_eta{eta}"] = r_sf.throughput
        out[f"inflight_eta{eta}"] = r_if.throughput
    return out


if __name__ == "__main__":
    run()
