"""Traced threaded-streaming smoke: the observability plane end to end.

Runs a tiny threaded streaming runtime with tracing enabled, then gates
on the three properties the plane promises:

* the exported Chrome trace is schema-valid (``validate_chrome_trace``);
* span conservation holds — every ROUTED trajectory span closed with
  exactly one terminal event (CONSUMED or ABORTED);
* the staleness the tracer *reconstructs* from span versions matches the
  protocol's own accounting (``StalenessManager.max_consumed_staleness``)
  and respects the eta bound.

CI uploads the trace JSON as an artifact (open it at
https://ui.perfetto.dev); exit code is non-zero on any violation.

    PYTHONPATH=src python -m benchmarks.bench_trace_smoke \
        --json BENCH_trace_smoke.json
"""
from __future__ import annotations

import argparse
import sys
import time

from benchmarks.common import emit, note
from repro.core.types import reset_traj_ids


def run(json_path: str = "BENCH_trace_smoke.json", total_steps: int = 2) -> int:
    note("bench_trace_smoke: traced threaded streaming runtime")
    from repro.configs import get_arch
    from repro.obs.export import load_trace, validate_chrome_trace
    from repro.obs.report import summarize
    from repro.runtime.async_runtime import AsyncRLRuntime, RuntimeConfig

    reset_traj_ids()
    rcfg = RuntimeConfig(
        eta=1, batch_size=2, group_size=2, n_instances=2, max_slots=4,
        max_len=48, max_new_tokens=10, total_steps=total_steps, seed=0,
        scheduler="threaded", streaming=True, stream_min_fill=1,
        reward_latency=0.002, observability=True, trace_path=json_path,
    )
    rt = AsyncRLRuntime(get_arch("qwen2-1.5b").reduced(), rcfg)
    t0 = time.perf_counter()
    rt.run(max_ticks=20000)
    wall = time.perf_counter() - t0

    failures = []
    trace = load_trace(json_path)
    schema_errors = validate_chrome_trace(trace)
    if schema_errors:
        failures.append(f"{len(schema_errors)} schema errors")
        for e in schema_errors[:10]:
            note(f"SCHEMA ERROR: {e}")

    violations = rt.tracer.check_conservation(allow_open=True)
    if violations:
        failures.append(f"{len(violations)} conservation violations")
        for v in violations[:10]:
            note(f"CONSERVATION: {v}")

    traced = rt.tracer.realized_max_staleness()
    managed = rt.manager.max_consumed_staleness()
    if traced != managed:
        failures.append(
            f"staleness mismatch: trace says {traced}, manager {managed}"
        )
    if traced > rcfg.eta:
        failures.append(f"staleness {traced} exceeds eta={rcfg.eta}")

    emit("trace_smoke", "wall_s", wall)
    emit("trace_smoke", "steps", rt.model_version)
    emit("trace_smoke", "trace_events", len(trace["traceEvents"]))
    emit("trace_smoke", "spans", trace["otherData"]["spans"])
    emit("trace_smoke", "max_realized_staleness", traced)
    emit("trace_smoke", "schema_errors", len(schema_errors))
    emit("trace_smoke", "conservation_violations", len(violations))
    note(f"wrote {json_path}")
    print(summarize(trace))

    if failures:
        for f in failures:
            note(f"FAIL: {f}")
        return 1
    note("trace smoke OK")
    return 0


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument(
        "--json", default="BENCH_trace_smoke.json",
        help="path for the exported Chrome trace (also the CI artifact)",
    )
    ap.add_argument("--steps", type=int, default=2)
    args = ap.parse_args()
    sys.exit(run(json_path=args.json, total_steps=args.steps))
