"""Shared benchmark helpers: CSV emission + scaled-down default configs.

Every benchmark prints ``bench,metric,value`` CSV rows (plus human-readable
headers to stderr-like comment lines starting with '#') and returns a dict
so ``benchmarks.run`` can aggregate. Scales are chosen so the full suite
finishes in minutes on one CPU; each module documents which paper
table/figure it reproduces and what the expected qualitative result is.
"""
from __future__ import annotations

import dataclasses
import time

from repro.core import PAPER_H20_QWEN3_30B
from repro.core.types import reset_traj_ids
from repro.sim.engine import SimConfig


def emit(bench: str, metric: str, value) -> None:
    if isinstance(value, float):
        value = f"{value:.6g}"
    print(f"{bench},{metric},{value}", flush=True)


def note(text: str) -> None:
    print(f"# {text}", flush=True)


def kv_bound_cost_model(tokens_per_instance: int = 75_000):
    return dataclasses.replace(
        PAPER_H20_QWEN3_30B,
        kv_budget=tokens_per_instance * PAPER_H20_QWEN3_30B.k5,
    )


def sim_cfg(**kw) -> SimConfig:
    """Paper-shaped but CPU-sized simulation default."""
    d = dict(
        n_instances=8,
        batch_size=16,
        group_size=8,
        eta=1,
        prompt_len=2048,
        response_mean=4000.0,
        response_sigma=1.6,
        response_cap=40000,
        total_steps=6,
        dt=0.5,
        train_fixed=20.0,
        train_per_token=2e-5,
        cost_model=kv_bound_cost_model(),
    )
    d.update(kw)
    return SimConfig(**d)


def fresh(fn, *args, **kw):
    reset_traj_ids()
    return fn(*args, **kw)


class Timer:
    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        self.seconds = time.perf_counter() - self.t0
