"""Benchmark suite entry point: one benchmark per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--quick] [--only NAME]

Emits ``bench,metric,value`` CSV rows. Mapping to the paper:

  bench_throughput      Fig. 13   system x eta throughput (simulator)
  bench_convergence     Fig. 3/14 reward & IS drift vs eta (real runtime)
  bench_scalability     Fig. 15   len/batch/instance sweeps (simulator)
  bench_ablation        Fig. 16   R/S/M strategy grid (simulator)
  bench_case_study      Fig. 17   per-instance load timelines (simulator)
  bench_staleness_dist  Fig. 18   buffer staleness histogram (simulator)
  bench_sync_overhead   Fig.19/T3 time breakdown + PS comm plans (runtime)
  bench_cost_model      Fig.24/T4 cost-model fit on our engine (runtime)
  bench_redundancy      Fig. 25   redundant rollout ablation (simulator)
  bench_kernels         (substrate) kernel microbench + interpret probes
  bench_engine          (substrate) batched admission + compacted decode
                        vs the seed single-row engine path (real runtime)

The dry-run / roofline deliverables are separate:
  PYTHONPATH=src python -m repro.launch.dryrun --all
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback

from benchmarks import (
    bench_ablation,
    bench_case_study,
    bench_convergence,
    bench_cost_model,
    bench_engine,
    bench_kernels,
    bench_redundancy,
    bench_scalability,
    bench_staleness_dist,
    bench_sync_overhead,
    bench_throughput,
)

ALL = {
    "throughput": bench_throughput,
    "convergence": bench_convergence,
    "scalability": bench_scalability,
    "ablation": bench_ablation,
    "case_study": bench_case_study,
    "staleness_dist": bench_staleness_dist,
    "sync_overhead": bench_sync_overhead,
    "cost_model": bench_cost_model,
    "redundancy": bench_redundancy,
    "kernels": bench_kernels,
    "engine": bench_engine,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", default=None)
    args = ap.parse_args()

    names = [args.only] if args.only else list(ALL)
    print("bench,metric,value")
    failures = []
    for name in names:
        mod = ALL[name]
        t0 = time.time()
        try:
            mod.run(quick=args.quick)
            print(f"# {name} done in {time.time() - t0:.1f}s", flush=True)
        except Exception:
            failures.append(name)
            print(f"# {name} FAILED:", flush=True)
            traceback.print_exc()
    if failures:
        print(f"# FAILED benches: {failures}")
        sys.exit(1)
    print("# all benches passed")


if __name__ == "__main__":
    main()
