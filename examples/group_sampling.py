"""Group sampling end-to-end: shared-prefix rollout -> GRPO advantages ->
DAPO zero-signal filtering.

The paper's workload (§2.1) expands every dataset prompt into
``group_size`` member trajectories. On a paged engine with prefix sharing
the group admits as ONE unit: the prompt prefills once, its full KV blocks
are mapped (refcounted) into every member's block table, and only the
partially-filled tail block is copied per member — so at a fixed HBM
budget a replica holds ~group_size x more members of prompt-heavy groups
while doing 1/group_size of the prefill work.

Downstream, the rewarded groups flow through the GRPO group-relative
advantage estimator and DAPO's zero-signal filter (groups whose rewards
are all identical carry no gradient and are dropped — the proactive
filtering hook of §4.3).

    PYTHONPATH=src python examples/group_sampling.py --groups 4 --group-size 4
"""
import argparse

import jax

from repro.configs import get_arch
from repro.core.types import Trajectory, next_traj_id, reset_traj_ids
from repro.data.tasks import ArithmeticDataset
from repro.data.tokenizer import decode as tok_decode
from repro.models import model as M
from repro.reward.verifier import RewardModel
from repro.rl.advantages import group_advantages, zero_signal_groups
from repro.rollout.backend import create_backend


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--groups", type=int, default=4)
    ap.add_argument("--group-size", type=int, default=4)
    ap.add_argument("--slots", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=10)
    ap.add_argument("--block-size", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=1.0)
    ap.add_argument("--no-share-prefix", action="store_true")
    args = ap.parse_args()
    reset_traj_ids()

    cfg = get_arch("qwen2-1.5b").reduced()
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    inst = create_backend(
        "jax", 0, cfg=cfg, params=params, version=0,
        max_slots=args.slots, max_len=64, temperature=args.temperature,
        paged=True, kv_block_size=args.block_size,
        share_prefix=not args.no_share_prefix,
    )

    # --- group rollout: G member trajectories per prompt, one group_id ----
    ds = ArithmeticDataset(args.groups, seed=3)
    reward_model = RewardModel(lambda prompt: ds.answer_for(prompt))
    trajs = []
    for gid, p in enumerate(ds.problems):
        group = [
            Trajectory(
                traj_id=next_traj_id(), prompt=list(p.prompt_ids),
                group_id=gid, max_new_tokens=args.max_new,
            )
            for _ in range(args.group_size)
        ]
        trajs.extend(group)
        inst.route_many(group)  # one wave -> one shared prompt prefill

    done = []
    for _ in range(4000):
        done.extend(inst.step())
        if len(done) == len(trajs):
            break
    assert len(done) == len(trajs), "rollout did not drain"
    inst.allocator.check()

    # --- rewards + GRPO group-relative advantages -------------------------
    rewards, gids = [], []
    for t in sorted(done, key=lambda t: t.traj_id):
        t.reward = reward_model.score(list(t.prompt), list(t.response))
        rewards.append(t.reward)
        gids.append(t.group_id)
    adv = group_advantages(rewards, gids)
    dropped = set(zero_signal_groups(rewards, gids))  # DAPO filtering

    print(f"{args.groups} groups x {args.group_size} members, "
          f"prompt len {len(trajs[0].prompt)}")
    print(f"prefix sharing: {inst.shared_prefix_hits} members admitted off "
          f"a shared prompt, {inst.prefill_tokens_saved} prefill tokens "
          f"saved ({inst.prefill_tokens} actually prefilled)")
    for gid in range(args.groups):
        m = [i for i, g in enumerate(gids) if g == gid]
        tag = "DROPPED (zero signal)" if gid in dropped else "kept"
        print(f"  group {gid} [{tag}] prompt="
              f"'{tok_decode(ds.problems[gid].prompt_ids)}' "
              f"rewards={[round(rewards[i], 2) for i in m]} "
              f"adv={[round(float(adv[i]), 2) for i in m]}")
    kept = [i for i, g in enumerate(gids) if g not in dropped]
    print(f"training batch: {len(kept)}/{len(gids)} members after DAPO "
          f"zero-signal filtering")
    if not args.no_share_prefix and args.group_size > 1:
        assert inst.shared_prefix_hits > 0, "sharing never engaged"


if __name__ == "__main__":
    main()
