"""Hybrid cluster: ONE coordinator drives real JAX + simulated instances.

The engine-backend contract (``repro.rollout.backend.EngineBackend``) makes
the coordinator provably backend-agnostic: instance 0 is a real
``RolloutInstance`` (tiny qwen2 replica actually decoding tokens on CPU),
the rest are cost-model-driven ``SimBackend`` replicas. All of them hang
off the same trajectory server, staleness manager, and coordinator, and
every coordinator command is applied through the shared
``execute_commands`` executor — no isinstance checks anywhere.

Use cases: shadow-testing coordination strategies against a mostly
simulated fleet with a handful of canary replicas, or scaling a laptop
repro to paper-sized instance counts without paper-sized hardware.

    PYTHONPATH=src python examples/mixed_cluster.py --sim-instances 6
"""
import argparse

import jax

from repro.configs import get_arch
from repro.core import (
    CostModel,
    ParameterServer,
    RolloutCoordinator,
    StalenessManager,
    TrajectoryServer,
)
from repro.core.types import reset_traj_ids
from repro.data.tasks import ArithmeticDataset
from repro.models import model as M
from repro.rollout.backend import create_backend, execute_commands


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--sim-instances", type=int, default=6)
    ap.add_argument("--batch-size", type=int, default=4)
    ap.add_argument("--group-size", type=int, default=2)
    ap.add_argument("--eta", type=int, default=1)
    ap.add_argument("--batches", type=int, default=3)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--max-ticks", type=int, default=4000)
    ap.add_argument(
        "--paged", action="store_true",
        help="block-paged KV on the real replica (the sim replicas adopt "
             "the same block-granular cost-model accounting)",
    )
    args = ap.parse_args()
    reset_traj_ids()

    cfg = get_arch("qwen2-1.5b").reduced()
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    ps = ParameterServer()
    ps.push(params, 0)

    manager = StalenessManager(batch_size=args.batch_size, eta=args.eta)
    ds = ArithmeticDataset(4096, seed=0)
    ts = TrajectoryServer(
        ds.prompt_source(),
        capacity_groups=(args.eta + 1) * args.batch_size,
        group_size=args.group_size,
        max_new_tokens=args.max_new,
    )
    k5 = 2.0 * cfg.n_layers * cfg.n_kv_heads * cfg.hd * 4
    cm = CostModel(
        k1=1e-12, k2=1e-3, k3=1e-4, k4=5e-3, k5=k5, kv_budget=k5 * 64 * 4,
        block_size=16 if args.paged else 1,
    )
    coordinator = RolloutCoordinator(manager, ts, cost_model=cm)

    # --- the mixed fleet: id 0 is real, the rest simulated -----------------
    instances = {
        0: create_backend(
            "jax", 0, cfg=cfg, params=params, version=0,
            max_slots=4, max_len=64, kv_bytes_per_token=k5,
            kv_budget=cm.kv_budget, temperature=1.0,
            paged=args.paged, kv_block_size=16,
        )
    }
    for i in range(1, 1 + args.sim_instances):
        instances[i] = create_backend(
            "sim", i, cost_model=cm, prefill_tps=50000.0, pull_time=0.1
        )
    coordinator.spec.resync({i: b.snapshot() for i, b in instances.items()})

    ts.refill()
    now, dt = 0.0, 0.5
    consumed_batches = 0
    real_tokens = 0
    sim_tokens = 0.0
    for tick in range(args.max_ticks):
        # simulated trajectories need a target length; real ones decode for
        # real and ignore it
        for t in ts.peek():
            if t.sim_target_len == 0:
                t.sim_target_len = args.max_new

        # 1) advance every backend through the SAME interface
        done = []
        for inst in instances.values():
            done.extend(inst.step(now, dt))
        for traj in done:
            if ts.get(traj.traj_id) is None:
                continue
            ts.complete(traj.traj_id)
            traj.reward = 1.0 if traj.response else 0.5  # stand-in reward
            for tid in coordinator.on_trajectory_rewarded(traj):
                for inst in instances.values():
                    inst.abort([tid], now)
                ts.drop(tid)

        # 2) coordinator cycle — identical for real and simulated replicas
        commands = coordinator.step(
            {i: b.snapshot() for i, b in instances.items()}, ps.version
        )
        execute_commands(commands, instances, ts, ps, now=now)

        # 3) "trainer": consume protocol-ready batches, bump the version
        if manager.ready():
            ids = coordinator.try_consume()
            if ids is not None:
                consumed_batches += 1
                ps.push(params, ps.version + 1)
                if consumed_batches >= args.batches:
                    break
        ts.refill()
        now += dt

    real_tokens = instances[0].decode_tokens
    sim_tokens = sum(
        instances[i].decode_tokens for i in instances if i != 0
    )
    manager.check_invariants()
    print(f"consumed {consumed_batches} training batches "
          f"({args.batch_size} groups x {args.group_size})")
    print(f"real instance 0:  {instances[0].decode_steps} decode steps, "
          f"{real_tokens} real tokens sampled")
    print(f"sim instances:    {sim_tokens:.0f} simulated tokens across "
          f"{args.sim_instances} replicas")
    print(f"final PS version: {ps.version}, staleness hists: "
          f"{[list(h) for h in manager.consumed_staleness]}")
    assert consumed_batches == args.batches
    assert instances[0].decode_steps > 0, "real replica never decoded"
    if args.sim_instances > 0:
        assert sim_tokens > 0, "sim replicas never decoded"


if __name__ == "__main__":
    main()
