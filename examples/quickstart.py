"""Quickstart: end-to-end asynchronous RL post-training on one CPU.

Runs the full StaleFlow stack — trajectory server, staleness protocol,
rollout coordinator, two real JAX rollout instances, verifiable arithmetic
reward, DAPO training — on a tiny model for a handful of steps.

    PYTHONPATH=src python examples/quickstart.py
"""
from repro.configs import get_arch
from repro.runtime.async_runtime import AsyncRLRuntime, RuntimeConfig


def main() -> None:
    arch = get_arch("qwen2-1.5b").reduced()
    rcfg = RuntimeConfig(
        eta=1,                # staleness bound
        batch_size=4,         # protocol entries (groups) per train step
        group_size=2,         # responses per prompt (GRPO/DAPO grouping)
        n_instances=2,
        max_slots=4,
        max_len=48,
        max_new_tokens=8,
        total_steps=5,
        lr=3e-3,
    )
    rt = AsyncRLRuntime(arch, rcfg)
    print(f"arch={arch.name} eta={rcfg.eta} instances={rcfg.n_instances}")
    print("step  reward  loss      IS-ratio  staleness")

    def progress(rec):
        print(
            f"{rec.step:4d}  {rec.mean_reward:.3f}  {rec.loss:+.4f}  "
            f"{rec.mean_is_ratio:.3f}    {rec.staleness_hist}"
        )

    rt.run(progress=progress)
    print("\ncommand stats:", rt.coordinator.stats.commands)
    print("protocol: consumed", len(rt.manager.consumed_staleness),
          "buffers; all staleness <=", rt.rcfg.eta)


if __name__ == "__main__":
    main()
