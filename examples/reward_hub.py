"""Reward-hub demo: mixed verifiers behind one threaded RewardServer,
with deterministic fault injection.

Three routes on one hub, all hermetic (loopback only, no external
network):

* ``math``   — the in-process arithmetic verifier (the trivial case);
* ``code``   — a subprocess-sandboxed scoring program (resource-limited,
  kill-on-timeout);
* ``remote`` — an HTTP submit-then-poll judge served by the stdlib
  :class:`~repro.reward.stub_judge.StubJudge`, reached through the retry
  + circuit-breaker client, wrapped in a seeded
  :class:`~repro.reward.faults.FaultInjectingVerifier` so transient
  errors, latency spikes, and drops actually fire.

Completions stream through the threaded RewardServer worker pool; at the
end the demo asserts the tentpole invariant at this scale: every
submitted completion reached exactly one disposition (REWARDED or
fallback-scored — no stuck spans, no dead workers), and prints the
per-route telemetry.

    PYTHONPATH=src python examples/reward_hub.py --trajectories 48
"""
import argparse
import collections

from repro.core import RewardServer, RewardServerConfig, TrajectoryLifecycle
from repro.core.types import Trajectory, next_traj_id
from repro.data import tokenizer as tok
from repro.data.tasks import ArithmeticDataset
from repro.reward import (
    CircuitBreaker,
    FaultInjectingVerifier,
    FaultSchedule,
    HttpVerifier,
    RetryPolicy,
    RetryingVerifier,
    RewardHub,
    RewardModel,
    SandboxVerifier,
    StubJudge,
)

SANDBOX_PROGRAM = """
def score(prompt_ids, response_ids):
    # toy code-execution reward: the program runs *inside* the sandbox
    return 1.0 if len(response_ids) % 2 == 0 else 0.0
"""


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--trajectories", type=int, default=48)
    ap.add_argument("--workers", type=int, default=4)
    ap.add_argument("--seed", type=int, default=7)
    ap.add_argument("--error-rate", type=float, default=0.15,
                    help="injected transient-error rate on the remote route")
    ap.add_argument("--drop-rate", type=float, default=0.05,
                    help="injected request-vanished rate (poll deadline)")
    ap.add_argument("--delay-rate", type=float, default=0.2,
                    help="injected latency-spike rate")
    args = ap.parse_args()

    ds = ArithmeticDataset(args.trajectories, seed=args.seed)
    math = RewardModel(lambda prompt: ds.answer_for(prompt))
    sandbox = SandboxVerifier(SANDBOX_PROGRAM, timeout_s=5.0)

    judge = StubJudge(
        score_fn=lambda p, r, task: 1.0, pending_polls=1
    ).start()
    remote = HttpVerifier(
        judge.url,
        policy=RetryPolicy(max_attempts=4, request_timeout_s=2.0,
                           backoff_base_s=0.005, backoff_cap_s=0.05),
        breaker=CircuitBreaker(failure_threshold=8, reset_timeout_s=0.2),
        total_timeout_s=5.0,
        poll_interval_s=0.005,
        seed=args.seed,
    )
    # inject faults between the retry wrapper and the HTTP client: a
    # transient injected error is retried (next call index is usually ok),
    # while a run of bad luck exhausts the attempts and the hub resolves
    # it to the fallback score. The seeded schedule reproduces the same
    # fault for call i on every run.
    faulty_remote = FaultInjectingVerifier(
        remote,
        FaultSchedule(
            seed=args.seed,
            error_rate=args.error_rate,
            drop_rate=args.drop_rate,
            delay_rate=args.delay_rate,
            delay_s=0.01,
        ),
        drop_hang_s=0.01,
    )
    retrying_remote = RetryingVerifier(
        faulty_remote,
        RetryPolicy(max_attempts=3, backoff_base_s=0.002, backoff_cap_s=0.02),
        seed=args.seed,
        name="retry[faulty[http]]",
    )

    hub = RewardHub(default=math, on_failure="fallback", fallback_score=0.0)
    hub.register("math", math)
    hub.register("code", sandbox)
    hub.register("remote", retrying_remote)
    print(f"hub routes: {hub.tags()}   (stub judge at {judge.url})")

    lifecycle = TrajectoryLifecycle()
    server = RewardServer(
        hub, lifecycle, RewardServerConfig(n_workers=args.workers)
    )
    server.start()

    tags = ["math", "code", "remote"]
    sent = collections.Counter()
    trajs = []
    for i, p in enumerate(ds.problems):
        tag = tags[i % len(tags)]
        t = Trajectory(
            traj_id=next_traj_id(), prompt=list(p.prompt_ids), task=tag
        )
        t.response = tok.encode(p.answer)  # every math answer is correct
        sent[tag] += 1
        trajs.append(t)
        lifecycle.completed(t)  # -> bounded queue -> worker pool

    ok = server.drain(timeout=60.0)
    server.stop()
    judge.stop()

    print(f"\nsubmitted {server.submitted} "
          f"({dict(sent)}), drained={ok}")
    print(f"server: {server.stats()}")
    pct = server.latency_percentiles((0.5, 0.95))
    print(f"submit->rewarded p50={1e3 * (pct[0.5] or 0):.1f}ms "
          f"p95={1e3 * (pct[0.95] or 0):.1f}ms")
    print("\nper-route stats:")
    for tag, rs in hub.stats()["routes"].items():
        print(f"  {tag:8s} calls={rs['calls']:3d} "
              f"failures={rs['failures']:2d} fallbacks={rs['fallbacks']:2d} "
              f"inner={rs.get('inner')}")
    print(f"\ninjected faults: {faulty_remote.counts} "
          f"(total {faulty_remote.injected()})")
    print(f"judge served: {judge.stats()}")

    # the tentpole invariant at demo scale: every completion reached
    # exactly one disposition and no worker died doing it
    assert ok, "drain timed out: some completion never reached a disposition"
    assert server.scored + server.dropped + server.aborted == server.submitted
    assert server.worker_errors == 0, "a worker-side guard tripped"
    scored = [t for t in trajs if t.reward is not None]
    print(f"\nall {len(scored)}/{len(trajs)} trajectories scored "
          f"(fallbacks count as scores); no stuck spans, no dead workers")


if __name__ == "__main__":
    main()
