"""Serving scenario: one rollout instance as a continuous-batching
generation server — requests arrive over 'time', join slots as they free,
interrupt/resume demonstrates partial rollout on the serving path.

    PYTHONPATH=src python examples/serve_batched.py --requests 12
"""
import argparse

import jax

from repro.configs import get_arch
from repro.core.types import Trajectory, next_traj_id
from repro.data import tokenizer as tok
from repro.data.tasks import ArithmeticDataset
from repro.models import model as M
from repro.rollout.engine import RolloutInstance


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--slots", type=int, default=4)
    args = ap.parse_args()

    cfg = get_arch(args.arch).reduced()
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    inst = RolloutInstance(
        0, cfg, params, version=0, max_slots=args.slots, max_len=64,
        temperature=0.8,
    )
    ds = ArithmeticDataset(args.requests, seed=1)
    pending = [
        Trajectory(traj_id=next_traj_id(), prompt=list(p.prompt_ids),
                   max_new_tokens=10)
        for p in ds.problems
    ]
    print(f"serving {len(pending)} requests on {args.slots} slots "
          f"({cfg.name} reduced)")

    done, step = [], 0
    # staggered arrivals: one new request every 2 decode steps
    while len(done) < args.requests:
        if pending and step % 2 == 0:
            inst.route(pending.pop(0))
        for t in inst.step():
            done.append(t)
            print(
                f"  [{step:3d}] req {t.traj_id}: "
                f"'{tok.decode(t.prompt)}' -> '{tok.decode(t.response)}' "
                f"({t.n_generated} tok)"
            )
        step += 1
        if step > 2000:
            break
    print(f"\ndecode steps: {inst.decode_steps}, "
          f"tokens: {inst.decode_tokens}, "
          f"batched avg: {inst.decode_tokens / max(inst.decode_steps, 1):.2f} "
          f"tok/step")


if __name__ == "__main__":
    main()
