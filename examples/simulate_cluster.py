"""Cluster-scale what-if: simulate StaleFlow vs baselines at paper scale
(H20 cost model, heavy-tail DAPO-Math-like lengths) without hardware.

    PYTHONPATH=src python examples/simulate_cluster.py --eta 3 --instances 16
"""
import argparse
import dataclasses

from repro.core import PAPER_H20_QWEN3_30B, StrategySuite
from repro.core.types import reset_traj_ids
from repro.sim.baselines import OneStepSim, SyncSim
from repro.sim.engine import SimConfig, StaleFlowSim


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--eta", type=int, default=3)
    ap.add_argument("--instances", type=int, default=16)
    ap.add_argument("--batch-size", type=int, default=32)
    ap.add_argument("--group-size", type=int, default=16)
    ap.add_argument("--steps", type=int, default=6)
    ap.add_argument("--response-mean", type=float, default=4000)
    ap.add_argument("--kv-tokens-per-instance", type=int, default=75_000)
    args = ap.parse_args()

    cm = dataclasses.replace(
        PAPER_H20_QWEN3_30B,
        kv_budget=args.kv_tokens_per_instance * PAPER_H20_QWEN3_30B.k5,
    )
    cfg = SimConfig(
        n_instances=args.instances,
        batch_size=args.batch_size,
        group_size=args.group_size,
        eta=args.eta,
        total_steps=args.steps,
        response_mean=args.response_mean,
        response_sigma=1.6,
        response_cap=40000,
        cost_model=cm,
        train_fixed=20.0,
        train_per_token=2e-5,
    )

    rows = []
    for name, run in (
        ("VeRL (sync)", lambda: SyncSim(cfg).run()),
        ("VeRL-Pipeline (one-step)", lambda: OneStepSim(cfg).run()),
        ("VeRL-Async (in-flight limit)", lambda: StaleFlowSim(
            dataclasses.replace(cfg, suite=StrategySuite.vanilla())).run()),
        ("StaleFlow", lambda: StaleFlowSim(cfg).run()),
    ):
        reset_traj_ids()
        r = run()
        rows.append((name, r))
    base = rows[0][1].throughput
    print(f"{'system':32s} {'tokens/s':>12s} {'vs sync':>8s} {'time':>9s}")
    for name, r in rows:
        print(f"{name:32s} {r.throughput:12.0f} {r.throughput/base:7.2f}x "
              f"{r.total_time:8.0f}s")
    sf = rows[-1][1]
    flat = [s for h in sf.staleness_hists for s in h]
    print(f"\nStaleFlow staleness: max={max(flat)} (bound {args.eta}); "
          f"interrupts={sf.interrupt_count} routes={sf.route_count} "
          f"pulls={len(sf.sync_events)}")


if __name__ == "__main__":
    main()
