"""End-to-end driver: train a (reduced) model with async RL for a few
hundred steps, with checkpointing and a mid-run instance failure + elastic
replacement — the fault-tolerance story at laptop scale.

    PYTHONPATH=src python examples/train_async_rl.py \
        --arch qwen2-1.5b --eta 2 --steps 40 --ckpt-dir /tmp/staleflow_ckpt
"""
import argparse

from repro.configs import get_arch
from repro.core import StrategySuite
from repro.runtime.async_runtime import AsyncRLRuntime, RuntimeConfig


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b")
    ap.add_argument("--eta", type=int, default=2)
    ap.add_argument("--steps", type=int, default=40)
    ap.add_argument("--instances", type=int, default=2)
    ap.add_argument("--batch-size", type=int, default=4)
    ap.add_argument("--group-size", type=int, default=4)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default="/tmp/staleflow_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--inject-failure-at", type=int, default=15,
                    help="train step at which instance 0 dies (-1: never)")
    ap.add_argument("--vanilla", action="store_true",
                    help="use the vanilla strategy suite (ablation)")
    ap.add_argument("--scheduler", choices=("tick", "threaded"),
                    default="tick",
                    help="tick: deterministic cooperative loop; threaded: "
                         "rollout/reward/trainer on separate threads "
                         "(the paper's asynchronous deployment shape)")
    args = ap.parse_args()

    arch = get_arch(args.arch).reduced()
    rt = AsyncRLRuntime(
        arch,
        RuntimeConfig(
            eta=args.eta,
            batch_size=args.batch_size,
            group_size=args.group_size,
            n_instances=args.instances,
            max_slots=4,
            max_len=64,
            max_new_tokens=12,
            total_steps=args.steps,
            lr=args.lr,
            filter_zero_signal=False,
            suite=StrategySuite.vanilla() if args.vanilla else StrategySuite.staleflow(),
            scheduler=args.scheduler,
        ),
    )

    failed = False
    window = []

    def progress(rec):
        nonlocal failed
        window.append(rec.mean_reward)
        if len(window) > 10:
            window.pop(0)
        print(
            f"step {rec.step:4d}  reward {rec.mean_reward:.3f} "
            f"(avg10 {sum(window)/len(window):.3f})  loss {rec.loss:+.4f}  "
            f"stale {max(rec.staleness_hist)}"
        )
        if rec.step % args.ckpt_every == 0:
            path = rt.checkpoint(args.ckpt_dir)
            print(f"  checkpoint -> {path}")
        if rec.step == args.inject_failure_at and not failed:
            failed = True
            returned = rt.fail_instance(0)
            print(f"  !! instance 0 FAILED; {len(returned)} trajectories "
                  f"returned to TS; protocol intact")
            rt.add_instance(99)
            print("  ++ elastic replacement instance 99 joined")

    rt.run(progress=progress)
    print("\nfinal reward (avg last 10):", sum(window) / len(window))
    print("staleness histogram ok:", all(
        s <= args.eta for h in rt.manager.consumed_staleness for s in h
    ))


if __name__ == "__main__":
    main()
