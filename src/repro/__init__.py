"""StaleFlow reproduction: staleness-constrained asynchronous RL
post-training in JAX (+ Pallas TPU kernels). See README.md."""
