"""Concurrency correctness tooling.

Two complementary halves, both stdlib-only (importable without jax):

* :mod:`repro.analysis.lint` — AST-based static lint (``python -m
  repro.analysis.lint``) enforcing the project's lock discipline
  (RPL001–RPL005) with precise ``file:line`` diagnostics,
  ``# repro: allow[RPLxxx] reason=...`` suppressions, and a committed
  clean baseline.
* :mod:`repro.analysis.witness` — opt-in runtime lock-order witness:
  ``TrackedLock``/``TrackedRLock`` drop-ins that record per-thread
  held-sets, build a global acquisition graph, and report lock-order
  cycles and emit-under-lock events with offending stacks.

The shared declared partial order lives in
:mod:`repro.analysis.lock_order`.
"""

from repro.analysis import lock_order, witness  # noqa: F401
