"""AST-based lock-discipline lint (``python -m repro.analysis.lint``).

Project-specific rules, each born from a real bug:

* **RPL001** — no lifecycle ``emit()``/``publish`` (or any shorthand:
  ``routed``/``interrupted``/``completed``/``rewarded``/``consumed``/
  ``aborted``) reachable — directly or through a same-module call chain
  — while a ``with <lock>:`` block is open, unless every held lock is
  in the emit-safe coordinator prefix (:data:`lock_order.EMIT_SAFE`).
  Prevents the PR 5 deadlock: REWARDED dispatched under a bus lock vs
  INTERRUPTED emitted under the coordinator lock.
* **RPL002** — lock acquisitions must respect the declared partial
  order in :mod:`repro.analysis.lock_order` (coordinator → instances →
  instance → domain → event plane → leaves; condition locks are
  leaves). Re-acquiring a non-reentrant lock in the same lexical scope
  is a self-deadlock and is also flagged.
* **RPL003** — concurrency hygiene in multi-role modules (modules whose
  state is touched by ≥ 2 thread roles, see
  :data:`lock_order.MODULE_ROLES` or a ``# repro: roles=a,b``
  directive): (a) no bare ``threading.Lock()``/``RLock()``/
  ``Condition()`` attribute — use the witness-aware factory
  ``make_lock(name)`` so the lock joins the declared order; (b) a
  container attribute of a lock-owning class that is mutated from ≥ 2
  methods must be mutated under a lock at every site (methods named
  ``*_locked`` are exempt — their callers hold the lock). Catches the
  PR 7 shape: ``ThreadedScheduler.busy`` written from three loop
  threads without ``_busy_lock``.
* **RPL004** — no wall-clock (``time.time``/``time_ns``,
  ``datetime.now``), no unseeded ``random.*`` module calls, no unkeyed
  ``jax.random.*`` in seed-deterministic modules
  (:data:`lock_order.DETERMINISTIC_MODULES` or a
  ``# repro: deterministic`` directive). Seeded constructions
  (``random.Random(seed)``, ``np.random.default_rng(seed)``) are fine.
* **RPL005** — every ``Condition.notify``/``notify_all`` must hold
  exactly its own condition lock and nothing else (condition locks are
  leaves; notifying under extra locks hands waiters a lock-order
  landmine, notifying under none is a lost wakeup).

Suppressions: a ``# repro: allow[RPLxxx] reason=<why>`` comment on the
same line or the line above silences one rule at that site; an allow
without a reason is ignored. Non-suppressed diagnostics must be empty
(``--check``) — the committed baseline (``analysis/baseline.txt``) is
empty and should stay that way; ``--write-baseline`` exists for
emergency triage only.
"""
from __future__ import annotations

import argparse
import ast
import re
import sys
from dataclasses import dataclass
from pathlib import Path
from typing import Dict, List, Optional, Sequence, Set, Tuple

from repro.analysis import lock_order

RULES = {
    "RPL001": "lifecycle emit reachable under a non-emit-safe lock",
    "RPL002": "lock acquisition violates the declared partial order",
    "RPL003": "unannotated lock / unguarded shared container",
    "RPL004": "wall-clock or unseeded randomness in deterministic module",
    "RPL005": "Condition.notify must hold its own lock and nothing else",
}

EMIT_SHORTHANDS = frozenset(
    {"routed", "interrupted", "completed", "rewarded", "consumed", "aborted"}
)
EMIT_RECEIVERS = frozenset({"lifecycle", "bus"})
LOCK_FACTORIES = {
    "make_lock": "lock",
    "make_rlock": "rlock",
    "make_condition": "cond",
}
BARE_LOCKS = {"Lock": "lock", "RLock": "rlock", "Condition": "cond"}
CONTAINER_CALLS = frozenset(
    {"dict", "list", "set", "deque", "defaultdict", "OrderedDict", "Counter"}
)
MUTATORS = frozenset({
    "append", "appendleft", "extend", "insert", "pop", "popitem",
    "popleft", "remove", "clear", "add", "discard", "update", "setdefault",
})
SAFE_NP_RANDOM = frozenset(
    {"default_rng", "Generator", "SeedSequence", "RandomState", "PCG64"}
)
SAFE_RANDOM = frozenset({"Random", "SystemRandom"})

_ALLOW_RE = re.compile(
    r"#\s*repro:\s*allow\[(RPL\d{3})\]\s*reason=(\S.*)$"
)
_ROLES_RE = re.compile(r"#\s*repro:\s*roles=([\w,\- ]+)")
_DET_RE = re.compile(r"#\s*repro:\s*deterministic\b")
_CONDISH_RE = re.compile(r"(_cond|\bcond)$")
_LOCKISH_RE = re.compile(r"(_lock|\block|_mutex|_mu)$")


@dataclass(frozen=True)
class Diagnostic:
    path: str
    line: int
    col: int
    rule: str
    msg: str

    @property
    def key(self) -> str:
        return f"{self.path}:{self.line}:{self.col} {self.rule}"

    def __str__(self) -> str:
        return f"{self.key} {self.msg}"


@dataclass
class LockInfo:
    name: Optional[str]  # declared name from the factory, None if bare
    kind: str  # "lock" | "rlock" | "cond"
    bare: bool
    line: int
    col: int


@dataclass(frozen=True)
class LockRef:
    name: Optional[str]
    kind: str
    src: str


@dataclass
class MutSite:
    method: str
    line: int
    col: int
    guarded: bool


@dataclass
class FuncInfo:
    node: ast.AST
    cls: Optional[str]
    name: str
    direct_emit: bool = False
    callees: Tuple[Tuple[Optional[str], str], ...] = ()


def _dotted(node: ast.AST) -> Optional[str]:
    """'a.b.c' for an attribute chain of Names, else None."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def _receiver_tail(node: ast.AST) -> str:
    if isinstance(node, ast.Attribute):
        return node.attr
    if isinstance(node, ast.Name):
        return node.id
    return ""


def _emit_desc(call: ast.Call) -> Optional[str]:
    f = call.func
    if not isinstance(f, ast.Attribute):
        return None
    if f.attr in ("emit", "publish"):
        return ast.unparse(f)
    if f.attr in EMIT_SHORTHANDS and _receiver_tail(f.value) in EMIT_RECEIVERS:
        return ast.unparse(f)
    return None


def _classify_factory(value: ast.AST) -> Optional[LockInfo]:
    """LockInfo for ``make_lock("x")`` / ``threading.Lock()`` values."""
    if not isinstance(value, ast.Call):
        return None
    f = value.func
    fname = f.attr if isinstance(f, ast.Attribute) else (
        f.id if isinstance(f, ast.Name) else None
    )
    if fname in LOCK_FACTORIES:
        name = None
        if value.args and isinstance(value.args[0], ast.Constant) \
                and isinstance(value.args[0].value, str):
            name = value.args[0].value
        return LockInfo(name, LOCK_FACTORIES[fname], False,
                        value.lineno, value.col_offset)
    if fname in BARE_LOCKS:
        # require threading.X() or a bare imported name — not foo.Lock()
        if isinstance(f, ast.Attribute):
            base = _dotted(f.value)
            if base not in ("threading", "_thread"):
                return None
        return LockInfo(None, BARE_LOCKS[fname], True,
                        value.lineno, value.col_offset)
    return None


class ModuleLinter:
    """Lints one source file; appends to a shared diagnostics list."""

    def __init__(self, relpath: str, source: str) -> None:
        self.relpath = relpath
        self.tree = ast.parse(source, filename=relpath)
        lines = source.splitlines()
        # suppressions: line -> rules allowed there (and on the next line)
        self.allow: Dict[int, Set[str]] = {}
        roles_directive: Tuple[str, ...] = ()
        det_directive = False
        for i, ln in enumerate(lines, start=1):
            m = _ALLOW_RE.search(ln)
            if m:
                self.allow.setdefault(i, set()).add(m.group(1))
            m = _ROLES_RE.search(ln)
            if m:
                roles_directive = tuple(
                    r.strip() for r in m.group(1).split(",") if r.strip()
                )
            if _DET_RE.search(ln):
                det_directive = True
        self.roles = lock_order.module_roles(relpath) or roles_directive
        self.multi_role = len(self.roles) >= 2
        self.deterministic = (
            lock_order.is_deterministic_module(relpath) or det_directive
        )
        # collected state
        self.lock_attrs: Dict[Tuple[Optional[str], str], LockInfo] = {}
        self.container_attrs: Dict[Tuple[str, str], int] = {}  # -> def line
        self.class_has_lock: Set[str] = set()
        self.functions: Dict[Tuple[Optional[str], str], FuncInfo] = {}
        self.may_emit: Dict[Tuple[Optional[str], str], bool] = {}
        self.mutations: Dict[Tuple[str, str], List[MutSite]] = {}
        self.diags: List[Diagnostic] = []

    # -------------------------------------------------------------- driver
    def run(self) -> List[Diagnostic]:
        self._collect()
        self._fixpoint_emit()
        for (cls, _name), fi in self.functions.items():
            _ContextWalker(self, fi).run()
        self._check_containers()
        return [
            d for d in self.diags
            if d.rule not in self.allow.get(d.line, ())
            and d.rule not in self.allow.get(d.line - 1, ())
        ]

    def diag(self, node: ast.AST, rule: str, msg: str) -> None:
        self.diags.append(Diagnostic(
            self.relpath, node.lineno, node.col_offset, rule, msg
        ))

    # ---------------------------------------------------------- collection
    def _collect(self) -> None:
        for top in self.tree.body:
            if isinstance(top, (ast.FunctionDef, ast.AsyncFunctionDef)):
                self._register_func(None, top)
            elif isinstance(top, ast.ClassDef):
                for item in top.body:
                    if isinstance(
                        item, (ast.FunctionDef, ast.AsyncFunctionDef)
                    ):
                        self._register_func(top.name, item)
                        self._collect_attrs(top.name, item)

    def _register_func(self, cls: Optional[str], fn: ast.AST) -> None:
        direct = False
        callees: List[Tuple[Optional[str], str]] = []
        for node in ast.walk(fn):
            if isinstance(node, ast.Call):
                if _emit_desc(node) is not None:
                    direct = True
                f = node.func
                if isinstance(f, ast.Name):
                    callees.append((None, f.id))
                elif isinstance(f, ast.Attribute) \
                        and isinstance(f.value, ast.Name) \
                        and f.value.id == "self":
                    callees.append((cls, f.attr))
        self.functions[(cls, fn.name)] = FuncInfo(
            fn, cls, fn.name, direct, tuple(callees)
        )

    def _collect_attrs(self, cls: str, fn: ast.AST) -> None:
        for node in ast.walk(fn):
            if not isinstance(node, ast.Assign) or len(node.targets) != 1:
                continue
            tgt = node.targets[0]
            if not (isinstance(tgt, ast.Attribute)
                    and isinstance(tgt.value, ast.Name)
                    and tgt.value.id == "self"):
                continue
            info = _classify_factory(node.value)
            if info is not None:
                self.lock_attrs[(cls, tgt.attr)] = info
                self.class_has_lock.add(cls)
                if info.bare and self.multi_role:
                    roles = ",".join(self.roles)
                    prim = {"lock": "Lock", "rlock": "RLock",
                            "cond": "Condition"}[info.kind]
                    factory = {"lock": "make_lock", "rlock": "make_rlock",
                               "cond": "make_condition"}[info.kind]
                    self.diag(
                        node.value, "RPL003",
                        f"bare threading.{prim}() attribute '{tgt.attr}' "
                        f"in multi-role module (roles: {roles}); use the "
                        f"witness-aware factory {factory}(name) from "
                        f"repro.analysis.witness so it joins the declared "
                        f"lock order",
                    )
                continue
            if fn.name == "__init__" and self._is_container(node.value):
                self.container_attrs[(cls, tgt.attr)] = node.lineno

    @staticmethod
    def _is_container(value: ast.AST) -> bool:
        if isinstance(value, (ast.Dict, ast.List, ast.Set, ast.DictComp,
                              ast.ListComp, ast.SetComp)):
            return True
        if isinstance(value, ast.Call):
            f = value.func
            name = f.attr if isinstance(f, ast.Attribute) else (
                f.id if isinstance(f, ast.Name) else None
            )
            return name in CONTAINER_CALLS
        return False

    def _fixpoint_emit(self) -> None:
        self.may_emit = {
            k: fi.direct_emit for k, fi in self.functions.items()
        }
        changed = True
        while changed:
            changed = False
            for k, fi in self.functions.items():
                if self.may_emit[k]:
                    continue
                for callee in fi.callees:
                    tgt = callee if callee in self.may_emit else None
                    if tgt is None and callee[0] is not None:
                        # method not on this class: try any class
                        for other in self.may_emit:
                            if other[1] == callee[1] and other[0] is not None:
                                tgt = other
                                break
                    if tgt is not None and self.may_emit.get(tgt):
                        self.may_emit[k] = True
                        changed = True
                        break

    # ------------------------------------------------------ lock resolution
    def classify_lock(
        self, expr: ast.AST, cls: Optional[str]
    ) -> Optional[LockRef]:
        """Map a with-context expression to a lock reference, if any."""
        try:
            src = ast.unparse(expr)
        except Exception:  # pragma: no cover - malformed expr
            return None
        if isinstance(expr, ast.Attribute) \
                and isinstance(expr.value, ast.Name) \
                and expr.value.id == "self":
            info = self.lock_attrs.get((cls, expr.attr))
            if info is not None:
                return LockRef(info.name, info.kind, src)
        for pat, name in lock_order.ATTR_HINTS:
            if re.search(pat, src):
                return LockRef(name, "rlock", src)
        if isinstance(expr, ast.Name):
            info = self.lock_attrs.get((None, expr.id))
            if info is not None:
                return LockRef(info.name, info.kind, src)
        if _CONDISH_RE.search(src):
            return LockRef(None, "cond", src)
        if _LOCKISH_RE.search(src):
            return LockRef(None, "lock", src)
        return None

    def is_condition_expr(self, expr: ast.AST, cls: Optional[str]) -> bool:
        ref = self.classify_lock(expr, cls)
        if ref is not None and ref.kind == "cond":
            return True
        if ref is not None and ref.name in lock_order.CONDITIONS:
            return True
        try:
            return bool(_CONDISH_RE.search(ast.unparse(expr)))
        except Exception:  # pragma: no cover
            return False

    def resolve_callee(
        self, call: ast.Call, cls: Optional[str]
    ) -> Optional[Tuple[Optional[str], str]]:
        f = call.func
        if isinstance(f, ast.Name) and (None, f.id) in self.functions:
            return (None, f.id)
        if isinstance(f, ast.Attribute) and isinstance(f.value, ast.Name) \
                and f.value.id == "self":
            if (cls, f.attr) in self.functions:
                return (cls, f.attr)
        return None

    # --------------------------------------------------- facet B post-pass
    def _check_containers(self) -> None:
        if not self.multi_role:
            return
        for (cls, attr), sites in sorted(self.mutations.items()):
            if (cls, attr) not in self.container_attrs:
                continue
            if cls not in self.class_has_lock:
                continue
            methods = {s.method for s in sites}
            if len(methods) < 2:
                continue
            for s in sites:
                if s.guarded:
                    continue
                others = ",".join(sorted(methods - {s.method})) or "-"
                self.diags.append(Diagnostic(
                    self.relpath, s.line, s.col, "RPL003",
                    f"shared container '{cls}.{attr}' mutated in "
                    f"'{s.method}' without holding a lock (also mutated "
                    f"in: {others}); guard every site or rename the "
                    f"method '*_locked' if callers hold the lock",
                ))


class _ContextWalker:
    """Walks one function tracking the lexically-held lock stack."""

    def __init__(self, ml: ModuleLinter, fi: FuncInfo) -> None:
        self.ml = ml
        self.fi = fi
        self.cls = fi.cls
        self.held: List[LockRef] = []
        self.exitstacks: Set[str] = set()

    def run(self) -> None:
        for stmt in self.fi.node.body:
            self.visit(stmt)

    # ------------------------------------------------------------- visitor
    def visit(self, node: ast.AST) -> None:
        if isinstance(node, (ast.With, ast.AsyncWith)):
            self._with(node)
            return
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.Lambda)):
            # nested defs / lambdas run later, not under these locks
            saved, self.held = self.held, []
            body = node.body if isinstance(node.body, list) else [node.body]
            for child in body:
                self.visit(child)
            self.held = saved
            return
        if isinstance(node, ast.Call):
            self._call(node)
        elif isinstance(node, (ast.Assign, ast.AugAssign, ast.Delete)):
            self._mutation(node)
        for child in ast.iter_child_nodes(node):
            self.visit(child)

    def _with(self, node: ast.With) -> None:
        mark = len(self.held)
        new_es: List[str] = []
        for item in node.items:
            ce = item.context_expr
            ref = self.ml.classify_lock(ce, self.cls)
            if ref is not None:
                self._check_acquire(ref, ce)
                self.held.append(ref)
                continue
            if isinstance(ce, ast.Call) and isinstance(
                item.optional_vars, ast.Name
            ):
                f = ce.func
                fname = f.attr if isinstance(f, ast.Attribute) else (
                    f.id if isinstance(f, ast.Name) else None
                )
                if fname == "ExitStack":
                    new_es.append(item.optional_vars.id)
            self.visit(ce)
        added = set(new_es) - self.exitstacks
        self.exitstacks |= added
        for stmt in node.body:
            self.visit(stmt)
        self.exitstacks -= added
        del self.held[mark:]

    def _check_acquire(self, ref: LockRef, node: ast.AST) -> None:
        for h in self.held:
            if h.src == ref.src:
                if ref.kind != "rlock":
                    self.ml.diag(
                        node, "RPL002",
                        f"re-acquiring non-reentrant lock {ref.src} "
                        f"already held in this scope (self-deadlock)",
                    )
                continue
            if h.name is None or ref.name is None:
                continue
            if not lock_order.can_acquire(h.name, ref.name):
                self.ml.diag(
                    node, "RPL002",
                    f"acquiring '{ref.name}' ({ref.src}) while holding "
                    f"'{h.name}' ({h.src}) violates the declared lock "
                    f"order (see repro/analysis/lock_order.py)",
                )

    # --------------------------------------------------------------- calls
    def _call(self, node: ast.Call) -> None:
        f = node.func
        # stack.enter_context(<lock>) inside a live ExitStack
        if isinstance(f, ast.Attribute) and f.attr == "enter_context" \
                and isinstance(f.value, ast.Name) \
                and f.value.id in self.exitstacks and len(node.args) == 1:
            ref = self.ml.classify_lock(node.args[0], self.cls)
            if ref is not None:
                self._check_acquire(ref, node)
                self.held.append(ref)  # held until the ExitStack closes
        self._check_emit(node)
        self._check_notify(node)
        if self.ml.deterministic:
            self._check_determinism(node)
        if isinstance(f, ast.Attribute) and f.attr in MUTATORS:
            recv = f.value
            if isinstance(recv, ast.Attribute) \
                    and isinstance(recv.value, ast.Name) \
                    and recv.value.id == "self":
                self._record_mut(recv.attr, node)

    def _check_emit(self, node: ast.Call) -> None:
        if not self.held:
            return
        desc = _emit_desc(node)
        if desc is None:
            callee = self.ml.resolve_callee(node, self.cls)
            if callee is not None and self.ml.may_emit.get(callee):
                desc = f"{callee[1]}() [which can emit]"
        if desc is None:
            return
        bad = [
            h for h in self.held
            if h.name is None or h.name not in lock_order.EMIT_SAFE
        ]
        if bad:
            locks = ", ".join(h.src for h in bad)
            self.ml.diag(
                node, "RPL001",
                f"lifecycle dispatch via {desc} while holding "
                f"non-emit-safe lock(s) {locks}: subscribers take their "
                f"own locks during dispatch (PR 5 deadlock shape) — "
                f"emit after releasing, or snapshot and defer",
            )

    def _check_notify(self, node: ast.Call) -> None:
        f = node.func
        if not (isinstance(f, ast.Attribute)
                and f.attr in ("notify", "notify_all")):
            return
        if not self.ml.is_condition_expr(f.value, self.cls):
            return
        recv = ast.unparse(f.value)
        if not self.held:
            self.ml.diag(
                node, "RPL005",
                f"{recv}.{f.attr}() outside 'with {recv}:' — an unlocked "
                f"notify races the waiter's predicate check (lost wakeup)",
            )
            return
        extra = [h.src for h in self.held if h.src != recv]
        if recv not in [h.src for h in self.held]:
            self.ml.diag(
                node, "RPL005",
                f"{recv}.{f.attr}() without holding {recv} "
                f"(held: {', '.join(extra)})",
            )
        elif extra:
            self.ml.diag(
                node, "RPL005",
                f"{recv}.{f.attr}() while also holding "
                f"{', '.join(extra)} — condition locks are leaves; "
                f"notify must hold its own lock and nothing else",
            )

    def _check_determinism(self, node: ast.Call) -> None:
        dotted = _dotted(node.func)
        if dotted is None:
            return
        parts = dotted.split(".")
        leaf = parts[-1]
        if dotted in ("time.time", "time.time_ns"):
            self.ml.diag(
                node, "RPL004",
                f"{dotted}() in seed-deterministic module: wall-clock "
                f"reads break tick reproducibility; use the tick counter "
                f"or time.perf_counter for local durations",
            )
        elif len(parts) == 2 and parts[0] == "random" \
                and leaf not in SAFE_RANDOM:
            self.ml.diag(
                node, "RPL004",
                f"{dotted}() draws from the global unseeded RNG; use a "
                f"seeded random.Random(seed) instance",
            )
        elif len(parts) >= 3 and parts[-3] in ("np", "numpy") \
                and parts[-2] == "random" and leaf not in SAFE_NP_RANDOM:
            self.ml.diag(
                node, "RPL004",
                f"{dotted}() uses numpy's global RNG; use "
                f"np.random.default_rng(seed)",
            )
        elif len(parts) >= 2 and parts[-2:] == ["jax", "random"]:
            pass  # module ref, not a call of interest
        elif "jax" in parts and "random" in parts and not node.args:
            self.ml.diag(
                node, "RPL004",
                f"{dotted}() called without a PRNG key in a "
                f"seed-deterministic module; thread an explicit "
                f"jax.random.PRNGKey through",
            )
        elif dotted.endswith(("datetime.now", "datetime.utcnow",
                              "date.today")):
            self.ml.diag(
                node, "RPL004",
                f"{dotted}() is wall-clock; deterministic modules must "
                f"derive time from the tick counter",
            )

    # ----------------------------------------------------------- mutations
    def _mutation(self, node: ast.AST) -> None:
        targets: List[ast.AST]
        if isinstance(node, ast.Assign):
            targets = list(node.targets)
        elif isinstance(node, ast.AugAssign):
            targets = [node.target]
        else:  # Delete
            targets = list(node.targets)
        for tgt in targets:
            if isinstance(tgt, ast.Subscript) \
                    and isinstance(tgt.value, ast.Attribute) \
                    and isinstance(tgt.value.value, ast.Name) \
                    and tgt.value.value.id == "self":
                self._record_mut(tgt.value.attr, node)

    def _record_mut(self, attr: str, node: ast.AST) -> None:
        if self.cls is None or self.fi.name == "__init__":
            return
        if (self.cls, attr) not in self.ml.container_attrs:
            return
        guarded = bool(self.held) or self.fi.name.endswith("_locked")
        self.ml.mutations.setdefault((self.cls, attr), []).append(
            MutSite(self.fi.name, node.lineno, node.col_offset, guarded)
        )


# ------------------------------------------------------------------ driver
def _default_root() -> Path:
    return Path(__file__).resolve().parents[1]  # src/repro


def _src_root() -> Path:
    return Path(__file__).resolve().parents[2]  # src


def iter_files(paths: Sequence[Path]) -> List[Tuple[Path, str]]:
    """(abspath, relpath-for-reporting) for every .py under ``paths``."""
    out: List[Tuple[Path, str]] = []
    for root in paths:
        root = root.resolve()
        files = [root] if root.is_file() else sorted(root.rglob("*.py"))
        for f in files:
            if "__pycache__" in f.parts:
                continue
            try:
                rel = f.relative_to(_src_root()).as_posix()
            except ValueError:
                base = root if root.is_dir() else root.parent
                try:
                    rel = f.relative_to(base).as_posix()
                except ValueError:  # pragma: no cover
                    rel = f.as_posix()
            out.append((f, rel))
    return out


def run_lint(paths: Sequence[Path]) -> List[Diagnostic]:
    diags: List[Diagnostic] = []
    for path, rel in iter_files(paths):
        source = path.read_text()
        try:
            diags.extend(ModuleLinter(rel, source).run())
        except SyntaxError as e:  # pragma: no cover
            diags.append(Diagnostic(rel, e.lineno or 0, 0, "RPL000",
                                    f"syntax error: {e.msg}"))
    return sorted(diags, key=lambda d: (d.path, d.line, d.col, d.rule))


def _load_baseline(path: Path) -> Set[str]:
    if not path.exists():
        return set()
    return {
        ln.strip() for ln in path.read_text().splitlines()
        if ln.strip() and not ln.lstrip().startswith("#")
    }


def selftest(fixtures: Path) -> int:
    """Run against the seeded violation fixtures; the diagnostic set must
    match expected.txt exactly — every seeded hit found at its exact
    position, zero false positives on the clean fixtures."""
    expected_file = fixtures / "expected.txt"
    expected = _load_baseline(expected_file)
    got = {d.key for d in run_lint([fixtures])}
    missing = sorted(expected - got)
    surplus = sorted(got - expected)
    for k in missing:
        print(f"MISSING (seeded violation not caught): {k}")
    for k in surplus:
        print(f"FALSE POSITIVE (not in expected.txt): {k}")
    if missing or surplus:
        return 1
    print(f"selftest OK: {len(expected)} seeded violations caught, "
          f"0 false positives")
    return 0


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="repro-lint",
        description="Project lock-discipline lint (RPL001-RPL005).",
    )
    ap.add_argument("paths", nargs="*", type=Path,
                    help="files/dirs to lint (default: src/repro)")
    ap.add_argument("--check", action="store_true",
                    help="exit 1 on any non-baselined diagnostic")
    ap.add_argument("--baseline", type=Path,
                    default=Path(__file__).parent / "baseline.txt")
    ap.add_argument("--write-baseline", action="store_true",
                    help="write current diagnostics as the new baseline")
    ap.add_argument("--selftest", nargs="?", type=Path, const=None,
                    default=False, metavar="FIXTURES",
                    help="verify the seeded fixtures are caught exactly "
                         "(default dir: tests/fixtures/lint_violations)")
    args = ap.parse_args(argv)

    if args.selftest is not False:
        fixtures = args.selftest
        if fixtures is None:
            fixtures = (
                _src_root().parent / "tests" / "fixtures" / "lint_violations"
            )
        return selftest(fixtures)

    paths = args.paths or [_default_root()]
    diags = run_lint(paths)

    if args.write_baseline:
        args.baseline.write_text(
            "".join(f"{d.key}\n" for d in diags)
        )
        print(f"wrote {len(diags)} entries to {args.baseline}")
        return 0

    baseline = _load_baseline(args.baseline)
    fresh = [d for d in diags if d.key not in baseline]
    for d in fresh:
        print(d)
    stale = baseline - {d.key for d in diags}
    if stale and args.check:
        for k in sorted(stale):
            print(f"note: stale baseline entry (fixed?): {k}")
    if fresh:
        print(f"{len(fresh)} diagnostic(s) "
              f"({len(baseline)} baselined, {len(stale)} stale)")
        return 1 if args.check else 0
    if args.check:
        print(f"lint clean ({len(baseline)} baselined)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
