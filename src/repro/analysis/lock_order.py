"""Declared lock partial order for the runtime.

This registry is the single source of truth consumed by both the static
lint (:mod:`repro.analysis.lint`, rule RPL002) and the runtime witness
(:mod:`repro.analysis.witness`). A thread may acquire lock *B* while
holding lock *A* only if ``can_acquire(A, B)`` — i.e. B's rank is
strictly greater than A's, or A and B are the same order-keyed lock
class acquired in increasing key order (the sorted per-instance barrier
acquisition in ``RuntimeCore.coordinator_cycle``).

Rank bands (gaps left for future locks):

* 0–29   coordination roots: coordinator, instances registry, instance
* 30–49  domain state: trajectory server, staleness, group book,
         reward hub / breaker / verifier internals, retired store
* 50–69  event plane: lifecycle subscriber table, tracer, metrics
         registry
* 70–89  terminal leaves: per-instrument metric locks, ring stats,
         scheduler busy map, timers, history
* 90+    condition locks (EventGate, ReadWriteLock) — always leaves

Names in :data:`TERMINAL` are hard leaves: *nothing* may be acquired
while one is held, regardless of rank.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

#: name -> rank. Lower rank = acquired earlier (outermost).
RANKS: Dict[str, int] = {
    # coordination roots
    "coordinator": 0,
    "instances": 10,
    "instance": 20,  # per-backend LockedBackend.lock, order-keyed by inst_id
    # domain state
    "ts": 30,  # trajectory server table
    "staleness": 32,
    "groupbook": 34,
    "hub": 40,  # reward hub routing table
    "route": 41,  # per-route telemetry counters
    "breaker": 42,
    "retry": 43,
    "http": 43,
    "judge": 44,
    "sandbox": 44,
    "faults": 44,
    "reward": 46,  # RewardServer queue/accounting
    "retired": 48,
    # event plane
    "lifecycle": 50,  # subscriber table only; never held across dispatch
    "tracer": 60,
    "metrics": 62,  # MetricsRegistry instrument table
    # terminal leaves
    "metric": 70,  # individual Counter/Gauge/Histogram
    "stats": 70,  # Ring buffers
    "busy": 70,
    "timers": 70,
    "history": 70,
    # condition locks
    "gate": 90,  # EventGate
    "ps": 90,  # ReadWriteLock (parameter server)
}

#: Lock classes where several same-named locks exist and nesting among
#: them is legal in strictly increasing ``order_key`` (inst_id) order.
ORDER_KEYED = frozenset({"instance"})

#: Hard leaves: nothing may be acquired while one of these is held.
TERMINAL = frozenset(
    {"metric", "stats", "busy", "timers", "history", "gate", "ps"}
)

#: Condition-lock names (RPL005: notify must hold exactly its own lock).
CONDITIONS = frozenset({"gate", "ps"})

#: Locks under which lifecycle emission is tolerated. The coordinator /
#: fleet prefix of the order is emit-safe *by construction*: every
#: lifecycle subscriber that takes a lock takes the coordinator lock (or
#: something below it), and the coordinator lock is reentrant — so a
#: dispatch from inside this prefix can never invert the order. Emitting
#: under any *other* lock (a leaf, a reward/server lock, or the bus's
#: own subscriber-table lock) is the PR 5 deadlock shape and is flagged
#: by RPL001 / the witness.
EMIT_SAFE = frozenset({"coordinator", "instances", "instance"})

#: Modules whose attributes are touched from >= 2 thread roles
#: (coordinator loop, decode loops, reward workers, trainer, pusher,
#: obs samplers). Bare ``threading.Lock()`` attributes here must go
#: through the witness-aware factory (RPL003 facet A), and shared
#: containers must be mutated under a lock (facet B). Keys are path
#: suffixes; values name the roles for diagnostics.
MODULE_ROLES: Dict[str, Tuple[str, ...]] = {
    "runtime/core.py": ("coordinator", "decode", "trainer", "obs"),
    "runtime/schedulers.py": ("coordinator", "decode", "trainer"),
    "core/lifecycle.py": ("coordinator", "decode", "reward", "trainer"),
    "core/coordinator.py": ("coordinator", "reward", "trainer"),
    "core/reward_server.py": ("coordinator", "reward"),
    "core/parameter_server.py": ("trainer", "decode", "pusher"),
    "core/staleness.py": ("coordinator", "trainer"),
    "core/trajectory_server.py": ("coordinator", "reward", "trainer"),
    "obs/metrics.py": ("coordinator", "decode", "reward", "obs"),
    "obs/tracer.py": ("coordinator", "decode", "reward", "obs"),
    "obs/stats.py": ("coordinator", "obs"),
    "reward/hub.py": ("reward",),
    "reward/retry.py": ("reward",),
    "reward/faults.py": ("reward",),
    "reward/stub_judge.py": ("reward",),
    "reward/sandbox.py": ("reward",),
    "reward/http_verifier.py": ("reward",),
}

#: Seed-deterministic modules (RPL004): wall-clock reads and unseeded
#: PRNG draws here would break tick/seed reproducibility. Path suffixes.
DETERMINISTIC_MODULES: Tuple[str, ...] = (
    "sim/engine.py",
    "sim/baselines.py",
    "sim/workload.py",
    "runtime/schedulers.py",  # tick scheduler seed path
    "kernels/",
    "reward/faults.py",  # FaultSchedule: pure function of (seed, i)
    "rollout/sampler.py",
)

#: Source-pattern hints mapping lock *expressions* to declared names,
#: for cross-module references the lint cannot resolve from a factory
#: call in the same file (e.g. ``self.coordinator.lock`` seen from
#: runtime/core.py). Checked in order; first match wins. Patterns are
#: regexes applied to the unparsed expression source.
ATTR_HINTS: Tuple[Tuple[str, str], ...] = (
    (r"(^|\.)coordinator\.lock$", "coordinator"),
    (r"_instances_lock$", "instances"),
    (r"^(h|handle|inst|backend)\.lock$", "instance"),
    (r"_busy_lock$", "busy"),
    (r"_timers_lock$", "timers"),
    (r"_history_lock$", "history"),
)


def rank(name: str) -> Optional[int]:
    """Rank of a declared lock name, or None if unknown."""
    return RANKS.get(name)


def can_acquire(
    held: str,
    new: str,
    *,
    held_key: Optional[int] = None,
    new_key: Optional[int] = None,
) -> bool:
    """May a thread holding ``held`` acquire ``new``?

    Unknown names are permissive (the caller should skip them); the
    lint and witness only enforce between *declared* locks.
    """
    rh, rn = RANKS.get(held), RANKS.get(new)
    if rh is None or rn is None:
        return True
    if held in TERMINAL:
        return False
    if held == new and held in ORDER_KEYED:
        if held_key is None or new_key is None:
            return True  # keys unknown -> witness checks at runtime
        return new_key > held_key
    return rn > rh


def is_deterministic_module(path: str) -> bool:
    """True if ``path`` falls under a seed-deterministic module."""
    p = path.replace("\\", "/")
    for suffix in DETERMINISTIC_MODULES:
        if suffix.endswith("/"):
            if ("/" + suffix) in ("/" + p) or p.startswith(suffix):
                return True
        elif p.endswith(suffix):
            return True
    return False


def module_roles(path: str) -> Tuple[str, ...]:
    """Declared thread roles for ``path`` (empty if single-role)."""
    p = path.replace("\\", "/")
    for suffix, roles in MODULE_ROLES.items():
        if p.endswith(suffix):
            return roles
    return ()
