"""Runtime lock-order witness: tracked locks + acquisition graph.

Drop-in ``TrackedLock`` / ``TrackedRLock`` wrappers record, per thread,
the stack of witness-aware locks currently held, and maintain a global
*acquisition graph* (edge ``A -> B`` whenever some thread acquired B
while holding A). The witness reports three violation classes:

* **order violations** — acquiring B while holding A when the declared
  partial order (:mod:`repro.analysis.lock_order`) forbids it, checked
  *before* blocking so a real deadlock still leaves a report behind;
* **cycles** in the acquisition graph — two threads that each took the
  same pair of locks in opposite orders never need to actually collide
  to be reported (the PR 5 deadlock was exactly such a cycle between
  the reward worker's REWARDED dispatch and the coordinator's
  INTERRUPTED dispatch);
* **emit-under-lock** — :meth:`LockWitness.record_emit` is called by
  ``TrajectoryLifecycle.emit`` at dispatch time; holding any lock
  outside :data:`repro.analysis.lock_order.EMIT_SAFE` at that point is
  reported with the offending stack.

Everything is opt-in: ``REPRO_LOCK_WITNESS=1`` in the environment, or
``RuntimeConfig(lock_witness=True)``, or ``with witness.enabled():`` in
tests. When inactive, ``make_lock``/``make_rlock``/``make_condition``
return plain ``threading`` primitives and ``on_emit`` is a single
global read — the tick/seed path is byte-identical with the witness
off.
"""
from __future__ import annotations

import json
import os
import threading
import traceback
from contextlib import contextmanager
from typing import Any, Dict, List, Optional, Set, Tuple

from repro.analysis import lock_order

_STACK_FRAMES = 12  # frames kept per violation sample
_MAX_SAMPLES = 200  # cap per violation class (counters keep exact totals)


def _stack() -> List[str]:
    frames = traceback.format_stack()[:-2]
    return [ln.rstrip("\n") for ln in frames[-_STACK_FRAMES:]]


class LockWitness:
    """Global acquisition graph + per-thread held-set recorder."""

    def __init__(self) -> None:
        self.active = True
        self._mu = threading.Lock()  # raw: guards the graph, never tracked
        self._tls = threading.local()
        # graph over node labels ("name" or "name[key]")
        self._edges: Dict[str, Set[str]] = {}
        self._edge_samples: Dict[Tuple[str, str], List[str]] = {}
        # counters (exact) + capped samples
        self.acquires = 0
        self.emits = 0
        self.order_violation_count = 0
        self.emit_violation_count = 0
        self.order_violations: List[Dict[str, Any]] = []
        self.emit_under_lock: List[Dict[str, Any]] = []
        self._seen_order: Set[Tuple[str, str]] = set()
        self._seen_emit: Set[Tuple[str, Tuple[str, ...]]] = set()

    # ------------------------------------------------------------ held set
    def _held(self) -> List["TrackedLock"]:
        held = getattr(self._tls, "held", None)
        if held is None:
            held = []
            self._tls.held = held
        return held

    def held_labels(self) -> List[str]:
        return [lk.label for lk in self._held()]

    # ----------------------------------------------------------- recording
    def before_acquire(self, lock: "TrackedLock") -> None:
        """Record edges + order check *before* blocking on ``lock``."""
        held = self._held()
        if not held:
            return
        with self._mu:
            for h in held:
                self._edges.setdefault(h.label, set()).add(lock.label)
                key = (h.label, lock.label)
                if key not in self._edge_samples:
                    self._edge_samples[key] = _stack()
                ok = lock_order.can_acquire(
                    h.name, lock.name,
                    held_key=h.order_key, new_key=lock.order_key,
                )
                if not ok:
                    self.order_violation_count += 1
                    if key not in self._seen_order:
                        self._seen_order.add(key)
                        if len(self.order_violations) < _MAX_SAMPLES:
                            self.order_violations.append({
                                "held": h.label,
                                "acquiring": lock.label,
                                "thread": threading.current_thread().name,
                                "stack": _stack(),
                            })

    def after_acquire(self, lock: "TrackedLock") -> None:
        self._held().append(lock)
        with self._mu:
            self.acquires += 1

    def on_release(self, lock: "TrackedLock") -> None:
        held = self._held()
        for i in range(len(held) - 1, -1, -1):
            if held[i] is lock:
                del held[i]
                break

    def record_emit(self, kind: str) -> None:
        """Called by the lifecycle bus at dispatch time."""
        with self._mu:
            self.emits += 1
        held = self._held()
        if not held:
            return
        bad = [h.label for h in held if h.name not in lock_order.EMIT_SAFE]
        if not bad:
            return
        with self._mu:
            self.emit_violation_count += 1
            key = (kind, tuple(bad))
            if key not in self._seen_emit:
                self._seen_emit.add(key)
                if len(self.emit_under_lock) < _MAX_SAMPLES:
                    self.emit_under_lock.append({
                        "event": kind,
                        "held": bad,
                        "thread": threading.current_thread().name,
                        "stack": _stack(),
                    })

    # ------------------------------------------------------------ analysis
    def edges(self) -> List[Tuple[str, str]]:
        with self._mu:
            return sorted(
                (a, b) for a, outs in self._edges.items() for b in outs
            )

    def cycles(self) -> List[List[str]]:
        """Elementary cycles in the acquisition graph (DFS, deduped)."""
        with self._mu:
            graph = {a: sorted(outs) for a, outs in self._edges.items()}
        out: List[List[str]] = []
        seen: Set[Tuple[str, ...]] = set()

        def dfs(node: str, path: List[str], on_path: Set[str]) -> None:
            for nxt in graph.get(node, ()):
                if nxt in on_path:
                    cyc = path[path.index(nxt):] + [nxt]
                    # canonical rotation for dedup
                    body = cyc[:-1]
                    k = min(range(len(body)), key=lambda i: body[i])
                    canon = tuple(body[k:] + body[:k])
                    if canon not in seen:
                        seen.add(canon)
                        out.append(list(canon) + [canon[0]])
                elif len(path) < 32:
                    on_path.add(nxt)
                    dfs(nxt, path + [nxt], on_path)
                    on_path.discard(nxt)

        for start in sorted(graph):
            dfs(start, [start], {start})
        return out

    def violations(self) -> Dict[str, int]:
        return {
            "order": self.order_violation_count,
            "emit_under_lock": self.emit_violation_count,
            "cycles": len(self.cycles()),
        }

    def assert_clean(self) -> None:
        v = self.violations()
        if any(v.values()):
            raise AssertionError(
                "lock witness found violations: "
                f"{v}\n{json.dumps(self.report(), indent=2)}"
            )

    def report(self) -> Dict[str, Any]:
        return {
            "acquires": self.acquires,
            "emits": self.emits,
            "nodes": sorted(
                set(self._edges)
                | {b for outs in self._edges.values() for b in outs}
            ),
            "edges": [list(e) for e in self.edges()],
            "cycles": self.cycles(),
            "order_violations": self.order_violations,
            "order_violation_count": self.order_violation_count,
            "emit_under_lock": self.emit_under_lock,
            "emit_violation_count": self.emit_violation_count,
        }

    def to_json(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.report(), f, indent=2, sort_keys=True)
            f.write("\n")


class TrackedLock:
    """Witness-aware ``threading.Lock`` drop-in."""

    reentrant = False

    def __init__(
        self,
        name: str,
        order_key: Optional[int] = None,
        witness: Optional[LockWitness] = None,
    ) -> None:
        self.name = name
        self.order_key = order_key
        self.label = name if order_key is None else f"{name}[{order_key}]"
        self._w = witness if witness is not None else _active
        self._inner = self._make_inner()

    def _make_inner(self):
        return threading.Lock()

    def _tracking(self) -> bool:
        w = self._w
        return w is not None and w.active

    def acquire(self, blocking: bool = True, timeout: float = -1) -> bool:
        track = self._tracking()
        if track and blocking:
            self._w.before_acquire(self)
        got = self._inner.acquire(blocking, timeout)
        if track and got:
            self._w.after_acquire(self)
        return got

    def release(self) -> None:
        if self._tracking():
            self._w.on_release(self)
        self._inner.release()

    def locked(self) -> bool:
        return self._inner.locked()

    def __enter__(self) -> bool:
        return self.acquire()

    def __exit__(self, *exc) -> None:
        self.release()

    def __repr__(self) -> str:
        return f"<{type(self).__name__} {self.label}>"


class TrackedRLock(TrackedLock):
    """Witness-aware ``threading.RLock`` drop-in.

    Reentrant acquisitions by the owning thread are transparent to the
    witness: only the outermost acquire/release pair is recorded, so
    reentry never shows up as a self-edge.
    """

    reentrant = True

    def __init__(
        self,
        name: str,
        order_key: Optional[int] = None,
        witness: Optional[LockWitness] = None,
    ) -> None:
        super().__init__(name, order_key, witness)
        self._depth = threading.local()

    def _make_inner(self):
        return threading.RLock()

    def acquire(self, blocking: bool = True, timeout: float = -1) -> bool:
        depth = getattr(self._depth, "d", 0)
        track = self._tracking() and depth == 0
        if track and blocking:
            self._w.before_acquire(self)
        got = self._inner.acquire(blocking, timeout)
        if got:
            self._depth.d = depth + 1
            if track:
                self._w.after_acquire(self)
        return got

    def release(self) -> None:
        depth = getattr(self._depth, "d", 0)
        if depth <= 1 and self._tracking():
            self._w.on_release(self)
        self._depth.d = depth - 1
        self._inner.release()


# --------------------------------------------------------------- module API
_active: Optional[LockWitness] = None


def enable() -> LockWitness:
    """Activate the witness (idempotent); new locks become tracked."""
    global _active
    if _active is None or not _active.active:
        _active = LockWitness()
    return _active


def disable() -> None:
    """Deactivate; existing tracked locks go dormant (attr-check only)."""
    if _active is not None:
        _active.active = False


def reset() -> None:
    global _active
    _active = None


def get_witness() -> Optional[LockWitness]:
    """The active witness, or None when the witness is off."""
    if _active is not None and _active.active:
        return _active
    return None


def current() -> Optional[LockWitness]:
    """Last witness, active or not (for post-run inspection)."""
    return _active


def is_enabled() -> bool:
    return get_witness() is not None


@contextmanager
def enabled():
    """Enable a fresh witness for the duration of a block (tests)."""
    w = enable()
    try:
        yield w
    finally:
        disable()


def on_emit(kind: str) -> None:
    """Lifecycle dispatch hook; near-free when the witness is off."""
    w = _active
    if w is not None and w.active:
        w.record_emit(kind)


def make_lock(name: str, order_key: Optional[int] = None):
    """A named mutex: ``TrackedLock`` when the witness is active, else a
    plain ``threading.Lock``."""
    w = get_witness()
    if w is None:
        return threading.Lock()
    return TrackedLock(name, order_key, w)


def make_rlock(name: str, order_key: Optional[int] = None):
    w = get_witness()
    if w is None:
        return threading.RLock()
    return TrackedRLock(name, order_key, w)


def make_condition(name: str):
    """A condition variable over a named (tracked) leaf lock."""
    w = get_witness()
    if w is None:
        return threading.Condition()
    return threading.Condition(TrackedLock(name, None, w))


if os.environ.get("REPRO_LOCK_WITNESS", "").strip().lower() not in (
    "", "0", "false", "no",
):
    enable()


# ------------------------------------------------------------- smoke main
def _smoke_main(argv: Optional[List[str]] = None) -> int:
    """Run a tiny threaded streaming runtime under the witness and dump
    the lock acquisition graph. Non-zero exit on any violation — this is
    the CI race gate."""
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--json", default=None, help="write lock-graph JSON")
    ap.add_argument("--steps", type=int, default=2)
    ap.add_argument("--barrier", action="store_true",
                    help="also run a non-streaming (barrier) pass")
    args = ap.parse_args(argv)

    # heavyweight imports deferred: the module itself stays stdlib-only
    from repro.configs import get_arch
    from repro.core.types import reset_traj_ids
    from repro.runtime.async_runtime import AsyncRLRuntime, RuntimeConfig

    arch = get_arch("qwen2-1.5b").reduced()
    w = enable()
    try:
        modes = [dict(streaming=True, stream_min_fill=1)]
        if args.barrier:
            modes.append(dict(streaming=False))
        for mode in modes:
            reset_traj_ids()
            rt = AsyncRLRuntime(arch, RuntimeConfig(
                eta=1, batch_size=2, group_size=2, n_instances=2,
                max_slots=2, max_len=48, max_new_tokens=8,
                total_steps=args.steps, seed=0, scheduler="threaded",
                lock_witness=True, **mode,
            ))
            rt.scheduler.wall_timeout_s = 240.0
            rt.run()
            assert rt.model_version == args.steps, "run did not complete"
    finally:
        disable()
        if args.json:
            w.to_json(args.json)

    v = w.violations()
    print(f"lock witness: acquires={w.acquires} emits={w.emits} "
          f"edges={len(w.edges())} violations={v}")
    if any(v.values()):
        print(json.dumps(w.report(), indent=2))
        return 1
    return 0


if __name__ == "__main__":
    # ``python -m repro.analysis.witness`` executes this file as
    # ``__main__`` while the runtime's lock factories consult the
    # canonical ``repro.analysis.witness`` module — delegate so both
    # share one ``_active`` witness.
    from repro.analysis import witness as _canonical

    raise SystemExit(_canonical._smoke_main())
