"""Architecture registry: the 10 assigned archs + the paper's own model."""
from repro.configs.base import (
    ALL_SHAPES,
    DECODE_32K,
    LONG_500K,
    PREFILL_32K,
    SHAPES,
    TRAIN_4K,
    ArchConfig,
    ShapeConfig,
)
from repro.configs.dbrx_132b import CONFIG as DBRX_132B
from repro.configs.glm4_9b import CONFIG as GLM4_9B
from repro.configs.granite_3_8b import CONFIG as GRANITE_3_8B
from repro.configs.hymba_1_5b import CONFIG as HYMBA_1_5B
from repro.configs.internvl2_76b import CONFIG as INTERNVL2_76B
from repro.configs.llama4_scout_17b_a16e import CONFIG as LLAMA4_SCOUT_17B_A16E
from repro.configs.qwen2_1_5b import CONFIG as QWEN2_1_5B
from repro.configs.qwen2_5_14b import CONFIG as QWEN2_5_14B
from repro.configs.qwen3_30b_a3b import CONFIG as QWEN3_30B_A3B
from repro.configs.whisper_tiny import CONFIG as WHISPER_TINY
from repro.configs.xlstm_1_3b import CONFIG as XLSTM_1_3B

# The 10 assigned architectures (cell matrix rows).
ASSIGNED = (
    LLAMA4_SCOUT_17B_A16E,
    DBRX_132B,
    QWEN2_5_14B,
    GRANITE_3_8B,
    QWEN2_1_5B,
    GLM4_9B,
    HYMBA_1_5B,
    INTERNVL2_76B,
    WHISPER_TINY,
    XLSTM_1_3B,
)

# Full registry (assigned + the paper's evaluation model).
REGISTRY = {cfg.name: cfg for cfg in ASSIGNED + (QWEN3_30B_A3B,)}


def get_arch(name: str) -> ArchConfig:
    if name not in REGISTRY:
        raise KeyError(f"unknown arch {name!r}; available: {sorted(REGISTRY)}")
    return REGISTRY[name]


def get_shape(name: str) -> ShapeConfig:
    if name not in SHAPES:
        raise KeyError(f"unknown shape {name!r}; available: {sorted(SHAPES)}")
    return SHAPES[name]


def cell_matrix():
    """All (arch, shape) cells; ``supported=False`` cells are documented skips."""
    cells = []
    for arch in ASSIGNED:
        for shape in ALL_SHAPES:
            cells.append((arch, shape, arch.supports_shape(shape)))
    return cells
