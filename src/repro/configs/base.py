"""Base configuration dataclasses for the architecture zoo.

Every assigned architecture is expressed as an ``ArchConfig``; input-shape
cells are ``ShapeConfig``. Full-size configs are only ever *lowered*
(ShapeDtypeStruct dry-run); smoke tests use ``reduced()`` variants.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Tuple


@dataclass(frozen=True)
class ShapeConfig:
    """One input-shape cell (seq_len x global_batch, plus which step it lowers)."""

    name: str
    seq_len: int
    global_batch: int
    # "train"   -> lowers train_step      (full fwd+bwd+opt update)
    # "prefill" -> lowers prefill_step    (inference prefill, builds KV cache)
    # "decode"  -> lowers serve_step      (one new token vs seq_len-sized cache)
    kind: str


TRAIN_4K = ShapeConfig("train_4k", seq_len=4096, global_batch=256, kind="train")
PREFILL_32K = ShapeConfig("prefill_32k", seq_len=32768, global_batch=32, kind="prefill")
DECODE_32K = ShapeConfig("decode_32k", seq_len=32768, global_batch=128, kind="decode")
LONG_500K = ShapeConfig("long_500k", seq_len=524288, global_batch=1, kind="decode")

ALL_SHAPES: Tuple[ShapeConfig, ...] = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)
SHAPES = {s.name: s for s in ALL_SHAPES}


@dataclass(frozen=True)
class ArchConfig:
    """Unified architecture description covering the whole assigned zoo.

    family:
      dense   -- standard GQA transformer
      moe     -- mixture-of-experts FFN
      hybrid  -- parallel attention + Mamba (SSM) heads per block  (hymba)
      ssm     -- alternating mLSTM / sLSTM blocks                  (xlstm)
      vlm     -- LM backbone + patch-embedding stub frontend       (internvl2)
      audio   -- encoder-decoder backbone + frame-embedding stub   (whisper)
    """

    name: str
    family: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // n_heads

    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    shared_expert: bool = False  # llama4-style shared expert alongside routed ones
    moe_capacity_factor: float = 1.25  # token-choice capacity (drops overflow)

    # --- attention details ---
    qkv_bias: bool = False
    rope_theta: float = 1e4

    # --- hybrid / ssm ---
    ssm_state: int = 0          # mamba state size (hymba) / 0
    ssm_expand: int = 2         # mamba inner expansion
    ssm_conv: int = 4           # mamba depthwise conv width
    block_pattern: str = "attn"  # "attn" | "attn+ssm" | "mlstm/slstm"
    # sub-quadratic long-context mode: sliding-window attention width used when
    # seq_len exceeds ``long_context_threshold`` (hybrid archs); SSM/xLSTM parts
    # are O(1)-state by construction.
    sliding_window: int = 0
    long_context_threshold: int = 65536

    # --- encoder-decoder (whisper) ---
    encoder_layers: int = 0
    encoder_seq: int = 0        # stub frontend: number of frame embeddings
    cross_attention: bool = False

    # --- frontend stub (vlm / audio) ---
    frontend: str = "none"      # "none" | "patch" | "frames"
    n_patches: int = 0          # vlm: patch embeddings prepended to the text

    # --- misc ---
    tie_embeddings: bool = False
    norm_eps: float = 1e-5
    # sub-quadratic archs may run long_500k
    subquadratic: bool = False
    source: str = ""            # provenance note [source; verified-tier]

    # ---------------------------------------------------------------- helpers
    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def padded_vocab(self) -> int:
        """Vocab rounded up to a 256 multiple (divisible by data x model =
        16 x 16) so embeddings/logits shard cleanly — Megatron-style vocab
        padding. Padded logit columns are masked to -inf in the model."""
        return ((self.vocab_size + 255) // 256) * 256

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def n_params(self) -> int:
        """Approximate total parameter count (embedding + blocks)."""
        d, hd = self.d_model, self.hd
        attn = d * self.n_heads * hd + 2 * d * self.n_kv_heads * hd + self.n_heads * hd * d
        if self.block_pattern == "attn+ssm":
            inner = self.ssm_expand * d
            ssm = d * 2 * inner + inner * d + inner * (2 * self.ssm_state + 2)
            attn = attn + ssm
        if self.block_pattern == "mlstm/slstm":
            # xLSTM: mostly mLSTM layers (wq/wk/wv/wo + gates), 1-per-period
            # sLSTM (4-gate proj + recurrent + out). hd*n_heads == d here.
            hh = self.n_heads * hd
            mlstm = 4 * d * hh + 2 * d * self.n_heads
            slstm = 4 * d * hh + 4 * self.n_heads * hd * hd + hh * d
            # period-8 blend (7:1) matching models.model.xlstm_period
            attn = (7 * mlstm + slstm) / 8.0
        if self.is_moe:
            ffn = self.n_experts * 3 * d * self.d_ff
            if self.shared_expert:
                ffn += 3 * d * self.d_ff
            ffn += d * self.n_experts  # router
        else:
            ffn = 3 * d * self.d_ff
        block = attn + ffn + 2 * d
        emb = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        enc = self.encoder_layers * (4 * d * d + 3 * d * self.d_ff + 2 * d)
        cross = self.n_layers * (4 * d * d) if self.cross_attention else 0
        return self.n_layers * block + emb + enc + cross

    @property
    def n_active_params(self) -> int:
        """Active parameters per token (MoE: only top_k experts count)."""
        if not self.is_moe:
            return self.n_params
        d = self.d_model
        inactive = (self.n_experts - self.top_k) * 3 * d * self.d_ff * self.n_layers
        return self.n_params - inactive

    def supports_shape(self, shape: ShapeConfig) -> bool:
        """long_500k needs a sub-quadratic path; everything else always runs."""
        if shape.name == "long_500k":
            return self.subquadratic
        return True

    def reduced(self) -> "ArchConfig":
        """Smoke-test variant: same family/block structure, tiny dims."""
        hd = 16
        n_heads = max(2, min(4, self.n_heads))
        # preserve the GQA ratio flavor (kv < q whenever original had it)
        n_kv = 1 if self.n_kv_heads < self.n_heads else n_heads
        changes = dict(
            n_layers=min(4, self.n_layers) if self.block_pattern != "mlstm/slstm" else 4,
            d_model=n_heads * hd,
            n_heads=n_heads,
            n_kv_heads=n_kv,
            d_ff=0 if self.d_ff == 0 else 128,
            vocab_size=256,
            head_dim=hd,
            long_context_threshold=512,
            sliding_window=64 if self.sliding_window else 0,
        )
        if self.is_moe:
            changes.update(n_experts=4, top_k=min(self.top_k, 2))
        if self.encoder_layers:
            changes.update(encoder_layers=2, encoder_seq=32)
        if self.n_patches:
            changes.update(n_patches=8)
        if self.ssm_state:
            changes.update(ssm_state=4)
        return dataclasses.replace(self, **changes)
