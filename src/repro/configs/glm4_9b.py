"""glm4-9b [dense] — RoPE, GQA kv=2. [hf:THUDM/glm-4-9b; hf]

GLM uses partial-rotary (0.5); we apply full rotary — backbone-equivalent for
systems purposes (noted in DESIGN.md §Arch-applicability).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="glm4-9b",
    family="dense",
    n_layers=40,
    d_model=4096,
    n_heads=32,
    n_kv_heads=2,
    d_ff=13696,
    vocab_size=151552,
    head_dim=128,
    rope_theta=1e4,
    source="hf:THUDM/glm-4-9b; hf",
)
