"""hymba-1.5b [hybrid] — parallel attention + mamba heads per block.

[arXiv:2411.13676; hf]. Sub-quadratic at long context: the attention heads
switch to a sliding window while the SSM heads carry global state, so
``long_500k`` runs. Meta tokens omitted (systems-irrelevant).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="hymba-1.5b",
    family="hybrid",
    n_layers=32,
    d_model=1600,
    n_heads=25,
    n_kv_heads=5,
    d_ff=5504,
    vocab_size=32001,
    head_dim=64,
    ssm_state=16,
    ssm_expand=2,
    block_pattern="attn+ssm",
    sliding_window=1024,
    subquadratic=True,
    rope_theta=1e4,
    tie_embeddings=True,
    source="arXiv:2411.13676; hf",
)
