"""internvl2-76b [vlm] — InternViT + InternLM2 backbone. [arXiv:2404.16821; unverified]

The vision frontend is a STUB per the assignment: ``input_specs()`` provides
precomputed patch embeddings (n_patches x d_model) prepended to the text
sequence. Only the LM backbone (80L) is modeled.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="internvl2-76b",
    family="vlm",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=28672,
    vocab_size=128256,
    head_dim=128,
    frontend="patch",
    n_patches=256,
    rope_theta=5e5,
    source="arXiv:2404.16821; unverified",
)
