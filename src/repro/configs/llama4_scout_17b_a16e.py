"""llama4-scout-17b-a16e [moe] — MoE 16e top-1 with shared expert, early fusion.

[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="llama4-scout-17b-a16e",
    family="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    d_ff=8192,
    vocab_size=202048,
    head_dim=128,
    n_experts=16,
    top_k=1,
    shared_expert=True,  # llama4 routes top-1 + always-on shared expert
    rope_theta=5e5,
    source="hf:meta-llama/Llama-4-Scout-17B-16E; unverified",
)
