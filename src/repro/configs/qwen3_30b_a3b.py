"""qwen3-30b-a3b [moe] — the paper's own evaluation model (Qwen3-30B-A3B).

48L d_model=2048 32H (GQA kv=4) 128 experts top-8, expert d_ff=768.
[arXiv:2505.09388; hf] — not part of the assigned 10; used by the paper's
benchmarks (Fig. 13d, §6.5) and by our convergence/throughput reproductions.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-30b-a3b",
    family="moe",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv_heads=4,
    d_ff=768,
    vocab_size=151936,
    head_dim=128,
    n_experts=128,
    top_k=8,
    rope_theta=1e6,
    source="arXiv:2505.09388; hf",
)
