"""whisper-tiny [audio] — enc-dec, conv frontend (stub). [arXiv:2212.04356; unverified]

The conv/mel frontend is a STUB per the assignment: ``input_specs()`` provides
precomputed frame embeddings (encoder_seq x d_model). Encoder is bidirectional;
decoder has causal self-attention (KV cache) + cross-attention to the encoder.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-tiny",
    family="audio",
    n_layers=4,            # decoder layers
    d_model=384,
    n_heads=6,
    n_kv_heads=6,
    d_ff=1536,
    vocab_size=51865,
    head_dim=64,
    encoder_layers=4,
    encoder_seq=1500,
    cross_attention=True,
    frontend="frames",
    tie_embeddings=True,
    rope_theta=1e4,
    source="arXiv:2212.04356; unverified",
)
