"""xlstm-1.3b [ssm] — alternating sLSTM + mLSTM blocks. [arXiv:2405.04517; unverified]

d_ff = 0: projections live inside the blocks. Pure recurrent state, so
``long_500k`` decode runs with O(1) memory in sequence length.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="xlstm-1.3b",
    family="ssm",
    n_layers=48,
    d_model=2048,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    head_dim=512,          # d_model / n_heads
    block_pattern="mlstm/slstm",
    subquadratic=True,
    tie_embeddings=True,
    source="arXiv:2405.04517; unverified",
)
