"""StaleFlow core: the paper's contribution.

* ``staleness``          — global consistency protocol (§4)
* ``cost_model``         — decode-throughput model (Eq. 2-4, App. B)
* ``snapshot``           — per-instance snapshots (Fig. 11)
* ``commands``           — Pull / Route / Interrupt / Abort (Table 1)
* ``speculative``        — speculative state P + Eq. 1 validation
* ``strategies``         — routing / synchronization / migration (Alg. 2-5)
* ``coordinator``        — snapshot->command cycle (Alg. 1)
* ``lifecycle``          — trajectory-lifecycle event bus (the single
                           write path for trajectory state, §5.1)
* ``reward_server``      — the disaggregated reward phase (§2.1, Fig. 6)
* ``trajectory_server``  — TS middleware (§5.1)
* ``parameter_server``   — PS middleware + comm planning (§5.1, App. A)
"""
from repro.core.commands import Abort, Command, Interrupt, Pull, Route
from repro.core.coordinator import GroupBook, RolloutCoordinator, StalenessVerifier
from repro.core.cost_model import PAPER_H20_QWEN3_30B, CostModel, fit_coefficients
from repro.core.lifecycle import (
    LifecycleEvent,
    LifecycleEventKind,
    RetiredPayloadStore,
    TrajectoryLifecycle,
)
from repro.core.parameter_server import (
    BackgroundPusher,
    CommPlan,
    ParameterServer,
    ReadWriteLock,
    plan_transfers,
    replicated_pull_plan,
    sharded_push_plan,
)
from repro.core.reward_server import FnVerifier, RewardServer, RewardServerConfig
from repro.core.snapshot import InstanceSnapshot, Snapshot, clone_snapshot, collect
from repro.core.speculative import SpeculativeState
from repro.core.staleness import (
    BufferState,
    EntryState,
    StalenessBuffer,
    StalenessManager,
    StalenessViolation,
)
from repro.core.strategies import (
    StrategyConfig,
    StrategySuite,
    check_routable,
    migration_strategy,
    prefix_routing_strategy,
    routing_strategy,
    synchronization_strategy,
    vanilla_migration,
    vanilla_routing,
    vanilla_synchronization,
)
from repro.core.trajectory_server import TrajectoryServer
from repro.core.types import Trajectory, TrajectoryGroup, TrajStatus, next_traj_id
