"""Rollout commands issued by the coordinator (paper §5.1, Table 1).

``Pull``      — instance fetches latest parameters from the PS.
``Route``     — trajectories move TS -> instance.
``Interrupt`` — trajectories stop on the instance and return to the TS
                (partial rollout / migration).
``Abort``     — trajectories are irrevocably discarded (redundancy surplus /
                filtering); they do *not* return to the TS.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Tuple


@dataclass(frozen=True)
class Command:
    inst: int


@dataclass(frozen=True)
class Pull(Command):
    """Fetch latest model parameters from the PS (blocks instance decode)."""


@dataclass(frozen=True)
class Route(Command):
    traj_ids: Tuple[int, ...] = ()
    # V_traj assigned at routing time (None entries keep their existing one)
    v_traj: int = -1


@dataclass(frozen=True)
class Interrupt(Command):
    traj_ids: Tuple[int, ...] = ()


@dataclass(frozen=True)
class Abort(Command):
    traj_ids: Tuple[int, ...] = ()


CommandList = List[Command]
