"""Centralized rollout coordinator (paper §5, Algorithm 1 + Fig. 12).

The coordinator runs a snapshot -> command cycle:

1. A snapshot of all rollout instances arrives and is validated against the
   speculative state ``P`` (Eq. 1); invalid snapshots are discarded.
2. The strategy suite runs sequentially — synchronization, migration,
   routing (Alg. 1) — each producing commands that are applied to the
   *local* snapshot copy so later strategies see their effects.
3. Commands are issued asynchronously; ``P`` is updated per Table 1.

The coordinator also owns protocol bookkeeping that spans servers:
``V_traj`` assignment (Reserve on first route), group accounting
(Occupy when a whole group is rewarded, §4.3), redundancy surplus and
filtering aborts.
"""
from __future__ import annotations

import threading
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Set, Tuple

from repro.analysis.witness import make_rlock
from repro.core.commands import Abort, Command, CommandList, Interrupt, Pull, Route
from repro.core.cost_model import CostModel
from repro.core.lifecycle import (
    LifecycleEvent,
    LifecycleEventKind,
    TrajectoryLifecycle,
)
from repro.core.snapshot import InstanceSnapshot, Snapshot, clone_snapshot
from repro.core.speculative import SpeculativeState
from repro.core.staleness import StalenessManager
from repro.core.strategies import StrategyConfig, StrategySuite
from repro.core.trajectory_server import TrajectoryServer
from repro.core.types import Trajectory, TrajStatus


class GroupBook:
    """Group-sampling accounting (§4.3, Fig. 8a).

    Protocol entries live at group granularity: the staleness-buffer key for
    a grouped trajectory is its ``group_id`` (offset into a disjoint key
    space). Occupy fires only when ``group_size`` members are rewarded;
    surplus members (group-level redundancy) are then reported for Abort.
    """

    GROUP_KEY_BASE = 1 << 40  # disjoint from trajectory IDs

    def __init__(self, ts: TrajectoryServer):
        self.ts = ts
        self._rewarded: Dict[int, Set[int]] = {}
        self._lock = make_rlock("groupbook")

    @staticmethod
    def key(group_id: int) -> int:
        return GroupBook.GROUP_KEY_BASE + group_id

    def group_size(self, group_id: int) -> int:
        return self.ts.groups[group_id].group_size

    def on_rewarded(self, traj: Trajectory) -> Tuple[bool, List[int]]:
        """Returns (group_now_complete, surplus_member_ids_to_abort)."""
        with self._lock:
            group = self.ts.groups.get(traj.group_id)
            if group is None:
                return False, []  # group already retired: no new entry
            done = self._rewarded.setdefault(traj.group_id, set())
            done.add(traj.traj_id)
            if len(done) == group.group_size:
                surplus = [
                    tid
                    for tid in group.traj_ids
                    if tid not in done and self.ts.get(tid) is not None
                ]
                return True, surplus
            return False, []

    def rewarded_members(self, group_id: int) -> Set[int]:
        with self._lock:
            return set(self._rewarded.get(group_id, set()))

    def forget(self, group_id: int) -> None:
        with self._lock:
            self._rewarded.pop(group_id, None)


class StalenessVerifier:
    """Discriminator facade for Alg. 2 — group-aware ``can_assign``."""

    def __init__(self, manager: StalenessManager, groups: Optional[GroupBook]):
        self.manager = manager
        self.groups = groups

    def _group_key(self, traj: Trajectory) -> Optional[int]:
        if traj.group_id >= 0 and self.groups is not None:
            return GroupBook.key(traj.group_id)
        return None

    def can_assign(self, traj: Trajectory, version: int) -> bool:
        key = self._group_key(traj)
        if key is not None and self.manager.is_tracked(key):
            info = self.manager.entry_info(key)
            v_buf, _, entry_version = info
            if version >= entry_version:
                return True  # group min unchanged
            # joining member lowers the group min: entry must stay legal or
            # be relocatable
            if version + self.manager.eta >= v_buf:
                return True
            return self.manager.can_reserve(version)
        return self.manager.can_reserve(version)


@dataclass
class CoordinatorStats:
    cycles: int = 0
    snapshots_rejected: int = 0
    commands: Dict[str, int] = field(
        default_factory=lambda: {"Pull": 0, "Route": 0, "Interrupt": 0, "Abort": 0}
    )
    # streaming fast path (``route_instance``): event-driven admission
    # decisions, routes they issued, and single-instance snapshots rejected
    # by Eq. 1 (counted separately so full-cycle rejection telemetry keeps
    # its seed meaning)
    stream_cycles: int = 0
    stream_routes: int = 0
    stream_rejected: int = 0


class RolloutCoordinator:
    def __init__(
        self,
        manager: StalenessManager,
        ts: TrajectoryServer,
        *,
        cost_model: CostModel,
        cfg: Optional[StrategyConfig] = None,
        suite: Optional[StrategySuite] = None,
        group_sampling: bool = True,
        group_filter=None,  # callable([Trajectory]) -> keep? (§4.3 filtering)
        lifecycle: Optional[TrajectoryLifecycle] = None,
    ):
        self.manager = manager
        self.ts = ts
        self.cost_model = cost_model
        # Lifecycle bus: protocol-side effects (Occupy, surplus/filter
        # aborts, Consume retirement) are *published* as events; the TS,
        # retired-payload store, and instance cleanup subscribe. When the
        # caller provides no bus the coordinator creates a private one and
        # attaches the TS, preserving the standalone (unit-test) behavior
        # where aborts drop payloads and consume retires them directly.
        if lifecycle is None:
            lifecycle = TrajectoryLifecycle()
            ts.attach(lifecycle)
        self.lifecycle = lifecycle
        # protocol Occupy runs off REWARDED events: the StalenessManager is
        # effectively a bus subscriber, with the coordinator translating
        # trajectory/group events into protocol keys on its behalf
        lifecycle.subscribe(LifecycleEventKind.REWARDED, self._on_rewarded)
        # a fresh StrategyConfig per coordinator: a class-level default
        # instance would be silently shared (and mutated) across every
        # coordinator constructed without an explicit config
        self.cfg = cfg if cfg is not None else StrategyConfig()
        self.suite = suite or StrategySuite.staleflow()
        self.groups = GroupBook(ts) if group_sampling else None
        self.group_filter = group_filter
        self.verifier = StalenessVerifier(manager, self.groups)
        self.spec = SpeculativeState()
        self.stats = CoordinatorStats()
        # last-seen cumulative preemption count per instance: snapshots
        # report monotone totals (a pure read on the engine), and the
        # coordinator differences them into the per-cycle thrash rate the
        # cost model's routing penalty consumes
        self._preempt_seen: Dict[int, int] = {}
        self._lock = make_rlock("coordinator")
        # thread currently inside a routing decision (full ``step`` or the
        # ``route_instance`` fast path). Event subscribers that trigger
        # incremental admission re-entrantly — e.g. an ABORTED published by
        # this cycle's own command execution — check ``in_cycle`` and bail:
        # the running cycle already accounts for the freed capacity.
        self._cycle_thread: Optional[int] = None

    def in_cycle(self) -> bool:
        """True iff the *calling thread* is inside a routing decision."""
        return self._cycle_thread == threading.get_ident()

    @property
    def lock(self) -> threading.RLock:
        """The coordination critical-section lock. Schedulers hold it across
        a whole snapshot->command->execute cycle so reward-side protocol
        events (Occupy/aborts) cannot interleave mid-cycle."""
        return self._lock

    def _on_rewarded(self, e: LifecycleEvent) -> None:
        """REWARDED bus subscriber: run protocol Occupy + surplus aborts.

        A trajectory aborted while queued for reward is dead to the
        protocol (its entry was already aborted) — do not resurrect its
        status or group accounting.
        """
        if e.traj is not None and e.traj.status != TrajStatus.ABORTED:
            self.on_trajectory_rewarded(e.traj)

    def drop_instance(self, inst_id: int) -> None:
        """An instance left the fleet (failure): forget its expectations."""
        with self._lock:
            self.spec.expectations.pop(inst_id, None)
            self._preempt_seen.pop(inst_id, None)

    # --------------------------------------------------------- protocol keys
    def _protocol_key(self, traj: Trajectory) -> int:
        if traj.group_id >= 0 and self.groups is not None:
            return GroupBook.key(traj.group_id)
        return traj.traj_id

    def _reserve_on_route(self, traj: Trajectory, version: int) -> bool:
        """Reserve / group-min update at Route issuance. Returns success."""
        key = self._protocol_key(traj)
        if self.manager.is_tracked(key):
            info = self.manager.entry_info(key)
            if info is not None and version < info[2]:
                return self.manager.lower_version(key, version)
            return True
        if not self.manager.can_reserve(version):
            return False
        self.manager.reserve(key, version)
        return True

    # ------------------------------------------------------------ the cycle
    def step(self, snapshot: Snapshot, ps_version: int) -> CommandList:
        """One snapshot->command cycle (Alg. 1). Returns issued commands.

        The caller (runtime / simulator) is responsible for executing the
        commands on the data planes; the coordinator updates ``P`` here so
        the *next* snapshot is validated against the expected effects.
        """
        with self._lock:
            self.stats.cycles += 1
            if not self.spec.validate(snapshot):
                self.stats.snapshots_rejected += 1
                return []
            self._cycle_thread = threading.get_ident()
            try:
                return self._step_locked(snapshot, ps_version)
            finally:
                self._cycle_thread = None

    def _step_locked(self, snapshot: Snapshot, ps_version: int) -> CommandList:
        s = clone_snapshot(snapshot)
        # rewrite cumulative preemption counters into the rate since
        # the previous cycle (only on the local clone the strategies
        # see — the caller's snapshot is untouched)
        for inst_id, si in s.items():
            total = si.preemptions
            si.preemptions = max(
                0, total - self._preempt_seen.get(inst_id, 0)
            )
            self._preempt_seen[inst_id] = total
        commands: CommandList = []
        ts_trajs = list(self.ts.peek())
        k5 = self.cost_model.k5
        kv_bs = self.cost_model.block_size

        # ---- redundancy surplus + protocol-dropped payload aborts
        for cmd in self._collect_aborts(s):
            commands.append(cmd)
            self.spec.apply(cmd, ps_version=ps_version)
            s[cmd.inst].discard(
                cmd.traj_ids, bytes_per_token=k5, block_size=kv_bs
            )

        # ---- Alg. 1 line 3: synchronization strategy
        for inst in self.suite.synchronization(
            s, ts_trajs, ps_version, self.cost_model, self.verifier, self.cfg
        ):
            resident = sorted(s[inst].resident())
            if resident:
                cmd_i = Interrupt(inst, tuple(resident))
                commands.append(cmd_i)
                self.spec.apply(cmd_i, ps_version=ps_version)
            cmd_p = Pull(inst)
            commands.append(cmd_p)
            self.spec.apply(cmd_p, ps_version=ps_version)
            s[inst].discard(resident, bytes_per_token=k5, block_size=kv_bs)
            s[inst].complete_trajs = set()
            s[inst].inst_version = ps_version
            ts_trajs.extend(
                t for tid in resident if (t := self.ts.get(tid)) is not None
            )

        # ---- Alg. 1 line 9: migration strategy
        for inst, trajs in self.suite.migration(s, self.cost_model, self.cfg):
            cmd = Interrupt(inst, tuple(trajs))
            commands.append(cmd)
            self.spec.apply(cmd, ps_version=ps_version)
            s[inst].discard(trajs, bytes_per_token=k5, block_size=kv_bs)
            ts_trajs.extend(
                t for tid in trajs if (t := self.ts.get(tid)) is not None
            )

        # ---- Alg. 1 line 13: routing strategy
        for inst, traj, version in self.suite.routing(
            s, ts_trajs, self.cost_model, self.verifier, self.cfg
        ):
            if not self._reserve_on_route(traj, version):
                continue  # discriminator said no at issue time
            if traj.v_traj is None:
                traj.v_traj = version
            cmd = Route(inst, (traj.traj_id,), v_traj=version)
            commands.append(cmd)
            self.spec.apply(cmd, ps_version=ps_version)

        for c in commands:
            self.stats.commands[type(c).__name__] += 1
        return commands

    # ------------------------------------------- streaming incremental path
    def route_instance(
        self, snap: "InstanceSnapshot", ps_version: int
    ) -> CommandList:
        """Event-driven incremental admission (streaming fast path).

        One instance just freed capacity (COMPLETED/ABORTED): make a
        routing-only decision for *that instance* under the coordinator
        lock — the caller holds the instance's lock, no fleet barrier.
        Reuses the full waterfall routing strategy (Alg. 3 ->
        ``CostModel.marginal_gain`` / ``admit_group``) and the
        ``StalenessVerifier.can_assign`` gate over a one-instance snapshot,
        so admission decisions are identical to what a global cycle would
        route to this instance. Sync, migration, and surplus aborts stay
        with the rarer background ``step`` rebalance.

        Returns the issued Route commands (the caller executes them). The
        single-instance snapshot is still Eq. 1-validated: commands whose
        effects haven't landed on this instance yet reject it, and the
        admission simply retries on the next event or background cycle.
        """
        with self._lock:
            if self.in_cycle():
                return []  # re-entrant emit from a running cycle's dispatch
            self.stats.stream_cycles += 1
            inst_id = snap.inst_id
            if not self.spec.validate({inst_id: snap}):
                self.stats.stream_rejected += 1
                return []
            self._cycle_thread = threading.get_ident()
            try:
                ts_trajs = list(self.ts.peek())
                if not ts_trajs:
                    return []
                s = {inst_id: clone_snapshot({inst_id: snap})[inst_id]}
                total = s[inst_id].preemptions
                s[inst_id].preemptions = max(
                    0, total - self._preempt_seen.get(inst_id, 0)
                )
                self._preempt_seen[inst_id] = total
                commands: CommandList = []
                for inst, traj, version in self.suite.routing(
                    s, ts_trajs, self.cost_model, self.verifier, self.cfg
                ):
                    if not self._reserve_on_route(traj, version):
                        continue
                    if traj.v_traj is None:
                        traj.v_traj = version
                    cmd = Route(inst, (traj.traj_id,), v_traj=version)
                    commands.append(cmd)
                    self.spec.apply(cmd, ps_version=ps_version)
                    self.stats.commands["Route"] += 1
                self.stats.stream_routes += len(commands)
                return commands
            finally:
                self._cycle_thread = None

    def _collect_aborts(self, s: Snapshot) -> List[Abort]:
        """Redundancy surplus (batch level) and stale-protocol filtering."""
        aborts: List[Abort] = []
        surplus = set(self.manager.surplus_keys())
        if not surplus:
            return aborts
        # map protocol keys back to resident trajectory IDs per instance
        for key in surplus:
            if key >= GroupBook.GROUP_KEY_BASE and self.groups is not None:
                gid = key - GroupBook.GROUP_KEY_BASE
                group = self.ts.groups.get(gid)
                member_ids = set(group.traj_ids) if group else set()
            else:
                member_ids = {key}
            self.manager.abort(key)
            commanded: set = set()
            for inst, si in s.items():
                hit = sorted(member_ids & si.resident())
                if hit:
                    aborts.append(Abort(inst, tuple(hit)))
                    commanded |= set(hit)
            # resident members are aborted by the Abort *commands* (whose
            # execution publishes the ABORTED events); the rest leave the
            # lifecycle here
            for tid in sorted(member_ids - commanded):
                self.lifecycle.aborted(tid, self.ts.get(tid))
            if key >= GroupBook.GROUP_KEY_BASE and self.groups is not None:
                self.groups.forget(key - GroupBook.GROUP_KEY_BASE)
        return aborts

    # ----------------------------------------------------- lifecycle events
    def _abort_members(self, traj_ids: List[int]) -> List[int]:
        """Protocol-initiated aborts (redundancy surplus / group filtering).

        CRITICAL: these bypass the snapshot->command cycle, so the
        speculative state P must be updated here (Table 1: Abort decrements
        accum_traj_num) or Eq. 1 would reject every subsequent snapshot and
        the coordinator would deadlock. Only trajectories actually RESIDENT
        on an instance (running/waiting) change P; TS-resident ones don't.

        The data-plane cleanup (TS drop, engine slot release, retired-
        payload eviction) runs off the published ABORTED events — the
        speculative fixup must precede the event because subscribers clear
        the residency markers the fixup inspects.
        """
        for tid in traj_ids:
            t = self.ts.get(tid)
            if (
                t is not None
                and t.instance is not None
                and t.status == TrajStatus.RUNNING
            ):
                self.spec.apply(Abort(t.instance, (tid,)))
            self.lifecycle.aborted(tid, t)
        return traj_ids

    def on_trajectory_rewarded(self, traj: Trajectory) -> List[int]:
        """Reward landed: run protocol Occupy. Returns surplus member IDs the
        caller must Abort on their instances (group-level redundancy)."""
        with self._lock:
            traj.status = TrajStatus.REWARDED
            key = self._protocol_key(traj)
            if self.groups is not None and traj.group_id >= 0:
                complete, surplus = self.groups.on_rewarded(traj)
                if not complete:
                    return []
                # proactive filtering (Fig. 8c): e.g. DAPO drops zero-signal
                # groups (identical rewards carry no learning signal)
                if self.group_filter is not None:
                    members = [
                        self.ts.get(tid)
                        for tid in self.groups.rewarded_members(traj.group_id)
                    ]
                    members = [m for m in members if m is not None]
                    if not self.group_filter(members):
                        group = self.ts.groups.get(traj.group_id)
                        all_ids = list(group.traj_ids) if group else []
                        self.manager.abort(key)
                        self._abort_members(all_ids)
                        self.groups.forget(traj.group_id)
                        return all_ids  # caller aborts any still running
                if self.manager.is_tracked(key):
                    self.manager.occupy(key)
                self._abort_members(list(surplus))
                return surplus
            if self.manager.is_tracked(key):
                self.manager.occupy(key)
            return []

    def abort_unverifiable(self, traj: Trajectory) -> List[int]:
        """Terminal verification failure (reward hub ``on_failure="abort"``):
        release the trajectory's protocol entry and publish clean ABORTED
        events instead of REWARDED.

        Grouped trajectories abort the *whole group*: the protocol entry
        lives at group granularity, and a group that can never reach
        ``group_size`` rewarded members would leave its buffer entry
        Reserved forever (training stalls on a stuck entry). Mirrors the
        group-filter abort path in ``on_trajectory_rewarded``.

        Idempotent under concurrency: a second worker aborting a sibling
        of an already-aborted group (or a trajectory consumed/aborted in
        the meantime) is a no-op — the ``ts.get`` / status gate runs under
        the coordinator lock, so at most one caller publishes the
        terminal events (tracer span conservation depends on this).
        Returns the aborted member IDs.
        """
        with self._lock:
            t = self.ts.get(traj.traj_id)
            if t is None or t.status in (
                TrajStatus.ABORTED, TrajStatus.CONSUMED
            ):
                return []
            # mark this thread as inside a routing decision: the ABORTED
            # events below wake streaming admission re-entrantly, and the
            # freed capacity is already visible to the next event/cycle
            prev = self._cycle_thread
            self._cycle_thread = threading.get_ident()
            try:
                key = self._protocol_key(traj)
                if traj.group_id >= 0 and self.groups is not None:
                    group = self.ts.groups.get(traj.group_id)
                    members = (
                        list(group.traj_ids) if group else [traj.traj_id]
                    )
                    self.manager.abort(key)  # idempotent on untracked keys
                    self._abort_members(members)
                    self.groups.forget(traj.group_id)
                    return members
                self.manager.abort(key)
                self._abort_members([traj.traj_id])
                return [traj.traj_id]
            finally:
                self._cycle_thread = prev

    def try_consume(
        self, min_fill: Optional[int] = None
    ) -> Optional[List[int]]:
        """Trainer-side Consume: returns the batch's trajectory IDs or None.

        For grouped entries the returned IDs are the *rewarded members* of
        each consumed group.

        ``min_fill`` enables streaming partial-batch consumption (see
        ``StalenessManager.consume``). Keys the manager had to drop while
        re-homing leftovers under the advanced train floor are aborted here
        — their payloads can never legally train, and under streaming the
        floor advances often enough that leaving them would leak TS
        registry slots and KV residency.
        """
        with self._lock:
            keys = self.manager.consume(min_fill)
            if keys is None:
                return None
            traj_ids: List[int] = []
            for key in keys:
                if key >= GroupBook.GROUP_KEY_BASE and self.groups is not None:
                    gid = key - GroupBook.GROUP_KEY_BASE
                    members = sorted(self.groups.rewarded_members(gid))
                    traj_ids.extend(members)
                    for tid in members:
                        self.lifecycle.consumed(tid)
                    self.groups.forget(gid)
                else:
                    traj_ids.append(key)
                    self.lifecycle.consumed(key)
            for key in self.manager.take_evicted():
                if key >= GroupBook.GROUP_KEY_BASE and self.groups is not None:
                    gid = key - GroupBook.GROUP_KEY_BASE
                    group = self.ts.groups.get(gid)
                    members = sorted(group.traj_ids) if group else []
                    self._abort_members(members)
                    self.groups.forget(gid)
                else:
                    self._abort_members([key])
            return traj_ids
