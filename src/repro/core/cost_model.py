"""Analytical rollout throughput model (paper §5.3 Eq. 2-4, Appendix B).

Per-decoding-step latency of instance *i*:

    L_i = k1 * kv_cache_i + max(k2, k3 * n_i) + k4        (Eq. 11)

* ``k1`` — inverse effective HBM bandwidth for KV reads (attention is
  memory-bound at decode);
* ``k2`` — parameter-read latency floor of the matmuls (memory-bound
  regime, small batch);
* ``k3`` — per-trajectory compute latency slope (compute-bound regime,
  ``n > k2/k3`` = the arithmetic-intensity threshold);
* ``k4`` — constant overhead (normalization, kernel launch, ...).

Throughput ``T_i = n_i / L_i`` (one token per running trajectory per step).
``k5`` is the per-token KV footprint (bytes); ``M`` the KV budget.

Coefficients come from offline profiling + linear regression
(``repro.benchmarks.bench_cost_model`` fits them for our JAX engine); the
paper's H20-profiled values for Qwen3-30B-A3B (Table 4) ship as a preset and
drive the discrete-event simulator.
"""
from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Sequence

from repro.core.snapshot import InstanceSnapshot


@dataclass(frozen=True)
class CostModel:
    k1: float   # s / byte of KV cache
    k2: float   # s, matmul memory-latency floor
    k3: float   # s / running trajectory (matmul compute slope)
    k4: float   # s, constant overhead
    k5: float   # bytes of KV per token
    kv_budget: float  # M, bytes
    # KV allocation granularity in tokens: 1 = dense per-token reservation
    # (legacy), >1 = paged engine with fixed-size blocks — footprints round
    # up to whole blocks so routing decisions see the engine's real
    # block-granular memory picture.
    block_size: int = 1
    # Routing penalty per pool preemption reported in the snapshot window:
    # a replica evicting residents is over-committed, so its marginal gain
    # is discounted by 1 / (1 + penalty * preemptions) — the coordinator
    # stops feeding a thrashing pool until it drains. 0 disables.
    preemption_penalty: float = 0.5
    # Devices per rollout instance (sharded backend: instance = pod). k5
    # stays the trajectory's total per-token footprint; every byte figure
    # the model produces or consumes (kv_bytes_for, snapshots' kv_cache,
    # kv_budget) is *per device* — the head-sharded pool spreads each
    # token's KV evenly, so per-device bytes are total / shard_count.
    shard_count: int = 1
    # Engine decode-slot cap (live engines run a fixed number of concurrent
    # sequences regardless of KV headroom — trajectories much shorter than
    # max_len would otherwise let the byte budget admit past the pool and
    # pile the excess into engine wait queues, whose presence then zeroes
    # every later marginal gain). 0 = unlimited (the simulator's pools
    # admit purely by byte budget).
    max_concurrency: int = 0

    def token_bytes(self, tokens: float) -> float:
        """Per-device bytes of ``tokens`` worth of KV."""
        return self.k5 * tokens / self.shard_count

    def kv_bytes_for(self, length: int) -> float:
        """Per-device bytes a trajectory of ``length`` tokens occupies on
        an instance (block-rounded under paging)."""
        if self.block_size <= 1:
            return self.token_bytes(length)
        return self.token_bytes(
            self.block_size * (-(-length // self.block_size))
        )

    # ------------------------------------------------- prefix-shared groups
    def shared_prefix_blocks(self, prompt_len: int) -> int:
        """Full prompt blocks a shared-prefix group stores once."""
        if self.block_size <= 1:
            return 0
        return prompt_len // self.block_size

    def group_kv_bytes_for(
        self, prompt_len: int, lengths: Sequence[int],
        *, undiverged: int = 0,
    ) -> float:
        """Per-device bytes a shared-prefix group occupies: the prompt's
        full blocks once, plus each member's exclusive blocks (private
        tail copy + response). Without paging there is no sharing — plain
        sum.

        ``undiverged`` (lazy CoW): the first that many members still share
        the group's single partial-tail block — charged once — instead of
        each owning a private copy. 0 (the default) is the eager/worst-case
        view existing callers and admission decisions use."""
        if self.block_size <= 1:
            return self.token_bytes(float(sum(lengths)))
        n_full, tail = divmod(prompt_len, self.block_size)
        blocks = n_full + (1 if tail and undiverged > 0 else 0)
        for i, length in enumerate(lengths):
            excl = max(0, -(-length // self.block_size) - n_full)
            if tail and i < undiverged:
                excl = max(0, excl - 1)
            blocks += excl
        return self.token_bytes(self.block_size * blocks)

    # ----------------------------------------------------------------- Eq. 2
    def step_latency(self, kv_cache: float, n_run: int) -> float:
        return self.k1 * kv_cache + max(self.k2, self.k3 * n_run) + self.k4

    def throughput(self, s: InstanceSnapshot) -> float:
        n = s.n_run
        if n == 0:
            return 0.0
        return n / self.step_latency(s.kv_cache, n)

    # ----------------------------------------------------------------- Eq. 3
    def admit(self, s: InstanceSnapshot, length: int) -> bool:
        """gamma_i: can a routed trajectory of ``length`` run immediately?"""
        return (
            s.kv_cache + self.kv_bytes_for(length) <= self.kv_budget
            and s.n_wait == 0
            and (self.max_concurrency <= 0 or s.n_run < self.max_concurrency)
        )

    def with_routed(self, s: InstanceSnapshot, traj_id: int, length: int) -> InstanceSnapshot:
        """S' after routing ``traj_id`` (Eq. 3 state update)."""
        s2 = s.clone()
        if self.admit(s, length):
            s2.kv_cache = s.kv_cache + self.kv_bytes_for(length)
            s2.run_trajs = s.run_trajs | {traj_id}
        else:
            s2.wait_trajs = s.wait_trajs | {traj_id}
        s2.traj_lengths = dict(s.traj_lengths)
        s2.traj_lengths[traj_id] = length
        return s2

    def _preempt_discount(self, s: InstanceSnapshot) -> float:
        """1 / (1 + penalty * preemptions): discounts the gain of feeding a
        replica whose pool evicted residents in the last snapshot window."""
        if self.preemption_penalty <= 0.0 or s.preemptions <= 0:
            return 1.0
        return 1.0 / (1.0 + self.preemption_penalty * s.preemptions)

    def marginal_gain(self, s: InstanceSnapshot, length: int) -> float:
        """Delta T_i of routing a trajectory of ``length`` to instance ``s``,
        discounted by the instance's recent preemption thrash."""
        if not self.admit(s, length):
            return 0.0  # waits -> contributes no throughput
        n2 = s.n_run + 1
        t2 = n2 / self.step_latency(s.kv_cache + self.kv_bytes_for(length), n2)
        return (t2 - self.throughput(s)) * self._preempt_discount(s)

    # ------------------------------------------ Eq. 3, shared-prefix groups
    def admit_group(
        self, s: InstanceSnapshot, prompt_len: int, lengths: Sequence[int]
    ) -> bool:
        """Can a whole shared-prefix group run immediately on ``s``?"""
        return (
            s.kv_cache + self.group_kv_bytes_for(prompt_len, lengths)
            <= self.kv_budget
            and s.n_wait == 0
            and (
                self.max_concurrency <= 0
                or s.n_run + len(lengths) <= self.max_concurrency
            )
        )

    def with_routed_group(
        self,
        s: InstanceSnapshot,
        traj_ids: Sequence[int],
        prompt_len: int,
        lengths: Sequence[int],
    ) -> InstanceSnapshot:
        """S' after routing a shared-prefix group as one unit. The clone's
        prefix bookkeeping is updated so later in-cycle discards release the
        shared blocks once."""
        s2 = s.clone()
        s2.traj_lengths = dict(s.traj_lengths)
        if self.admit_group(s, prompt_len, lengths):
            s2.kv_cache = s.kv_cache + self.group_kv_bytes_for(
                prompt_len, lengths
            )
            s2.run_trajs = s.run_trajs | set(traj_ids)
            if self.shared_prefix_blocks(prompt_len) > 0:
                # synthetic cycle-local key, below any existing key so a
                # discard-then-route sequence can never collide
                pk = min(s2.prefix_groups, default=0) - 1
                s2.prefix_groups[pk] = set(traj_ids)
                s2.prefix_tokens[pk] = (
                    self.shared_prefix_blocks(prompt_len) * self.block_size
                )
        else:
            s2.wait_trajs = s.wait_trajs | set(traj_ids)
        for tid, length in zip(traj_ids, lengths):
            s2.traj_lengths[tid] = length
        return s2

    def group_marginal_gain(
        self, s: InstanceSnapshot, prompt_len: int, lengths: Sequence[int]
    ) -> float:
        """Delta T_i of routing a whole shared-prefix group to ``s``."""
        if not self.admit_group(s, prompt_len, lengths):
            return 0.0
        n2 = s.n_run + len(lengths)
        t2 = n2 / self.step_latency(
            s.kv_cache + self.group_kv_bytes_for(prompt_len, lengths), n2
        )
        return (t2 - self.throughput(s)) * self._preempt_discount(s)

    # ----------------------------------------------------------------- Eq. 4
    def ideal_gain(self, length: int) -> float:
        """Delta T_ideal: gain of routing to a fully idle instance."""
        return 1.0 / (
            self.k1 * self.kv_bytes_for(length)
            + max(self.k2, self.k3 * 1) + self.k4
        )

    def group_ideal_gain(
        self, prompt_len: int, lengths: Sequence[int]
    ) -> float:
        """Delta T_ideal of a shared-prefix group on a fully idle instance."""
        g = len(lengths)
        return g / (
            self.k1 * self.group_kv_bytes_for(prompt_len, lengths)
            + max(self.k2, self.k3 * g) + self.k4
        )

    def scaled(self, **kw) -> "CostModel":
        return replace(self, **kw)


# Paper Table 4: H20-profiled coefficients for Qwen3-30B-A3B. k5/budget are
# derived from the model shape (48 KV-cache bytes/token/layer group at bf16)
# and the H20's 96 GB HBM with ~60% allocatable to KV.
PAPER_H20_QWEN3_30B = CostModel(
    k1=7.28e-8 / 1e6,   # Table 4 value is per-MB; normalize to per-byte
    k2=1.72e-3,
    k3=1.25e-4,
    k4=1.07e-2,
    k5=2 * 48 * 128 * 4 * 2,          # layers*hd*kv_heads*2(bf16) per token
    kv_budget=60e9,
)


def fit_coefficients(samples, k5: float, kv_budget: float) -> CostModel:
    """Least-squares fit of (k1, k2, k3, k4) from profiled samples.

    ``samples``: iterable of (kv_cache_bytes, n_run, step_latency_s). The
    max() kink makes this piecewise-linear; we fit the two regimes split at
    the empirical knee (Appendix B: n > k2/k3 is compute-bound) by scanning
    candidate knees and keeping the best residual.
    """
    import numpy as np

    data = np.asarray(list(samples), dtype=np.float64)
    if len(data) < 4:
        raise ValueError("need >= 4 profiling samples")
    kv, n, lat = data[:, 0], data[:, 1], data[:, 2]
    best = None
    for knee in sorted(set(n)):
        mem = n <= knee  # memory-bound side: L = k1*kv + k2 + k4
        cmp_ = ~mem      # compute-bound side: L = k1*kv + k3*n + k4
        # joint LS: unknowns [k1, k2+k4 (b_mem), k3, k4]
        a = np.zeros((len(data), 4))
        a[:, 0] = kv
        a[mem, 1] = 1.0
        a[cmp_, 2] = n[cmp_]
        a[cmp_, 3] = 1.0
        coef, res, *_ = np.linalg.lstsq(a, lat, rcond=None)
        pred = a @ coef
        ss = float(np.sum((pred - lat) ** 2))
        if best is None or ss < best[0]:
            best = (ss, coef)
    _, coef = best
    k1 = max(coef[0], 1e-15)
    k4 = max(coef[3], 0.0)
    k2 = max(coef[1] - k4, 1e-9)
    k3 = max(coef[2], 1e-12)
    return CostModel(k1=k1, k2=k2, k3=k3, k4=k4, k5=k5, kv_budget=kv_budget)
