"""Trajectory lifecycle event bus — the single write path for trajectory
state (paper §5.1, Fig. 6 data flow as a *service* boundary).

Before this module existed, trajectory lifecycle state was smeared across
four hand-synchronized owners: ``TrajectoryServer`` status fields, the
``StalenessManager`` (via coordinator calls), the coordinator's speculative
state, and the runtime's private retired-payload dict. Every new consumer
(reward workers, a threaded trainer, telemetry) had to be spliced into each
call site by hand.

Now there is ONE typed event stream::

    ROUTED -> (INTERRUPTED ->)* COMPLETED -> REWARDED -> CONSUMED
                                                      \\-> ABORTED

and every party *subscribes*:

* the TS applies payload/status transitions (``TrajectoryServer.attach``),
* the coordinator runs protocol Occupy / surplus aborts / speculative-state
  fixups off ``REWARDED`` and ``ABORTED`` (on behalf of the
  ``StalenessManager`` it owns),
* ``RetiredPayloadStore`` (below) retains rewarded payloads until training
  consumes them — and, unlike the old private dict, drops payloads of
  group-filtered members on ``ABORTED`` instead of leaking them,
* the ``RewardServer`` scores off ``COMPLETED`` and publishes ``REWARDED``,
* schedulers/benchmarks read the per-kind counters for telemetry.

Dispatch is synchronous and reentrant (emitting from inside a handler is
allowed — surplus aborts cascade off ``REWARDED``) and runs in the
emitter's thread *without* a global bus lock, so the cooperative scheduler
sees exactly the old deterministic call ordering while threaded services
emit concurrently; cross-thread consistency is the subscribers' own locks
(TS, coordinator, stores), never the bus's.
"""
from __future__ import annotations

import enum
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional

from repro.analysis.witness import make_lock, make_rlock, on_emit
from repro.core.types import Trajectory


class LifecycleEventKind(enum.Enum):
    """The six trajectory-lifecycle transitions (one per ``TrajStatus``
    edge that crosses a service boundary)."""

    ROUTED = "routed"            # TS -> instance (Route executed)
    INTERRUPTED = "interrupted"  # instance -> TS (partial rollout / failure)
    COMPLETED = "completed"      # generation finished, awaiting reward
    REWARDED = "rewarded"        # reward landed -> protocol Occupy
    CONSUMED = "consumed"        # retired by a training Consume
    ABORTED = "aborted"          # discarded (surplus / filtering / restart)


@dataclass(frozen=True)
class LifecycleEvent:
    """One lifecycle transition.

    ``traj`` carries the payload when the emitter holds it; ``traj_id`` is
    always set. ``inst`` is the instance that already applied the data-plane
    side of the transition (command execution), or ``None`` for
    protocol-initiated events whose data-plane cleanup is a *subscriber's*
    job (e.g. surplus aborts fan out to every instance).
    """

    kind: LifecycleEventKind
    traj_id: int
    traj: Optional[Trajectory] = None
    inst: Optional[int] = None
    version: Optional[int] = None


Subscriber = Callable[[LifecycleEvent], None]


class TrajectoryLifecycle:
    """Typed pub/sub bus over :class:`LifecycleEvent`.

    Subscribers for a kind run in registration order, synchronously, in the
    emitter's thread — event ordering IS the old call ordering, which is
    what keeps the cooperative scheduler bit-for-bit deterministic.
    """

    def __init__(self) -> None:
        self._subs: Dict[LifecycleEventKind, List[Subscriber]] = {
            k: [] for k in LifecycleEventKind
        }
        self._lock = make_rlock("lifecycle")
        self.counts: Dict[LifecycleEventKind, int] = {
            k: 0 for k in LifecycleEventKind
        }

    def subscribe(
        self, kind: LifecycleEventKind, fn: Subscriber
    ) -> Subscriber:
        with self._lock:
            self._subs[kind].append(fn)
        return fn

    def subscribe_many(
        self, kinds: List[LifecycleEventKind], fn: Subscriber
    ) -> Subscriber:
        """Subscribe one handler to several kinds (event-driven scheduler
        wakeups, benchmark latency probes). Unsubscribe per kind."""
        for kind in kinds:
            self.subscribe(kind, fn)
        return fn

    def unsubscribe(self, kind: LifecycleEventKind, fn: Subscriber) -> None:
        with self._lock:
            if fn in self._subs[kind]:
                self._subs[kind].remove(fn)

    def unsubscribe_many(
        self, kinds: List[LifecycleEventKind], fn: Subscriber
    ) -> None:
        for kind in kinds:
            self.unsubscribe(kind, fn)

    def emit(self, event: LifecycleEvent) -> None:
        # The bus lock guards only the subscriber table and counters —
        # dispatch runs OUTSIDE it, in the emitter's thread. Holding a
        # global bus lock across handlers would order it against the
        # domain locks handlers take (coordinator, instances) and deadlock
        # the moment two services emit concurrently; instead, mutual
        # exclusion is the subscribers' own responsibility (every stateful
        # subscriber here is internally locked), and per-emitter event
        # order is preserved because dispatch is synchronous.
        with self._lock:
            self.counts[event.kind] += 1
            # snapshot: a handler may subscribe/unsubscribe re-entrantly
            subs = list(self._subs[event.kind])
        # lock-order witness hook: dispatching while holding any lock
        # outside the emit-safe coordinator prefix is the PR 5 deadlock
        # shape and gets reported with the offending stack
        on_emit(event.kind.value)
        for fn in subs:
            fn(event)

    # ------------------------------------------------- typed emit shorthands
    def routed(
        self, traj: Trajectory, inst: int, version: Optional[int] = None
    ) -> None:
        self.emit(LifecycleEvent(
            LifecycleEventKind.ROUTED, traj.traj_id, traj, inst, version
        ))

    def interrupted(
        self, traj: Trajectory, inst: Optional[int] = None
    ) -> None:
        self.emit(LifecycleEvent(
            LifecycleEventKind.INTERRUPTED, traj.traj_id, traj, inst
        ))

    def completed(self, traj: Trajectory, inst: Optional[int] = None) -> None:
        self.emit(LifecycleEvent(
            LifecycleEventKind.COMPLETED, traj.traj_id, traj, inst
        ))

    def rewarded(self, traj: Trajectory) -> None:
        self.emit(LifecycleEvent(
            LifecycleEventKind.REWARDED, traj.traj_id, traj
        ))

    def consumed(self, traj_id: int) -> None:
        self.emit(LifecycleEvent(LifecycleEventKind.CONSUMED, traj_id))

    def aborted(
        self,
        traj_id: int,
        traj: Optional[Trajectory] = None,
        inst: Optional[int] = None,
    ) -> None:
        self.emit(LifecycleEvent(
            LifecycleEventKind.ABORTED, traj_id, traj, inst
        ))


class RetiredPayloadStore:
    """Rewarded-payload retention, as a bus subscriber.

    ``consume`` retires trajectories from the TS registry, but training
    still needs their token payloads to build the batch. The store holds
    every ``REWARDED`` payload until the trainer ``take``s it — and evicts
    on ``ABORTED`` so group-filtered members (rewarded, then thrown away
    whole-group) no longer leak, which the runtime's old private
    ``_retired`` dict silently did.
    """

    def __init__(self, lifecycle: TrajectoryLifecycle):
        self._lock = make_lock("retired")
        self._store: Dict[int, Trajectory] = {}
        lifecycle.subscribe(LifecycleEventKind.REWARDED, self._on_rewarded)
        lifecycle.subscribe(LifecycleEventKind.ABORTED, self._on_aborted)

    def _on_rewarded(self, e: LifecycleEvent) -> None:
        from repro.core.types import TrajStatus

        # a trajectory aborted while its completion sat in the reward
        # queue must not re-enter the store after its eviction fired
        if e.traj is not None and e.traj.status != TrajStatus.ABORTED:
            with self._lock:
                self._store[e.traj_id] = e.traj

    def _on_aborted(self, e: LifecycleEvent) -> None:
        with self._lock:
            self._store.pop(e.traj_id, None)

    def take(self, traj_ids: List[int]) -> List[Trajectory]:
        """Claim consumed payloads (missing IDs are skipped, matching the
        old ``pop-if-present`` semantics under filtering races)."""
        with self._lock:
            return [
                self._store.pop(tid)
                for tid in traj_ids
                if tid in self._store
            ]

    def clear(self) -> None:
        with self._lock:
            self._store.clear()

    def __len__(self) -> int:
        with self._lock:
            return len(self._store)

    def ids(self) -> List[int]:
        with self._lock:
            return list(self._store)

    def payloads(self) -> Dict[int, Trajectory]:
        """Snapshot view (test/benchmark introspection)."""
        with self._lock:
            return dict(self._store)
