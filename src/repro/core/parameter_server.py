"""Parameter server (PS) — middleware between training and rollout (§5.1,
Appendix A).

* Versioned parameter store with database-style read-write locking: Push
  (exclusive write) blocks Pulls; concurrent Pulls (shared reads) proceed
  together.
* Push is triggered by training workers right after a step and is meant to
  overlap the next training step (the runtime pushes from a background
  thread; correctness only requires Push to land before the *next* Push).
* Load-balancing communication planning (Appendix A.2): each parameter
  slice may come from several candidate senders; the planner greedily
  assigns each required transfer to the sender with the smallest
  accumulated estimated latency. The plan is static and reused for every
  subsequent Push/Pull.

On the TPU target the Pull path maps to ICI/PCIe-local replicas (PS workers
co-located with rollout hosts, Appendix A.1) while Push crosses DCN; the
planner is parameterized by a bandwidth function so both fabrics are
modeled. The same planner drives the simulator's sync-overhead accounting
and the ``bench_sync_overhead`` benchmark.
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Sequence, Tuple

from repro.analysis.witness import make_condition


class ReadWriteLock:
    """Writer-preference RW lock (Pull = shared read, Push = exclusive write)."""

    def __init__(self) -> None:
        self._cond = make_condition("ps")
        self._readers = 0
        self._writer = False
        self._writers_waiting = 0

    def acquire_read(self) -> None:
        with self._cond:
            while self._writer or self._writers_waiting:
                self._cond.wait()
            self._readers += 1

    def release_read(self) -> None:
        with self._cond:
            self._readers -= 1
            if self._readers == 0:
                self._cond.notify_all()

    def acquire_write(self) -> None:
        with self._cond:
            self._writers_waiting += 1
            while self._writer or self._readers:
                self._cond.wait()
            self._writers_waiting -= 1
            self._writer = True

    def release_write(self) -> None:
        with self._cond:
            self._writer = False
            self._cond.notify_all()

    class _Read:
        def __init__(self, lock: "ReadWriteLock"):
            self.lock = lock

        def __enter__(self):
            self.lock.acquire_read()

        def __exit__(self, *exc):
            self.lock.release_read()

    class _Write:
        def __init__(self, lock: "ReadWriteLock"):
            self.lock = lock

        def __enter__(self):
            self.lock.acquire_write()

        def __exit__(self, *exc):
            self.lock.release_write()

    def read(self) -> "_Read":
        return self._Read(self)

    def write(self) -> "_Write":
        return self._Write(self)


class ParameterServer:
    """Versioned latest-parameter store with RW-locked Push/Pull."""

    def __init__(self, n_workers: int = 1):
        self.n_workers = n_workers
        self._rw = ReadWriteLock()
        self._params: Any = None
        self._version = -1
        # telemetry
        self.push_count = 0
        self.pull_count = 0

    @property
    def version(self) -> int:
        with self._rw.read():
            return self._version

    def push(self, params: Any, version: int) -> None:
        with self._rw.write():
            if version <= self._version:
                return  # stale push (restart races) — keep the newer one
            self._params = params
            self._version = version
            self.push_count += 1

    def pull(self) -> Tuple[Any, int]:
        with self._rw.read():
            self.pull_count += 1
            return self._params, self._version


class BackgroundPusher:
    """Background Push worker: training hands off ``(params, version)`` and
    immediately starts the next step; a dedicated thread lands the Push on
    the PS — the overlap the module docstring promises, made real by the
    threaded scheduler (and demonstrable standalone via ``launch.train
    --ps-push``).

    Correctness needs only FIFO delivery (Push k lands before Push k+1),
    which a single worker draining a queue guarantees; the PS additionally
    drops stale versions, so even a restart-raced pusher cannot regress the
    published version.
    """

    def __init__(self, ps: ParameterServer, *, tracer=None, metrics=None):
        import queue

        self.ps = ps
        self._queue: "queue.Queue" = queue.Queue()
        self._thread = threading.Thread(
            target=self._loop, name="ps-push", daemon=True
        )
        self._started = False
        self.pushes = 0
        self.errors = 0
        self._tracer = tracer
        self._m_pushes = (
            metrics.counter("ps_background_pushes")
            if metrics is not None else None
        )

    def start(self) -> "BackgroundPusher":
        if not self._started:
            self._started = True
            self._thread.start()
        return self

    def push(self, params: Any, version: int) -> None:
        """Enqueue a Push; returns immediately (training overlaps it)."""
        if not self._started:
            self.ps.push(params, version)  # degenerate synchronous mode
            return
        self._queue.put((params, version))

    def _loop(self) -> None:
        while True:
            item = self._queue.get()
            try:
                if item is None:
                    return
                params, version = item
                try:
                    t0 = time.perf_counter()
                    self.ps.push(params, version)
                    self.pushes += 1
                    if self._m_pushes is not None:
                        self._m_pushes.inc()
                    if self._tracer is not None:
                        self._tracer.activity(
                            "push", t0, time.perf_counter(),
                            args={"version": version},
                        )
                except Exception as exc:  # keep the push thread alive:
                    self.errors += 1      # a dead pusher hangs flush/stop
                    if self.errors == 1:  # and freezes the PS version
                        print(f"[BackgroundPusher] WARNING: push of "
                              f"version {version} raised {exc!r}",
                              flush=True)
            finally:
                self._queue.task_done()

    def flush(self) -> None:
        """Block until every enqueued Push has landed."""
        if self._started:
            self._queue.join()

    def stop(self) -> None:
        if self._started:
            self._queue.join()
            self._queue.put(None)
            self._thread.join(timeout=5.0)
            self._started = False


# --------------------------------------------------------------------- plan
@dataclass(frozen=True)
class Transfer:
    slice_name: str
    nbytes: int
    sender: str
    receiver: str
    est_latency: float


@dataclass
class CommPlan:
    transfers: List[Transfer] = field(default_factory=list)

    def per_sender_latency(self) -> Dict[str, float]:
        acc: Dict[str, float] = {}
        for t in self.transfers:
            acc[t.sender] = acc.get(t.sender, 0.0) + t.est_latency
        return acc

    @property
    def makespan(self) -> float:
        """Senders transmit concurrently; total time = max accumulated latency."""
        lat = self.per_sender_latency()
        return max(lat.values()) if lat else 0.0

    @property
    def total_bytes(self) -> int:
        return sum(t.nbytes for t in self.transfers)


def plan_transfers(
    required: Sequence[Tuple[str, int, str, Sequence[str]]],
    bandwidth: Callable[[str, str], float],
    fixed_latency: float = 1e-4,
) -> CommPlan:
    """Appendix A.2 greedy load-balancing planner.

    ``required``: per transfer ``(slice_name, nbytes, receiver,
    candidate_senders)``. Estimated latency of assigning a slice to a sender
    is ``nbytes / bandwidth(sender, receiver) + fixed_latency``; the planner
    picks, per slice, the candidate sender with the smallest *accumulated*
    latency so far (greedy bottleneck minimization). The resulting plan is
    static — reused for every subsequent Push/Pull (paper: 'kept static and
    reused').
    """
    acc: Dict[str, float] = {}
    transfers: List[Transfer] = []
    # largest slices first: classic LPT greedy gives a tighter makespan
    order = sorted(range(len(required)), key=lambda i: -required[i][1])
    for i in order:
        name, nbytes, receiver, senders = required[i]
        if not senders:
            raise ValueError(f"slice {name!r} has no candidate sender")
        best, best_cost = None, None
        for s in senders:
            est = nbytes / bandwidth(s, receiver) + fixed_latency
            cost = acc.get(s, 0.0) + est
            if best_cost is None or cost < best_cost:
                best, best_cost, best_est = s, cost, est
        acc[best] = acc.get(best, 0.0) + best_est
        transfers.append(Transfer(name, nbytes, best, receiver, best_est))
    return CommPlan(transfers)


def replicated_pull_plan(
    slice_sizes: Dict[str, int],
    n_rollout_hosts: int,
    *,
    local_bw: float = 64e9,     # PCIe DMA / same-host path (App. A.1 Pull)
) -> CommPlan:
    """Fully-replicated PS deployment (Fig. 20 right): every rollout host
    pulls from its co-located PS worker over the local fabric."""
    required = []
    for h in range(n_rollout_hosts):
        for name, nbytes in slice_sizes.items():
            required.append((f"{name}@host{h}", nbytes, f"rollout{h}", [f"ps{h}"]))
    return plan_transfers(required, lambda s, r: local_bw)


def sharded_push_plan(
    slice_sizes: Dict[str, int],
    train_holders: Dict[str, Sequence[str]],
    n_ps_workers: int,
    *,
    cross_bw: float = 25e9,     # RDMA / DCN path (App. A.1 Push)
) -> CommPlan:
    """Push: each PS worker (replica holder) needs every slice; candidate
    senders are the training workers holding that slice (DP replicas)."""
    required = []
    for w in range(n_ps_workers):
        for name, nbytes in slice_sizes.items():
            required.append(
                (f"{name}->ps{w}", nbytes, f"ps{w}", list(train_holders[name]))
            )
    return plan_transfers(required, lambda s, r: cross_bw)
