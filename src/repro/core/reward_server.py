"""Reward server — the paper's third disaggregated phase (§2.1, Fig. 6).

The paper's architecture runs rollout, *reward*, and training as
independently-scaled services against the data servers. The seed runtime
scored rewards inline inside the rollout loop; this module promotes reward
to a first-class service on the trajectory-lifecycle bus:

* it subscribes to ``COMPLETED`` events and, once a score lands, publishes
  ``REWARDED`` — downstream protocol Occupy, retired-payload retention, and
  surplus aborts all hang off that event, not off the caller;
* **inline mode** (default, the cooperative scheduler): scoring runs
  synchronously inside the ``COMPLETED`` dispatch, preserving the seed
  runtime's deterministic ordering bit-for-bit;
* **threaded mode** (``start()``, the threaded scheduler): completions land
  in a bounded queue and a worker pool scores them concurrently with decode
  and training — the disaggregation the paper's Fig. 6 promises. Back
  pressure is real: a full queue blocks the submitting instance thread, so
  rollout cannot outrun verification unboundedly.

The verifier is pluggable: anything with ``score(prompt_ids, response_ids)
-> float`` (``repro.reward.verifier.RewardModel``, or a bare callable via
``FnVerifier``); verifiers that care about routing expose
``score_trajectory(traj)`` instead and the server prefers it — this is
how a ``repro.reward.RewardHub`` (per-task routing to remote/sandboxed
verifiers) drops in. ``simulated_latency`` models slow verifiers so
overlap behavior is observable in benchmarks.

Failure contract (the hub's tentpole invariant): scoring a completion
must end in **exactly one** terminal disposition — REWARDED (real or
fallback score), a clean ABORTED through ``on_abort`` (the hub raised
``VerificationAbort``), or a counted drop (liveness/shutdown). No
exception may escape ``_score``: a worker thread dying silently would
shrink the pool for the rest of the run, and an unscored trajectory
would leave its staleness entry Reserved forever (buffer stuck, training
stalls). Worker-side exceptions are counted in ``worker_errors`` and
mirrored to the ``reward_worker_errors`` metric.
"""
from __future__ import annotations

import queue
import threading
import time
from dataclasses import dataclass
from typing import Callable, List, Optional, Sequence

from repro.analysis.witness import make_lock
from repro.core.lifecycle import (
    LifecycleEvent,
    LifecycleEventKind,
    TrajectoryLifecycle,
)
from repro.core.types import Trajectory
from repro.obs.stats import Ring, percentiles
from repro.reward.retry import VerificationAbort


class FnVerifier:
    """Adapt a bare ``(prompt_ids, response_ids) -> float`` callable to the
    verifier protocol."""

    def __init__(self, fn: Callable[[List[int], List[int]], float]):
        self._fn = fn

    def score(self, prompt_ids: List[int], response_ids: List[int]) -> float:
        return self._fn(prompt_ids, response_ids)


@dataclass
class RewardServerConfig:
    n_workers: int = 2
    queue_capacity: int = 256        # bounded: full queue back-pressures rollout
    simulated_latency: float = 0.0   # seconds per score (slow-verifier model)
    max_latency_samples: int = 4096  # telemetry ring size


class RewardServer:
    """Bounded-queue + worker-pool reward phase on the lifecycle bus."""

    def __init__(
        self,
        verifier,
        lifecycle: TrajectoryLifecycle,
        cfg: Optional[RewardServerConfig] = None,
        *,
        clock: Callable[[], float] = time.perf_counter,
        liveness: Optional[Callable[[Trajectory], bool]] = None,
        metrics=None,
        tracer=None,
        on_abort: Optional[Callable[[Trajectory], object]] = None,
    ):
        self.verifier = verifier
        self.lifecycle = lifecycle
        self.cfg = cfg or RewardServerConfig()
        self._clock = clock
        # terminal verification failure (hub on_failure="abort"): called
        # instead of publishing REWARDED. The runtime wires the
        # coordinator's abort_unverifiable (protocol release + group-wide
        # ABORTED); standalone use defaults to a bare ABORTED event.
        self._on_abort = on_abort
        # observability (optional): submit->rewarded latency histogram on
        # the registry, per-score activity spans on the tracer's
        # reward-worker track
        self._m_latency = (
            metrics.histogram("reward_submit_to_rewarded_s")
            if metrics is not None else None
        )
        self._m_worker_errors = (
            metrics.counter("reward_worker_errors")
            if metrics is not None else None
        )
        self._tracer = tracer
        # liveness gate re-checked at scoring time: a trajectory aborted
        # (surplus/filtering) while sitting in the queue is dropped, not
        # scored — without this, threaded mode would publish REWARDED for
        # dead work and re-insert evicted payloads into the retired store
        self._liveness = liveness
        self._queue: "queue.Queue" = queue.Queue(
            maxsize=max(1, self.cfg.queue_capacity)
        )
        self._workers: List[threading.Thread] = []
        self._running = False
        self._lock = make_lock("reward")
        self._stopped = False            # post-shutdown completions dropped
        # telemetry
        self.submitted = 0
        self.scored = 0
        self.errors = 0                  # verifier exceptions (scored as 0.0)
        self.aborted = 0                 # VerificationAbort -> clean ABORTED
        self.worker_errors = 0           # exceptions past the scoring guard
        self.dropped = 0                 # aborted-while-queued / shutdown
        self.score_time = 0.0            # seconds spent inside the verifier
        # submit -> rewarded seconds, true ring buffer: once full, the
        # oldest samples are overwritten so percentiles track steady state
        # (not warm-up) on long runs
        self._latencies = Ring(self.cfg.max_latency_samples)
        lifecycle.subscribe(LifecycleEventKind.COMPLETED, self._on_completed)

    # ----------------------------------------------------------- lifecycle
    @property
    def threaded(self) -> bool:
        return self._running

    def start(self) -> None:
        """Switch to threaded mode: spawn the worker pool."""
        with self._lock:
            if self._running:
                return
            self._running = True
            self._stopped = False
        for i in range(max(1, self.cfg.n_workers)):
            t = threading.Thread(
                target=self._worker_loop, name=f"reward-{i}", daemon=True
            )
            t.start()
            self._workers.append(t)

    def stop(self, drain: bool = True) -> None:
        """Stop the pool; with ``drain`` the queue is emptied first."""
        with self._lock:
            if not self._running:
                return
        if drain:
            self._queue.join()
        with self._lock:
            self._running = False
            self._stopped = True
        for _ in self._workers:
            self._queue.put(None)  # wake sentinels
        for t in self._workers:
            t.join(timeout=5.0)
        self._workers = []
        # flush leftovers (sentinels + any completions still queued when
        # drain=False): nothing gets scored after shutdown — the runtime
        # is mid-teardown and a late REWARDED would drive protocol
        # cascades on stopped services; the work is simply dropped
        while True:
            try:
                item = self._queue.get_nowait()
            except queue.Empty:
                break
            self._queue.task_done()
            if item is not None:
                with self._lock:
                    self.dropped += 1

    def drain(self, timeout: float = 30.0) -> bool:
        """Block until every submitted completion has been scored."""
        deadline = self._clock() + timeout
        while self._clock() < deadline:
            with self._lock:
                done = self.scored + self.dropped + self.aborted
                if done >= self.submitted:
                    return True
            time.sleep(0.001)
        return False

    # -------------------------------------------------------------- intake
    def _on_completed(self, e: LifecycleEvent) -> None:
        assert e.traj is not None, "COMPLETED events must carry the payload"
        with self._lock:
            self.submitted += 1
            running = self._running
            stopped = self._stopped
        if stopped:
            # a straggler decode thread that outlived shutdown: never score
            # into torn-down services (the inline fallback below is for the
            # cooperative scheduler, not post-stop zombies)
            with self._lock:
                self.dropped += 1
            return
        if running:
            self._queue.put((e.traj, self._clock()))  # blocks when full
        else:
            self._score(e.traj, self._clock())

    # ------------------------------------------------------------- scoring
    def _call_verifier(self, traj: Trajectory) -> float:
        """Dispatch to the verifier: routing-aware verifiers (the reward
        hub) take the whole trajectory; plain ones the token lists."""
        fn = getattr(self.verifier, "score_trajectory", None)
        if fn is not None:
            return fn(traj)
        return self.verifier.score(list(traj.prompt), list(traj.response))

    def _count_worker_error(self, where: str, exc: BaseException) -> None:
        with self._lock:
            self.worker_errors += 1
            first = self.worker_errors == 1
        if self._m_worker_errors is not None:
            self._m_worker_errors.inc()
        if first:
            print(f"[RewardServer] WARNING: {where} raised {exc!r}; "
                  f"worker kept alive (further errors counted silently)",
                  flush=True)

    def _abort(self, traj: Trajectory) -> None:
        """Publish the clean-ABORTED disposition for an unverifiable
        trajectory. Must not raise into the worker loop."""
        try:
            if self._on_abort is not None:
                self._on_abort(traj)
            else:
                self.lifecycle.aborted(traj.traj_id, traj)
        except Exception as exc:
            self._count_worker_error("abort dispatch", exc)

    def _score(self, traj: Trajectory, t_submit: float) -> None:
        """Score one completion. Never raises: every path ends in exactly
        one disposition — REWARDED, ABORTED (via ``_abort``), or a counted
        drop — and worker threads survive any verifier/subscriber bug."""
        try:
            live = self._liveness is None or self._liveness(traj)
        except Exception as exc:
            # a liveness probe that raises must not strand the completion
            # in limbo: treat it as dead (the abort path already ran or
            # will run for it; scoring into torn-down state is worse)
            self._count_worker_error("liveness probe", exc)
            live = False
        if not live:
            with self._lock:
                self.dropped += 1
            return
        t0 = self._clock()
        if self.cfg.simulated_latency > 0.0:
            time.sleep(self.cfg.simulated_latency)
        abort_exc: Optional[BaseException] = None
        try:
            traj.reward = self._call_verifier(traj)
        except VerificationAbort as exc:
            abort_exc = exc
        except Exception as exc:  # pluggable verifier: stay alive
            # score as 0.0 and keep the protocol flowing — an unscored
            # trajectory would leave its staleness entry Reserved forever
            # (buffer Stuck, training stalls)
            traj.reward = 0.0
            with self._lock:
                self.errors += 1
                first = self.errors == 1
            if first:
                print(f"[RewardServer] WARNING: verifier raised {exc!r}; "
                      f"scoring 0.0 (further errors counted silently)",
                      flush=True)
        now = self._clock()
        with self._lock:
            self.score_time += now - t0
            if abort_exc is None:
                self.scored += 1
            else:
                self.aborted += 1
        self._latencies.append(now - t_submit)
        if self._m_latency is not None:
            self._m_latency.observe(now - t_submit)
        if self._tracer is not None:
            self._tracer.activity(
                "score", t0, now,
                args={"traj": traj.traj_id,
                      "outcome": "abort" if abort_exc else "ok"},
            )
        if abort_exc is not None:
            self._abort(traj)
            return
        try:
            self.lifecycle.rewarded(traj)
        except Exception as exc:
            # a downstream REWARDED subscriber raised mid-dispatch: count
            # it and keep the worker; the bug is in the subscriber, and a
            # dead pool would turn one bad event into a stalled run
            self._count_worker_error("REWARDED dispatch", exc)

    def _worker_loop(self) -> None:
        while True:
            item = self._queue.get()
            try:
                if item is None:
                    return
                try:
                    self._score(*item)
                except Exception as exc:  # belt and braces: _score already
                    self._count_worker_error("scoring", exc)  # guards
            finally:
                self._queue.task_done()

    # ----------------------------------------------------------- telemetry
    def queue_depth(self) -> int:
        return self._queue.qsize()

    def alive_workers(self) -> int:
        """Worker threads still running (pool-shrink regression probe)."""
        return sum(1 for t in self._workers if t.is_alive())

    def latency_percentiles(
        self, qs: Sequence[float] = (0.5, 0.95, 0.99)
    ) -> dict:
        """Submit->rewarded latency percentiles, seconds. ``{q: None}`` when
        nothing has been scored yet."""
        return percentiles(self._latencies.values(), qs)

    def stats(self) -> dict:
        with self._lock:
            return {
                "submitted": self.submitted,
                "scored": self.scored,
                "errors": self.errors,
                "aborted": self.aborted,
                "worker_errors": self.worker_errors,
                "dropped": self.dropped,
                "queue_depth": self._queue.qsize(),
                "score_time_s": self.score_time,
                "threaded": self._running,
            }
