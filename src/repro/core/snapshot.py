"""Per-instance system snapshots (paper §5.2, Fig. 11).

A snapshot ``S`` aggregates five fields per rollout instance:
``kv_cache`` (bytes of KV cache in use), ``run_trajs``, ``wait_trajs``,
``complete_trajs`` (completed since last sync) and ``inst_version``.

Snapshots are *plain data*: strategies and the coordinator operate on them
functionally, which keeps the control plane unit-testable without any
rollout engine attached.
"""
from __future__ import annotations

import copy
from dataclasses import dataclass, field
from typing import Dict, Iterable, Set


@dataclass
class InstanceSnapshot:
    inst_id: int
    kv_cache: float = 0.0                      # bytes in use
    run_trajs: Set[int] = field(default_factory=set)
    wait_trajs: Set[int] = field(default_factory=set)
    complete_trajs: Set[int] = field(default_factory=set)
    inst_version: int = 0
    # per-trajectory current lengths (tokens) — used by the cost model to
    # estimate KV footprints of routed/migrated trajectories. Not one of the
    # paper's five fields but carried alongside in every real system.
    traj_lengths: Dict[int, int] = field(default_factory=dict)

    @property
    def n_run(self) -> int:
        return len(self.run_trajs)

    @property
    def n_wait(self) -> int:
        return len(self.wait_trajs)

    def resident(self) -> Set[int]:
        return self.run_trajs | self.wait_trajs

    def discard(
        self,
        traj_ids: Iterable[int],
        bytes_per_token: float = 0.0,
        block_size: int = 1,
    ) -> None:
        """Remove trajectories from run/wait (post-Interrupt bookkeeping).

        ``bytes_per_token`` (the cost model's k5) releases their estimated
        KV footprint; lengths are tracked in tokens. ``block_size`` > 1
        rounds the released footprint up to whole KV blocks, matching the
        paged engine's block-granular accounting.
        """
        ids = set(traj_ids)
        for t in ids & self.run_trajs:
            length = self.traj_lengths.get(t, 0)
            if block_size > 1:
                length = block_size * (-(-length // block_size))
            self.kv_cache = max(
                0.0, self.kv_cache - bytes_per_token * length
            )
        self.run_trajs -= ids
        self.wait_trajs -= ids
        for t in ids:
            self.traj_lengths.pop(t, None)

    def clone(self) -> "InstanceSnapshot":
        return copy.deepcopy(self)


Snapshot = Dict[int, InstanceSnapshot]


def clone_snapshot(s: Snapshot) -> Snapshot:
    return {i: inst.clone() for i, inst in s.items()}
