"""Per-instance system snapshots (paper §5.2, Fig. 11).

A snapshot ``S`` aggregates five fields per rollout instance:
``kv_cache`` (bytes of KV cache in use), ``run_trajs``, ``wait_trajs``,
``complete_trajs`` (completed since last sync) and ``inst_version``.

Snapshots are *plain data*: strategies and the coordinator operate on them
functionally, which keeps the control plane unit-testable without any
rollout engine attached.
"""
from __future__ import annotations

import copy
from dataclasses import dataclass, field
from typing import Dict, Iterable, Set


@dataclass
class InstanceSnapshot:
    inst_id: int
    kv_cache: float = 0.0                      # bytes in use
    run_trajs: Set[int] = field(default_factory=set)
    wait_trajs: Set[int] = field(default_factory=set)
    complete_trajs: Set[int] = field(default_factory=set)
    inst_version: int = 0
    # per-trajectory current lengths (tokens) — used by the cost model to
    # estimate KV footprints of routed/migrated trajectories. Not one of the
    # paper's five fields but carried alongside in every real system.
    traj_lengths: Dict[int, int] = field(default_factory=dict)
    # engine telemetry: cumulative pool preemptions. The coordinator
    # differences consecutive snapshots into a per-cycle rate before the
    # strategies run; the cost model folds that rate into marginal_gain as
    # a routing penalty so the coordinator stops feeding replicas
    # thrashing their block pools.
    preemptions: int = 0
    # prefix sharing (paged group admission): opaque prefix id -> member
    # trajectory ids still holding the shared full prompt blocks, and the
    # token capacity of those blocks. ``kv_cache`` charges shared blocks
    # once per group; ``discard`` uses these to release a member's
    # *exclusive* blocks only, freeing the shared bytes when the last
    # member leaves.
    prefix_groups: Dict[int, Set[int]] = field(default_factory=dict)
    prefix_tokens: Dict[int, int] = field(default_factory=dict)
    # lazy CoW: prefix id -> members still pointing at the group's single
    # shared partial-tail block (not yet diverged by a decode write). A
    # tail member's exclusive footprint is one block smaller — it owns no
    # private tail copy — and the shared tail block itself releases once,
    # when the last tail member leaves. Empty under eager CoW.
    prefix_tail_members: Dict[int, Set[int]] = field(default_factory=dict)
    # devices the instance spans (sharded backend: instance = pod).
    # ``kv_cache`` is *per-device* bytes — the pool is head-sharded, so
    # each device holds 1/shard_count of every trajectory's KV — and
    # ``discard`` scales released footprints accordingly.
    shard_count: int = 1

    @property
    def n_run(self) -> int:
        return len(self.run_trajs)

    @property
    def n_wait(self) -> int:
        return len(self.wait_trajs)

    def resident(self) -> Set[int]:
        return self.run_trajs | self.wait_trajs

    def discard(
        self,
        traj_ids: Iterable[int],
        bytes_per_token: float = 0.0,
        block_size: int = 1,
    ) -> None:
        """Remove trajectories from run/wait (post-Interrupt bookkeeping).

        ``bytes_per_token`` (the cost model's k5) releases their estimated
        KV footprint; lengths are tracked in tokens. ``block_size`` > 1
        rounds the released footprint up to whole KV blocks, matching the
        paged engine's block-granular accounting.

        Shared-prefix members release only their exclusive blocks (tail +
        response); the shared full prompt blocks are released exactly once,
        when the last co-owning member is discarded.

        ``bytes_per_token`` is the cost model's k5 — the trajectory's
        *total* per-token footprint across the pod; released bytes are
        divided by ``shard_count`` to stay on the snapshot's per-device
        basis.
        """
        bytes_per_token = bytes_per_token / self.shard_count
        ids = set(traj_ids)
        shared_handled: Set[int] = set()
        if block_size > 1:
            for pk, members in list(self.prefix_groups.items()):
                hit = ids & members
                if not hit:
                    continue
                n_full = self.prefix_tokens.get(pk, 0) // block_size
                tail_set = self.prefix_tail_members.get(pk)
                for t in hit & self.run_trajs:
                    length = self.traj_lengths.get(t, 0)
                    excl = max(0, -(-length // block_size) - n_full)
                    if tail_set and t in tail_set:
                        # undiverged member: its tail block is the group's
                        # shared one, not part of its exclusive footprint
                        excl = max(0, excl - 1)
                    self.kv_cache = max(
                        0.0,
                        self.kv_cache - bytes_per_token * block_size * excl,
                    )
                if tail_set is not None:
                    tail_set -= hit
                    if not tail_set:
                        # last undiverged member left: the shared lazy
                        # tail block itself is released (one block, once)
                        self.kv_cache = max(
                            0.0,
                            self.kv_cache - bytes_per_token * block_size,
                        )
                        del self.prefix_tail_members[pk]
                shared_handled |= hit
                members -= hit
                if not members:
                    self.kv_cache = max(
                        0.0,
                        self.kv_cache
                        - bytes_per_token * block_size * n_full,
                    )
                    del self.prefix_groups[pk]
                    self.prefix_tokens.pop(pk, None)
        for t in (ids - shared_handled) & self.run_trajs:
            length = self.traj_lengths.get(t, 0)
            if block_size > 1:
                length = block_size * (-(-length // block_size))
            self.kv_cache = max(
                0.0, self.kv_cache - bytes_per_token * length
            )
        self.run_trajs -= ids
        self.wait_trajs -= ids
        for t in ids:
            self.traj_lengths.pop(t, None)

    def clone(self) -> "InstanceSnapshot":
        return copy.deepcopy(self)


Snapshot = Dict[int, InstanceSnapshot]


def clone_snapshot(s: Snapshot) -> Snapshot:
    return {i: inst.clone() for i, inst in s.items()}


def collect(instances: Dict[int, "object"]) -> Snapshot:
    """Snapshot every instance of a fleet (runtime/sim shared helper).

    Under the threaded scheduler the caller must hold the instances' locks
    for the whole snapshot->execute cycle (``RuntimeCore.coordinator_cycle``
    does) so the five fields are mutually consistent per Eq. 1.
    """
    return {i: inst.snapshot() for i, inst in instances.items()}
