"""Speculative state & snapshot validation (paper §5.2, Table 1, Eq. 1).

Commands take a variable time ``Δt`` to take effect; a snapshot captured
before the effect lands would drive decisions on stale information and
cause oscillation. The coordinator therefore maintains a *speculative
state* ``P`` — the expected post-command state — and only accepts a
snapshot when it matches ``P`` (Eq. 1):

    P[i].inst_version   == S[i].inst_version
    P[i].accum_traj_num == |resident(i) ∪ complete(i)|

Deviation from the paper: we count ``wait_trajs`` in the accumulated number
(residency = run ∪ wait ∪ complete). The paper's Eq. 1 writes
``run ∪ complete``, but instances preempt run→wait autonomously when the KV
budget fills (Fig. 11), which would falsify Eq. 1 without any outstanding
command; residency is the quantity commands actually add to / subtract
from. Recorded in DESIGN.md §assumption-changes.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict

from repro.core.commands import Abort, Command, Interrupt, Pull, Route
from repro.core.snapshot import Snapshot


@dataclass
class InstanceExpectation:
    inst_version: int = 0
    accum_traj_num: int = 0


@dataclass
class SpeculativeState:
    expectations: Dict[int, InstanceExpectation] = field(default_factory=dict)

    def ensure(self, inst: int) -> InstanceExpectation:
        if inst not in self.expectations:
            self.expectations[inst] = InstanceExpectation()
        return self.expectations[inst]

    # Table 1: effects on P after issuance
    def apply(self, cmd: Command, *, ps_version: int = 0) -> None:
        p = self.ensure(cmd.inst)
        if isinstance(cmd, Pull):
            p.inst_version = ps_version
            p.accum_traj_num = 0
        elif isinstance(cmd, Route):
            p.accum_traj_num += len(cmd.traj_ids)
        elif isinstance(cmd, (Interrupt, Abort)):
            p.accum_traj_num -= len(cmd.traj_ids)
        else:  # pragma: no cover
            raise TypeError(f"unknown command {cmd!r}")

    def validate(self, snapshot: Snapshot) -> bool:
        """Eq. 1: accept the snapshot only if all commands have landed."""
        for inst, s in snapshot.items():
            p = self.ensure(inst)
            if p.inst_version != s.inst_version:
                return False
            observed = len(s.run_trajs | s.wait_trajs | s.complete_trajs)
            if p.accum_traj_num != observed:
                return False
        return True

    def resync(self, snapshot: Snapshot) -> None:
        """Force P to match an accepted snapshot (startup / failure recovery)."""
        for inst, s in snapshot.items():
            p = self.ensure(inst)
            p.inst_version = s.inst_version
            p.accum_traj_num = len(s.run_trajs | s.wait_trajs | s.complete_trajs)
