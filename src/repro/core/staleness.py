"""Global consistency protocol: virtual staleness buffers (paper §4).

The staleness manager enforces a strict staleness bound ``eta`` at
*trajectory* granularity via three buffer primitives:

* ``Reserve`` — worst-case *backward* scan: when a trajectory (or group)
  with version ``v`` starts, reserve the latest available empty entry in
  buffers ``V_buf = v + eta`` down to ``max(v, train_version)``.
* ``Occupy`` — greedy *forward* scan: when the trajectory completes (and is
  rewarded), delete its reserved entry (triggering the entry-movement
  cascade of Fig. 7 right) and occupy the earliest empty entry.
* ``Consume`` — training retires the earliest buffer once it is Ready
  (all entries occupied), advancing the train version.

Invariant (checked by ``check_invariants``): every entry in every buffer
satisfies ``V_traj + eta >= V_buf``.

The manager is *metadata only*: it stores ``(key, version)`` pairs, never
payloads, and tracks at most ``(eta + 1) * batch_size`` in-flight entries
regardless of cluster size (paper §4.2 discussion) — this is what makes the
control plane viable at 1000+ nodes.

Group sampling (§4.3) is supported by using group IDs as keys; redundancy
expands capacity at batch level (extra entries) or is handled by the caller
at group level (extra members per entry); ``abort`` implements filtering
with forward-fill from later buffers.

Thread safety: all public methods take an internal lock, so the manager can
be shared by the coordinator, reward workers, and the trainer thread.
"""
from __future__ import annotations

import enum
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from repro.analysis.witness import make_rlock


class EntryState(enum.Enum):
    EMPTY = 0
    RESERVED = 1
    OCCUPIED = 2


class BufferState(enum.Enum):
    WAITING = "waiting"   # has empty entries -> Reserve may continue
    READY = "ready"       # all occupied -> consumable
    STUCK = "stuck"       # full, but >= 1 reserved -> blocked on in-flight data


@dataclass
class Entry:
    state: EntryState = EntryState.EMPTY
    key: Optional[int] = None       # traj_id or group_id
    version: Optional[int] = None   # V_traj (group: min over members)

    def clear(self) -> None:
        self.state = EntryState.EMPTY
        self.key = None
        self.version = None


@dataclass
class StalenessBuffer:
    """One virtual buffer: trajectories trained as the model goes V_buf -> V_buf+1."""

    v_buf: int
    capacity: int
    entries: List[Entry] = field(default_factory=list)

    def __post_init__(self) -> None:
        if not self.entries:
            self.entries = [Entry() for _ in range(self.capacity)]

    # -- queries ------------------------------------------------------------
    def slots(self, state: EntryState) -> List[int]:
        return [i for i, e in enumerate(self.entries) if e.state == state]

    def first_empty(self) -> Optional[int]:
        for i, e in enumerate(self.entries):
            if e.state == EntryState.EMPTY:
                return i
        return None

    def last_empty(self) -> Optional[int]:
        for i in range(len(self.entries) - 1, -1, -1):
            if self.entries[i].state == EntryState.EMPTY:
                return i
        return None

    @property
    def n_empty(self) -> int:
        return sum(1 for e in self.entries if e.state == EntryState.EMPTY)

    @property
    def n_reserved(self) -> int:
        return sum(1 for e in self.entries if e.state == EntryState.RESERVED)

    @property
    def n_occupied(self) -> int:
        return sum(1 for e in self.entries if e.state == EntryState.OCCUPIED)

    @property
    def state(self) -> BufferState:
        if self.n_empty > 0:
            return BufferState.WAITING
        if self.n_reserved > 0:
            return BufferState.STUCK
        return BufferState.READY


class StalenessViolation(RuntimeError):
    """Raised when an operation would break ``V_traj + eta >= V_buf``."""


class StalenessManager:
    """The staleness manager of Fig. 6: discriminator + tracker.

    Parameters
    ----------
    batch_size:
        Entries per buffer (trajectories, or groups under group sampling).
    eta:
        The staleness bound. ``eta = 0`` degenerates to fully synchronous.
    batch_redundancy:
        Extra entries per buffer (batch-level redundant rollout, §4.3 /
        Fig. 8b). Only ``batch_size`` occupied entries are consumed; once a
        buffer holds ``batch_size`` occupied entries its surplus reserved
        entries are reported via ``surplus_keys`` so the coordinator can
        Abort them.
    """

    def __init__(self, batch_size: int, eta: int, *, batch_redundancy: int = 0):
        if batch_size <= 0:
            raise ValueError("batch_size must be positive")
        if eta < 0:
            raise ValueError("eta must be >= 0")
        self.batch_size = batch_size
        self.eta = eta
        self.batch_redundancy = batch_redundancy
        self.capacity = batch_size + batch_redundancy
        self.train_version = 0          # next buffer to consume
        self._buffers: Dict[int, StalenessBuffer] = {}
        self._index: Dict[int, Tuple[int, int]] = {}  # key -> (v_buf, slot)
        self._lock = make_rlock("staleness")
        # telemetry: staleness (V_buf - V_traj) histogram per consumed buffer
        self.consumed_staleness: List[List[int]] = []
        # keys dropped by a Consume because their entry could not be
        # re-homed under the advanced train floor (version + eta <
        # train_version, or no empty slot). The payloads behind them are
        # orphaned until the coordinator drains this via ``take_evicted``
        # and Aborts them — under streaming partial consumption the floor
        # advances fast enough for this to happen routinely, so silent
        # drops would leak TS registry slots.
        self._evicted: List[int] = []

    # ------------------------------------------------------------- internals
    def _buffer(self, v_buf: int) -> StalenessBuffer:
        if v_buf not in self._buffers:
            self._buffers[v_buf] = StalenessBuffer(v_buf=v_buf, capacity=self.capacity)
        return self._buffers[v_buf]

    def _active_range(self, version: int) -> range:
        """Buffers a trajectory of ``version`` may legally inhabit."""
        lo = max(version, self.train_version)
        hi = version + self.eta
        return range(lo, hi + 1)

    # ---------------------------------------------------------- discriminator
    def can_reserve(self, version: int) -> bool:
        """Simulate a Reserve (§4.2 'as a discriminator'): any empty entry in
        buffers ``[max(version, train_version), version + eta]``?"""
        with self._lock:
            if version + self.eta < self.train_version:
                return False  # already older than anything consumable
            return any(
                self._buffer(v).n_empty > 0 for v in self._active_range(version)
            )

    def min_admissible_version(self, at_least: int = 0) -> Optional[int]:
        """Smallest ``v >= at_least`` for which a Reserve would succeed.

        Used by the coordinator when an instance's current version is
        inadmissible: 'a larger V_traj is needed to unlock newer buffers'.
        Bounded search: beyond ``train_version + eta`` a fresh buffer always
        has room, so the scan terminates.
        """
        with self._lock:
            v = max(at_least, self.train_version - self.eta)
            while not self.can_reserve(v):
                v += 1
                if v > self.train_version + 10 * (self.eta + 1) + 1:  # safety net
                    return None
            return v

    # --------------------------------------------------------------- tracker
    def reserve(self, key: int, version: int) -> int:
        """Worst-case backward Reserve. Returns the chosen ``V_buf``.

        Scans from ``version + eta`` (latest legal buffer) *down* to
        ``max(version, train_version)`` and takes the latest available empty
        entry — the worst-case position the trajectory could end up in.
        """
        with self._lock:
            if key in self._index:
                raise KeyError(f"key {key} already tracked at {self._index[key]}")
            if version + self.eta < self.train_version:
                raise StalenessViolation(
                    f"version {version} + eta {self.eta} < train_version "
                    f"{self.train_version}: cannot reserve"
                )
            rng = self._active_range(version)
            for v_buf in reversed(rng):
                buf = self._buffer(v_buf)
                slot = buf.last_empty()
                if slot is not None:
                    buf.entries[slot] = Entry(EntryState.RESERVED, key, version)
                    self._index[key] = (v_buf, slot)
                    return v_buf
            raise StalenessViolation(
                f"no empty entry in buffers {list(rng)} for version {version}"
            )

    def lower_version(self, key: int, new_version: int) -> bool:
        """Lower a tracked entry's version (group min dropped, §4.3).

        If the entry's current buffer would violate the bound, try to
        relocate it (backward scan under the new version). Returns False if
        impossible — the caller must then refuse the assignment.
        """
        with self._lock:
            v_buf, slot = self._index[key]
            entry = self._buffers[v_buf].entries[slot]
            if new_version >= (entry.version if entry.version is not None else new_version):
                return True  # not actually lower
            if new_version + self.eta >= v_buf:
                entry.version = new_version
                return True
            # must relocate to an earlier buffer
            for v in reversed(self._active_range(new_version)):
                buf = self._buffer(v)
                s = buf.last_empty()
                if s is not None:
                    buf.entries[s] = Entry(entry.state, key, new_version)
                    self._buffers[v_buf].entries[slot].clear()
                    self._index[key] = (v, s)
                    return True
            return False

    def _cascade_fill(self, v_buf: int, slot: int) -> None:
        """Entry-movement cascade (Fig. 7 right, steps 2-3).

        An entry at ``(v_buf, slot)`` was just vacated. Pull the *earliest*
        reserved entry B from a strictly earlier buffer that may legally sit
        in ``v_buf`` (``V_B + eta >= v_buf``) into the hole; recurse into B's
        former position. This keeps occupied entries early and pushes
        reserved entries late, maximizing training readiness.
        """
        while True:
            moved = False
            for v in sorted(self._buffers):
                if v >= v_buf or v < self.train_version:
                    continue
                buf = self._buffers[v]
                for s, e in enumerate(buf.entries):
                    if (
                        e.state == EntryState.RESERVED
                        and e.version is not None
                        and e.version + self.eta >= v_buf
                    ):
                        self._buffers[v_buf].entries[slot] = Entry(
                            EntryState.RESERVED, e.key, e.version
                        )
                        self._index[e.key] = (v_buf, slot)
                        buf.entries[s].clear()
                        v_buf, slot = v, s
                        moved = True
                        break
                if moved:
                    break
            if not moved:
                return

    def occupy(self, key: int) -> int:
        """Delete the reserved entry for ``key`` (with movement cascade) and
        greedily Occupy the earliest empty entry. Returns the final V_buf."""
        with self._lock:
            if key not in self._index:
                raise KeyError(f"key {key} is not tracked (was it aborted?)")
            v_buf, slot = self._index.pop(key)
            entry = self._buffers[v_buf].entries[slot]
            if entry.state != EntryState.RESERVED:
                raise RuntimeError(f"occupy on non-reserved entry {entry}")
            version = entry.version
            assert version is not None
            entry.clear()
            # Fig. 7 right: refill A's hole from earlier reserved entries
            self._cascade_fill(v_buf, slot)
            # greedy forward Occupy at the earliest legal empty entry
            for v in self._active_range(version):
                buf = self._buffer(v)
                s = buf.first_empty()
                if s is not None:
                    buf.entries[s] = Entry(EntryState.OCCUPIED, key, version)
                    self._index[key] = (v, s)
                    return v
            # Cannot happen: deleting our own reservation freed >= 1 slot in range.
            raise StalenessViolation(f"no empty entry to occupy for {key}")

    def abort(self, key: int) -> None:
        """Filtering / redundancy abort (§4.3, Fig. 8c): drop an entry.

        Occupied entries from *later* buffers are moved forward into the
        freed slot so the buffer becomes Ready without waiting for new
        trajectories; reserved entries cascade as usual.
        """
        with self._lock:
            if key not in self._index:
                return  # already consumed or never tracked — idempotent
            v_buf, slot = self._index.pop(key)
            self._buffers[v_buf].entries[slot].clear()
            # pull an occupied entry forward from a later buffer if legal
            for v in sorted(self._buffers):
                if v <= v_buf:
                    continue
                buf = self._buffers[v]
                for s, e in enumerate(buf.entries):
                    if (
                        e.state == EntryState.OCCUPIED
                        and e.version is not None
                        and e.version + self.eta >= v_buf
                        and e.version <= v_buf  # never train on "future" data
                        and v_buf >= self.train_version
                    ):
                        self._buffers[v_buf].entries[slot] = Entry(
                            EntryState.OCCUPIED, e.key, e.version
                        )
                        self._index[e.key] = (v_buf, slot)
                        buf.entries[s].clear()
                        self._cascade_fill(v, s)
                        return
            self._cascade_fill(v_buf, slot)

    def _consumable_locked(self, min_occupied: Optional[int]) -> bool:
        """Is the train-floor buffer consumable? Full-batch rule by default;
        with ``min_occupied`` set (streaming partial consumption) the buffer
        is also consumable once it holds that many occupied entries, or as
        soon as any occupied entry sits at the ``eta`` bound (it cannot get
        staler — waiting buys nothing, so the partial batch ships)."""
        buf = self._buffer(self.train_version)
        n_occ = buf.n_occupied
        if n_occ >= self.batch_size:
            return True
        if min_occupied is None or min_occupied <= 0 or n_occ == 0:
            return False
        if n_occ >= min_occupied:
            return True
        return any(
            e.state == EntryState.OCCUPIED
            and e.version is not None
            and e.version + self.eta <= self.train_version
            for e in buf.entries
        )

    def ready(self, min_occupied: Optional[int] = None) -> bool:
        with self._lock:
            return self._consumable_locked(min_occupied)

    def consume(self, min_occupied: Optional[int] = None) -> Optional[List[int]]:
        """Retire the earliest buffer if Ready; returns its keys (batch) or None.

        Under batch redundancy a buffer is consumable once ``batch_size``
        entries are occupied; surplus entries are left for the caller to
        Abort (they are reported by ``surplus_keys`` *before* consuming).

        ``min_occupied`` enables streaming partial-batch mode: the buffer is
        retired once it holds that many occupied entries (or an occupied
        entry hits the ``eta`` bound) even if not full — see
        ``_consumable_locked``. At most ``batch_size`` keys are returned
        either way, and the staleness bound is unaffected: partial consumes
        only ever advance the floor *earlier*, never admit staler entries.
        """
        with self._lock:
            buf = self._buffer(self.train_version)
            if not self._consumable_locked(min_occupied):
                return None
            occupied = [
                (s, e) for s, e in enumerate(buf.entries) if e.state == EntryState.OCCUPIED
            ]
            take = occupied[: self.batch_size]
            keys = [e.key for _, e in take]
            self.consumed_staleness.append(
                [self.train_version - e.version for _, e in take]
            )
            for s, e in take:
                self._index.pop(e.key, None)
                buf.entries[s].clear()
            # surplus (redundancy) entries and any reserved stragglers must be
            # re-homed: their buffer is being retired.
            leftovers = [(s, e) for s, e in enumerate(buf.entries) if e.state != EntryState.EMPTY]
            del self._buffers[self.train_version]
            self.train_version += 1
            for _, e in leftovers:
                self._index.pop(e.key, None)
                # Re-insert under the new floor; abort if now illegal.
                if e.version is not None and e.version + self.eta >= self.train_version:
                    self._reinsert(e)
                else:
                    self._evicted.append(e.key)
            return keys

    def _reinsert(self, e: Entry) -> None:
        for v in self._active_range(e.version):
            buf = self._buffer(v)
            slot = buf.first_empty() if e.state == EntryState.OCCUPIED else buf.last_empty()
            if slot is not None:
                buf.entries[slot] = Entry(e.state, e.key, e.version)
                self._index[e.key] = (v, slot)
                return
        # No room under the advanced floor: the entry is dropped and its
        # key reported via ``take_evicted`` so the coordinator can Abort
        # the orphaned payload.
        self._evicted.append(e.key)

    def take_evicted(self) -> List[int]:
        """Drain keys dropped by Consume re-homing (see ``_evicted``)."""
        with self._lock:
            out, self._evicted = self._evicted, []
            return out

    def surplus_keys(self) -> List[int]:
        """Keys that redundancy has made unnecessary (buffer already has
        ``batch_size`` occupied entries; these are reserved stragglers)."""
        with self._lock:
            out: List[int] = []
            for v, buf in self._buffers.items():
                if buf.n_occupied >= self.batch_size:
                    out.extend(
                        e.key for e in buf.entries if e.state == EntryState.RESERVED
                    )
            return out

    # ------------------------------------------------------------- telemetry
    def tracked_keys(self) -> List[int]:
        with self._lock:
            return list(self._index)

    def is_tracked(self, key: int) -> bool:
        with self._lock:
            return key in self._index

    def entry_info(self, key: int) -> Optional[Tuple[int, EntryState, int]]:
        """(v_buf, state, version) for a tracked key."""
        with self._lock:
            if key not in self._index:
                return None
            v_buf, slot = self._index[key]
            e = self._buffers[v_buf].entries[slot]
            return (v_buf, e.state, e.version)

    def buffer_states(self) -> Dict[int, str]:
        with self._lock:
            return {v: b.state.value for v, b in sorted(self._buffers.items())}

    def snapshot(self) -> Dict[int, Dict[str, int]]:
        with self._lock:
            return {
                v: {
                    "empty": b.n_empty,
                    "reserved": b.n_reserved,
                    "occupied": b.n_occupied,
                }
                for v, b in sorted(self._buffers.items())
            }

    def in_flight(self) -> int:
        with self._lock:
            return len(self._index)

    def max_consumed_staleness(self) -> int:
        """Largest staleness over every consumed batch so far (0 when
        nothing was consumed). The protocol guarantees this never exceeds
        ``eta`` — asserted by the threaded-runtime smoke under real
        concurrency."""
        with self._lock:
            return max(
                (s for hist in self.consumed_staleness for s in hist),
                default=0,
            )

    # ------------------------------------------------------------ invariants
    def check_invariants(self) -> None:
        """Property-test hook: raises AssertionError on any protocol breach."""
        with self._lock:
            seen: Dict[int, Tuple[int, int]] = {}
            for v_buf, buf in self._buffers.items():
                assert len(buf.entries) == self.capacity
                for slot, e in enumerate(buf.entries):
                    if e.state == EntryState.EMPTY:
                        assert e.key is None and e.version is None
                        continue
                    assert e.key is not None and e.version is not None
                    assert e.version + self.eta >= v_buf, (
                        f"staleness violation: key {e.key} v={e.version} "
                        f"in buffer {v_buf} with eta={self.eta}"
                    )
                    assert e.key not in seen, f"duplicate key {e.key}"
                    seen[e.key] = (v_buf, slot)
            assert seen == self._index, "index out of sync with buffers"
            max_buffers = self.eta + 1
            live = [v for v, b in self._buffers.items()
                    if b.n_empty < self.capacity]
            if live:
                # in-flight data bound: entries only span eta+1 consecutive
                # buffers above the train floor plus lookahead to max version
                assert len(self._index) <= (max_buffers + max(
                    0, max(live) - self.train_version - self.eta
                )) * self.capacity
