"""Staleness-aware, throughput-oriented rollout coordination strategies
(paper §5.3, Appendix D, Algorithms 2-5) plus the vanilla counterparts used
by the §6.5 ablation.

All strategies are pure functions over (snapshot, TS contents, cost model,
verifier) so they can be unit-tested and reused by both the live runtime and
the discrete-event simulator.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Protocol, Sequence, Tuple

from repro.core.cost_model import CostModel
from repro.core.snapshot import InstanceSnapshot, Snapshot, clone_snapshot
from repro.core.types import Trajectory


@dataclass(frozen=True)
class StrategyConfig:
    """Hyper-parameters (paper §6.1: mu=0.3, phi_wait=3, phi_throughput=5)."""

    mu: float = 0.3
    phi_wait: int = 3
    phi_throughput: float = 5.0


class Verifier(Protocol):
    """Staleness-manager facade used by Alg. 2 (check_routable)."""

    def can_assign(self, traj: Trajectory, version: int) -> bool:
        """Would assigning ``V_traj = version`` to this (possibly grouped)
        initial trajectory violate eta?"""
        ...


# --------------------------------------------------------------- Algorithm 2
def check_routable(
    s_i: InstanceSnapshot, traj: Trajectory, verifier: Verifier
) -> bool:
    """Can ``traj`` be routed to instance ``i`` without violating eta?

    * initial trajectory: propose ``V_traj = inst_version`` and ask the
      staleness manager (discriminator);
    * partially generated: the re-routed instance must be no older than the
      already-assigned ``V_traj``.
    """
    if traj.v_traj is None:
        return verifier.can_assign(traj, s_i.inst_version)
    return s_i.inst_version >= traj.v_traj


# --------------------------------------------------------------- Algorithm 3
def _waterfall_route(
    snapshot: Snapshot,
    units: Sequence[List[Trajectory]],
    cost_model: CostModel,
    verifier: Verifier,
    cfg: StrategyConfig,
) -> List[Tuple[int, Trajectory, int]]:
    """Alg. 3 waterfall over routing *units*.

    A unit is a list of trajectories routed to one instance as a whole:
    singletons reproduce the per-trajectory waterfall exactly; multi-member
    units are shared-prefix groups, whose gain/footprint the cost model
    charges with the prompt's full blocks counted once
    (``group_marginal_gain`` / ``with_routed_group``).
    """
    s = clone_snapshot(snapshot)
    routing: List[Tuple[int, Trajectory, int]] = []

    # Multi-level queue: levels ordered by V_traj ascending (staler = higher
    # priority); initial trajectories (V_traj None) lowest priority.
    levels: Dict[Optional[int], List[List[Trajectory]]] = {}
    for unit in units:
        levels.setdefault(unit[0].v_traj, []).append(unit)
    keyed = sorted(
        levels.items(), key=lambda kv: (kv[0] is None, kv[0] if kv[0] is not None else 0)
    )

    stop = False
    for _, queue in keyed:
        if stop:
            break
        idx = 0
        while idx < len(queue):
            unit = queue[idx]
            rep = unit[0]  # members of a unit are interchangeable for Alg. 2
            grouped = len(unit) > 1
            lengths = [t.length for t in unit]
            # Step 1: candidate instances
            candidates = [
                i for i, si in s.items() if check_routable(si, rep, verifier)
            ]
            if not candidates:
                stop = True
                break
            # Step 2: group by inst_version ascending (older versions admit
            # fewer trajectories -> serve them first)
            by_version: Dict[int, List[int]] = {}
            for i in candidates:
                by_version.setdefault(s[i].inst_version, []).append(i)
            groups = [by_version[v] for v in sorted(by_version)]
            # Step 3: ideal gain upper bound
            if grouped:
                ideal = cost_model.group_ideal_gain(len(rep.prompt), lengths)
            else:
                ideal = cost_model.ideal_gain(rep.length)
            # Step 4: waterfall selection
            selected: Optional[int] = None
            for group in groups:
                best_gain, best_inst = -1.0, None
                for i in group:
                    if grouped:
                        g = cost_model.group_marginal_gain(
                            s[i], len(rep.prompt), lengths
                        )
                    else:
                        g = cost_model.marginal_gain(s[i], rep.length)
                    if g > best_gain:
                        best_gain, best_inst = g, i
                if best_gain >= cfg.mu * ideal:
                    selected = best_inst
                    break
            if selected is None:
                if grouped:
                    # the whole group fits nowhere as a unit (pool smaller
                    # than the group, or every instance loaded): fall back
                    # to routing its members individually so the group can
                    # trickle in — engine-side sharing still applies to
                    # members landing in one wave, and stragglers fork the
                    # resident prefix. Without this, an unplaceable group
                    # would stop the waterfall and starve everything
                    # queued behind it, every cycle.
                    queue[idx : idx + 1] = [[t] for t in unit]
                    continue
                # withhold: let running work drain for a better gain later
                stop = True
                break
            # Step 5: route + update speculative snapshot
            v = (
                rep.v_traj
                if rep.v_traj is not None
                else s[selected].inst_version
            )
            for traj in unit:
                routing.append((selected, traj, v))
            if grouped:
                s[selected] = cost_model.with_routed_group(
                    s[selected], [t.traj_id for t in unit],
                    len(rep.prompt), lengths,
                )
            else:
                s[selected] = cost_model.with_routed(
                    s[selected], rep.traj_id, rep.length
                )
            queue.pop(idx)
    return routing


def routing_strategy(
    snapshot: Snapshot,
    ts_trajs: Sequence[Trajectory],
    cost_model: CostModel,
    verifier: Verifier,
    cfg: StrategyConfig = StrategyConfig(),
) -> List[Tuple[int, Trajectory, int]]:
    """Waterfall routing over a multi-level queue (Fig. 12c).

    Returns ``[(inst_id, trajectory, proposed_v_traj)]``. Mutates a *clone*
    of the snapshot internally so successive decisions see each other's
    marginal effects; callers apply the decisions to the real system via
    Route commands.
    """
    return _waterfall_route(
        snapshot, [[t] for t in ts_trajs], cost_model, verifier, cfg
    )


def prefix_routing_strategy(
    snapshot: Snapshot,
    ts_trajs: Sequence[Trajectory],
    cost_model: CostModel,
    verifier: Verifier,
    cfg: StrategyConfig = StrategyConfig(),
) -> List[Tuple[int, Trajectory, int]]:
    """Group-affine waterfall routing for prefix-sharing engines.

    Initial members of the same sampling group (identical prompt, nothing
    generated, no ``V_traj`` yet) bundle into ONE routing unit placed on a
    single instance, so they arrive in one wave and the engine prefills the
    shared prompt once, mapping its full KV blocks into every member's
    table. Partially generated or already-versioned trajectories route
    individually exactly as ``routing_strategy`` would.
    """
    units: List[List[Trajectory]] = []
    bundles: Dict[int, List[Trajectory]] = {}
    for t in ts_trajs:
        shareable = (
            t.group_id >= 0
            and t.v_traj is None
            and not t.response
            and not t.sim_generated
        )
        if not shareable:
            units.append([t])
            continue
        bundle = bundles.get(t.group_id)
        if bundle is not None and bundle[0].prompt == t.prompt:
            bundle.append(t)
        else:
            bundle = [t]
            bundles[t.group_id] = bundle
            units.append(bundle)  # anchored at the first member's position
    return _waterfall_route(snapshot, units, cost_model, verifier, cfg)


# --------------------------------------------------------------- Algorithm 4
def synchronization_strategy(
    snapshot: Snapshot,
    ts_trajs: Sequence[Trajectory],
    ps_version: int,
    cost_model: CostModel,
    verifier: Verifier,
    cfg: StrategyConfig = StrategyConfig(),
) -> List[int]:
    """Sync an instance only when (a) it is route-starved at its current
    version and (b) a tentative update would let the routing strategy place
    new work on it."""
    sync: List[int] = []
    candidates: List[int] = []
    for i, si in snapshot.items():
        if ps_version <= si.inst_version:
            continue
        if any(check_routable(si, t, verifier) for t in ts_trajs):
            continue  # still routable at the stale version -> no need
        candidates.append(i)
    for i in candidates:
        s_temp = clone_snapshot(snapshot)
        s_temp[i].inst_version = ps_version
        routed = routing_strategy(s_temp, ts_trajs, cost_model, verifier, cfg)
        if any(inst == i for inst, _, _ in routed):
            sync.append(i)
    return sync


# --------------------------------------------------------------- Algorithm 5
def migration_strategy(
    snapshot: Snapshot,
    cost_model: CostModel,
    cfg: StrategyConfig = StrategyConfig(),
) -> List[Tuple[int, List[int]]]:
    """Two triggers: wait-queue overflow (phi_wait) and throughput imbalance
    (phi_throughput). Returns ``[(inst_id, [traj_ids to interrupt])]``."""
    migration: List[Tuple[int, List[int]]] = []
    handled: Dict[int, set] = {}

    # Case 1: excessive waiting trajectories
    for i, si in snapshot.items():
        if si.n_wait > cfg.phi_wait:
            excess = si.n_wait - cfg.phi_wait
            # interrupt the longest waiters first: they profit most from
            # landing on an emptier instance
            waiters = sorted(
                si.wait_trajs,
                key=lambda t: si.traj_lengths.get(t, 0),
                reverse=True,
            )[:excess]
            migration.append((i, list(waiters)))
            handled.setdefault(i, set()).update(waiters)

    # Case 2: throughput gap between fastest and slowest instances
    if len(snapshot) >= 2:
        thr = {i: cost_model.throughput(si) for i, si in snapshot.items()}
        max_inst = max(thr, key=thr.get)
        min_inst = min(thr, key=thr.get)
        t_max, t_min = thr[max_inst], thr[min_inst]
        gap = float("inf") if t_min <= 0 < t_max else (t_max / t_min if t_min > 0 else 0.0)
        if gap > cfg.phi_throughput:
            all_trajs = set(snapshot[max_inst].run_trajs)
            all_trajs -= handled.get(max_inst, set())
            if all_trajs:
                migration.append((max_inst, sorted(all_trajs)))
    return migration


# ------------------------------------------------------- vanilla counterparts
def vanilla_routing(
    snapshot: Snapshot,
    ts_trajs: Sequence[Trajectory],
    cost_model: CostModel,
    verifier: Verifier,
    cfg: StrategyConfig = StrategyConfig(),
) -> List[Tuple[int, Trajectory, int]]:
    """§6.5 'vanilla routing': pure count load-balancing — every TS
    trajectory goes to the routable instance with the fewest resident
    trajectories."""
    s = clone_snapshot(snapshot)
    routing: List[Tuple[int, Trajectory, int]] = []
    for traj in ts_trajs:
        candidates = [i for i, si in s.items() if check_routable(si, traj, verifier)]
        if not candidates:
            continue
        tgt = min(candidates, key=lambda i: len(s[i].resident()))
        v = traj.v_traj if traj.v_traj is not None else s[tgt].inst_version
        routing.append((tgt, traj, v))
        s[tgt] = cost_model.with_routed(s[tgt], traj.traj_id, traj.length)
    return routing


def vanilla_synchronization(
    snapshot: Snapshot,
    ts_trajs: Sequence[Trajectory],
    ps_version: int,
    cost_model: CostModel,
    verifier: Verifier,
    cfg: StrategyConfig = StrategyConfig(),
) -> List[int]:
    """§6.5 'vanilla synchronization': greedy — sync as soon as the PS has a
    newer version, regardless of load."""
    return [i for i, si in snapshot.items() if ps_version > si.inst_version]


def vanilla_migration(
    snapshot: Snapshot,
    cost_model: CostModel,
    cfg: StrategyConfig = StrategyConfig(),
) -> List[Tuple[int, List[int]]]:
    """§6.5 'vanilla migration': none — only passive re-routing on sync."""
    return []


@dataclass(frozen=True)
class StrategySuite:
    """Pluggable strategy triple (for the §6.5 ablation grid)."""

    routing: Callable = routing_strategy
    synchronization: Callable = synchronization_strategy
    migration: Callable = migration_strategy

    @staticmethod
    def staleflow() -> "StrategySuite":
        return StrategySuite(routing_strategy, synchronization_strategy, migration_strategy)

    @staticmethod
    def prefix_sharing() -> "StrategySuite":
        """StaleFlow with group-affine routing: sampling groups land on one
        instance so paged engines can prefill the shared prompt once."""
        return StrategySuite(
            prefix_routing_strategy, synchronization_strategy, migration_strategy
        )

    @staticmethod
    def vanilla() -> "StrategySuite":
        return StrategySuite(vanilla_routing, vanilla_synchronization, vanilla_migration)
