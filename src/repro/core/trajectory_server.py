"""Trajectory server (TS) — middleware between dataset and rollout (§5.1).

The TS stores every trajectory involved in rollout generation:

* *initial* trajectories sampled from the dataset (no ``V_traj`` yet),
  enqueued up to the capacity limit ``(eta + 1) * batch_size`` groups;
* *interrupted* trajectories returned by Interrupt commands, awaiting
  re-routing (their ``V_traj`` is already assigned).

It also keeps a registry of all live trajectories (including ones currently
routed to instances) so the coordinator can resolve IDs from snapshots into
payload metadata, and so migration can move token state between instances
through the TS as the paper prescribes (Fig. 10 top).

Group sampling: one dataset prompt expands into ``group_size + redundancy``
member trajectories sharing a ``group_id``.

Lifecycle integration: ``attach(lifecycle)`` subscribes the TS to the
trajectory-lifecycle bus so status transitions (``COMPLETED`` -> reward
queue, ``INTERRUPTED`` -> routable pool, ``ABORTED`` -> drop, ``CONSUMED``
-> retire) are driven by events instead of ad-hoc calls from every
component that observes a transition. ``take`` (payload hand-off at Route
execution) stays a direct call — it *returns* the payload.
"""
from __future__ import annotations

from typing import Callable, Dict, Iterator, List, Optional

from repro.analysis.witness import make_rlock

from repro.core.types import Trajectory, TrajectoryGroup, TrajStatus, next_traj_id


class TrajectoryServer:
    def __init__(
        self,
        prompt_source: Iterator,  # List[int] or (List[int], task) tuples
        *,
        capacity_groups: int,
        group_size: int = 1,
        group_redundancy: int = 0,
        max_new_tokens: int = 512,
        clock: Callable[[], float] = lambda: 0.0,
    ):
        self._source = prompt_source
        self.capacity_groups = capacity_groups
        self.group_size = group_size
        self.group_redundancy = group_redundancy
        self.max_new_tokens = max_new_tokens
        self._clock = clock
        self._lock = make_rlock("ts")
        self._available: Dict[int, Trajectory] = {}   # in TS, routable
        self.registry: Dict[int, Trajectory] = {}     # all live trajectories
        self.groups: Dict[int, TrajectoryGroup] = {}
        self._group_counter = 0
        self._live_groups = 0
        self._exhausted = False

    # -------------------------------------------------------------- lifecycle
    def attach(self, lifecycle) -> None:
        """Subscribe this TS to a ``TrajectoryLifecycle`` bus: events become
        the single write path for trajectory status. Call once, by whoever
        constructs the bus."""
        from repro.core.lifecycle import LifecycleEventKind as K

        lifecycle.subscribe(K.COMPLETED, lambda e: self.complete(e.traj_id))
        lifecycle.subscribe(K.INTERRUPTED, lambda e: self.put_back(e.traj_id))
        lifecycle.subscribe(K.ABORTED, lambda e: self.drop(e.traj_id))
        lifecycle.subscribe(K.CONSUMED, lambda e: self.retire(e.traj_id))

    # ------------------------------------------------------------------ fill
    def refill(self) -> int:
        """Sample prompts until ``capacity_groups`` groups are live.

        Capacity counts *live* groups (in TS or on instances, not yet
        consumed/aborted), matching the paper's in-flight bound.
        """
        added = 0
        with self._lock:
            while self._live_groups < self.capacity_groups and not self._exhausted:
                try:
                    item = next(self._source)
                except StopIteration:
                    self._exhausted = True
                    break
                # tagged sources yield (prompt_ids, task); plain sources
                # yield bare prompt_ids (task "" -> hub default route)
                if isinstance(item, tuple):
                    prompt, task = item
                else:
                    prompt, task = item, ""
                gid = self._group_counter
                self._group_counter += 1
                group = TrajectoryGroup(
                    group_id=gid,
                    group_size=self.group_size,
                    redundancy=self.group_redundancy,
                )
                for _ in range(group.total_members):
                    t = Trajectory(
                        traj_id=next_traj_id(),
                        prompt=list(prompt),
                        group_id=gid,
                        max_new_tokens=self.max_new_tokens,
                        created_at=self._clock(),
                        task=task,
                    )
                    group.traj_ids.append(t.traj_id)
                    self._available[t.traj_id] = t
                    self.registry[t.traj_id] = t
                self.groups[gid] = group
                self._live_groups += 1
                added += 1
        return added

    # ----------------------------------------------------------------- queues
    def peek(self) -> List[Trajectory]:
        """Routable trajectories (initial + interrupted), insertion order."""
        with self._lock:
            return list(self._available.values())

    def take(self, traj_id: int) -> Trajectory:
        """Remove from the available queue (being routed); stays registered."""
        with self._lock:
            t = self._available.pop(traj_id)
            t.status = TrajStatus.RUNNING
            return t

    def try_take(self, traj_id: int) -> Optional[Trajectory]:
        """``take`` that tolerates the trajectory having left the routable
        pool since the Route was issued (aborted/completed by a concurrent
        service thread) — returns ``None`` instead of raising."""
        with self._lock:
            t = self._available.pop(traj_id, None)
            if t is None:
                return None
            t.status = TrajStatus.RUNNING
            return t

    def put_back(self, traj_id: int) -> Optional[Trajectory]:
        """An Interrupt returned this trajectory (partial rollout state kept).
        No-op (``None``) if the trajectory was dropped meanwhile — under the
        threaded scheduler an abort can race the interrupt's event."""
        with self._lock:
            t = self.registry.get(traj_id)
            if t is None:
                return None
            t.status = TrajStatus.INTERRUPTED
            t.instance = None
            self._available[traj_id] = t
            return t

    def complete(self, traj_id: int) -> Optional[Trajectory]:
        """Rollout finished; the trajectory leaves the routable pool for the
        reward phase (still registered until consumed). No-op (``None``) if
        already dropped (aborted earlier — surplus/filtering)."""
        with self._lock:
            t = self.registry.get(traj_id)
            if t is None:
                return None
            t.status = TrajStatus.GENERATED
            t.instance = None
            t.completed_at = self._clock()
            self._available.pop(traj_id, None)
            return t

    def drop(self, traj_id: int) -> None:
        """Abort: remove everywhere; retire the group slot when empty."""
        with self._lock:
            self._available.pop(traj_id, None)
            t = self.registry.pop(traj_id, None)
            if t is None:
                return
            t.status = TrajStatus.ABORTED
            self._maybe_retire_group(t.group_id)

    def retire(self, traj_id: int) -> None:
        """Consumed by training: free the registry slot."""
        with self._lock:
            t = self.registry.pop(traj_id, None)
            self._available.pop(traj_id, None)
            if t is None:
                return
            t.status = TrajStatus.CONSUMED
            self._maybe_retire_group(t.group_id)

    def _maybe_retire_group(self, gid: int) -> None:
        group = self.groups.get(gid)
        if group is None:
            return
        if not any(tid in self.registry for tid in group.traj_ids):
            del self.groups[gid]
            self._live_groups -= 1

    # ------------------------------------------------------------------ stats
    def get(self, traj_id: int) -> Optional[Trajectory]:
        with self._lock:
            return self.registry.get(traj_id)

    @property
    def n_available(self) -> int:
        with self._lock:
            return len(self._available)

    @property
    def n_live(self) -> int:
        with self._lock:
            return len(self.registry)

    @property
    def exhausted(self) -> bool:
        with self._lock:
            return self._exhausted and not self._available
