"""Shared data types for the StaleFlow control plane.

The protocol layer (``staleness.py``) tracks only *metadata* (IDs and
versions); trajectory payloads (tokens) live in the trajectory server and
rollout instances. These types are the common vocabulary.
"""
from __future__ import annotations

import enum
import itertools
from dataclasses import dataclass, field
from typing import List, Optional


class TrajStatus(enum.Enum):
    """Lifecycle of one trajectory (Fig. 1 / Fig. 6 data flow).

    Status transitions are published on the ``TrajectoryLifecycle`` event
    bus (``repro.core.lifecycle``): ROUTED -> RUNNING, INTERRUPTED ->
    INTERRUPTED, COMPLETED -> GENERATED, REWARDED -> REWARDED, CONSUMED ->
    CONSUMED, ABORTED -> ABORTED. ``TERMINAL`` states retire the registry
    slot.
    """

    PENDING = "pending"        # in TS, not yet routed / never started
    RUNNING = "running"        # on a rollout instance, generating
    INTERRUPTED = "interrupted"  # returned to TS mid-generation (partial rollout)
    GENERATED = "generated"    # rollout complete, awaiting reward
    REWARDED = "rewarded"      # reward computed -> protocol Occupy
    CONSUMED = "consumed"      # retired by a training Consume
    ABORTED = "aborted"        # discarded (redundancy surplus / filtering)


TERMINAL_STATUSES = frozenset({TrajStatus.CONSUMED, TrajStatus.ABORTED})


_traj_counter = itertools.count()


def next_traj_id() -> int:
    return next(_traj_counter)


def reset_traj_ids() -> None:
    """Test/benchmark helper: restart the global trajectory ID counter."""
    global _traj_counter
    _traj_counter = itertools.count()


@dataclass
class Trajectory:
    """One RL trajectory: a prompt plus its (possibly partial) response.

    ``v_traj`` is the paper's trajectory version identifier: the *oldest
    tolerated model version* over the whole generation. ``None`` until the
    coordinator routes the trajectory for the first time (initial
    trajectories carry no version, Fig. 10 top).

    ``segments`` records (model_version, n_tokens) per generation segment so
    partial rollout / migration provenance is auditable and the staleness
    importance-sampling correction in ``repro.rl`` can weight tokens by the
    version that produced them.
    """

    traj_id: int
    prompt: List[int]
    group_id: int = -1                  # group sampling (GRPO/DAPO): -1 = ungrouped
    response: List[int] = field(default_factory=list)
    v_traj: Optional[int] = None
    status: TrajStatus = TrajStatus.PENDING
    instance: Optional[int] = None      # rollout instance currently hosting it
    segments: List[tuple] = field(default_factory=list)  # [(version, n_tokens)]
    reward: Optional[float] = None
    finished: bool = False              # hit EOS / max length
    max_new_tokens: int = 0             # generation budget
    # per-token logprobs under the version that generated each token —
    # the importance-sampling denominator for staleness correction
    behavior_logprobs: List[float] = field(default_factory=list)
    # bookkeeping for benchmarks
    created_at: float = 0.0
    completed_at: float = 0.0
    # discrete-event simulator: generated tokens tracked as a count instead
    # of materialized token lists (cluster-scale runs would need GBs)
    sim_generated: int = 0
    sim_target_len: int = 0
    # reward-hub routing tag ("math", "code", "remote", ...); "" takes the
    # hub's default route
    task: str = ""
    # lazily built (hash, tuple) of the prompt — prefix-registry lookups
    # compare the hash first instead of rebuilding the tuple per admission
    _prompt_key: Optional[tuple] = field(
        default=None, repr=False, compare=False
    )

    @property
    def length(self) -> int:
        return len(self.prompt) + len(self.response) + self.sim_generated

    def prompt_key(self) -> tuple:
        """Cached ``(hash(prompt_tuple), prompt_tuple)`` for registry
        lookups. Prompts are immutable once a trajectory exists."""
        if self._prompt_key is None:
            tp = tuple(self.prompt)
            self._prompt_key = (hash(tp), tp)
        return self._prompt_key

    @property
    def n_generated(self) -> int:
        return len(self.response)

    def record_segment(self, version: int, n_tokens: int) -> None:
        """Append/extend the (version, n_tokens) provenance log."""
        if n_tokens <= 0:
            return
        if self.segments and self.segments[-1][0] == version:
            self.segments[-1] = (version, self.segments[-1][1] + n_tokens)
        else:
            self.segments.append((version, n_tokens))

    def oldest_segment_version(self) -> Optional[int]:
        return min((v for v, _ in self.segments), default=None)


@dataclass
class TrajectoryGroup:
    """Group sampling unit (§4.3): ``group_size`` responses to one prompt.

    The protocol entry lives at group granularity; the group version is
    ``min(v_traj)`` over members (maximum staleness tolerated by the whole
    group).
    """

    group_id: int
    traj_ids: List[int] = field(default_factory=list)
    group_size: int = 1                 # required completions
    redundancy: int = 0                 # surplus members (group-level redundant rollout)

    @property
    def total_members(self) -> int:
        return self.group_size + self.redundancy
