"""Synthetic verifiable tasks + prompt sources for the trajectory server.

``arithmetic_task`` mirrors the DAPO-Math-17k setup at toy scale: prompts
are arithmetic questions, rewards are rule-verifiable (exact answer match).
``heavy_tail_lengths`` draws response lengths from a lognormal to reproduce
the long-tail skewness of Fig. 4 in the simulator and skewness benchmarks.
"""
from __future__ import annotations

import random
from dataclasses import dataclass
from typing import Iterator, List, Tuple

import numpy as np

from repro.data import tokenizer as tok


@dataclass(frozen=True)
class ArithmeticProblem:
    prompt_ids: Tuple[int, ...]
    answer: str


def make_problem(rng: random.Random, max_operand: int = 99) -> ArithmeticProblem:
    a = rng.randint(0, max_operand)
    b = rng.randint(0, max_operand)
    op = rng.choice("+-*")
    result = {"+": a + b, "-": a - b, "*": a * b}[op]
    text = f"{a}{op}{b}="
    return ArithmeticProblem(tuple(tok.encode(text)), str(result))


def arithmetic_prompts(
    n: int, seed: int = 0, max_operand: int = 99
) -> Iterator[List[int]]:
    """Prompt source for the TrajectoryServer (IDs only)."""
    rng = random.Random(seed)
    for _ in range(n):
        yield list(make_problem(rng, max_operand).prompt_ids)


class ArithmeticDataset:
    """Prompt source that also remembers answers for the reward phase."""

    def __init__(self, n: int, seed: int = 0, max_operand: int = 99):
        rng = random.Random(seed)
        self.problems = [make_problem(rng, max_operand) for _ in range(n)]
        self._by_prompt = {p.prompt_ids: p.answer for p in self.problems}

    def prompt_source(self) -> Iterator[List[int]]:
        for p in self.problems:
            yield list(p.prompt_ids)

    def tagged_source(
        self, tags: List[str], seed: int = 0
    ) -> Iterator[Tuple[List[int], str]]:
        """Prompt source yielding ``(prompt_ids, task)`` for reward-hub
        routing: each prompt draws a tag from ``tags`` deterministically."""
        rng = random.Random(seed)
        for p in self.problems:
            yield list(p.prompt_ids), rng.choice(tags)

    def answer_for(self, prompt_ids: List[int]) -> str:
        return self._by_prompt[tuple(prompt_ids)]


def heavy_tail_lengths(
    n: int, *, mean: float = 2000.0, sigma: float = 1.0, cap: int = 20000,
    seed: int = 0,
) -> np.ndarray:
    """Lognormal response lengths (tokens) reproducing RL's long-tail
    skewness (Fig. 4): most responses short, a few near the cap."""
    rng = np.random.default_rng(seed)
    mu = np.log(mean) - sigma ** 2 / 2
    out = rng.lognormal(mu, sigma, size=n)
    return np.clip(out, 1, cap).astype(np.int64)
