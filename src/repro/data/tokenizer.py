"""Toy character tokenizer for the synthetic arithmetic RL task.

The paper's contribution is orthogonal to tokenization; this minimal
vocabulary keeps the end-to-end convergence benchmarks (Fig. 3/14 analogs)
fast on CPU while exercising the full rollout->reward->training loop.
"""
from __future__ import annotations

from typing import List

PAD = 0
BOS = 1
EOS = 2

_CHARS = "0123456789+-*= "
CHAR_BASE = 3
VOCAB_SIZE = CHAR_BASE + len(_CHARS)

_C2I = {c: CHAR_BASE + i for i, c in enumerate(_CHARS)}
_I2C = {v: k for k, v in _C2I.items()}


def encode(text: str, *, bos: bool = True) -> List[int]:
    ids = [_C2I[c] for c in text]
    return ([BOS] if bos else []) + ids


def decode(ids: List[int]) -> str:
    return "".join(_I2C.get(i, "") for i in ids if i >= CHAR_BASE)


def pad_to(ids: List[int], length: int) -> List[int]:
    if len(ids) > length:
        return ids[:length]
    return ids + [PAD] * (length - len(ids))
