"""Distribution layer: GSPMD sharding rules, activation-sharding context,
pipeline parallelism, and compressed collectives."""
from repro.distributed.collectives import make_dp_allreduce, psum_compressed
from repro.distributed.ctx import activation_sharding, constrain
from repro.distributed.pipeline import bubble_fraction, gpipe_apply
from repro.distributed.sharding import (
    cache_shardings,
    opt_shardings,
    param_spec,
    params_shardings,
    replicated,
    train_batch_shardings,
)
