"""Distributed-optimization collectives (shard_map building blocks).

``compressed_psum_grads`` — gradient all-reduce over the DP axes with int8
quantization: each shard quantizes its local gradient (per-leaf symmetric
scale), all-reduces the int8 payload in int32 accumulation space, and
all-reduces the scales; the dequantized result approximates the exact psum
with 4x less wire traffic (2x vs bf16). Used by ``train.py --compress-dp``
and accounted in the roofline's collective term via
``training.compression.compressed_bytes``.

Error feedback lives OUTSIDE the collective (``training.compression``):
the residual between the exact local grad and its quantized form is
carried on-host per worker.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

try:  # jax >= 0.5 exports it at top level
    from jax import shard_map
except ImportError:  # jax 0.4.x
    from jax.experimental.shard_map import shard_map


def _quantize(g: jax.Array) -> Tuple[jax.Array, jax.Array]:
    amax = jnp.max(jnp.abs(g)).astype(jnp.float32)
    scale = jnp.where(amax > 0, amax / 127.0, 1.0)
    q = jnp.clip(jnp.round(g.astype(jnp.float32) / scale), -127, 127).astype(jnp.int8)
    return q, scale


def psum_compressed(tree: Any, axis_name) -> Any:
    """int8-compressed psum; call INSIDE shard_map.

    A shared quantization grid is required for exactness of the sum: the
    scale is the GLOBAL amax (scalar pmax — negligible wire cost), every
    shard quantizes against it, payloads accumulate in int32, and a single
    dequant recovers the sum. Per-shard error <= scale/2, so the summed
    error is bounded by n_shards * scale / 2 (tight and unbiased-ish; the
    error-feedback wrapper in ``training.compression`` absorbs the rest).
    """

    def one(g):
        amax = jax.lax.pmax(jnp.max(jnp.abs(g)).astype(jnp.float32), axis_name)
        scale = jnp.where(amax > 0, amax / 127.0, 1.0)
        q = jnp.clip(
            jnp.round(g.astype(jnp.float32) / scale), -127, 127
        ).astype(jnp.int8)
        acc = jax.lax.psum(q.astype(jnp.int32), axis_name)
        return (acc.astype(jnp.float32) * scale).astype(g.dtype)

    return jax.tree_util.tree_map(one, tree)


def make_dp_allreduce(mesh: Mesh, *, compress: bool = False, axes=("data",)):
    """Returns grads -> grads averaged over the DP axes, via shard_map.

    Gradient leaves are expected replicated over the DP axes already under
    GSPMD; this explicit variant exists for the compressed path where the
    wire format matters (int8), which GSPMD cannot express.
    """
    axis_names = tuple(a for a in axes if a in mesh.shape)

    def allreduce(grads):
        if not axis_names:
            return grads

        spec = P()  # replicated per-shard view

        @partial(
            shard_map, mesh=mesh,
            in_specs=jax.tree_util.tree_map(lambda _: spec, grads),
            out_specs=jax.tree_util.tree_map(lambda _: spec, grads),
        )
        def body(g):
            n = 1
            for a in axis_names:
                n *= mesh.shape[a]
            if compress:
                summed = g
                for a in axis_names:
                    summed = psum_compressed(summed, a)
                return jax.tree_util.tree_map(lambda x: x / n, summed)
            return jax.tree_util.tree_map(
                lambda x: jax.lax.psum(x, axis_names) / n, g
            )

        return body(grads)

    return allreduce
