"""Activation-sharding context.

Model code is mesh-agnostic; the launcher activates a context and the model
calls ``constrain(x, kind)`` at a few strategic points. Outside a context
(CPU smoke tests, single-host runtime) the calls are no-ops.

Kinds:
* ``"boundary"`` — (B, S, D) per-block boundary activations. Sharded
  batch -> (pod, data) and sequence -> "model" (Megatron-style sequence
  parallelism): the lever that keeps 76B-class training under HBM.
* ``"logits"``   — (B, S, V) output logits. vocab -> "model": the
  log-softmax then runs on vocab shards with tiny cross-shard reductions
  instead of materializing the full vocab per device.
"""
from __future__ import annotations

import threading
from contextlib import contextmanager
from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_state = threading.local()


def _active():
    return getattr(_state, "ctx", None)


@contextmanager
def activation_sharding(mesh: Mesh, *, sp: bool = True, logits_tp: bool = True):
    prev = _active()
    _state.ctx = {"mesh": mesh, "sp": sp, "logits_tp": logits_tp}
    try:
        yield
    finally:
        _state.ctx = prev


def _fit(mesh: Mesh, dim: int, axis) -> Optional[str]:
    if axis is None:
        return None
    if isinstance(axis, tuple):
        import numpy as np

        size = int(np.prod([mesh.shape.get(a, 1) for a in axis]))
    else:
        size = mesh.shape.get(axis, 1)
    return axis if dim % size == 0 else None


def constrain(x: jax.Array, kind: str) -> jax.Array:
    ctx = _active()
    if ctx is None:
        return x
    mesh = ctx["mesh"]
    batch = ("pod", "data") if "pod" in mesh.shape else ("data",)
    if kind == "boundary" and ctx["sp"] and x.ndim == 3:
        spec = P(
            _fit(mesh, x.shape[0], batch),
            _fit(mesh, x.shape[1], "model"),
            None,
        )
    elif kind == "logits" and ctx["logits_tp"] and x.ndim == 3:
        spec = P(
            _fit(mesh, x.shape[0], batch),
            None,
            _fit(mesh, x.shape[2], "model"),
        )
    elif kind == "heads" and x.ndim == 4:
        # (B, S, H, hd): pin head-parallel attention (q/k/v and scores stay
        # head-sharded; without this GSPMD may replicate the O(S^2) score
        # tensor across "model" and all-reduce it — observed 46 GB/layer on
        # internvl2-76b prefill_32k, EXPERIMENTS.md §Perf)
        spec = P(
            _fit(mesh, x.shape[0], batch),
            None,
            _fit(mesh, x.shape[2], "model"),
            None,
        )
    else:
        return x
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
