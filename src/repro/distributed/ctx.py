"""Activation-sharding context.

Model code is mesh-agnostic; the launcher activates a context and the model
calls ``constrain(x, kind)`` at a few strategic points. Outside a context
(CPU smoke tests, single-host runtime) the calls are no-ops.

Kinds:
* ``"boundary"`` — (B, S, D) per-block boundary activations. Sharded
  batch -> (pod, data) and sequence -> "model" (Megatron-style sequence
  parallelism): the lever that keeps 76B-class training under HBM.
* ``"logits"``   — (B, S, V) output logits. vocab -> "model": the
  log-softmax then runs on vocab shards with tiny cross-shard reductions
  instead of materializing the full vocab per device.

Rollout tensor-parallel context (``rollout_sharding`` / ``gather``): the
sharded rollout backend (``repro.rollout.sharded``) runs one instance's
prefill/decode SPMD over a 1-D ``("tensor",)`` mesh with head-sharded
weights and a head-sharded paged KV pool. Its contract is *bit-for-bit*
equality with the single-device engine, so cross-shard reductions are
forbidden: instead of letting GSPMD partial-sum a contraction over a
sharded dimension (float addition order would change), the model gathers
activations to replicated form at each sharded-dim boundary via
``gather(x)`` — per-shard values are exact, the following full-width
reduction then runs identically on every device. Outside the context
``gather`` is a no-op, like ``constrain``.
"""
from __future__ import annotations

import threading
from contextlib import contextmanager
from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_state = threading.local()


def _active():
    return getattr(_state, "ctx", None)


@contextmanager
def activation_sharding(mesh: Mesh, *, sp: bool = True, logits_tp: bool = True):
    prev = _active()
    _state.ctx = {"mesh": mesh, "sp": sp, "logits_tp": logits_tp}
    try:
        yield
    finally:
        _state.ctx = prev


def _fit(mesh: Mesh, dim: int, axis) -> Optional[str]:
    if axis is None:
        return None
    if isinstance(axis, tuple):
        import numpy as np

        size = int(np.prod([mesh.shape.get(a, 1) for a in axis]))
    else:
        size = mesh.shape.get(axis, 1)
    return axis if dim % size == 0 else None


def constrain(x: jax.Array, kind: str) -> jax.Array:
    ctx = _active()
    if ctx is None:
        return x
    mesh = ctx["mesh"]
    batch = ("pod", "data") if "pod" in mesh.shape else ("data",)
    if kind == "boundary" and ctx["sp"] and x.ndim == 3:
        spec = P(
            _fit(mesh, x.shape[0], batch),
            _fit(mesh, x.shape[1], "model"),
            None,
        )
    elif kind == "logits" and ctx["logits_tp"] and x.ndim == 3:
        spec = P(
            _fit(mesh, x.shape[0], batch),
            None,
            _fit(mesh, x.shape[2], "model"),
        )
    elif kind == "heads" and x.ndim == 4:
        # (B, S, H, hd): pin head-parallel attention (q/k/v and scores stay
        # head-sharded; without this GSPMD may replicate the O(S^2) score
        # tensor across "model" and all-reduce it — observed 46 GB/layer on
        # internvl2-76b prefill_32k, EXPERIMENTS.md §Perf)
        spec = P(
            _fit(mesh, x.shape[0], batch),
            None,
            _fit(mesh, x.shape[2], "model"),
            None,
        )
    else:
        return x
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


# ---------------------------------------------------- rollout tensor parallel
_rollout_state = threading.local()


def _rollout_mesh() -> Optional[Mesh]:
    return getattr(_rollout_state, "mesh", None)


@contextmanager
def rollout_sharding(mesh: Mesh):
    """Activate decode-TP gathers for one sharded rollout instance.

    The sharded runners (``repro.rollout.sharded``) enter this context
    around every jitted prefill/decode call so the traced model body bakes
    in the ``gather`` constraints. Nesting restores the previous mesh on
    exit, and instances on different meshes never share jit caches (each
    runner owns its own), so contexts cannot leak across backends.
    """
    prev = _rollout_mesh()
    _rollout_state.mesh = mesh
    try:
        yield
    finally:
        _rollout_state.mesh = prev


def gather(x: jax.Array) -> jax.Array:
    """Pin ``x`` fully replicated at a sharded-dimension boundary.

    Called by model code right before a reduction would cross a
    tensor-sharded dimension (attention head outputs before ``wo``, the
    SwiGLU hidden before the down projection, final logits before
    sampling). The all-gather reconstructs exact per-shard values, so the
    following full-width contraction is bitwise identical to the
    single-device computation — the property the sharded backend's
    equivalence tests pin. No-op outside ``rollout_sharding``.
    """
    mesh = _rollout_mesh()
    if mesh is None:
        return x
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, P()))


def gather_params(tree):
    """Gather a (possibly shard-stored) parameter tree to replicated form
    at the top of a jitted rollout step (ZeRO-3 style just-in-time
    materialization).

    Weights *stored* sharded (``sharding.rollout_param_spec``) cut
    per-device parameter HBM, but a column-sharded matmul is not
    bitwise-stable against its full-width counterpart on every backend
    (XLA may pick a different micro-kernel per output width — observed on
    CPU for 2-row prefill buckets). Gathering the weights inside the step
    keeps every matmul full-width and replicated, so only the KV pool —
    whose ops are per-head and reduction-free — stays sharded during
    compute. No-op outside ``rollout_sharding``.
    """
    if _rollout_mesh() is None:
        return tree
    return jax.tree_util.tree_map(gather, tree)
