"""GPipe-style pipeline parallelism over a "stage" mesh axis.

Not used by the default production mesh (DP x TP saturates 256 chips for
the assigned model sizes); provided as the scale-out lever beyond ~10^3
chips, where a third axis keeps TP groups intra-pod and DCN hops become
pipeline edges (DESIGN.md §3).

``gpipe_apply`` runs a stage-sharded stack of layers over M microbatches
with the classic (M + S - 1)-tick schedule inside ONE shard_map:

  tick t:  stage 0 ingests microbatch t (while t < M);
           every stage applies its layers to its current buffer;
           activations hop stage s -> s+1 via collective_permute;
           stage S-1 emits microbatch t-(S-1) (while t >= S-1).

Bubble fraction = (S-1)/(M+S-1), the GPipe bound. Activations are the only
cross-stage traffic (one (mb, ...) tensor per tick per edge).
"""
from __future__ import annotations

from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

try:  # jax >= 0.5 exports it at top level
    from jax import shard_map
except ImportError:  # jax 0.4.x
    from jax.experimental.shard_map import shard_map


def _pvary(x, axes):
    """Mark ``x`` as varying over ``axes`` for shard_map's rep typing.
    jax 0.4.x has no ``lax.pvary`` (and no varying-axis check) — identity."""
    fn = getattr(jax.lax, "pvary", None)
    return fn(x, axes) if fn is not None else x


def gpipe_apply(
    stage_fn: Callable[[Any, jax.Array], jax.Array],
    stage_params: Any,            # leaves with leading dim = n_stages
    x_microbatches: jax.Array,    # (M, mb, ...) microbatched inputs
    *,
    mesh: Mesh,
    stage_axis: str = "stage",
) -> jax.Array:
    """Returns (M, mb, ...) outputs of the full stage stack."""
    n_stages = mesh.shape[stage_axis]
    m = x_microbatches.shape[0]
    ticks = m + n_stages - 1

    params_specs = jax.tree_util.tree_map(lambda _: P(stage_axis), stage_params)

    @partial(
        shard_map,
        mesh=mesh,
        in_specs=(params_specs, P()),
        out_specs=P(),
    )
    def run(sp_local, xs):
        sp = jax.tree_util.tree_map(lambda a: a[0], sp_local)  # drop stage dim
        sid = jax.lax.axis_index(stage_axis)
        perm = [(i, i + 1) for i in range(n_stages - 1)]

        def tick(t, carry):
            buf, outs = carry
            mb_in = jnp.clip(t, 0, m - 1)
            inp = jnp.where(sid == 0, xs[mb_in], buf)
            y = stage_fn(sp, inp)
            # stage S-1 emits microbatch t-(S-1); other stages contribute 0
            mb_out = jnp.clip(t - (n_stages - 1), 0, m - 1)
            emit = jnp.logical_and(sid == n_stages - 1, t >= n_stages - 1)
            outs = outs.at[mb_out].add(
                jnp.where(emit, y, jnp.zeros_like(y))
            )
            buf = jax.lax.ppermute(y, stage_axis, perm)
            return buf, outs

        # initial carries must be marked stage-varying for shard_map typing
        buf0 = _pvary(jnp.zeros_like(xs[0]), (stage_axis,))
        outs0 = _pvary(jnp.zeros_like(xs), (stage_axis,))
        _, outs = jax.lax.fori_loop(0, ticks, tick, (buf0, outs0))
        # outputs live on the last stage only; sum across stages replicates
        return jax.lax.psum(outs, stage_axis)

    return run(stage_params, x_microbatches)


def bubble_fraction(n_stages: int, n_microbatches: int) -> float:
    return (n_stages - 1) / (n_microbatches + n_stages - 1)
