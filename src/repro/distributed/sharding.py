"""GSPMD sharding rules for the production mesh.

Mesh axes (launch/mesh.py): single-pod ``(data=16, model=16)``; multi-pod
``(pod=2, data=16, model=16)`` — "pod" is pure data parallelism across the
DCN (params replicated per pod, gradients all-reduced over pod+data).

Parameter layout (FSDP x TP, ZeRO-3 style):
* matmul weights:  input-feature dim -> "data" (FSDP), output-feature /
  head / expert dim -> "model" (TP / EP);
* embeddings & LM head: vocab -> "model", d_model -> "data";
* MoE experts: expert dim -> "model" (expert parallelism), inner dims ->
  "data";
* norms / small vectors: replicated.

Every rule validates divisibility: a dimension that does not divide the
mesh axis falls back to replication on that dim (e.g. granite's vocab
49155 is not 16-divisible -> vocab stays unsharded rather than relying on
GSPMD padding). Optimizer states reuse the parameter specs (m/v mirror the
param tree).

Activation/cache policy:
* training batch -> ("pod","data"); sequence-parallel boundary constraint
  (d_model activations sharded over "model") is applied inside the scanned
  block when ``sp=True`` — the memory lever that fits 76B+ training;
* decode caches: batch -> ("pod","data") when divisible, cache sequence ->
  "model" (flash-decode style partial-softmax partitioning);
* recurrent states: head/inner dims -> "model".
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def _axis_size(mesh: Mesh, name: Optional[str]) -> int:
    if name is None:
        return 1
    if isinstance(name, tuple):
        return int(np.prod([_axis_size(mesh, n) for n in name]))
    return mesh.shape[name] if name in mesh.shape else 1


def _fit(mesh: Mesh, dim: int, axis) -> Optional[str]:
    """Return ``axis`` if ``dim`` divides its size, else None (replicate)."""
    return axis if axis is not None and dim % _axis_size(mesh, axis) == 0 else None


def _spec(mesh: Mesh, shape: Tuple[int, ...], *dims) -> P:
    """Right-aligned axis proposals -> PartitionSpec with divisibility
    fallback; leading dimensions (stacked layers) stay replicated."""
    lead = len(shape) - len(dims)
    out = [None] * lead
    for size, ax in zip(shape[lead:], dims):
        out.append(_fit(mesh, size, ax))
    return P(*out)


def batch_axes(mesh: Mesh) -> Tuple[str, ...]:
    return ("pod", "data") if "pod" in mesh.shape else ("data",)


# ------------------------------------------------------------------- params
def param_spec(mesh: Mesh, path: str, shape: Tuple[int, ...]) -> P:
    """PartitionSpec for one parameter leaf, keyed by its tree path.

    ``path`` is a ``jax.tree_util.keystr`` string like ``['blocks']['wq']``;
    the rule dispatches on the LAST quoted segment, so optimizer-state paths
    (``['m']['blocks']['wq']``) resolve to the same spec as their params.
    """
    name = path.split("'")[-2] if "'" in path else path

    def spec(*dims):
        """dims: one axis proposal per trailing dimension (right-aligned)."""
        return _spec(mesh, shape, *dims)

    if name in ("embed",):
        return spec("model", "data")           # (V, D)
    if name in ("lm_head",):
        return spec("data", "model")           # (D, V)
    if name in ("wq", "wk", "wv"):
        return spec("data", "model")           # (..., D, H*hd)
    if name in ("bq", "bk", "bv"):
        return spec("model")
    if name == "wo":
        return spec("model", "data")           # (..., H*hd, D)
    if name in ("w_gate", "w_up"):
        return spec("data", "model")           # (..., D, F)
    if name == "w_down":
        return spec("model", "data")           # (..., F, D)
    if name in ("ws_gate", "ws_up"):
        return spec("data", "model")
    if name == "ws_down":
        return spec("model", "data")
    if name == "router":
        return spec("data", None)              # (..., D, E) E small
    if name in ("we_gate", "we_up"):
        return spec("model", "data", None)     # (..., E, D, F): EP on E
    if name == "we_down":
        return spec("model", None, "data")     # (..., E, F, D)
    # --- mamba (hybrid) ---
    if name == "w_in":
        return spec("data", "model")           # (..., D, 2I)
    if name == "w_out":
        return spec("model", "data")           # (..., I, D)
    if name == "conv_w":
        return spec(None, "model")             # (..., W, I)
    if name == "w_bc":
        return spec("model", None)             # (..., I, 2N)
    if name in ("w_dt", "d_skip", "dt_bias"):
        return spec("model")                   # (..., I)
    if name == "a_log":
        return spec("model", None)             # (..., I, N)
    # --- xlstm ---
    if name == "w_gates":
        return spec("data", "model")           # (..., D, 4*H*hd)
    if name == "w_if":
        return spec("data", "model")           # (..., D, 2H)
    if name == "r_weights":
        return spec(None, None, "model")       # (..., 4, H, hd, hd)
    # norms & everything small: replicated
    return P()


def params_shardings(mesh: Mesh, params: Any) -> Any:
    def one(path, leaf):
        return NamedSharding(
            mesh, param_spec(mesh, jax.tree_util.keystr(path), np.shape(leaf))
        )

    return jax.tree_util.tree_map_with_path(one, params)


def opt_shardings(mesh: Mesh, opt_state: Any) -> Any:
    """m/v mirror the params; scalar step is replicated."""

    def one(path, leaf):
        if np.ndim(leaf) == 0:
            return NamedSharding(mesh, P())
        key = jax.tree_util.keystr(path)
        return NamedSharding(mesh, param_spec(mesh, key, np.shape(leaf)))

    return jax.tree_util.tree_map_with_path(one, opt_state)


# ------------------------------------------------------------------- batches
def train_batch_shardings(mesh: Mesh, batch: Any) -> Any:
    b_axes = batch_axes(mesh)

    def one(path, leaf):
        key = jax.tree_util.keystr(path)
        shape = np.shape(leaf)
        if not shape:
            return NamedSharding(mesh, P())
        first = _fit(mesh, shape[0], b_axes)
        if "frontend" in key and len(shape) == 3:
            # patch/frame embeddings: d_model -> "model" (batch uses "data")
            return NamedSharding(mesh, P(first, None, _fit(mesh, shape[2], "model")))
        return NamedSharding(mesh, P(first, *([None] * (len(shape) - 1))))

    return jax.tree_util.tree_map_with_path(one, batch)


def cache_shardings(mesh: Mesh, cache: Any) -> Any:
    """Decode caches: batch -> (pod,data); cache sequence -> model; recurrent
    inner dims -> model."""
    b_axes = batch_axes(mesh)

    def kv(path, leaf):
        key = jax.tree_util.keystr(path)
        shape = np.shape(leaf)
        name = key.split("'")[-2] if "'" in key else key
        if name == "pos" or not shape:
            return NamedSharding(mesh, P())
        if name in ("k", "v", "xk", "xv") and len(shape) == 5:
            # (L, B, S, Hkv, hd)
            return NamedSharding(mesh, P(
                None,
                _fit(mesh, shape[1], b_axes),
                _fit(mesh, shape[2], "model"),
                None,
                None,
            ))
        if name == "conv" and len(shape) == 4:   # (L, B, W-1, I)
            return NamedSharding(mesh, P(
                None, _fit(mesh, shape[1], b_axes), None,
                _fit(mesh, shape[3], "model"),
            ))
        if name == "ssm" and len(shape) == 4:    # (L, B, I, N)
            return NamedSharding(mesh, P(
                None, _fit(mesh, shape[1], b_axes),
                _fit(mesh, shape[2], "model"), None,
            ))
        if name == "mlstm":                      # (G, p-1, B, H, dk[, dv])
            rest = [None] * (len(shape) - 3)
            if len(shape) >= 5:
                rest[-1] = _fit(mesh, shape[-1], "model")
            return NamedSharding(mesh, P(
                None, None, _fit(mesh, shape[2], b_axes), *rest
            ))
        if name == "slstm":                      # (G, B, H, dh)
            return NamedSharding(mesh, P(
                None, _fit(mesh, shape[1], b_axes), None,
                _fit(mesh, shape[-1], "model"),
            ))
        return NamedSharding(mesh, P(*([None] * len(shape))))

    return jax.tree_util.tree_map_with_path(kv, cache)


def replicated(mesh: Mesh, tree: Any) -> Any:
    return jax.tree_util.tree_map(
        lambda leaf: NamedSharding(mesh, P()), tree
    )


# ------------------------------------------------- rollout tensor parallel
# A sharded rollout instance ("instance = pod") runs prefill/decode SPMD
# over a 1-D ("tensor",) mesh with a *bitwise* contract
# (repro.rollout.sharded): the paged KV pool is sharded on its KV-head
# axis — attention is per-head and softmax reduces over the unsharded
# sequence axis, so no partitioned computation ever changes a float —
# and head outputs gather to replicated form before the wo contraction
# (ctx.gather). Parameters are *stored* column-sharded (output dims
# only: heads on wq/wk/wv, SwiGLU hidden on w_gate/w_up, vocab on
# lm_head; wo / w_down / embed / norms replicate) and are gathered
# replicated inside each jitted step (ctx.gather_params, ZeRO-3 style)
# so matmuls stay full-width: column-sharded matmuls are not
# bitwise-stable against their full-width counterparts.
ROLLOUT_AXIS = "tensor"


def validate_rollout_shards(
    shard_count: int, *, n_heads: int, n_kv_heads: int
) -> None:
    """Head divisibility required by the head-sharded rollout layout.

    The paged K/V pool shards its ``Hkv`` axis and q its head axis, so
    ``shard_count`` must divide both head counts — otherwise the pool
    cannot split without GSPMD padding (which would break the exact
    per-device memory accounting the coordinator relies on).
    """
    if shard_count < 1:
        raise ValueError(f"shard_count must be >= 1, got {shard_count}")
    if n_kv_heads % shard_count or n_heads % shard_count:
        raise ValueError(
            f"shard_count {shard_count} must divide n_kv_heads "
            f"{n_kv_heads} and n_heads {n_heads} (head-sharded KV pool)"
        )


def rollout_param_spec(mesh: Mesh, path: str, shape: Tuple[int, ...]) -> P:
    """PartitionSpec for one rollout-replica parameter leaf.

    Column (output-dim) sharding only — see the module comment above for
    why the reduction-side weights stay replicated.
    """
    name = path.split("'")[-2] if "'" in path else path
    if name in ("wq", "wk", "wv"):
        return _spec(mesh, shape, None, ROLLOUT_AXIS)   # (..., D, H*hd)
    if name in ("bq", "bk", "bv"):
        return _spec(mesh, shape, ROLLOUT_AXIS)
    if name in ("w_gate", "w_up", "ws_gate", "ws_up"):
        return _spec(mesh, shape, None, ROLLOUT_AXIS)   # (..., D, F)
    if name == "lm_head":
        return _spec(mesh, shape, None, ROLLOUT_AXIS)   # (D, V)
    return P()


def rollout_params_shardings(mesh: Mesh, params: Any) -> Any:
    def one(path, leaf):
        return NamedSharding(
            mesh,
            rollout_param_spec(
                mesh, jax.tree_util.keystr(path), np.shape(leaf)
            ),
        )

    return jax.tree_util.tree_map_with_path(one, params)


def paged_pool_spec(mesh: Mesh, shape: Tuple[int, ...]) -> P:
    """Spec for one paged K/V pool: ``(L, n_blocks, bs, Hkv, hd)`` with
    the KV-head axis sharded over ``tensor`` — every device holds the
    full block structure (tables replicate) but only ``Hkv/shards`` heads
    per block, so per-device KV bytes are ``total / shard_count``."""
    if len(shape) != 5:
        raise ValueError(f"paged pool must be rank 5, got shape {shape}")
    return P(None, None, None, _fit(mesh, shape[3], ROLLOUT_AXIS), None)


def paged_cache_shardings(mesh: Mesh, cache: Any) -> Any:
    """NamedShardings for a paged decode cache: K/V pools head-sharded,
    per-slot small state (pos, hybrid conv/ssm, audio cross) replicated —
    it is O(1) per slot and host-indexed by the runners."""
    out = {}
    for name, val in cache.items():
        if name in ("k", "v"):
            out[name] = NamedSharding(mesh, paged_pool_spec(mesh, val.shape))
        else:
            out[name] = replicated(mesh, val)
    return out
