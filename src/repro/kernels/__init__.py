"""Pallas TPU kernels for the perf-critical compute layers.

* ``flash_attention``  — training/prefill attention (online softmax, GQA)
* ``decode_attention`` — rollout decode vs KV cache (paper Table 3: 89.9%
                         of rollout step time is per-token decode)
* ``moe_gmm``          — grouped expert matmul (MoE FFN)
* ``dapo_loss``        — fused token-level clipped PG loss + reduction
* ``block_copy``       — paged-pool block move (copy-on-write tails for
                         prefix-shared group rollout)

``ops`` is the dispatch layer (ref | pallas | interpret); ``ref`` holds the
pure-jnp oracles the tests validate against.
"""
