"""Pallas TPU pool-block copy — the device side of copy-on-write.

Prefix sharing (``repro.rollout.prefix_cache``) maps a group prompt's full
KV blocks read-only into every member's block table, but the partially
filled tail block must be duplicated per member so decode appends never
alias. The duplication is a pure HBM->HBM block move inside the K/V pools
(``(layers, n_blocks, bs, Hkv, hd)``); materializing it in XLA as
``pool.at[:, dst].set(pool[:, src])`` round-trips the *entire* pool through
a gather/scatter pair. This kernel moves only the touched blocks:

* ``src``/``dst`` block indices are scalar-prefetched; grid step
  ``(c, layer)`` DMAs pool block ``src[c]`` of one layer into VMEM and
  writes it back at ``dst[c]`` — both K and V in the same step;
* the pools alias their outputs (``input_output_aliases``), so untouched
  blocks never move — per copy, exactly ``2 * bs * Hkv * hd`` elements of
  HBM traffic per layer, independent of pool size.

Callers pad the copy list to a bucketed length with ``dst = NULL_BLOCK``
(the pool's garbage sink): padded steps write garbage into a block nothing
reads unmasked, keeping compiled shapes stable.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _copy_kernel(src_ref, dst_ref, ki_ref, vi_ref, ko_ref, vo_ref):
    del src_ref, dst_ref  # consumed by the BlockSpec index maps
    ko_ref[...] = ki_ref[...]
    vo_ref[...] = vi_ref[...]


@functools.partial(
    jax.jit, static_argnames=("interpret",), donate_argnums=(0, 1)
)
def copy_pool_blocks(
    k_pool: jax.Array,        # (L, N, bs, Hkv, hd) — aliased, updated in place
    v_pool: jax.Array,        # (L, N, bs, Hkv, hd) — aliased, updated in place
    src: jax.Array,           # (C,) int32 source block per copy
    dst: jax.Array,           # (C,) int32 destination block per copy
    *,
    interpret: bool = False,
):
    """Copy pool blocks ``src[c] -> dst[c]`` in both K and V pools.

    Returns ``(k_pool', v_pool')``. Destinations must be distinct (the
    rollout allocator hands out fresh tail blocks, so they are); a padded
    entry may target the null block.
    """
    l, n, bs, hkv, hd = k_pool.shape
    c = src.shape[0]

    blk = pl.BlockSpec(
        (1, 1, bs, hkv, hd), lambda ic, il, s, d: (il, s[ic], 0, 0, 0)
    )
    out_blk = pl.BlockSpec(
        (1, 1, bs, hkv, hd), lambda ic, il, s, d: (il, d[ic], 0, 0, 0)
    )
    new_k, new_v = pl.pallas_call(
        _copy_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=(c, l),
            in_specs=[blk, blk],
            out_specs=[out_blk, out_blk],
        ),
        out_shape=[
            jax.ShapeDtypeStruct(k_pool.shape, k_pool.dtype),
            jax.ShapeDtypeStruct(v_pool.shape, v_pool.dtype),
        ],
        # operand order: (src, dst, k_pool, v_pool)
        input_output_aliases={2: 0, 3: 1},
        interpret=interpret,
    )(src.astype(jnp.int32), dst.astype(jnp.int32), k_pool, v_pool)
    return new_k, new_v
