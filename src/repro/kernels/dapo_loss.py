"""Pallas TPU fused DAPO loss (token-level clipped PG objective).

Training consumes batches of up to ``batch * group * seq`` token logprobs;
the loss is elementwise (ratio, clip, min) followed by a masked global
reduction. Unfused, XLA materializes several (B, T) f32 temporaries in HBM;
the kernel fuses the elementwise chain with a two-stage reduction — each
grid cell reduces its (bb, bt) tile to partial sums in VMEM and the final
(n_bb, n_bt) partials are summed outside (tiny).

Outputs three partial-sum planes: clipped objective, ratio (a staleness
diagnostic: mean importance weight of the consumed batch), and mask count.

Interpret-mode validated against ``ref.dapo_loss_ref``.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _dapo_kernel(
    lp_ref, olp_ref, adv_ref, mask_ref,
    obj_ref, ratio_ref, cnt_ref,
    *, eps_low: float, eps_high: float,
):
    lp = lp_ref[...].astype(jnp.float32)
    olp = olp_ref[...].astype(jnp.float32)
    adv = adv_ref[...].astype(jnp.float32)          # (bb, 1)
    m = mask_ref[...].astype(jnp.float32)
    ratio = jnp.exp(lp - olp)
    clipped = jnp.clip(ratio, 1.0 - eps_low, 1.0 + eps_high)
    obj = jnp.minimum(ratio * adv, clipped * adv)
    obj_ref[0, 0] = jnp.sum(obj * m)
    ratio_ref[0, 0] = jnp.sum(ratio * m)
    cnt_ref[0, 0] = jnp.sum(m)


@functools.partial(
    jax.jit, static_argnames=("eps_low", "eps_high", "bb", "bt", "interpret")
)
def dapo_loss(
    logprobs: jax.Array,       # (B, T)
    old_logprobs: jax.Array,   # (B, T)
    advantages: jax.Array,     # (B,)
    mask: jax.Array,           # (B, T)
    *,
    eps_low: float = 0.2,
    eps_high: float = 0.28,
    bb: int = 8,
    bt: int = 512,
    interpret: bool = False,
) -> Tuple[jax.Array, jax.Array]:
    b, t = logprobs.shape
    bb, bt = min(bb, b), min(bt, t)
    if b % bb or t % bt:
        raise ValueError(f"shape ({b},{t}) must divide blocks ({bb},{bt})")
    grid = (b // bb, t // bt)
    adv2d = advantages.reshape(b, 1)

    partial_shape = jax.ShapeDtypeStruct(grid, jnp.float32)
    obj_p, ratio_p, cnt_p = pl.pallas_call(
        functools.partial(_dapo_kernel, eps_low=eps_low, eps_high=eps_high),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bb, bt), lambda ib, it: (ib, it)),
            pl.BlockSpec((bb, bt), lambda ib, it: (ib, it)),
            pl.BlockSpec((bb, 1), lambda ib, it: (ib, 0)),
            pl.BlockSpec((bb, bt), lambda ib, it: (ib, it)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1), lambda ib, it: (ib, it)),
            pl.BlockSpec((1, 1), lambda ib, it: (ib, it)),
            pl.BlockSpec((1, 1), lambda ib, it: (ib, it)),
        ],
        out_shape=[partial_shape, partial_shape, partial_shape],
        interpret=interpret,
    )(logprobs, old_logprobs, adv2d, mask)

    denom = jnp.maximum(cnt_p.sum(), 1.0)
    loss = -obj_p.sum() / denom
    mean_ratio = ratio_p.sum() / denom
    return loss, mean_ratio
