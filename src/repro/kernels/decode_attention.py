"""Pallas TPU decode attention — the rollout hot spot.

The paper's Table 3 attributes 89.9% of rollout step time to per-token
decode; on TPU this op is HBM-bandwidth bound (it streams the whole KV
cache per step), so the kernel's job is to move KV through VMEM in large
aligned blocks with no repeated GQA materialization.

One new token per sequence attends to a (B, S, Hkv, hd) cache:
grid ``(B, S/bk)`` with the cache dimension innermost; the query block
(all H heads of one sequence — a single token) stays resident in VMEM
across the whole sweep while K/V stream through. Online softmax scratch
(acc/m/l) is carried per-sequence and the output is written on the final
cache block. Ring-cache validity is handled with a per-sequence length
(SMEM scalar): positions ``>= length`` are masked.

GQA: the query is reshaped to (Hkv, rep, hd) so scores are computed
directly against un-repeated KV — ``rep``x less VMEM traffic than
repeat-then-MHA.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _decode_kernel(
    len_ref,                  # SMEM (1,) valid length for this sequence
    q_ref,                    # (1, H, hd)
    k_ref, v_ref,             # (1, bk, Hkv, hd)
    o_ref,                    # (1, H, hd)
    acc_ref, m_ref, l_ref,    # VMEM scratch (H, hd), (H, 1), (H, 1)
    *, bk: int, n_blocks: int, rep: int, scale: float,
):
    ik = pl.program_id(1)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    length = len_ref[pl.program_id(0)]
    k_lo = ik * bk

    @pl.when(k_lo < length)
    def _compute():
        q = q_ref[0].astype(jnp.float32)             # (H, hd)
        k = k_ref[0].astype(jnp.float32)             # (bk, Hkv, hd)
        v = v_ref[0].astype(jnp.float32)             # (bk, Hkv, hd)
        h, hd = q.shape
        hkv = k.shape[1]
        qg = q.reshape(hkv, rep, hd)
        # scores: (Hkv, rep, bk) = qg . k^T over hd, batched over Hkv
        s = jax.lax.dot_general(
            qg, k.transpose(1, 2, 0),               # (Hkv, hd, bk)
            (((2,), (1,)), ((0,), (0,))),
            preferred_element_type=jnp.float32,
        ) * scale
        kpos = k_lo + jax.lax.broadcasted_iota(jnp.int32, s.shape, 2)
        s = jnp.where(kpos < length, s, NEG_INF)

        sh = s.reshape(h, -1)                        # (H, bk)
        m_prev, l_prev = m_ref[...], l_ref[...]
        m_cur = jnp.max(sh, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(sh - m_new)                      # (H, bk)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = alpha * l_prev + jnp.sum(p, axis=-1, keepdims=True)
        pg = p.reshape(hkv, rep, -1)                 # (Hkv, rep, bk)
        out = jax.lax.dot_general(
            pg, v.transpose(1, 0, 2),               # (Hkv, bk, hd)
            (((2,), (1,)), ((0,), (0,))),
            preferred_element_type=jnp.float32,
        )                                            # (Hkv, rep, hd)
        acc_ref[...] = acc_ref[...] * alpha + out.reshape(h, hd)
        m_ref[...] = m_new

    @pl.when(ik == n_blocks - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l).astype(o_ref.dtype)


def _decode_update_kernel(
    scalars_ref,              # SMEM (2, B): row 0 = write_pos, row 1 = lengths
    q_ref, k_ref, v_ref,      # (1, H, hd), (1, bk, Hkv, hd) x2
    kn_ref, vn_ref,           # (1, Hkv, hd) new row
    o_ref, ko_ref, vo_ref,    # (1, H, hd), (1, bk, Hkv, hd) x2 (aliased caches)
    acc_ref, m_ref, l_ref,
    *, bk: int, n_blocks: int, rep: int, scale: float,
):
    ib = pl.program_id(0)
    ik = pl.program_id(1)
    wp = scalars_ref[0, ib]
    length = scalars_ref[1, ib]
    wp_blk = wp // bk

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    k_lo = ik * bk

    @pl.when(k_lo < length)
    def _compute():
        q = q_ref[0].astype(jnp.float32)
        k = k_ref[0].astype(jnp.float32)
        v = v_ref[0].astype(jnp.float32)
        h, hd = q.shape
        hkv = k.shape[1]
        qg = q.reshape(hkv, rep, hd)
        s = jax.lax.dot_general(
            qg, k.transpose(1, 2, 0), (((2,), (1,)), ((0,), (0,))),
            preferred_element_type=jnp.float32,
        ) * scale
        kpos = k_lo + jax.lax.broadcasted_iota(jnp.int32, s.shape, 2)
        # exclude the slot being overwritten (ring eviction) — its NEW
        # contribution is added analytically on the last step
        s = jnp.where((kpos < length) & (kpos != wp), s, NEG_INF)
        sh = s.reshape(h, -1)
        m_prev, l_prev = m_ref[...], l_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(sh, axis=-1, keepdims=True))
        p = jnp.exp(sh - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = alpha * l_prev + jnp.sum(p, axis=-1, keepdims=True)
        pg = p.reshape(hkv, rep, -1)
        out = jax.lax.dot_general(
            pg, v.transpose(1, 0, 2), (((2,), (1,)), ((0,), (0,))),
            preferred_element_type=jnp.float32,
        )
        acc_ref[...] = acc_ref[...] * alpha + out.reshape(h, hd)
        m_ref[...] = m_new

    # in-place row write: fill the aliased output block once (from the
    # matching input block) and overwrite the single row — the rest of the
    # cache never moves (input_output_aliasing)
    @pl.when(ik == wp_blk)
    def _write_row():
        blk_k = k_ref[0]
        blk_v = v_ref[0]
        row = wp % bk
        ko_ref[0] = blk_k
        vo_ref[0] = blk_v
        ko_ref[0, row] = kn_ref[0].astype(ko_ref.dtype)
        vo_ref[0, row] = vn_ref[0].astype(vo_ref.dtype)

    @pl.when(ik == n_blocks - 1)
    def _finalize():
        # analytic contribution of the NEW token (not yet in the cache)
        q = q_ref[0].astype(jnp.float32)
        h, hd = q.shape
        kn = kn_ref[0].astype(jnp.float32)       # (Hkv, hd)
        vn = vn_ref[0].astype(jnp.float32)
        hkv = kn.shape[0]
        qg = q.reshape(hkv, rep, hd)
        s_new = jnp.sum(qg * kn[:, None, :], axis=-1).reshape(h, 1) * scale
        m_prev, l_prev = m_ref[...], l_ref[...]
        m_fin = jnp.maximum(m_prev, s_new)
        p_new = jnp.exp(s_new - m_fin)           # (H, 1)
        alpha = jnp.exp(m_prev - m_fin)
        l_fin = alpha * l_prev + p_new
        vrep = jnp.broadcast_to(
            vn[:, None, :], (hkv, rep, hd)
        ).reshape(h, hd)
        acc_fin = acc_ref[...] * alpha + p_new * vrep
        o_ref[0] = (acc_fin / jnp.maximum(l_fin, 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bk", "interpret"), donate_argnums=(1, 2))
def decode_attention_update(
    q: jax.Array,            # (B, H, hd)
    k_cache: jax.Array,      # (B, S, Hkv, hd) — donated, updated in place
    v_cache: jax.Array,      # (B, S, Hkv, hd) — donated, updated in place
    k_new: jax.Array,        # (B, Hkv, hd) this step's key
    v_new: jax.Array,        # (B, Hkv, hd) this step's value
    write_pos: jax.Array,    # (B,) int32 ring slot to overwrite
    lengths: jax.Array,      # (B,) int32 valid entries INCLUDING the new one
    *,
    bk: int = 512,
    interpret: bool = False,
):
    """Fused decode attention + in-place ring-cache row write.

    The XLA-graph decode path must rewrite the full cache per layer (the
    one-hot select of EXPERIMENTS.md §Perf A1); this kernel streams the
    cache through VMEM once, writes back ONLY the touched block (the
    caches alias their outputs), and folds the new token's attention
    contribution in analytically — the useful-byte floor of the decode
    roofline. Returns (out (B, H, hd), k_cache', v_cache')."""
    b, h, hd = q.shape
    s, hkv = k_cache.shape[1], k_cache.shape[2]
    rep = h // hkv
    bk = min(bk, s)
    if s % bk:
        raise ValueError(f"cache length {s} must divide block {bk}")
    n_blocks = s // bk
    scale = 1.0 / math.sqrt(hd)
    scalars = jnp.stack(
        [write_pos.astype(jnp.int32), lengths.astype(jnp.int32)]
    )

    grid = (b, n_blocks)
    # scalar-prefetched write positions drive the OUTPUT cache block index:
    # only the touched block is ever written back (in-place via aliasing)
    out, new_k, new_v = pl.pallas_call(
        functools.partial(
            _decode_update_kernel, bk=bk, n_blocks=n_blocks, rep=rep,
            scale=scale,
        ),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, h, hd), lambda ib, ik, sc: (ib, 0, 0)),
                pl.BlockSpec((1, bk, hkv, hd), lambda ib, ik, sc: (ib, ik, 0, 0)),
                pl.BlockSpec((1, bk, hkv, hd), lambda ib, ik, sc: (ib, ik, 0, 0)),
                pl.BlockSpec((1, hkv, hd), lambda ib, ik, sc: (ib, 0, 0)),
                pl.BlockSpec((1, hkv, hd), lambda ib, ik, sc: (ib, 0, 0)),
            ],
            out_specs=[
                pl.BlockSpec((1, h, hd), lambda ib, ik, sc: (ib, 0, 0)),
                pl.BlockSpec(
                    (1, bk, hkv, hd),
                    lambda ib, ik, sc: (ib, sc[0, ib] // bk, 0, 0),
                ),
                pl.BlockSpec(
                    (1, bk, hkv, hd),
                    lambda ib, ik, sc: (ib, sc[0, ib] // bk, 0, 0),
                ),
            ],
            scratch_shapes=[
                pltpu.VMEM((h, hd), jnp.float32),
                pltpu.VMEM((h, 1), jnp.float32),
                pltpu.VMEM((h, 1), jnp.float32),
            ],
        ),
        out_shape=[
            jax.ShapeDtypeStruct((b, h, hd), q.dtype),
            jax.ShapeDtypeStruct(k_cache.shape, k_cache.dtype),
            jax.ShapeDtypeStruct(v_cache.shape, v_cache.dtype),
        ],
        input_output_aliases={2: 1, 3: 2},  # k_cache->new_k, v_cache->new_v
        interpret=interpret,
    )(scalars, q, k_cache, v_cache, k_new, v_new)
    return out, new_k, new_v


@functools.partial(jax.jit, static_argnames=("bk", "interpret"))
def decode_attention(
    q: jax.Array,            # (B, H, hd)
    k_cache: jax.Array,      # (B, S, Hkv, hd)
    v_cache: jax.Array,      # (B, S, Hkv, hd)
    lengths: jax.Array,      # (B,) int32
    *,
    bk: int = 512,
    interpret: bool = False,
) -> jax.Array:
    b, h, hd = q.shape
    s, hkv = k_cache.shape[1], k_cache.shape[2]
    rep = h // hkv
    bk = min(bk, s)
    if s % bk:
        raise ValueError(f"cache length {s} must divide block {bk}")
    n_blocks = s // bk
    scale = 1.0 / math.sqrt(hd)

    grid = (b, n_blocks)
    out = pl.pallas_call(
        functools.partial(
            _decode_kernel, bk=bk, n_blocks=n_blocks, rep=rep, scale=scale
        ),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=0,
            grid=grid,
            in_specs=[
                pl.BlockSpec(memory_space=pltpu.SMEM),
                pl.BlockSpec((1, h, hd), lambda ib, ik: (ib, 0, 0)),
                pl.BlockSpec((1, bk, hkv, hd), lambda ib, ik: (ib, ik, 0, 0)),
                pl.BlockSpec((1, bk, hkv, hd), lambda ib, ik: (ib, ik, 0, 0)),
            ],
            out_specs=pl.BlockSpec((1, h, hd), lambda ib, ik: (ib, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((h, hd), jnp.float32),
                pltpu.VMEM((h, 1), jnp.float32),
                pltpu.VMEM((h, 1), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((b, h, hd), q.dtype),
        interpret=interpret,
    )(lengths.astype(jnp.int32), q, k_cache, v_cache)
    return out
