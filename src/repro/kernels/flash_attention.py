"""Pallas TPU flash attention (training / prefill forward).

Online-softmax attention tiled for VMEM: grid ``(B*H, Sq/bq, Skv/bk)`` with
the KV dimension innermost; running max/denominator/accumulator live in
VMEM scratch and the output block is finalized on the last KV step. GQA is
folded into the K/V index maps (query head ``h`` reads KV head ``h / rep``),
so no repeated KV materialization. Causal and sliding-window masks skip
fully-masked KV blocks via ``pl.when`` (the block is scheduled but no MXU
work is issued).

Block sizes default to MXU-aligned 128x128 tiles in the (Sq, Skv) plane;
``hd`` stays whole (the MXU contracts over it). VMEM footprint per step:
``bq*hd + 2*bk*hd + bq*bk`` f32 words plus scratch — well under 16 MiB for
hd <= 256.

TPU is the target; CPU validation runs interpret mode against
``ref.flash_attention_ref`` (tests sweep shapes/dtypes).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _fa_kernel(
    q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref,
    *, bq: int, bk: int, n_kv_blocks: int, causal: bool, window: int,
    q_offset: int, scale: float,
):
    iq = pl.program_id(1)
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    # absolute positions of this block's queries/keys
    q_lo = iq * bq + q_offset
    k_lo = ik * bk

    # visibility pre-check: skip blocks that are fully masked
    diag_ok = (not causal) or (k_lo <= q_lo + bq - 1)
    win_ok = (window <= 0) or (k_lo + bk - 1 > q_lo - window)
    # (conditions are on traced ints when q_offset is traced; both paths jit)

    def _compute():
        q = q_ref[0].astype(jnp.float32)            # (bq, hd)
        k = k_ref[0].astype(jnp.float32)            # (bk, hd)
        v = v_ref[0].astype(jnp.float32)            # (bk, hd)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        ) * scale                                    # (bq, bk)
        qpos = q_lo + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = k_lo + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = jnp.ones((bq, bk), dtype=bool)
        if causal:
            mask &= kpos <= qpos
        if window > 0:
            mask &= kpos > qpos - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]                          # (bq, 1)
        l_prev = l_ref[...]
        m_cur = jnp.max(s, axis=-1, keepdims=True)   # (bq, 1)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)                       # (bq, bk)
        alpha = jnp.exp(m_prev - m_new)              # (bq, 1)
        l_new = alpha * l_prev + jnp.sum(p, axis=-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        m_ref[...] = m_new
        l_ref[...] = l_new

    if isinstance(diag_ok, bool) and isinstance(win_ok, bool):
        if diag_ok and win_ok:
            _compute()
    else:
        pl.when(jnp.logical_and(diag_ok, win_ok))(_compute)

    @pl.when(ik == n_kv_blocks - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "q_offset", "bq", "bk", "interpret"),
)
def flash_attention(
    q: jax.Array,            # (B, Sq, H, hd)
    k: jax.Array,            # (B, Skv, Hkv, hd)
    v: jax.Array,            # (B, Skv, Hkv, hd)
    *,
    causal: bool = True,
    window: int = 0,
    q_offset: int = 0,
    bq: int = 128,
    bk: int = 128,
    interpret: bool = False,
) -> jax.Array:
    b, sq, h, hd = q.shape
    skv, hkv = k.shape[1], k.shape[2]
    rep = h // hkv
    bq = min(bq, sq)
    bk = min(bk, skv)
    if sq % bq or skv % bk:
        raise ValueError(f"seq lengths ({sq},{skv}) must divide blocks ({bq},{bk})")
    scale = 1.0 / math.sqrt(hd)

    # (B, S, H, hd) -> (B*H, S, hd) head-major layout for clean 2D tiles
    qh = q.transpose(0, 2, 1, 3).reshape(b * h, sq, hd)
    kh = k.transpose(0, 2, 1, 3).reshape(b * hkv, skv, hd)
    vh = v.transpose(0, 2, 1, 3).reshape(b * hkv, skv, hd)

    n_kv_blocks = skv // bk
    grid = (b * h, sq // bq, n_kv_blocks)

    def q_map(bh, iq, ik):
        return (bh, iq, 0)

    def kv_map(bh, iq, ik):
        # query head bh = bi*H + hi reads KV head bi*Hkv + hi//rep
        bi = bh // h
        hi = bh % h
        return (bi * hkv + hi // rep, ik, 0)

    out = pl.pallas_call(
        functools.partial(
            _fa_kernel,
            bq=bq, bk=bk, n_kv_blocks=n_kv_blocks,
            causal=causal, window=window, q_offset=q_offset, scale=scale,
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, hd), q_map),
            pl.BlockSpec((1, bk, hd), kv_map),
            pl.BlockSpec((1, bk, hd), kv_map),
        ],
        out_specs=pl.BlockSpec((1, bq, hd), q_map),
        out_shape=jax.ShapeDtypeStruct((b * h, sq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, hd), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
        ],
        interpret=interpret,
    )(qh, kh, vh)
    return out.reshape(b, h, sq, hd).transpose(0, 2, 1, 3)
