"""Pallas TPU grouped matmul (MoE expert FFN building block).

MoE dispatch produces per-expert token blocks ``x: (E, C, D)``; each expert
applies its own weights ``w: (E, D, F)``. The kernel is a classic blocked
matmul with the expert index as the outermost grid dimension and the
contraction (D) dimension innermost, accumulating into the output block in
VMEM (initialized on the first D step):

    grid = (E, C/bc, F/bf, D/bd)
    x block (bc, bd) . w block (bd, bf) -> out block (bc, bf), f32 acc

Tiles are MXU-aligned (multiples of 128 where the dims allow). The SwiGLU
composition (gate/up/down) lives in ``ops.moe_ffn_pallas``: three gmm calls
with the silu fusion left to XLA — the matmuls dominate.

Interpret-mode validated against ``ref.moe_gmm_ref``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _gmm_kernel(x_ref, w_ref, o_ref, *, n_d_blocks: int):
    d = pl.program_id(3)

    @pl.when(d == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[0]                                    # (bc, bd)
    w = w_ref[0]                                    # (bd, bf)
    o_ref[0] += jax.lax.dot_general(
        x, w, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )


@functools.partial(jax.jit, static_argnames=("bc", "bf", "bd", "interpret"))
def grouped_matmul(
    x: jax.Array,            # (E, C, D)
    w: jax.Array,            # (E, D, F)
    *,
    bc: int = 128,
    bf: int = 128,
    bd: int = 512,
    interpret: bool = False,
) -> jax.Array:
    e, c, d = x.shape
    f = w.shape[-1]
    bc, bf, bd = min(bc, c), min(bf, f), min(bd, d)
    if c % bc or f % bf or d % bd:
        raise ValueError(f"dims ({c},{f},{d}) must divide blocks ({bc},{bf},{bd})")
    grid = (e, c // bc, f // bf, d // bd)

    out = pl.pallas_call(
        functools.partial(_gmm_kernel, n_d_blocks=d // bd),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bc, bd), lambda ie, ic, if_, id_: (ie, ic, id_)),
            pl.BlockSpec((1, bd, bf), lambda ie, ic, if_, id_: (ie, id_, if_)),
        ],
        out_specs=pl.BlockSpec((1, bc, bf), lambda ie, ic, if_, id_: (ie, ic, if_)),
        out_shape=jax.ShapeDtypeStruct((e, c, f), jnp.float32),
        scratch_shapes=[],
        interpret=interpret,
    )(x, w)
    return out


def moe_expert_ffn(
    x: jax.Array,            # (E, C, D) dispatched tokens
    w_gate: jax.Array,       # (E, D, F)
    w_up: jax.Array,         # (E, D, F)
    w_down: jax.Array,       # (E, F, D)
    *,
    interpret: bool = False,
) -> jax.Array:
    """SwiGLU expert FFN via three grouped matmuls (kernel composition)."""
    g = grouped_matmul(x, w_gate, interpret=interpret)
    u = grouped_matmul(x, w_up, interpret=interpret)
    h = (jax.nn.silu(g) * u).astype(x.dtype)
    return grouped_matmul(h, w_down, interpret=interpret).astype(x.dtype)
