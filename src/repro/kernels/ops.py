"""Jit'd dispatch layer over the Pallas kernels.

Select the execution path per call site:

* ``"ref"``       — pure-jnp oracle (default on CPU: fast under XLA:CPU,
                    and what the dry-run lowers when kernels are disabled);
* ``"pallas"``    — compiled Pallas kernel (TPU target);
* ``"interpret"`` — Pallas kernel body interpreted in Python (CPU
                    correctness validation; used by the kernel tests).

The global default is resolved from the backend: TPU -> pallas, anything
else -> ref; override per-process with ``set_default_impl`` or per-call
with ``impl=``. Model code calls these entry points only — swapping a
kernel never touches model definitions.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ref as _ref
from repro.kernels.block_copy import copy_pool_blocks as _block_copy_pallas
from repro.kernels.dapo_loss import dapo_loss as _dapo_pallas
from repro.kernels.decode_attention import decode_attention as _decode_pallas
from repro.kernels.decode_attention import (
    decode_attention_update as _decode_update_pallas,
)
from repro.kernels.flash_attention import flash_attention as _flash_pallas
from repro.kernels.paged_attention import (
    paged_decode_attention as _paged_decode_pallas,
)
from repro.kernels.paged_attention import (
    paged_decode_attention_update as _paged_update_pallas,
)
from repro.kernels.paged_attention import (
    paged_prefill_attention as _paged_prefill_pallas,
)
from repro.kernels.moe_gmm import grouped_matmul as _gmm_pallas
from repro.kernels.moe_gmm import moe_expert_ffn as _moe_ffn_pallas
from repro.kernels.selective_scan import selective_scan as _selective_scan_pallas
from repro.kernels.selective_scan import (
    selective_scan_ref as _ref_selective_scan,
)

_DEFAULT_IMPL: Optional[str] = None


def set_default_impl(impl: Optional[str]) -> None:
    """Force an implementation globally (None -> auto by backend)."""
    global _DEFAULT_IMPL
    if impl not in (None, "ref", "pallas", "interpret"):
        raise ValueError(f"unknown impl {impl!r}")
    _DEFAULT_IMPL = impl


def resolve_impl(impl: Optional[str] = None) -> str:
    if impl is not None:
        return impl
    if _DEFAULT_IMPL is not None:
        return _DEFAULT_IMPL
    return "pallas" if jax.default_backend() == "tpu" else "ref"


# ------------------------------------------------------------------ attention
def flash_attention(
    q: jax.Array, k: jax.Array, v: jax.Array,
    *, causal: bool = True, window: int = 0, q_offset: int = 0,
    impl: Optional[str] = None,
) -> jax.Array:
    mode = resolve_impl(impl)
    if mode == "ref":
        from repro.models import runmode

        if runmode.attention_chunked(k.shape[1]):
            return _ref.flash_attention_chunked_ref(
                q, k, v, causal=causal, window=window, q_offset=q_offset
            )
        return _ref.flash_attention_ref(
            q, k, v, causal=causal, window=window, q_offset=q_offset
        )
    return _flash_pallas(
        q, k, v, causal=causal, window=window, q_offset=q_offset,
        interpret=(mode == "interpret"),
    )


def decode_attention(
    q: jax.Array, k_cache: jax.Array, v_cache: jax.Array, lengths: jax.Array,
    *, impl: Optional[str] = None,
) -> jax.Array:
    mode = resolve_impl(impl)
    if mode == "ref":
        return _ref.decode_attention_ref(q, k_cache, v_cache, lengths)
    return _decode_pallas(
        q, k_cache, v_cache, lengths, interpret=(mode == "interpret")
    )


def decode_attention_update(
    q: jax.Array,            # (B, H, hd)
    k_cache: jax.Array,      # (B, S, Hkv, hd)
    v_cache: jax.Array,      # (B, S, Hkv, hd)
    k_new: jax.Array,        # (B, Hkv, hd)
    v_new: jax.Array,        # (B, Hkv, hd)
    write_pos: jax.Array,    # (B,) ring slot
    lengths: jax.Array,      # (B,) valid entries incl. the new token
    *, impl: Optional[str] = None,
):
    """Fused decode attention + ring-cache row write.

    Returns (out (B, H, hd), new_k, new_v). Pallas path writes the row
    in place (only the touched block moves); the ref path lowers the
    partition-friendly one-hot select (EXPERIMENTS.md §Perf A1/A3)."""
    mode = resolve_impl(impl)
    if mode == "ref":
        s = k_cache.shape[1]
        hit = (
            jnp.arange(s, dtype=jnp.int32)[None, :] == write_pos[:, None]
        )[..., None, None]
        new_k = jnp.where(hit, k_new[:, None].astype(k_cache.dtype), k_cache)
        new_v = jnp.where(hit, v_new[:, None].astype(v_cache.dtype), v_cache)
        out = _ref.decode_attention_ref(q, new_k, new_v, lengths)
        return out, new_k, new_v
    return _decode_update_pallas(
        q, k_cache, v_cache, k_new, v_new, write_pos, lengths,
        interpret=(mode == "interpret"),
    )


def paged_decode_attention(
    q: jax.Array,             # (B, H, hd)
    k_pool: jax.Array,        # (N, bs, Hkv, hd) shared block pool
    v_pool: jax.Array,        # (N, bs, Hkv, hd)
    block_tables: jax.Array,  # (B, nb) int32 per-sequence block tables
    lengths: jax.Array,       # (B,) int32 valid positions
    *, impl: Optional[str] = None,
) -> jax.Array:
    """Decode attention over a block-paged KV pool (vLLM-style layout)."""
    mode = resolve_impl(impl)
    if mode == "ref":
        return _ref.paged_decode_attention_ref(
            q, k_pool, v_pool, block_tables, lengths
        )
    return _paged_decode_pallas(
        q, k_pool, v_pool, block_tables, lengths,
        interpret=(mode == "interpret"),
    )


def paged_prefill_attention(
    q: jax.Array,             # (B, S, H, hd) suffix queries (right-padded)
    k_pool: jax.Array,        # (N, bs, Hkv, hd)
    v_pool: jax.Array,        # (N, bs, Hkv, hd)
    block_tables: jax.Array,  # (B, nb) int32
    q_offsets: jax.Array,     # (B,) int32 absolute position of q[:, 0]
    lengths: jax.Array,       # (B,) int32 total valid positions
    *, impl: Optional[str] = None,
) -> jax.Array:
    """Suffix-prefill attention over a block-paged KV pool: queries are a
    trajectory's suffix tokens, keys/values stream from the pool (resident
    prefix + pre-scattered suffix rows), causal over prefix+suffix.
    Returns (B, S, H, hd); padded query rows come back zero."""
    mode = resolve_impl(impl)
    if mode == "ref":
        return _ref.paged_prefill_attention_ref(
            q, k_pool, v_pool, block_tables, q_offsets, lengths
        )
    return _paged_prefill_pallas(
        q, k_pool, v_pool, block_tables, q_offsets, lengths,
        interpret=(mode == "interpret"),
    )


def paged_decode_attention_update(
    q: jax.Array,             # (B, H, hd)
    k_pool: jax.Array,        # (N, bs, Hkv, hd)
    v_pool: jax.Array,        # (N, bs, Hkv, hd)
    k_new: jax.Array,         # (B, Hkv, hd)
    v_new: jax.Array,         # (B, Hkv, hd)
    block_tables: jax.Array,  # (B, nb) int32
    write_pos: jax.Array,     # (B,) int32 logical position of the new token
    *, impl: Optional[str] = None,
):
    """Fused paged decode attention + new-token K/V write at ``write_pos``.

    Valid length is ``write_pos + 1``. The Pallas path writes only the one
    touched pool block in place (aliasing); the ref path scatters the row
    then attends over the table-gathered cache. Returns
    (out (B, H, hd), k_pool', v_pool')."""
    mode = resolve_impl(impl)
    if mode == "ref":
        return _ref.paged_decode_attention_update_ref(
            q, k_pool, v_pool, k_new, v_new, block_tables, write_pos
        )
    return _paged_update_pallas(
        q, k_pool, v_pool, k_new, v_new, block_tables, write_pos,
        interpret=(mode == "interpret"),
    )


def copy_pool_blocks(
    k_pool: jax.Array,        # (L, N, bs, Hkv, hd)
    v_pool: jax.Array,        # (L, N, bs, Hkv, hd)
    src: jax.Array,           # (C,) int32 source block per copy
    dst: jax.Array,           # (C,) int32 destination block per copy
    *, impl: Optional[str] = None,
) -> Tuple[jax.Array, jax.Array]:
    """Device-side pool-block copy ``src[c] -> dst[c]`` (K and V).

    The copy-on-write primitive behind prefix sharing: duplicates a shared
    prompt's partial tail block into each group member's private block.
    The Pallas path moves only the touched blocks in place (aliasing); the
    ref path lowers a gather + scatter over the pools."""
    mode = resolve_impl(impl)
    if mode == "ref":
        new_k = k_pool.at[:, dst].set(k_pool[:, src])
        new_v = v_pool.at[:, dst].set(v_pool[:, src])
        return new_k, new_v
    return _block_copy_pallas(
        k_pool, v_pool, src, dst, interpret=(mode == "interpret")
    )


# ------------------------------------------------------------------------ MoE
def grouped_matmul(
    x: jax.Array, w: jax.Array, *, impl: Optional[str] = None
) -> jax.Array:
    mode = resolve_impl(impl)
    if mode == "ref":
        return jnp.einsum("ecd,edf->ecf", x, w,
                          preferred_element_type=jnp.float32)
    return _gmm_pallas(x, w, interpret=(mode == "interpret"))


def moe_expert_ffn(
    x: jax.Array, w_gate: jax.Array, w_up: jax.Array, w_down: jax.Array,
    *, impl: Optional[str] = None,
) -> jax.Array:
    mode = resolve_impl(impl)
    if mode == "ref":
        return _ref.moe_gmm_ref(x, w_gate, w_up, w_down)
    # pad the token dim to the kernel's 128-aligned tile (zero rows are
    # inert through SwiGLU: silu(0)*0 @ w = 0)
    c = x.shape[1]
    pad = (-c) % 128
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
    out = _moe_ffn_pallas(
        x, w_gate, w_up, w_down, interpret=(mode == "interpret")
    )
    return out[:, :c] if pad else out


# ------------------------------------------------------------ selective scan
def selective_scan(
    dt: jax.Array, x: jax.Array, bmat: jax.Array, cmat: jax.Array,
    a: jax.Array, h0: jax.Array, *, impl: Optional[str] = None,
):
    """Fused Mamba/S6 recurrence. Returns (y (B,S,I), h_final (B,I,N))."""
    mode = resolve_impl(impl)
    if mode == "ref":
        return _ref_selective_scan(dt, x, bmat, cmat, a, h0)
    return _selective_scan_pallas(
        dt, x, bmat, cmat, a, h0, interpret=(mode == "interpret")
    )


# ----------------------------------------------------------------------- loss
def dapo_loss(
    logprobs: jax.Array, old_logprobs: jax.Array,
    advantages: jax.Array, mask: jax.Array,
    *, eps_low: float = 0.2, eps_high: float = 0.28,
    impl: Optional[str] = None,
) -> Tuple[jax.Array, jax.Array]:
    mode = resolve_impl(impl)
    if mode == "ref":
        return _ref.dapo_loss_ref(
            logprobs, old_logprobs, advantages, mask,
            eps_low=eps_low, eps_high=eps_high,
        )
    return _dapo_pallas(
        logprobs, old_logprobs, advantages, mask,
        eps_low=eps_low, eps_high=eps_high, interpret=(mode == "interpret"),
    )
