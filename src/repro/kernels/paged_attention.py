"""Pallas TPU paged decode attention — block-table-indirected KV streaming.

The dense decode kernel (``kernels.decode_attention``) streams one
contiguous ``(S, Hkv, hd)`` cache row per sequence. Under paging there is
no contiguous row: a trajectory's KV lives in fixed-size blocks scattered
across a pool shared by every slot on the replica, addressed through a
per-sequence **block table** (``repro.rollout.kv_allocator``).

The indirection moves into the BlockSpec index map: block tables (and the
per-sequence scalars) are scalar-prefetched, and grid step ``(b, j)`` DMAs
pool block ``tables[b, j]`` into VMEM — logical position ``j*bs + i`` of
sequence ``b``. Everything else is the dense kernel's online softmax:

* grid ``(B, nb)`` with the table dimension innermost; the query block (a
  single token, all H heads) stays resident across the sweep;
* blocks past the valid length are skipped (``pl.when``), so compute and
  (post-prefetch) bandwidth scale with the trajectory's *actual* length —
  the whole point of charging admission by allocated blocks;
* GQA queries are reshaped to (Hkv, rep, hd) against un-repeated KV.

The fused ``paged_decode_attention_update`` variant also writes the new
token's K/V row in place: the output pool block index comes from the
scalar-prefetched write position, the caches alias their outputs, and the
new token's attention contribution is folded in analytically on the last
grid step — only the single touched block ever moves back to HBM.

``paged_prefill_attention`` generalizes the decode sweep to multi-token
query blocks: Q rows are a trajectory's *suffix* tokens (absolute offset
scalar-prefetched per row) while K/V still stream block-by-block from the
pool — the suffix-prefill path shared-prefix forks use to skip re-running
the resident prompt.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _paged_kernel(
    tables_ref,               # SMEM (B, nb) block tables (prefetched)
    lens_ref,                 # SMEM (B,) valid lengths (prefetched)
    q_ref,                    # (1, H, hd)
    k_ref, v_ref,             # (1, bs, Hkv, hd) — pool block tables[b, j]
    o_ref,                    # (1, H, hd)
    acc_ref, m_ref, l_ref,    # VMEM scratch (H, hd), (H, 1), (H, 1)
    *, bs: int, nb: int, rep: int, scale: float,
):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    length = lens_ref[pl.program_id(0)]
    k_lo = j * bs

    @pl.when(k_lo < length)
    def _compute():
        q = q_ref[0].astype(jnp.float32)             # (H, hd)
        k = k_ref[0].astype(jnp.float32)             # (bs, Hkv, hd)
        v = v_ref[0].astype(jnp.float32)
        h, hd = q.shape
        hkv = k.shape[1]
        qg = q.reshape(hkv, rep, hd)
        s = jax.lax.dot_general(
            qg, k.transpose(1, 2, 0),               # (Hkv, hd, bs)
            (((2,), (1,)), ((0,), (0,))),
            preferred_element_type=jnp.float32,
        ) * scale                                    # (Hkv, rep, bs)
        kpos = k_lo + jax.lax.broadcasted_iota(jnp.int32, s.shape, 2)
        s = jnp.where(kpos < length, s, NEG_INF)

        sh = s.reshape(h, -1)
        m_prev, l_prev = m_ref[...], l_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(sh, axis=-1, keepdims=True))
        p = jnp.exp(sh - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = alpha * l_prev + jnp.sum(p, axis=-1, keepdims=True)
        pg = p.reshape(hkv, rep, -1)
        out = jax.lax.dot_general(
            pg, v.transpose(1, 0, 2), (((2,), (1,)), ((0,), (0,))),
            preferred_element_type=jnp.float32,
        )
        acc_ref[...] = acc_ref[...] * alpha + out.reshape(h, hd)
        m_ref[...] = m_new

    @pl.when(j == nb - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_decode_attention(
    q: jax.Array,             # (B, H, hd)
    k_pool: jax.Array,        # (N, bs, Hkv, hd)
    v_pool: jax.Array,        # (N, bs, Hkv, hd)
    block_tables: jax.Array,  # (B, nb) int32
    lengths: jax.Array,       # (B,) int32 valid positions
    *,
    interpret: bool = False,
) -> jax.Array:
    """Decode attention over a block-paged KV pool. Returns (B, H, hd)."""
    b, h, hd = q.shape
    n, bs, hkv, _ = k_pool.shape
    nb = block_tables.shape[1]
    rep = h // hkv
    scale = 1.0 / math.sqrt(hd)

    out = pl.pallas_call(
        functools.partial(
            _paged_kernel, bs=bs, nb=nb, rep=rep, scale=scale
        ),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=(b, nb),
            in_specs=[
                pl.BlockSpec((1, h, hd), lambda ib, j, tb, ln: (ib, 0, 0)),
                pl.BlockSpec(
                    (1, bs, hkv, hd),
                    lambda ib, j, tb, ln: (tb[ib, j], 0, 0, 0),
                ),
                pl.BlockSpec(
                    (1, bs, hkv, hd),
                    lambda ib, j, tb, ln: (tb[ib, j], 0, 0, 0),
                ),
            ],
            out_specs=pl.BlockSpec(
                (1, h, hd), lambda ib, j, tb, ln: (ib, 0, 0)
            ),
            scratch_shapes=[
                pltpu.VMEM((h, hd), jnp.float32),
                pltpu.VMEM((h, 1), jnp.float32),
                pltpu.VMEM((h, 1), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((b, h, hd), q.dtype),
        interpret=interpret,
    )(block_tables.astype(jnp.int32), lengths.astype(jnp.int32), q,
      k_pool, v_pool)
    return out


def _paged_prefill_kernel(
    tables_ref,               # SMEM (B, nb) block tables (prefetched)
    meta_ref,                 # SMEM (2, B): row 0 = q_offset, row 1 = length
    q_ref,                    # (1, S, H, hd) suffix queries (right-padded)
    k_ref, v_ref,             # (1, bs, Hkv, hd) — pool block tables[b, j]
    o_ref,                    # (1, S, H, hd)
    acc_ref, m_ref, l_ref,    # VMEM scratch (Hkv, S*rep, hd), (Hkv, S*rep, 1) x2
    *, bs: int, nb: int, rep: int, scale: float,
):
    ib = pl.program_id(0)
    j = pl.program_id(1)
    q_off = meta_ref[0, ib]
    length = meta_ref[1, ib]

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    k_lo = j * bs

    @pl.when(k_lo < length)
    def _compute():
        q = q_ref[0].astype(jnp.float32)             # (S, H, hd)
        k = k_ref[0].astype(jnp.float32)             # (bs, Hkv, hd)
        v = v_ref[0].astype(jnp.float32)
        sq, h, hd = q.shape
        hkv = k.shape[1]
        # group-major rows: row s*rep + r of group g is query (s, g*rep + r)
        qg = (
            q.reshape(sq, hkv, rep, hd)
            .transpose(1, 0, 2, 3)
            .reshape(hkv, sq * rep, hd)
        )
        s = jax.lax.dot_general(
            qg, k.transpose(1, 2, 0),               # (Hkv, hd, bs)
            (((2,), (1,)), ((0,), (0,))),
            preferred_element_type=jnp.float32,
        ) * scale                                    # (Hkv, S*rep, bs)
        kpos = k_lo + jax.lax.broadcasted_iota(jnp.int32, s.shape, 2)
        qpos = q_off + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1) // rep
        # causal over the combined prefix+suffix window; padded query rows
        # (qpos >= length) keep l == 0 and finalize to zeros
        s = jnp.where((kpos <= qpos) & (kpos < length), s, NEG_INF)

        m_prev, l_prev = m_ref[...], l_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = alpha * l_prev + jnp.sum(p, axis=-1, keepdims=True)
        out = jax.lax.dot_general(
            p, v.transpose(1, 0, 2), (((2,), (1,)), ((0,), (0,))),
            preferred_element_type=jnp.float32,
        )                                            # (Hkv, S*rep, hd)
        acc_ref[...] = acc_ref[...] * alpha + out
        m_ref[...] = m_new

    @pl.when(j == nb - 1)
    def _finalize():
        sq = q_ref.shape[1]
        hkv, _, hd = acc_ref.shape
        l = jnp.maximum(l_ref[...], 1e-30)
        o = (acc_ref[...] / l).reshape(hkv, sq, rep, hd)
        o_ref[0] = (
            o.transpose(1, 0, 2, 3).reshape(sq, hkv * rep, hd)
        ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_prefill_attention(
    q: jax.Array,             # (B, S, H, hd) suffix queries (right-padded)
    k_pool: jax.Array,        # (N, bs, Hkv, hd)
    v_pool: jax.Array,        # (N, bs, Hkv, hd)
    block_tables: jax.Array,  # (B, nb) int32
    q_offsets: jax.Array,     # (B,) int32 absolute position of q[:, 0]
    lengths: jax.Array,       # (B,) int32 total valid positions
    *,
    interpret: bool = False,
) -> jax.Array:
    """Suffix-prefill attention over a block-paged KV pool.

    Queries are a trajectory's suffix tokens (absolute positions
    ``q_offsets[b] + i``); K/V stream from the pool via the scalar-
    prefetched block table — the resident shared prefix plus the suffix
    rows the caller scattered in beforehand. Causal over prefix+suffix.
    Returns (B, S, H, hd); padded query rows come back zero.
    """
    b, sq, h, hd = q.shape
    n, bs, hkv, _ = k_pool.shape
    nb = block_tables.shape[1]
    rep = h // hkv
    scale = 1.0 / math.sqrt(hd)
    meta = jnp.stack([q_offsets.astype(jnp.int32), lengths.astype(jnp.int32)])

    out = pl.pallas_call(
        functools.partial(
            _paged_prefill_kernel, bs=bs, nb=nb, rep=rep, scale=scale
        ),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=(b, nb),
            in_specs=[
                pl.BlockSpec(
                    (1, sq, h, hd), lambda ib, j, tb, mt: (ib, 0, 0, 0)
                ),
                pl.BlockSpec(
                    (1, bs, hkv, hd),
                    lambda ib, j, tb, mt: (tb[ib, j], 0, 0, 0),
                ),
                pl.BlockSpec(
                    (1, bs, hkv, hd),
                    lambda ib, j, tb, mt: (tb[ib, j], 0, 0, 0),
                ),
            ],
            out_specs=pl.BlockSpec(
                (1, sq, h, hd), lambda ib, j, tb, mt: (ib, 0, 0, 0)
            ),
            scratch_shapes=[
                pltpu.VMEM((hkv, sq * rep, hd), jnp.float32),
                pltpu.VMEM((hkv, sq * rep, 1), jnp.float32),
                pltpu.VMEM((hkv, sq * rep, 1), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((b, sq, h, hd), q.dtype),
        interpret=interpret,
    )(block_tables.astype(jnp.int32), meta, q, k_pool, v_pool)
    return out


def _paged_update_kernel(
    tables_ref,               # SMEM (B, nb)
    meta_ref,                 # SMEM (2, B): row 0 = write_pos, row 1 = length
    q_ref, k_ref, v_ref,      # (1, H, hd), (1, bs, Hkv, hd) x2
    kn_ref, vn_ref,           # (1, Hkv, hd) new row
    o_ref, ko_ref, vo_ref,    # out + aliased pool blocks
    acc_ref, m_ref, l_ref,
    *, bs: int, nb: int, rep: int, scale: float,
):
    ib = pl.program_id(0)
    j = pl.program_id(1)
    wp = meta_ref[0, ib]
    length = meta_ref[1, ib]
    wp_blk = wp // bs

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    k_lo = j * bs

    @pl.when(k_lo < length)
    def _compute():
        q = q_ref[0].astype(jnp.float32)
        k = k_ref[0].astype(jnp.float32)
        v = v_ref[0].astype(jnp.float32)
        h, hd = q.shape
        hkv = k.shape[1]
        qg = q.reshape(hkv, rep, hd)
        s = jax.lax.dot_general(
            qg, k.transpose(1, 2, 0), (((2,), (1,)), ((0,), (0,))),
            preferred_element_type=jnp.float32,
        ) * scale
        kpos = k_lo + jax.lax.broadcasted_iota(jnp.int32, s.shape, 2)
        # the write slot holds garbage (not yet written); exclude it from
        # the stream — the NEW token's contribution lands analytically below
        s = jnp.where((kpos < length) & (kpos != wp), s, NEG_INF)
        sh = s.reshape(h, -1)
        m_prev, l_prev = m_ref[...], l_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(sh, axis=-1, keepdims=True))
        p = jnp.exp(sh - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = alpha * l_prev + jnp.sum(p, axis=-1, keepdims=True)
        pg = p.reshape(hkv, rep, -1)
        out = jax.lax.dot_general(
            pg, v.transpose(1, 0, 2), (((2,), (1,)), ((0,), (0,))),
            preferred_element_type=jnp.float32,
        )
        acc_ref[...] = acc_ref[...] * alpha + out.reshape(h, hd)
        m_ref[...] = m_new

    # in-place block write: copy the matching input block once, overwrite
    # the single row — only this block moves (input_output_aliasing)
    @pl.when(j == wp_blk)
    def _write_row():
        row = wp % bs
        ko_ref[0] = k_ref[0]
        vo_ref[0] = v_ref[0]
        ko_ref[0, row] = kn_ref[0].astype(ko_ref.dtype)
        vo_ref[0, row] = vn_ref[0].astype(vo_ref.dtype)

    @pl.when(j == nb - 1)
    def _finalize():
        q = q_ref[0].astype(jnp.float32)
        h, hd = q.shape
        kn = kn_ref[0].astype(jnp.float32)
        vn = vn_ref[0].astype(jnp.float32)
        hkv = kn.shape[0]
        qg = q.reshape(hkv, rep, hd)
        s_new = jnp.sum(qg * kn[:, None, :], axis=-1).reshape(h, 1) * scale
        m_prev, l_prev = m_ref[...], l_ref[...]
        m_fin = jnp.maximum(m_prev, s_new)
        p_new = jnp.exp(s_new - m_fin)
        alpha = jnp.exp(m_prev - m_fin)
        l_fin = alpha * l_prev + p_new
        vrep = jnp.broadcast_to(vn[:, None, :], (hkv, rep, hd)).reshape(h, hd)
        acc_fin = acc_ref[...] * alpha + p_new * vrep
        o_ref[0] = (acc_fin / jnp.maximum(l_fin, 1e-30)).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("interpret",), donate_argnums=(1, 2)
)
def paged_decode_attention_update(
    q: jax.Array,             # (B, H, hd)
    k_pool: jax.Array,        # (N, bs, Hkv, hd) — donated, updated in place
    v_pool: jax.Array,        # (N, bs, Hkv, hd) — donated, updated in place
    k_new: jax.Array,         # (B, Hkv, hd)
    v_new: jax.Array,         # (B, Hkv, hd)
    block_tables: jax.Array,  # (B, nb) int32
    write_pos: jax.Array,     # (B,) int32 logical position of the new token
    *,
    interpret: bool = False,
):
    """Fused paged decode attention + in-place pool block row write.

    ``write_pos`` is the new token's logical position; the valid attention
    length is ``write_pos + 1`` (the new token attends to itself via the
    analytic fold-in). Returns (out (B, H, hd), k_pool', v_pool')."""
    b, h, hd = q.shape
    n, bs, hkv, _ = k_pool.shape
    nb = block_tables.shape[1]
    rep = h // hkv
    scale = 1.0 / math.sqrt(hd)
    meta = jnp.stack(
        [write_pos.astype(jnp.int32), write_pos.astype(jnp.int32) + 1]
    )

    out, new_k, new_v = pl.pallas_call(
        functools.partial(
            _paged_update_kernel, bs=bs, nb=nb, rep=rep, scale=scale
        ),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=(b, nb),
            in_specs=[
                pl.BlockSpec((1, h, hd), lambda ib, j, tb, mt: (ib, 0, 0)),
                pl.BlockSpec(
                    (1, bs, hkv, hd),
                    lambda ib, j, tb, mt: (tb[ib, j], 0, 0, 0),
                ),
                pl.BlockSpec(
                    (1, bs, hkv, hd),
                    lambda ib, j, tb, mt: (tb[ib, j], 0, 0, 0),
                ),
                pl.BlockSpec((1, hkv, hd), lambda ib, j, tb, mt: (ib, 0, 0)),
                pl.BlockSpec((1, hkv, hd), lambda ib, j, tb, mt: (ib, 0, 0)),
            ],
            out_specs=[
                pl.BlockSpec((1, h, hd), lambda ib, j, tb, mt: (ib, 0, 0)),
                pl.BlockSpec(
                    (1, bs, hkv, hd),
                    lambda ib, j, tb, mt: (tb[ib, mt[0, ib] // bs], 0, 0, 0),
                ),
                pl.BlockSpec(
                    (1, bs, hkv, hd),
                    lambda ib, j, tb, mt: (tb[ib, mt[0, ib] // bs], 0, 0, 0),
                ),
            ],
            scratch_shapes=[
                pltpu.VMEM((h, hd), jnp.float32),
                pltpu.VMEM((h, 1), jnp.float32),
                pltpu.VMEM((h, 1), jnp.float32),
            ],
        ),
        out_shape=[
            jax.ShapeDtypeStruct((b, h, hd), q.dtype),
            jax.ShapeDtypeStruct(k_pool.shape, k_pool.dtype),
            jax.ShapeDtypeStruct(v_pool.shape, v_pool.dtype),
        ],
        # operand order: (tables, meta, q, k_pool, v_pool, k_new, v_new)
        input_output_aliases={3: 1, 4: 2},
        interpret=interpret,
    )(block_tables.astype(jnp.int32), meta, q, k_pool, v_pool, k_new, v_new)
    return out, new_k, new_v
