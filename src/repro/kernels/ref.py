"""Pure-jnp oracles for every Pallas kernel in this package.

These are the semantic ground truth: kernel tests sweep shapes/dtypes and
assert ``allclose`` against these functions (interpret mode on CPU). They
are also the default execution path on non-TPU backends.
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp


# ------------------------------------------------------------ flash attention
def flash_attention_ref(
    q: jax.Array,            # (B, Sq, H, hd)
    k: jax.Array,            # (B, Skv, Hkv, hd)
    v: jax.Array,            # (B, Skv, Hkv, hd)
    *,
    causal: bool = True,
    window: int = 0,
    q_offset: int = 0,
) -> jax.Array:
    """Reference multi-head GQA attention (materializes the score matrix)."""
    b, sq, h, hd = q.shape
    skv, hkv = k.shape[1], k.shape[2]
    rep = h // hkv
    if rep > 1:
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    scale = 1.0 / math.sqrt(hd)
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32) * scale
    qpos = jnp.arange(sq) + q_offset
    kpos = jnp.arange(skv)
    mask = jnp.ones((sq, skv), dtype=bool)
    if causal:
        mask &= kpos[None, :] <= qpos[:, None]
    if window > 0:
        mask &= kpos[None, :] > qpos[:, None] - window
    logits = jnp.where(mask[None, None], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", probs, v)


def flash_attention_chunked_ref(
    q: jax.Array,            # (B, Sq, H, hd)
    k: jax.Array,            # (B, Skv, Hkv, hd)
    v: jax.Array,            # (B, Skv, Hkv, hd)
    *,
    causal: bool = True,
    window: int = 0,
    q_offset: int = 0,
    chunk: int = 2048,
) -> jax.Array:
    """Online-softmax attention chunked over KV (pure jnp lax.scan).

    Memory O(Sq * chunk) instead of O(Sq * Skv) — the long-sequence
    execution path on non-TPU backends (the Pallas kernel's role on TPU).
    Numerically equivalent to ``flash_attention_ref``.
    """
    b, sq, h, hd = q.shape
    skv, hkv = k.shape[1], k.shape[2]
    chunk = min(chunk, skv)
    if skv % chunk:
        return flash_attention_ref(
            q, k, v, causal=causal, window=window, q_offset=q_offset
        )
    rep = h // hkv
    nc = skv // chunk
    scale = 1.0 / math.sqrt(hd)
    qf = q.astype(jnp.float32) * scale
    kc = k.reshape(b, nc, chunk, hkv, hd).swapaxes(0, 1)
    vc = v.reshape(b, nc, chunk, hkv, hd).swapaxes(0, 1)
    qpos = jnp.arange(sq) + q_offset

    def body(carry, inp):
        m, l, acc = carry                       # (B,H,Sq),(B,H,Sq),(B,H,Sq,hd)
        idx, kb, vb = inp
        if rep > 1:
            kb = jnp.repeat(kb, rep, axis=2)
            vb = jnp.repeat(vb, rep, axis=2)
        s = jnp.einsum("bqhd,bkhd->bhqk", qf, kb.astype(jnp.float32))
        kpos = idx * chunk + jnp.arange(chunk)
        mask = jnp.ones((sq, chunk), dtype=bool)
        if causal:
            mask &= kpos[None, :] <= qpos[:, None]
        if window > 0:
            mask &= kpos[None, :] > qpos[:, None] - window
        s = jnp.where(mask[None, None], s, -1e30)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m - m_new)
        l_new = alpha * l + jnp.sum(p, axis=-1)
        acc_new = acc * alpha[..., None] + jnp.einsum(
            "bhqk,bkhd->bhqd", p, vb.astype(jnp.float32)
        )
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, h, sq), -1e30, jnp.float32)
    l0 = jnp.zeros((b, h, sq), jnp.float32)
    a0 = jnp.zeros((b, h, sq, hd), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        body, (m0, l0, a0), (jnp.arange(nc), kc, vc)
    )
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.transpose(0, 2, 1, 3).astype(q.dtype)


# ----------------------------------------------------------- decode attention
def decode_attention_ref(
    q: jax.Array,            # (B, H, hd) single new token per sequence
    k_cache: jax.Array,      # (B, S, Hkv, hd)
    v_cache: jax.Array,      # (B, S, Hkv, hd)
    lengths: jax.Array,      # (B,) int32 valid cache lengths (incl. new token)
) -> jax.Array:
    """One-token decode attention against a (ring) KV cache.

    GQA folds the query-head group into the einsum (q reshaped to
    (B, Hkv, rep, hd)) instead of ``jnp.repeat``-ing the cache: identical
    math, rep-x less cache traffic (decode streams the full KV every step,
    so this is the dominant-byte path — EXPERIMENTS.md §Perf)."""
    b, h, hd = q.shape
    s, hkv = k_cache.shape[1], k_cache.shape[2]
    rep = h // hkv
    scale = 1.0 / math.sqrt(hd)
    qg = q.reshape(b, hkv, rep, hd)
    logits = (
        jnp.einsum("bgrd,bsgd->bgrs", qg, k_cache).astype(jnp.float32) * scale
    )                                                       # (B, Hkv, rep, S)
    valid = jnp.arange(s)[None, :] < lengths[:, None]       # (B, S)
    logits = jnp.where(valid[:, None, None, :], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    out = jnp.einsum("bgrs,bsgd->bgrd", probs, v_cache)     # (B, Hkv, rep, hd)
    return out.reshape(b, h, hd)


# ----------------------------------------------------- paged decode attention
def paged_gather_kv(
    pool: jax.Array,          # (N, bs, Hkv, hd) shared block pool
    block_tables: jax.Array,  # (B, nb) int32 block ids (pad -> null block)
) -> jax.Array:
    """Materialize each sequence's logical KV window from its block table.

    Returns (B, nb*bs, Hkv, hd) — position ``p`` of row ``b`` lives at
    ``pool[block_tables[b, p // bs], p % bs]``. Padded table entries gather
    the null block's garbage; callers mask by length.
    """
    b, nb = block_tables.shape
    n, bs, hkv, hd = pool.shape
    g = pool[block_tables]                       # (B, nb, bs, Hkv, hd)
    return g.reshape(b, nb * bs, hkv, hd)


def paged_decode_attention_ref(
    q: jax.Array,             # (B, H, hd) one new token per sequence
    k_pool: jax.Array,        # (N, bs, Hkv, hd)
    v_pool: jax.Array,        # (N, bs, Hkv, hd)
    block_tables: jax.Array,  # (B, nb) int32
    lengths: jax.Array,       # (B,) int32 valid positions
) -> jax.Array:
    """Decode attention with the KV cache gathered via block tables.

    Bit-for-bit equal to ``decode_attention_ref`` over a contiguous cache of
    width ``nb*bs`` holding the same valid values: masked lanes contribute
    exact zeros either way (exp(-1e30 - m) underflows to +0.0 in f32).
    """
    kg = paged_gather_kv(k_pool, block_tables)
    vg = paged_gather_kv(v_pool, block_tables)
    return decode_attention_ref(q, kg, vg, lengths)


def paged_decode_attention_update_ref(
    q: jax.Array,             # (B, H, hd)
    k_pool: jax.Array,        # (N, bs, Hkv, hd)
    v_pool: jax.Array,        # (N, bs, Hkv, hd)
    k_new: jax.Array,         # (B, Hkv, hd) this step's key
    v_new: jax.Array,         # (B, Hkv, hd) this step's value
    block_tables: jax.Array,  # (B, nb) int32
    write_pos: jax.Array,     # (B,) int32 logical position to write
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Write the new token's K/V into its pool block, then attend over the
    table-gathered cache (valid length = write_pos + 1).

    Block ownership is exclusive, so the (B,)-indexed scatter is conflict-
    free; rows whose table points a position at the null block (padding)
    harmlessly write garbage there. Returns (out, k_pool', v_pool').
    """
    b = q.shape[0]
    bs = k_pool.shape[1]
    blk = block_tables[jnp.arange(b), write_pos // bs]
    off = write_pos % bs
    new_k = k_pool.at[blk, off].set(k_new.astype(k_pool.dtype))
    new_v = v_pool.at[blk, off].set(v_new.astype(v_pool.dtype))
    out = paged_decode_attention_ref(
        q, new_k, new_v, block_tables, write_pos + 1
    )
    return out, new_k, new_v


def paged_prefill_attention_ref(
    q: jax.Array,             # (B, S, H, hd) suffix queries (right-padded)
    k_pool: jax.Array,        # (N, bs, Hkv, hd)
    v_pool: jax.Array,        # (N, bs, Hkv, hd)
    block_tables: jax.Array,  # (B, nb) int32
    q_offsets: jax.Array,     # (B,) int32 absolute position of q[:, 0]
    lengths: jax.Array,       # (B,) int32 total valid positions (prefix+suffix)
) -> jax.Array:
    """Suffix-prefill attention: queries are a trajectory's *suffix* tokens
    while keys/values come from the paged pool via its block table — the
    resident prefix (a shared-prefix fork's prompt blocks) plus the suffix
    K/V the caller has already scattered into the pool. Causal over the
    combined prefix+suffix window.

    Bit-for-bit equal to ``flash_attention_ref`` over a contiguous cache
    holding the same valid values *when the gathered window matches the
    contiguous sequence length* (``nb * bs == Skv``): the op sequence
    (einsum-logits in f32, -1e30 mask, softmax, einsum-out) is identical
    and masked lanes contribute exact zeros either way (exp underflows to
    +0.0). A wider window is still exact math over the same valid rows
    but regroups the reduction sums, so equality degrades to ~1 ulp —
    callers that need bitwise parity with a full prefill (the fork
    admission path) must size ``block_tables`` to the full prompt's
    padded bucket, not the pool-wide maximum. Padded query rows
    (``q_offsets + i >= lengths``) attend nothing valid — callers mask
    their outputs downstream.
    """
    b, sq, h, hd = q.shape
    hkv = k_pool.shape[2]
    rep = h // hkv
    kg = paged_gather_kv(k_pool, block_tables)   # (B, nb*bs, Hkv, hd)
    vg = paged_gather_kv(v_pool, block_tables)
    if rep > 1:
        kg = jnp.repeat(kg, rep, axis=2)
        vg = jnp.repeat(vg, rep, axis=2)
    skv = kg.shape[1]
    scale = 1.0 / math.sqrt(hd)
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, kg).astype(jnp.float32) * scale
    qpos = q_offsets[:, None] + jnp.arange(sq)               # (B, Sq)
    kpos = jnp.arange(skv)
    mask = (kpos[None, None, :] <= qpos[:, :, None]) & (
        kpos[None, None, :] < lengths[:, None, None]
    )                                                        # (B, Sq, Skv)
    logits = jnp.where(mask[:, None], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", probs, vg)


# -------------------------------------------------------------------- MoE GMM
def moe_gmm_ref(
    x: jax.Array,            # (E, C, D) dispatched tokens per expert
    w_gate: jax.Array,       # (E, D, F)
    w_up: jax.Array,         # (E, D, F)
    w_down: jax.Array,       # (E, F, D)
) -> jax.Array:
    """Grouped expert FFN (SwiGLU): per-expert batched matmul."""
    h = jnp.einsum("ecd,edf->ecf", x, w_gate)
    h = jax.nn.silu(h) * jnp.einsum("ecd,edf->ecf", x, w_up)
    return jnp.einsum("ecf,efd->ecd", h, w_down)


# ------------------------------------------------------------------ DAPO loss
def dapo_loss_ref(
    logprobs: jax.Array,       # (B, T) new-policy token logprobs
    old_logprobs: jax.Array,   # (B, T) behavior-policy token logprobs
    advantages: jax.Array,     # (B,)  trajectory advantages (broadcast to tokens)
    mask: jax.Array,           # (B, T) response-token mask
    *,
    eps_low: float = 0.2,
    eps_high: float = 0.28,
) -> Tuple[jax.Array, jax.Array]:
    """Token-level clipped policy-gradient loss with DAPO's decoupled clip
    range ('clip-higher') and token-mean normalization.

    Returns (scalar loss, scalar mean ratio) — the ratio is a training
    diagnostic (off-policy drift, §2.2 staleness analysis).
    """
    lp = logprobs.astype(jnp.float32)
    olp = old_logprobs.astype(jnp.float32)
    adv = advantages.astype(jnp.float32)[:, None]
    m = mask.astype(jnp.float32)
    ratio = jnp.exp(lp - olp)
    clipped = jnp.clip(ratio, 1.0 - eps_low, 1.0 + eps_high)
    obj = jnp.minimum(ratio * adv, clipped * adv)
    denom = jnp.maximum(m.sum(), 1.0)
    loss = -(obj * m).sum() / denom
    mean_ratio = (ratio * m).sum() / denom
    return loss, mean_ratio
