"""Pallas TPU fused selective scan (Mamba/S6 recurrence).

The XLA mamba path must materialize the discretized state tensors
``dA = exp(dt*A)`` and ``dBx = dt*B*x`` of shape (B, S, I, N) — an
``N``-fold (16x) memory amplification over the (B, S, I) activations that
makes hymba the worst roofline-fraction train cell (EXPERIMENTS.md
§Roofline summary). The CUDA selective-scan kernel keeps those tensors in
SRAM; this kernel is the TPU-native equivalent: everything lives in VMEM.

Grid ``(B, I/bi)``: each program owns one sequence row and a slice of the
inner dimension. The recurrence

    h_t = exp(dt_t * A) * h_{t-1} + (dt_t * x_t) * B_t
    y_t = h_t . C_t

runs as a ``fori_loop`` over time with the state (bi, N) in VMEM scratch;
dt/x stream in as (S, bi) blocks and B/C as (S, N) blocks. HBM traffic is
exactly the useful bytes: read dt, x (S*I), B, C (S*N), A (I*N); write y
(S*I). The (B, S, I, N) tensors never exist.

Interpret-mode validated against ``selective_scan_ref``.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def selective_scan_ref(
    dt: jax.Array,     # (B, S, I) post-softplus step sizes
    x: jax.Array,      # (B, S, I) conv+silu activations
    bmat: jax.Array,   # (B, S, N)
    cmat: jax.Array,   # (B, S, N)
    a: jax.Array,      # (I, N) negative state matrix
    h0: jax.Array,     # (B, I, N)
) -> Tuple[jax.Array, jax.Array]:
    """Oracle: explicit (B, S, I, N) construction + sequential scan."""
    dA = jnp.exp(dt[..., None].astype(jnp.float32) * a.astype(jnp.float32))
    dBx = (dt * x)[..., None].astype(jnp.float32) * bmat[:, :, None, :].astype(
        jnp.float32
    )

    def step(h, inp):
        da_t, dbx_t, c_t = inp
        h = da_t * h + dbx_t
        y = jnp.einsum("bin,bn->bi", h, c_t)
        return h, y

    h_final, ys = jax.lax.scan(
        step,
        h0.astype(jnp.float32),
        (
            dA.swapaxes(0, 1),
            dBx.swapaxes(0, 1),
            cmat.swapaxes(0, 1).astype(jnp.float32),
        ),
    )
    return ys.swapaxes(0, 1).astype(x.dtype), h_final


def _scan_kernel(
    dt_ref, x_ref, b_ref, c_ref, a_ref, h0_ref,
    y_ref, hout_ref,
    h_scratch,
    *, seq_len: int,
):
    h_scratch[...] = h0_ref[0].astype(jnp.float32)      # (bi, N)
    a = a_ref[...].astype(jnp.float32)                  # (bi, N)

    def step(t, _):
        dt_t = dt_ref[0, t].astype(jnp.float32)         # (bi,)
        x_t = x_ref[0, t].astype(jnp.float32)
        b_t = b_ref[0, t].astype(jnp.float32)           # (N,)
        c_t = c_ref[0, t].astype(jnp.float32)
        dA = jnp.exp(dt_t[:, None] * a)                 # (bi, N)
        h = dA * h_scratch[...] + (dt_t * x_t)[:, None] * b_t[None, :]
        h_scratch[...] = h
        y_ref[0, t] = (h @ c_t).astype(y_ref.dtype)     # (bi,)
        return 0

    jax.lax.fori_loop(0, seq_len, step, 0)
    hout_ref[0] = h_scratch[...]


@functools.partial(jax.jit, static_argnames=("bi", "interpret"))
def selective_scan(
    dt: jax.Array,     # (B, S, I)
    x: jax.Array,      # (B, S, I)
    bmat: jax.Array,   # (B, S, N)
    cmat: jax.Array,   # (B, S, N)
    a: jax.Array,      # (I, N)
    h0: jax.Array,     # (B, I, N)
    *,
    bi: int = 128,
    interpret: bool = False,
) -> Tuple[jax.Array, jax.Array]:
    b, s, i = dt.shape
    n = a.shape[-1]
    bi = min(bi, i)
    if i % bi:
        raise ValueError(f"inner dim {i} must divide block {bi}")
    grid = (b, i // bi)

    y, h_final = pl.pallas_call(
        functools.partial(_scan_kernel, seq_len=s),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, s, bi), lambda ib, ii: (ib, 0, ii)),
            pl.BlockSpec((1, s, bi), lambda ib, ii: (ib, 0, ii)),
            pl.BlockSpec((1, s, n), lambda ib, ii: (ib, 0, 0)),
            pl.BlockSpec((1, s, n), lambda ib, ii: (ib, 0, 0)),
            pl.BlockSpec((bi, n), lambda ib, ii: (ii, 0)),
            pl.BlockSpec((1, bi, n), lambda ib, ii: (ib, ii, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, s, bi), lambda ib, ii: (ib, 0, ii)),
            pl.BlockSpec((1, bi, n), lambda ib, ii: (ib, ii, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, s, i), x.dtype),
            jax.ShapeDtypeStruct((b, i, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((bi, n), jnp.float32)],
        interpret=interpret,
    )(dt, x, bmat, cmat, a, h0)
    return y, h_final
