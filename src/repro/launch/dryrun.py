import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input-shape x
mesh) cell against the production mesh, proving the distribution config is
coherent — sharding rules resolve, collectives partition, memory fits —
without TPU hardware. Records memory_analysis + cost_analysis + parsed
collective bytes per cell for §Dry-run / §Roofline of EXPERIMENTS.md.

The two XLA_FLAGS lines above MUST precede any other import (jax locks the
device count at first init); they are dry-run-only — smoke tests and
benchmarks see the real single CPU device.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-1.5b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both --out results/dryrun
"""
import argparse  # noqa: E402
import json  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402

import jax  # noqa: E402

from repro.configs import ALL_SHAPES, ASSIGNED, get_arch, get_shape  # noqa: E402
from repro.distributed import sharding as shd  # noqa: E402
from repro.distributed.ctx import activation_sharding  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.launch.specs import (  # noqa: E402
    abstract_opt,
    abstract_params,
    input_specs,
    make_prefill_step,
    make_serve_step,
)
from repro.roofline.analysis import (  # noqa: E402
    Roofline,
    collective_bytes,
    model_flops,
)
from repro.training.optimizer import AdamWConfig  # noqa: E402
from repro.training.train_step import make_rl_train_step  # noqa: E402


def _mem_dict(compiled) -> dict:
    try:
        m = compiled.memory_analysis()
        return {
            "argument_bytes": int(getattr(m, "argument_size_in_bytes", 0)),
            "output_bytes": int(getattr(m, "output_size_in_bytes", 0)),
            "temp_bytes": int(getattr(m, "temp_size_in_bytes", 0)),
            "generated_code_bytes": int(
                getattr(m, "generated_code_size_in_bytes", 0)
            ),
            "peak_bytes": int(
                getattr(m, "peak_memory_in_bytes",
                        getattr(m, "temp_size_in_bytes", 0))
            ),
        }
    except Exception as e:  # backend may not expose it
        return {"error": repr(e)}


def _cost_dict(compiled) -> dict:
    try:
        c = compiled.cost_analysis()
        if isinstance(c, (list, tuple)):
            c = c[0]
        return {k: float(v) for k, v in c.items() if isinstance(v, (int, float))}
    except Exception as e:
        return {"error": repr(e)}


def _compile_cell(cfg, shape, mesh, *, sp, remat, donate, accum_steps=1):
    """Lower + compile one cell under the current runmode. Returns compiled."""
    params = abstract_params(cfg)
    p_shard = shd.params_shardings(mesh, params)
    specs = input_specs(cfg, shape)
    with activation_sharding(mesh, sp=sp):
        if shape.kind == "train":
            opt = abstract_opt(cfg)
            o_shard = shd.opt_shardings(mesh, opt)
            b_shard = shd.train_batch_shardings(mesh, specs["batch"])
            step = make_rl_train_step(
                cfg, AdamWConfig(), objective="dapo", remat=remat,
                accum_steps=accum_steps,
            )
            jitted = jax.jit(
                step,
                in_shardings=(p_shard, o_shard, b_shard),
                out_shardings=(p_shard, o_shard, None),
                donate_argnums=(0, 1) if donate else (),
            )
            lowered = jitted.lower(params, opt, specs["batch"])
        elif shape.kind == "prefill":
            c_shard = shd.cache_shardings(mesh, specs["cache"])
            t_shard = shd.train_batch_shardings(
                mesh, {"tokens": specs["tokens"], "lengths": specs["lengths"]}
            )
            fe = specs.get("frontend_embeds")
            in_sh = [p_shard, t_shard["tokens"], t_shard["lengths"], c_shard]
            args = [params, specs["tokens"], specs["lengths"], specs["cache"]]
            if fe is not None:
                in_sh.append(
                    shd.train_batch_shardings(
                        mesh, {"frontend_embeds": fe}
                    )["frontend_embeds"]
                )
                args.append(fe)
            jitted = jax.jit(
                make_prefill_step(cfg),
                in_shardings=tuple(in_sh),
                out_shardings=(None, c_shard),
                donate_argnums=(3,) if donate else (),
            )
            lowered = jitted.lower(*args)
        else:  # decode
            c_shard = shd.cache_shardings(mesh, specs["cache"])
            tok_shard = shd.train_batch_shardings(
                mesh, {"tokens": specs["tokens"]}
            )["tokens"]
            jitted = jax.jit(
                make_serve_step(cfg),
                in_shardings=(p_shard, tok_shard, c_shard),
                out_shardings=(None, c_shard),
                donate_argnums=(2,) if donate else (),
            )
            lowered = jitted.lower(params, specs["tokens"], specs["cache"])
    return lowered.compile()


def _trip_count(cfg) -> int:
    """Iterations of the (only) trip-counted loop under roofline mode."""
    if cfg.family == "ssm":
        from repro.models.model import xlstm_period

        return cfg.n_layers // xlstm_period(cfg)
    return cfg.n_layers


HBM_BYTES = 16e9  # TPU v5e per-chip HBM: the memory gate


def lower_cell(arch_name: str, shape_name: str, *, multi_pod: bool,
               sp: bool = True, remat: bool = True,
               donate: bool = True, roofline_passes: bool = True,
               accum_steps: int = 0) -> dict:
    cfg = get_arch(arch_name)
    shape = get_shape(shape_name)
    mesh_name = "multi" if multi_pod else "single"
    record = {
        "arch": arch_name, "shape": shape_name, "mesh": mesh_name,
        "kind": shape.kind, "status": "unsupported",
    }
    if not cfg.supports_shape(shape):
        record["note"] = (
            "skipped per assignment: full attention has no sub-quadratic "
            "path at 500k"
        )
        return record

    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.size
    opts = dict(sp=sp, remat=remat, donate=donate)

    # ---- pass 1: deployment-faithful program -> the memory/compile gate.
    # Train cells autotune gradient accumulation (activation temp ~ 1/accum);
    # inference cells autotune SERVICE WAVES: the engine's KV-budget
    # admission control serves the global batch in sequential waves of
    # half the residents — identical total work, halved temp footprint.
    # Iterations logged for §Perf.
    import dataclasses as _dc

    t0 = time.time()
    accum = accum_steps or 1
    waves = 1
    eff_shape = shape
    gate_log = []
    # wave floor: one row per batch shard (pod x data); below it batch
    # sharding degrades to replication and memory EXPLODES
    min_batch = mesh.shape.get("pod", 1) * mesh.shape.get("data", 1)
    while True:
        compiled = _compile_cell(cfg, eff_shape, mesh, accum_steps=accum, **opts)
        mem = _mem_dict(compiled)
        total_dev_bytes = (
            mem.get("temp_bytes", 0) + mem.get("argument_bytes", 0)
        )
        gate_log.append({
            "accum": accum, "waves": waves,
            "temp_bytes": mem.get("temp_bytes", 0),
        })
        if accum_steps or total_dev_bytes <= HBM_BYTES:
            break
        if shape.kind == "train":
            if accum >= 8 or shape.global_batch // (accum * 2) < 1:
                break
            accum *= 2
        else:
            if eff_shape.global_batch // 2 < min_batch:
                break
            waves *= 2
            eff_shape = _dc.replace(
                eff_shape, global_batch=eff_shape.global_batch // 2
            )
    t_compile = time.time() - t0
    cost = _cost_dict(compiled)

    # ---- passes 2+3: roofline accounting. HloCostAnalysis counts a while
    # body ONCE, so lower with outer_unroll=1 and =2 (inner loops fully
    # unrolled, chunking disabled) and extrapolate
    #   total = f(1) + (trip - 1) * (f(2) - f(1)).
    rl_dict = None
    if roofline_passes:
        from repro.models.runmode import roofline_mode

        def measure(u):
            # roofline passes always use accum=1: gradient accumulation is a
            # memory lever with identical math, but its scan would hide
            # (accum-1)/accum of the step from HloCostAnalysis
            with roofline_mode(outer_unroll=u):
                c = _compile_cell(cfg, shape, mesh, accum_steps=1, **opts)
            cc = _cost_dict(c)
            coll = collective_bytes(c.as_text(), n_devices=chips)
            return cc, coll

        (c1, coll1) = measure(1)
        (c2, coll2) = measure(2)
        trip = _trip_count(cfg)

        def extrap(v1, v2):
            return v1 + (trip - 1) * max(v2 - v1, 0.0)

        flops = extrap(c1.get("flops", 0.0), c2.get("flops", 0.0))
        hbm = extrap(
            c1.get("bytes accessed", 0.0), c2.get("bytes accessed", 0.0)
        )
        coll = {
            k: int(extrap(float(coll1[k]), float(coll2[k]))) for k in coll1
        }
        mf = model_flops(cfg, shape.kind, shape.global_batch, shape.seq_len)
        rl = Roofline(
            arch=arch_name, shape=shape_name, mesh=mesh_name, chips=chips,
            flops_per_chip=flops,
            hbm_bytes_per_chip=hbm,
            coll_bytes_per_chip=float(sum(coll.values())),
            coll_breakdown=coll,
            model_flops_total=mf,
        )
        rl_dict = rl.to_dict()
        rl_dict["raw_pass1"] = c1
        rl_dict["trip_count"] = trip

    record.update(
        status="ok",
        chips=chips,
        compile_s=round(t_compile, 2),
        memory=mem,
        cost=cost,
        roofline=rl_dict,
        options={**opts, "accum_steps": accum, "service_waves": waves},
        hbm_gate=gate_log,
        fits_hbm=bool(
            mem.get("temp_bytes", 0) + mem.get("argument_bytes", 0) <= HBM_BYTES
        ),
    )
    return record


def run_cells(cells, meshes, out_dir: str, **opts) -> list:
    os.makedirs(out_dir, exist_ok=True)
    results = []
    for arch_name, shape_name in cells:
        for mesh_name in meshes:
            tag = f"{arch_name}_{shape_name}_{mesh_name}"
            path = os.path.join(out_dir, tag + ".json")
            try:
                rec = lower_cell(
                    arch_name, shape_name, multi_pod=(mesh_name == "multi"),
                    # the roofline table is single-pod only (per spec); the
                    # multi-pod pass proves the "pod" axis shards
                    roofline_passes=(mesh_name == "single"),
                    **opts,
                )
            except Exception as e:
                rec = {
                    "arch": arch_name, "shape": shape_name, "mesh": mesh_name,
                    "status": "FAILED", "error": repr(e),
                    "traceback": traceback.format_exc(),
                }
            results.append(rec)
            with open(path, "w") as f:
                json.dump(rec, f, indent=1)
            status = rec["status"]
            extra = ""
            if status == "ok":
                extra = (
                    f" compile={rec['compile_s']}s"
                    f" mem={rec['memory'].get('temp_bytes', 0) / 1e9:.2f}GB"
                )
                if rec.get("roofline"):
                    r = rec["roofline"]
                    extra += (
                        f" dominant={r['dominant']}"
                        f" frac={r['roofline_fraction']:.3f}"
                        f" useful={r['useful_flops_ratio']:.2f}"
                    )
            print(f"[{tag}] {status}{extra}", flush=True)
    return results


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--no-sp", action="store_true")
    ap.add_argument("--no-remat", action="store_true")
    ap.add_argument("--no-donate", action="store_true")
    args = ap.parse_args()

    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    if args.all:
        cells = [
            (a.name, s.name) for a in ASSIGNED for s in ALL_SHAPES
        ]
    else:
        archs = [args.arch] if args.arch else [a.name for a in ASSIGNED]
        shapes = [args.shape] if args.shape else [s.name for s in ALL_SHAPES]
        cells = [(a, s) for a in archs for s in shapes]

    results = run_cells(
        cells, meshes, args.out,
        sp=not args.no_sp, remat=not args.no_remat, donate=not args.no_donate,
    )
    ok = sum(1 for r in results if r["status"] == "ok")
    skip = sum(1 for r in results if r["status"] == "unsupported")
    fail = sum(1 for r in results if r["status"] == "FAILED")
    print(f"\ndry-run: {ok} ok, {skip} documented skips, {fail} FAILED")
    if fail:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
