"""Production mesh construction.

Defined as FUNCTIONS (never module-level constants) so importing this
module does not touch jax device state — the dry-run must set XLA_FLAGS
before first jax init.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (data=16, model=16) = 256 chips.
    Multi-pod: (pod=2, data=16, model=16) = 512 chips; the "pod" axis is
    pure data parallelism over DCN."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Degenerate 1-device mesh for smoke-testing launcher code paths."""
    return jax.make_mesh((1, 1), ("data", "model"))


def make_rollout_mesh(n_shards: int):
    """1-D ``("tensor",)`` mesh for one sharded rollout instance.

    A rollout "instance" in the paper is a resource pool, not a chip; the
    sharded backend (``repro.rollout.sharded``) spans one instance across
    ``n_shards`` devices of this mesh — params head-sharded, the paged KV
    pool split on its KV-head axis. Uses the first ``n_shards`` local
    devices; raises early (with the fix spelled out) when the process has
    fewer, since ``jax.make_mesh``'s own error is opaque.
    """
    if n_shards < 1:
        raise ValueError(f"n_shards must be >= 1, got {n_shards}")
    avail = jax.device_count()
    if n_shards > avail:
        raise ValueError(
            f"rollout mesh needs {n_shards} devices but only {avail} are "
            f"visible; on CPU set XLA_FLAGS="
            f"--xla_force_host_platform_device_count={n_shards} before the "
            f"first jax call"
        )
    return jax.make_mesh((n_shards,), ("tensor",))
