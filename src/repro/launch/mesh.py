"""Production mesh construction.

Defined as FUNCTIONS (never module-level constants) so importing this
module does not touch jax device state — the dry-run must set XLA_FLAGS
before first jax init.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (data=16, model=16) = 256 chips.
    Multi-pod: (pod=2, data=16, model=16) = 512 chips; the "pod" axis is
    pure data parallelism over DCN."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Degenerate 1-device mesh for smoke-testing launcher code paths."""
    return jax.make_mesh((1, 1), ("data", "model"))
