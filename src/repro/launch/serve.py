"""Serving launcher: continuous-batching generation on one model replica.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-1.5b \
        --requests 8 --slots 4 --max-new 12

Reduced configs execute numerically on CPU; the full-size serve_step for
every (arch x decode shape) cell is exercised by the dry-run.

``--trace PATH`` exports a Perfetto-loadable Chrome trace of the run
(queue/decode segments per request, reward-worker activity); ``--log-json``
switches the structured log to NDJSON.
"""
from __future__ import annotations

import argparse
import time

import jax

from repro.configs import get_arch
from repro.core.types import Trajectory, next_traj_id
from repro.data.tasks import ArithmeticDataset
from repro.data.tokenizer import decode as tok_decode
from repro.models import model as M
from repro.obs import get_logger, setup_logging
from repro.rollout.backend import create_backend


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--temperature", type=float, default=0.8)
    ap.add_argument(
        "--no-compact-decode", action="store_true",
        help="decode all slots every step (seed behavior) instead of "
             "compacting to the active power-of-two bucket",
    )
    ap.add_argument(
        "--paged", action="store_true",
        help="block-paged KV cache (shared pool + per-trajectory block "
             "tables) instead of dense per-slot rows",
    )
    ap.add_argument(
        "--block-size", type=int, default=16,
        help="tokens per KV block with --paged",
    )
    ap.add_argument(
        "--group-size", type=int, default=1,
        help="sample this many responses per prompt (GRPO-style group "
             "rollout); with --paged the shared prompt prefills once and "
             "its full KV blocks are refcount-shared across the group",
    )
    ap.add_argument(
        "--no-share-prefix", action="store_true",
        help="disable prefix sharing for group rollout (ablation)",
    )
    ap.add_argument(
        "--shards", type=int, default=1,
        help="devices this replica spans (requires --paged): params and "
             "the paged KV pool are head-sharded over a ('tensor',) mesh; "
             "on CPU set XLA_FLAGS=--xla_force_host_platform_device_count"
             "=<n> first",
    )
    ap.add_argument(
        "--kv-heads", type=int, default=0,
        help="override the reduced config's n_kv_heads (most reduced "
             "configs keep the GQA ratio with 1 KV head, which cannot "
             "split; --shards needs n_kv_heads %% shards == 0)",
    )
    ap.add_argument(
        "--score", action="store_true",
        help="verify completions through a threaded RewardServer (worker "
             "pool on the trajectory-lifecycle bus) overlapping decode — "
             "the disaggregated reward phase, standalone",
    )
    ap.add_argument(
        "--reward-workers", type=int, default=2,
        help="reward worker threads with --score",
    )
    ap.add_argument(
        "--score-url", default=None, metavar="URL",
        help="route completions through a RewardHub whose default route is "
             "a remote submit-then-poll judge at URL (HttpVerifier: "
             "per-request timeout, capped-backoff retries, circuit "
             "breaker); implies --score. The in-process RewardModel keeps "
             "the 'math' tag",
    )
    ap.add_argument(
        "--score-sandbox", default=None, metavar="SPEC",
        help="register a subprocess-sandboxed code-execution verifier "
             "under the 'code' task tag (resource/time-limited, "
             "kill-on-timeout); SPEC is inline Python source defining "
             "score(prompt_ids, response_ids), or @path/to/program.py; "
             "implies --score",
    )
    ap.add_argument(
        "--score-timeout", type=float, default=5.0,
        help="per-request / sandbox wall deadline (s) for hub verifiers",
    )
    ap.add_argument(
        "--score-retries", type=int, default=3,
        help="bounded attempts per remote-judge protocol step",
    )
    ap.add_argument(
        "--trace", default=None, metavar="PATH",
        help="export a Chrome trace (Perfetto-loadable) of the run",
    )
    ap.add_argument(
        "--log-json", action="store_true",
        help="structured NDJSON logs instead of human-readable lines",
    )
    args = ap.parse_args()
    if args.score_url or args.score_sandbox:
        args.score = True
    setup_logging(json_mode=args.log_json)
    log = get_logger("serve")

    cfg = get_arch(args.arch).reduced()
    if args.kv_heads:
        import dataclasses

        cfg = dataclasses.replace(cfg, n_kv_heads=args.kv_heads)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    kw = dict(
        cfg=cfg, params=params, version=0, max_slots=args.slots,
        max_len=64, temperature=args.temperature,
        compact_decode=not args.no_compact_decode,
        paged=args.paged, kv_block_size=args.block_size,
        share_prefix=not args.no_share_prefix,
    )
    if args.shards > 1:
        if not args.paged:
            raise SystemExit("--shards requires --paged (sharded KV pool)")
        inst = create_backend("sharded", 0, shard_count=args.shards, **kw)
        log.info(
            "sharded replica",
            extra={"shards": args.shards, "visible": jax.device_count()},
        )
    else:
        inst = create_backend("jax", 0, **kw)
    ds = ArithmeticDataset(args.requests, seed=2)
    n_requests = args.requests * args.group_size

    tracer = None
    lifecycle = None
    if args.trace or args.score:
        from repro.core import TrajectoryLifecycle

        lifecycle = TrajectoryLifecycle()
    if args.trace:
        from repro.obs import TrajectoryTracer

        tracer = TrajectoryTracer(lifecycle)
        inst.on_admit = tracer.on_admit
        inst.on_preempt = tracer.on_preempt

    reward_server = None
    hub = None
    if args.score:
        from repro.core import RewardServer, RewardServerConfig
        from repro.reward.verifier import RewardModel

        verifier = RewardModel(lambda prompt: ds.answer_for(prompt))
        if args.score_url or args.score_sandbox:
            from repro.reward import (
                DEFAULT_ROUTE,
                CircuitBreaker,
                HttpVerifier,
                RetryPolicy,
                RewardHub,
                SandboxVerifier,
            )

            hub = RewardHub(default=verifier, tracer=tracer)
            hub.register("math", verifier)
            if args.score_sandbox:
                hub.register("code", SandboxVerifier.from_spec(
                    args.score_sandbox, timeout_s=args.score_timeout,
                ))
            if args.score_url:
                remote = HttpVerifier(
                    args.score_url,
                    policy=RetryPolicy(
                        max_attempts=max(1, args.score_retries),
                        request_timeout_s=args.score_timeout,
                    ),
                    breaker=CircuitBreaker(),
                    total_timeout_s=args.score_timeout * 4,
                )
                hub.register("remote", remote)
                hub.register(DEFAULT_ROUTE, remote)
            verifier = hub
            log.info("reward hub routes", extra={"tags": hub.tags()})
        reward_server = RewardServer(
            verifier,
            lifecycle,
            RewardServerConfig(n_workers=args.reward_workers),
            tracer=tracer,
        )
        reward_server.start()  # worker pool: scoring overlaps decode

    for gid, p in enumerate(ds.problems):
        wave = [
            Trajectory(
                traj_id=next_traj_id(), prompt=list(p.prompt_ids),
                group_id=gid if args.group_size > 1 else -1,
                max_new_tokens=args.max_new,
            )
            for _ in range(args.group_size)
        ]
        if lifecycle is not None:
            # span opens at route — before route_many, which may admit
            # synchronously (the same ordering execute_commands uses)
            for t in wave:
                lifecycle.routed(t, inst.inst_id, 0)
        inst.route_many(wave)

    t0 = time.time()
    done = []
    while len(done) < n_requests and time.time() - t0 < 120:
        s0 = time.perf_counter()
        finished = inst.step()
        if tracer is not None:
            tracer.activity("decode", s0, time.perf_counter(), track="serve")
        for t in finished:
            done.append(t)
            log.info(
                "completion",
                extra={
                    "prompt": tok_decode(t.prompt),
                    "response": tok_decode(t.response),
                },
            )
            if lifecycle is not None:
                lifecycle.completed(t, inst.inst_id)
    dt = time.time() - t0
    log.info(
        "served",
        extra={
            "requests": len(done),
            "decode_tokens": inst.decode_tokens,
            "wall_s": round(dt, 2),
            "tok_per_s": round(inst.decode_tokens / dt, 1),
            "tok_per_step": round(
                inst.decode_tokens / max(inst.decode_steps, 1), 2
            ),
        },
    )
    if args.group_size > 1 and args.paged:
        log.info(
            "prefix sharing",
            extra={
                "shared_admits": inst.shared_prefix_hits,
                "prefill_tokens_saved": inst.prefill_tokens_saved,
            },
        )
    if reward_server is not None:
        reward_server.drain()
        reward_server.stop()
        correct = sum(1 for t in done if t.reward == 1.0)
        pct = reward_server.latency_percentiles((0.5, 0.95))
        log.info(
            "reward server",
            extra={
                "scored": reward_server.scored,
                "correct": correct,
                "queue_p50_ms": round(1e3 * (pct[0.5] or 0), 2),
                "queue_p95_ms": round(1e3 * (pct[0.95] or 0), 2),
            },
        )
        if hub is not None:
            log.info("reward hub", extra={"stats": hub.stats()})
    if tracer is not None:
        from repro.obs import export_chrome_trace

        trace = export_chrome_trace(tracer, args.trace)
        log.info(
            "trace written",
            extra={
                "path": args.trace,
                "events": len(trace["traceEvents"]),
                "spans": trace["otherData"]["spans"],
            },
        )


if __name__ == "__main__":
    main()
