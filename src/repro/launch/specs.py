"""Abstract input specs for the dry-run: ShapeDtypeStruct stand-ins for
every model input — weak-type-correct, shardable, zero allocation.

Cell semantics (assignment spec):
* ``train_4k``    — lowers the RL ``train_step`` (DAPO objective over a
                    consumed staleness-buffer batch, fwd+bwd+AdamW);
* ``prefill_32k`` — lowers ``prefill_step`` (inference prefill building the
                    KV cache);
* ``decode_32k`` / ``long_500k`` — lower ``serve_step`` (ONE new token
                    against a seq_len-sized cache / recurrent state).

Frontend stubs per the assignment: vlm cells carry precomputed patch
embeddings, audio cells precomputed frame embeddings.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ShapeConfig
from repro.models import model as M
from repro.training.optimizer import init_opt_state

PARAM_DTYPE = jnp.bfloat16


def abstract_params(cfg: ArchConfig) -> Any:
    key = jax.ShapeDtypeStruct((2,), jnp.uint32)
    return jax.eval_shape(partial(M.init_params, cfg, dtype=PARAM_DTYPE), key)


def abstract_opt(cfg: ArchConfig) -> Any:
    return jax.eval_shape(init_opt_state, abstract_params(cfg))


def abstract_cache(cfg: ArchConfig, batch: int, max_len: int) -> Any:
    return jax.eval_shape(
        partial(M.init_cache, cfg, batch, max_len, PARAM_DTYPE)
    )


def _frontend_spec(cfg: ArchConfig, batch: int):
    if cfg.family == "vlm":
        return jax.ShapeDtypeStruct((batch, cfg.n_patches, cfg.d_model), PARAM_DTYPE)
    if cfg.family == "audio":
        return jax.ShapeDtypeStruct((batch, cfg.encoder_seq, cfg.d_model), PARAM_DTYPE)
    return None


def input_specs(cfg: ArchConfig, shape: ShapeConfig) -> Dict[str, Any]:
    """Abstract inputs for the step function this cell lowers."""
    b, s = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        batch = {
            "tokens": jax.ShapeDtypeStruct((b, s), jnp.int32),
            "behavior_logprobs": jax.ShapeDtypeStruct((b, s), jnp.float32),
            "mask": jax.ShapeDtypeStruct((b, s), jnp.float32),
            "advantages": jax.ShapeDtypeStruct((b,), jnp.float32),
        }
        fe = _frontend_spec(cfg, b)
        if fe is not None:
            batch["frontend_embeds"] = fe
        return {"batch": batch}
    if shape.kind == "prefill":
        out = {
            "tokens": jax.ShapeDtypeStruct((b, s), jnp.int32),
            "lengths": jax.ShapeDtypeStruct((b,), jnp.int32),
            "cache": abstract_cache(cfg, b, _cache_len(cfg, s)),
        }
        fe = _frontend_spec(cfg, b)
        if fe is not None:
            out["frontend_embeds"] = fe
        return out
    # decode: one new token against a seq_len-sized cache
    return {
        "tokens": jax.ShapeDtypeStruct((b,), jnp.int32),
        "cache": abstract_cache(cfg, b, _cache_len(cfg, s)),
    }


def _cache_len(cfg: ArchConfig, s: int) -> int:
    # vlm caches hold the patch positions too
    return s + (cfg.n_patches if cfg.family == "vlm" else 0)


# ------------------------------------------------------------ step functions
def make_prefill_step(cfg: ArchConfig):
    def prefill_step(params, tokens, lengths, cache, frontend_embeds=None):
        return M.prefill(
            cfg, params, tokens, lengths, cache, frontend_embeds=frontend_embeds
        )

    return prefill_step


def make_serve_step(cfg: ArchConfig):
    def serve_step(params, tokens, cache):
        return M.decode_step(cfg, params, tokens, cache)

    return serve_step
