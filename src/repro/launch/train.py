"""Distributed training launcher.

Builds the production mesh, shards params/optimizer/batch with the
repository sharding rules, and drives the RL train step. On this CPU
container it runs REDUCED configs on a degenerate mesh (numerically); full
configs are exercised by the dry-run (``repro.launch.dryrun``). On a real
TPU slice the same file is the per-host entry point (jax.distributed
initialization + the identical mesh/sharding code paths).

    PYTHONPATH=src python -m repro.launch.train --arch qwen2-1.5b \
        --steps 3 --batch 8 --seq 64 [--compress-dp] [--ckpt-dir DIR]

``--trace PATH`` exports a Chrome trace of the step loop (train-step
spans, background PS-push activity); ``--log-json`` switches the
structured log to NDJSON.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_arch
from repro.distributed import sharding as shd
from repro.distributed.collectives import make_dp_allreduce
from repro.launch.mesh import make_host_mesh
from repro.models import model as M
from repro.obs import get_logger, setup_logging
from repro.training import checkpoint as ckpt_lib
from repro.training.optimizer import AdamWConfig, init_opt_state
from repro.training.train_step import make_rl_train_step


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b")
    ap.add_argument("--steps", type=int, default=3)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--remat", action="store_true")
    ap.add_argument("--compress-dp", action="store_true",
                    help="int8 gradient all-reduce demo (shard_map)")
    ap.add_argument("--ps-push", action="store_true",
                    help="publish each step's params to a ParameterServer "
                         "through the BackgroundPusher: Push overlaps the "
                         "next training step (Appendix A)")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--full-size", action="store_true",
                    help="use the full config (only sensible on real HW)")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="export a Chrome trace (Perfetto-loadable) of the "
                         "step loop")
    ap.add_argument("--log-json", action="store_true",
                    help="structured NDJSON logs instead of human-readable "
                         "lines")
    args = ap.parse_args()
    setup_logging(json_mode=args.log_json)
    log = get_logger("train")

    tracer = None
    if args.trace:
        from repro.obs import TrajectoryTracer

        tracer = TrajectoryTracer()  # activity tracks only: no lifecycle

    cfg = get_arch(args.arch)
    if not args.full_size:
        cfg = cfg.reduced()
    mesh = make_host_mesh()
    log.info(
        "mesh built",
        extra={
            "mesh": dict(mesh.shape),
            "arch": cfg.name,
            "params_m": round(cfg.n_params / 1e6, 1),
        },
    )

    key = jax.random.PRNGKey(0)
    params = M.init_params(cfg, key)
    opt = init_opt_state(params)
    p_sh = shd.params_shardings(mesh, params)
    o_sh = shd.opt_shardings(mesh, opt)
    params = jax.device_put(params, p_sh)
    opt = jax.device_put(opt, o_sh)

    b, t = args.batch, args.seq
    batch = {
        "tokens": jax.random.randint(key, (b, t), 3, cfg.vocab_size),
        "behavior_logprobs": jnp.full((b, t), -2.0),
        "mask": jnp.ones((b, t)),
        "advantages": jnp.linspace(-1.0, 1.0, b),
    }
    b_sh = shd.train_batch_shardings(mesh, batch)
    batch = jax.device_put(batch, b_sh)

    step = jax.jit(
        make_rl_train_step(
            cfg, AdamWConfig(lr=args.lr), remat=args.remat,
            accum_steps=args.accum,
        ),
        in_shardings=(p_sh, o_sh, b_sh),
        out_shardings=(p_sh, o_sh, None),
    )
    if args.compress_dp:
        # demonstration: grads would flow through the compressed DP
        # all-reduce on a multi-host mesh; on 1 device it's an identity
        make_dp_allreduce(mesh, compress=True)
        log.info("compressed DP all-reduce enabled (int8, global-scale psum)")

    pusher = None
    if args.ps_push:
        from repro.core import BackgroundPusher, ParameterServer

        ps = ParameterServer()
        ps.push(params, 0)
        pusher = BackgroundPusher(ps, tracer=tracer).start()
        log.info("background PS push enabled (overlaps the next step)")

    for i in range(args.steps):
        t0 = time.time()
        s0 = time.perf_counter()
        params, opt, metrics = step(params, opt, batch)
        loss = float(metrics["loss"])
        if tracer is not None:
            tracer.activity(
                "train_step", s0, time.perf_counter(),
                track="trainer", args={"step": i},
            )
        if pusher is not None:
            pusher.push(params, i + 1)  # returns immediately
        log.info(
            "step",
            extra={
                "step": i,
                "loss": round(loss, 4),
                "grad_norm": round(float(metrics["grad_norm"]), 3),
                "wall_s": round(time.time() - t0, 2),
            },
        )

    if pusher is not None:
        pusher.flush()
        log.info(
            "PS published",
            extra={
                "version": pusher.ps.version,
                "background_pushes": pusher.pushes,
            },
        )
        pusher.stop()

    if args.ckpt_dir:
        path = ckpt_lib.save_checkpoint(args.ckpt_dir, args.steps, params, opt)
        log.info("checkpoint written", extra={"path": path})

    if tracer is not None:
        from repro.obs import export_chrome_trace

        trace = export_chrome_trace(tracer, args.trace)
        log.info(
            "trace written",
            extra={
                "path": args.trace,
                "events": len(trace["traceEvents"]),
            },
        )


if __name__ == "__main__":
    main()
