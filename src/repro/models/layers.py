"""Neural-net building blocks shared by the architecture zoo.

Pure-jnp implementations; perf-critical paths (flash attention, decode
attention, MoE grouped matmul, DAPO loss) have Pallas TPU kernels in
``repro.kernels`` selected via ``repro.kernels.ops`` dispatch.
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.ctx import gather


def rms_norm(x: jax.Array, weight: jax.Array, eps: float = 1e-5) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * weight.astype(jnp.float32)).astype(dtype)


# --------------------------------------------------------------------- RoPE
def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, hd); positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)  # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    sin = jnp.sin(angles)[..., None, :]  # (..., S, 1, hd/2)
    cos = jnp.cos(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    rx1 = x1 * cos - x2 * sin
    rx2 = x2 * cos + x1 * sin
    return jnp.concatenate([rx1, rx2], axis=-1).astype(x.dtype)


# ---------------------------------------------------------------- attention
def _gqa_repeat(k: jax.Array, n_rep: int) -> jax.Array:
    """(B,S,Hkv,hd) -> (B,S,Hkv*n_rep,hd) by broadcast (no copy under XLA)."""
    if n_rep == 1:
        return k
    b, s, h, d = k.shape
    k = jnp.broadcast_to(k[:, :, :, None, :], (b, s, h, n_rep, d))
    return k.reshape(b, s, h * n_rep, d)


def attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: int = 0,
    q_offset: int = 0,
    kv_mask: Optional[jax.Array] = None,
) -> jax.Array:
    """Reference multi-head attention. q: (B,Sq,H,hd), k/v: (B,Skv,Hkv,hd).

    ``window > 0`` restricts each query to the last ``window`` keys
    (sliding-window / sub-quadratic mode). ``q_offset`` is the absolute
    position of q[0] relative to k[0] (used at decode: Sq=1, offset=pos).
    """
    b, sq, h, hd = q.shape
    skv, hkv = k.shape[1], k.shape[2]
    k = _gqa_repeat(k, h // hkv)
    v = _gqa_repeat(v, h // hkv)
    scale = 1.0 / math.sqrt(hd)
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32) * scale
    qpos = jnp.arange(sq) + q_offset
    kpos = jnp.arange(skv)
    mask = jnp.ones((sq, skv), dtype=bool)
    if causal:
        mask &= kpos[None, :] <= qpos[:, None]
    if window > 0:
        mask &= kpos[None, :] > qpos[:, None] - window
    if kv_mask is not None:  # (B, Skv) valid-key mask (decode ring caches)
        mask = mask[None, None] & kv_mask[:, None, None, :]
        logits = jnp.where(mask, logits, -1e30)
    else:
        logits = jnp.where(mask[None, None], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", probs, v)


# --------------------------------------------------------------------- MLPs
def swiglu(x: jax.Array, w_gate: jax.Array, w_up: jax.Array, w_down: jax.Array) -> jax.Array:
    h = jax.nn.silu(x @ w_gate) * (x @ w_up)
    # decode-TP: with w_gate/w_up column-sharded the hidden is sharded on
    # F; gather exact per-shard values before the down-projection so the
    # contraction stays full-width and bitwise (no-op unsharded)
    return gather(h) @ w_down


def moe_ffn(
    x: jax.Array,
    router_w: jax.Array,   # (D, E)
    w_gate: jax.Array,     # (E, D, F)
    w_up: jax.Array,       # (E, D, F)
    w_down: jax.Array,     # (E, F, D)
    *,
    top_k: int,
    capacity_factor: float = 1.25,
    expert_fn=None,        # optional (B,E,C,D)->(B,E,C,D) override (Pallas gmm)
) -> Tuple[jax.Array, jax.Array]:
    """Capacity-based token-choice MoE (GShard/MaxText-style dispatch einsum).

    x: (B, S, D). Tokens route within their own batch row; capacity
    C = ceil(S * top_k / E * factor). Returns (out, aux_loss).
    """
    b, s, d = x.shape
    e = router_w.shape[-1]
    cap = int(math.ceil(s * top_k / e * capacity_factor))
    cap = max(cap, top_k)

    logits = (x.astype(jnp.float32) @ router_w.astype(jnp.float32))  # (B,S,E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_ids = jax.lax.top_k(probs, top_k)  # (B,S,K)
    gate_vals = gate_vals / jnp.clip(gate_vals.sum(-1, keepdims=True), 1e-9)

    onehot = jax.nn.one_hot(expert_ids, e, dtype=jnp.float32)  # (B,S,K,E)
    # position of each (token, k) within its expert queue
    pos = jnp.cumsum(onehot.reshape(b, s * top_k, e), axis=1).reshape(b, s, top_k, e) - 1.0
    pos = jnp.sum(pos * onehot, axis=-1)  # (B,S,K)
    keep = pos < cap
    gate_vals = gate_vals * keep

    # dispatch/combine tensors: (B,S,K,E,C) one-hots contracted immediately
    pos_onehot = jax.nn.one_hot(pos.astype(jnp.int32), cap, dtype=jnp.float32) * keep[..., None]  # (B,S,K,C)
    dispatch = jnp.einsum("bske,bskc->bsec", onehot, pos_onehot)  # (B,S,E,C)
    combine = jnp.einsum("bsk,bske,bskc->bsec", gate_vals.astype(jnp.float32), onehot, pos_onehot)

    xin = jnp.einsum("bsec,bsd->becd", dispatch.astype(x.dtype), x)  # (B,E,C,D)
    if expert_fn is not None:
        xout = expert_fn(xin)                           # (B,E,C,D)
    else:
        h = jnp.einsum("becd,edf->becf", xin, w_gate)
        h = jax.nn.silu(h) * jnp.einsum("becd,edf->becf", xin, w_up)
        xout = jnp.einsum("becf,efd->becd", h, w_down)  # (B,E,C,D)
    out = jnp.einsum("bsec,becd->bsd", combine.astype(x.dtype), xout)

    # load-balance auxiliary loss (Switch-style)
    me = jnp.mean(probs, axis=(0, 1))                       # (E,)
    ce = jnp.mean(onehot.sum(2), axis=(0, 1))               # (E,) fraction routed
    aux = e * jnp.sum(me * ce) / top_k
    return out, aux


# -------------------------------------------------------------------- Mamba
def mamba_scan_chunked(
    dA: jax.Array,    # (B, S, I, N)  discrete state transition exp(dt*A)
    dBx: jax.Array,   # (B, S, I, N)  discrete input  dt*B*x
    cmat: jax.Array,  # (B, S, N)     output projection C
    h0: jax.Array,    # (B, I, N)     initial state
    chunk: int = 256,
) -> Tuple[jax.Array, jax.Array]:
    """Selective-scan h_t = dA_t * h_{t-1} + dBx_t with the C-contraction
    FUSED into each chunk, so the (B, S, I, N) state sequence is never
    materialized (per-chunk working set only — the memory property real
    Mamba kernels provide). Outer lax.scan over chunks (carry = boundary
    state, rematerialized on backward); inner associative scan.
    Returns (y (B, S, I), h_final (B, I, N))."""
    b, s, i, n = dA.shape
    chunk = min(chunk, s)
    assert s % chunk == 0, f"seq {s} not divisible by chunk {chunk}"
    nchunks = s // chunk
    dA_c = dA.reshape(b, nchunks, chunk, i, n).swapaxes(0, 1)
    dBx_c = dBx.reshape(b, nchunks, chunk, i, n).swapaxes(0, 1)
    cm_c = cmat.reshape(b, nchunks, chunk, n).swapaxes(0, 1).astype(jnp.float32)

    @jax.checkpoint
    def body(h, inputs):
        da, dbx, cm = inputs  # (B, chunk, I, N), (B, chunk, N)

        def combine(a, b_):
            a1, b1 = a
            a2, b2 = b_
            return a1 * a2, b1 * a2 + b2

        acc_a, acc_b = jax.lax.associative_scan(combine, (da, dbx), axis=1)
        states = acc_a * h[:, None] + acc_b  # (B, chunk, I, N)
        y = jnp.einsum("bsin,bsn->bsi", states, cm)
        return states[:, -1], y

    h_final, ys = jax.lax.scan(body, h0, (dA_c, dBx_c, cm_c))
    y = ys.swapaxes(0, 1).reshape(b, s, i)
    return y, h_final


def mamba_block(
    x: jax.Array,               # (B, S, D)
    p: dict,                    # params
    *,
    state: Optional[Tuple[jax.Array, jax.Array]] = None,  # (conv_state, ssm_state)
    decode: bool = False,
    impl: Optional[str] = None,  # kernels.ops dispatch for the scan
) -> Tuple[jax.Array, Tuple[jax.Array, jax.Array]]:
    """Simplified Mamba(S6) mixer. Returns (out (B,S,D), (conv_state, ssm_state)).

    conv_state: (B, W-1, I) last inputs; ssm_state: (B, I, N).
    """
    b, s, d = x.shape
    w_in, w_out = p["w_in"], p["w_out"]           # (D, 2I), (I, D)
    conv_w = p["conv_w"]                          # (W, I) depthwise
    w_bc, w_dt = p["w_bc"], p["w_dt"]             # (I, 2N), (I, I? -> use (I,)) low-rank simplified
    a_log, d_skip, dt_bias = p["a_log"], p["d_skip"], p["dt_bias"]  # (I,N),(I,),(I,)
    inner = w_in.shape[-1] // 2
    nstate = a_log.shape[-1]
    width = conv_w.shape[0]

    xz = x @ w_in
    xi, z = jnp.split(xz, 2, axis=-1)             # (B,S,I) each

    if state is None:
        conv_state = jnp.zeros((b, width - 1, inner), x.dtype)
        ssm_state = jnp.zeros((b, inner, nstate), jnp.float32)
    else:
        conv_state, ssm_state = state

    # depthwise causal conv over sequence
    xpad = jnp.concatenate([conv_state, xi], axis=1)  # (B, S+W-1, I)
    idx = jnp.arange(s)[:, None] + jnp.arange(width)[None, :]  # (S, W)
    windows = xpad[:, idx]                         # (B, S, W, I)
    xc = jnp.einsum("bswi,wi->bsi", windows, conv_w)
    xc = jax.nn.silu(xc)
    new_conv_state = xpad[:, s:]                   # last W-1 inputs

    bc = xc @ w_bc                                 # (B,S,2N)
    bmat, cmat = jnp.split(bc, 2, axis=-1)         # (B,S,N)
    dt = jax.nn.softplus(xc * w_dt + dt_bias)      # (B,S,I) elementwise dt
    a = -jnp.exp(a_log.astype(jnp.float32))        # (I,N)

    dA = jnp.exp(dt[..., None].astype(jnp.float32) * a)            # (B,S,I,N)
    dBx = (dt * xc)[..., None].astype(jnp.float32) * bmat[:, :, None, :].astype(jnp.float32)

    if decode:  # S == 1 single step
        h = dA[:, 0] * ssm_state + dBx[:, 0]       # (B,I,N)
        y = jnp.einsum("bin,bsn->bsi", h, cmat.astype(jnp.float32))
        new_ssm_state = h
    else:
        from repro.kernels import ops
        from repro.models import runmode

        if ops.resolve_impl(impl) != "ref":
            # fused Pallas selective scan: the (B,S,I,N) discretized state
            # tensors never leave VMEM (the 16x memory amplifier behind
            # hymba's worst-in-zoo roofline fraction)
            y, new_ssm_state = ops.selective_scan(
                dt, xc, bmat, cmat, a, ssm_state, impl=impl
            )
        else:
            y, new_ssm_state = mamba_scan_chunked(
                dA, dBx, cmat, ssm_state, chunk=runmode.mamba_chunk(s)
            )

    y = y.astype(x.dtype)
    y = y + xc * d_skip
    y = y * jax.nn.silu(z)
    return y @ w_out, (new_conv_state, new_ssm_state)


# -------------------------------------------------------------------- xLSTM
def mlstm_recurrent_step(c, n, m, q, k, v, i_raw, f_raw):
    """One stabilized mLSTM step (reference semantics).

    c: (B,H,dk,dv), n: (B,H,dk), m: (B,H); q,k,v: (B,H,dk|dv); gates: (B,H).
    """
    log_f = -jax.nn.softplus(-f_raw)               # log sigmoid(f)
    m_new = jnp.maximum(log_f + m, i_raw)
    i_g = jnp.exp(i_raw - m_new)
    f_g = jnp.exp(log_f + m - m_new)
    c_new = f_g[..., None, None] * c + i_g[..., None, None] * (k[..., :, None] * v[..., None, :])
    n_new = f_g[..., None] * n + i_g[..., None] * k
    denom = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", n_new, q)), jnp.exp(-m_new))
    h = jnp.einsum("bhkv,bhk->bhv", c_new, q) / denom[..., None]
    return c_new, n_new, m_new, h


def mlstm_sequence(q, k, v, i_raw, f_raw, state=None, chunk: int = 256):
    """Chunkwise-parallel stabilized mLSTM (official xLSTM parallel form).

    q,k,v: (B,S,H,dk|dv); gates i_raw/f_raw: (B,S,H). Only chunk-boundary
    states are materialized (O(S/chunk) memory); within-chunk outputs use the
    quadratic attention-like formulation. State is the *stabilized* triple
    (C_hat = C*exp(-m), n_hat = n*exp(-m), m), matching
    ``mlstm_recurrent_step`` (the decode/reference path).
    Returns (h (B,S,H,dv), final_state).
    """
    b, s, h, dk = q.shape
    dv = v.shape[-1]
    if state is None:
        c0 = jnp.zeros((b, h, dk, dv), jnp.float32)
        n0 = jnp.zeros((b, h, dk), jnp.float32)
        m0 = jnp.full((b, h), -1e30, jnp.float32)
    else:
        c0, n0, m0 = state
    scale = 1.0 / math.sqrt(dk)
    chunk = min(chunk, s)
    assert s % chunk == 0, f"seq {s} not divisible by chunk {chunk}"
    nc = s // chunk

    def to_chunks(x):
        return x.reshape((b, nc, chunk) + x.shape[2:]).swapaxes(0, 1)

    causal = jnp.tril(jnp.ones((chunk, chunk), dtype=bool))

    @jax.checkpoint
    def body(carry, inp):
        c_hat, n_hat, m_prev = carry           # (B,H,dk,dv), (B,H,dk), (B,H)
        qc, kc, vc, ic, fc = [x.astype(jnp.float32) for x in inp]  # (B,L,H,*)
        qc = qc * scale
        log_f = -jax.nn.softplus(-fc)           # (B,L,H)
        bcum = jnp.cumsum(log_f, axis=1)        # (B,L,H)
        # intra-chunk exponents w[t,s] = b_t - b_s + i_s   (s <= t)
        w = bcum[:, :, None, :] - bcum[:, None, :, :] + ic[:, None, :, :]  # (B,L,L,H)
        w = jnp.where(causal[None, :, :, None], w, -jnp.inf)
        a = bcum + m_prev[:, None, :]           # (B,L,H) initial-state exponent
        m_t = jnp.maximum(jnp.max(w, axis=2), a)  # (B,L,H)
        sc = jnp.exp(w - m_t[:, :, None, :])    # (B,L,L,H); exp(-inf)=0 on mask
        e0 = jnp.exp(a - m_t)                   # (B,L,H)
        qk = jnp.einsum("blhd,bshd->blsh", qc, kc) * sc
        h_num = (jnp.einsum("blh,blhd,bhdv->blhv", e0, qc, c_hat)
                 + jnp.einsum("blsh,bshv->blhv", qk, vc))
        n_vec = (jnp.einsum("blh,bhd->blhd", e0, n_hat)
                 + jnp.einsum("blsh,bshd->blhd", sc, kc))
        denom = jnp.maximum(jnp.abs(jnp.einsum("blhd,blhd->blh", qc, n_vec)),
                            jnp.exp(-m_t))
        h_out = h_num / denom[..., None]
        # chunk-final stabilized state
        b_last = bcum[:, -1]                    # (B,H)
        w_last = b_last[:, None, :] - bcum + ic  # (B,L,H) coefficient exponents
        m_new = jnp.maximum(b_last + m_prev, jnp.max(w_last, axis=1))
        coef = jnp.exp(w_last - m_new[:, None, :])
        carry_c = (jnp.exp(b_last + m_prev - m_new)[:, :, None, None] * c_hat
                   + jnp.einsum("bsh,bshd,bshv->bhdv", coef, kc, vc))
        carry_n = (jnp.exp(b_last + m_prev - m_new)[:, :, None] * n_hat
                   + jnp.einsum("bsh,bshd->bhd", coef, kc))
        return (carry_c, carry_n, m_new), h_out

    xs = tuple(to_chunks(x) for x in (q, k, v, i_raw, f_raw))
    (c, n, m), hs = jax.lax.scan(body, (c0, n0, m0), xs)
    hs = hs.swapaxes(0, 1).reshape(b, s, h, dv)
    return hs.astype(q.dtype), (c, n, m)


def slstm_sequence(x_gates, r_weights, state=None):
    """sLSTM with per-head recurrent gating.

    x_gates: (B,S,4,H,dh) precomputed input contributions for (i,f,z,o);
    r_weights: (4,H,dh,dh) recurrent weights. Returns (h (B,S,H,dh), state).
    """
    b, s, _, h, dh = x_gates.shape
    if state is None:
        hh = jnp.zeros((b, h, dh), jnp.float32)
        cc = jnp.zeros((b, h, dh), jnp.float32)
        nn = jnp.ones((b, h, dh), jnp.float32)
        mm = jnp.zeros((b, h, dh), jnp.float32)
    else:
        hh, cc, nn, mm = state
    rw = r_weights.astype(jnp.float32)

    def step(carry, xg):
        hh, cc, nn, mm = carry
        rec = jnp.einsum("bhd,ghde->gbhe", hh, rw)       # (4,B,H,dh)
        i_raw = xg[:, 0].astype(jnp.float32) + rec[0]
        f_raw = xg[:, 1].astype(jnp.float32) + rec[1]
        z = jnp.tanh(xg[:, 2].astype(jnp.float32) + rec[2])
        o = jax.nn.sigmoid(xg[:, 3].astype(jnp.float32) + rec[3])
        log_f = -jax.nn.softplus(-f_raw)
        m_new = jnp.maximum(log_f + mm, i_raw)
        i_g = jnp.exp(i_raw - m_new)
        f_g = jnp.exp(log_f + mm - m_new)
        c_new = f_g * cc + i_g * z
        n_new = f_g * nn + i_g
        h_new = o * c_new / jnp.maximum(n_new, 1e-6)
        return (h_new, c_new, n_new, m_new), h_new

    (hh, cc, nn, mm), hs = jax.lax.scan(jax.checkpoint(step), (hh, cc, nn, mm),
                                        x_gates.swapaxes(0, 1))
    return hs.swapaxes(0, 1).astype(x_gates.dtype), (hh, cc, nn, mm)
