"""Unified architecture zoo: init / train-forward / prefill / decode for all
assigned families.

families (``ArchConfig.family``):
  dense   — GQA transformer (qwen2.5-14b, granite-3-8b, qwen2-1.5b, glm4-9b)
  moe     — MoE FFN (llama4-scout top-1+shared, dbrx top-4, qwen3-30b-a3b)
  hybrid  — parallel attention + Mamba heads per block (hymba-1.5b)
  ssm     — alternating mLSTM/sLSTM blocks, no KV cache (xlstm-1.3b)
  vlm     — dense backbone, stub patch embeddings prepended (internvl2-76b)
  audio   — encoder-decoder, stub frame embeddings (whisper-tiny)

Design rules:
* params are pytrees with layer-stacked leaves; layers execute under
  ``lax.scan`` so the lowered HLO stays O(1) in depth (critical for the
  40-cell x 2-mesh dry-run compile budget);
* attention and MoE matmuls route through ``repro.kernels.ops`` so the
  Pallas kernels slot in on TPU without touching model code;
* decode carries an explicit cache pytree — KV ring caches for attention
  families, recurrent states for SSM/hybrid — and per-sequence positions,
  so the rollout engine can interrupt/migrate/re-prefill trajectories
  (StaleFlow partial rollout) by exporting tokens only;
* modality frontends are stubs per the assignment: ``vlm`` consumes
  precomputed patch embeddings, ``audio`` precomputed frame embeddings.

Documented simplifications (systems-equivalent; DESIGN.md §4): GLM partial
rotary -> full rotary; whisper GELU MLP -> SwiGLU and learned positions ->
sinusoidal; hymba meta tokens omitted; xLSTM block internals reduced to
q/k/v + gates + out-proj (cell math follows the stabilized formulation in
``layers.py``).
"""
from __future__ import annotations

import math
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.distributed.ctx import constrain, gather
from repro.models import runmode
from repro.kernels import ops
from repro.models import layers

Params = Dict[str, Any]
Cache = Dict[str, Any]


# =============================================================== param init
def _norm_init(key, d, dtype):
    return jnp.ones((d,), dtype)


def _dense_init(key, shape, dtype, fan_in=None):
    fan_in = fan_in if fan_in is not None else shape[0]
    scale = 1.0 / math.sqrt(fan_in)
    return (jax.random.normal(key, shape) * scale).astype(dtype)


def _split(key, n):
    return list(jax.random.split(key, n))


def _attn_init(cfg: ArchConfig, key, dtype, n_heads=None, n_kv=None) -> Params:
    h = n_heads or cfg.n_heads
    hkv = n_kv or cfg.n_kv_heads
    hd = cfg.hd
    d = cfg.d_model
    ks = _split(key, 4)
    p = {
        "wq": _dense_init(ks[0], (d, h * hd), dtype),
        "wk": _dense_init(ks[1], (d, hkv * hd), dtype),
        "wv": _dense_init(ks[2], (d, hkv * hd), dtype),
        "wo": _dense_init(ks[3], (h * hd, d), dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h * hd,), dtype)
        p["bk"] = jnp.zeros((hkv * hd,), dtype)
        p["bv"] = jnp.zeros((hkv * hd,), dtype)
    return p


def _ffn_init(cfg: ArchConfig, key, dtype) -> Params:
    d, f = cfg.d_model, cfg.d_ff
    ks = _split(key, 3)
    return {
        "w_gate": _dense_init(ks[0], (d, f), dtype),
        "w_up": _dense_init(ks[1], (d, f), dtype),
        "w_down": _dense_init(ks[2], (f, d), dtype),
    }


def _moe_init(cfg: ArchConfig, key, dtype) -> Params:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    ks = _split(key, 5)
    p = {
        "router": _dense_init(ks[0], (d, e), dtype),
        "we_gate": _dense_init(ks[1], (e, d, f), dtype, fan_in=d),
        "we_up": _dense_init(ks[2], (e, d, f), dtype, fan_in=d),
        "we_down": _dense_init(ks[3], (e, f, d), dtype, fan_in=f),
    }
    if cfg.shared_expert:
        sk = _split(ks[4], 3)
        p["ws_gate"] = _dense_init(sk[0], (d, f), dtype)
        p["ws_up"] = _dense_init(sk[1], (d, f), dtype)
        p["ws_down"] = _dense_init(sk[2], (f, d), dtype)
    return p


def _mamba_init(cfg: ArchConfig, key, dtype) -> Params:
    d = cfg.d_model
    inner = cfg.ssm_expand * d
    n = cfg.ssm_state
    w = cfg.ssm_conv
    ks = _split(key, 5)
    return {
        "w_in": _dense_init(ks[0], (d, 2 * inner), dtype),
        "w_out": _dense_init(ks[1], (inner, d), dtype),
        "conv_w": _dense_init(ks[2], (w, inner), dtype, fan_in=w),
        "w_bc": _dense_init(ks[3], (inner, 2 * n), dtype),
        "w_dt": (jax.random.uniform(ks[4], (inner,)) * 0.1).astype(dtype),
        "a_log": jnp.log(
            jnp.broadcast_to(jnp.arange(1, n + 1, dtype=jnp.float32), (inner, n))
        ).astype(dtype),
        "d_skip": jnp.ones((inner,), dtype),
        "dt_bias": jnp.full((inner,), -4.6, dtype),  # softplus^-1(0.01)
    }


def _mlstm_init(cfg: ArchConfig, key, dtype) -> Params:
    d, h, hd = cfg.d_model, cfg.n_heads, cfg.hd
    ks = _split(key, 6)
    return {
        "norm": _norm_init(ks[0], d, dtype),
        "wq": _dense_init(ks[1], (d, h * hd), dtype),
        "wk": _dense_init(ks[2], (d, h * hd), dtype),
        "wv": _dense_init(ks[3], (d, h * hd), dtype),
        "w_if": _dense_init(ks[4], (d, 2 * h), dtype),
        "wo": _dense_init(ks[5], (h * hd, d), dtype),
    }


def _slstm_init(cfg: ArchConfig, key, dtype) -> Params:
    d, h, hd = cfg.d_model, cfg.n_heads, cfg.hd
    ks = _split(key, 3)
    return {
        "norm": _norm_init(ks[0], d, dtype),
        "w_gates": _dense_init(ks[1], (d, 4 * h * hd), dtype),
        "r_weights": _dense_init(
            ks[2], (4, h, hd, hd), dtype, fan_in=hd
        ),
        "wo": _dense_init(jax.random.fold_in(ks[2], 1), (h * hd, d), dtype),
    }


def _block_init(cfg: ArchConfig, key, dtype) -> Params:
    """One transformer block (dense / moe / vlm / hybrid / audio-decoder)."""
    ks = _split(key, 4)
    p: Params = {"attn_norm": _norm_init(ks[0], cfg.d_model, dtype)}
    p.update(_attn_init(cfg, ks[1], dtype))
    p["ffn_norm"] = _norm_init(ks[2], cfg.d_model, dtype)
    if cfg.family == "moe":
        p.update(_moe_init(cfg, ks[3], dtype))
    else:
        p.update(_ffn_init(cfg, ks[3], dtype))
    if cfg.family == "hybrid":
        p["mamba"] = _mamba_init(cfg, jax.random.fold_in(key, 99), dtype)
    if cfg.cross_attention:
        ck = jax.random.fold_in(key, 7)
        p["cross_norm"] = _norm_init(ck, cfg.d_model, dtype)
        p["cross"] = _attn_init(cfg, ck, dtype, n_kv=cfg.n_heads)
    return p


def _stacked(fn, key, n):
    """Initialize ``n`` layers with independent keys, stacking the leaves."""
    keys = jnp.stack(jax.random.split(key, n))
    return jax.vmap(fn)(keys)


def xlstm_period(cfg: ArchConfig) -> int:
    """sLSTM placement period: 1 sLSTM per ``p`` blocks (xLSTM 7:1 ratio for
    48-layer configs; 3:1 for the reduced 4-layer smoke variant)."""
    for p in (8, 4, 2):
        if cfg.n_layers % p == 0 and cfg.n_layers >= p:
            return p
    return 1


def init_params(cfg: ArchConfig, key, dtype=jnp.float32) -> Params:
    ks = _split(key, 8)
    d, v = cfg.d_model, cfg.padded_vocab
    params: Params = {
        "embed": _dense_init(ks[0], (v, d), dtype, fan_in=d),
        "final_norm": _norm_init(ks[1], d, dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = _dense_init(ks[2], (d, v), dtype)

    if cfg.family == "ssm":
        p = xlstm_period(cfg)
        groups = cfg.n_layers // p
        params["mlstm"] = _stacked(
            lambda k: _stacked(lambda k2: _mlstm_init(cfg, k2, dtype), k, p - 1),
            ks[3],
            groups,
        )
        params["slstm"] = _stacked(
            lambda k: _slstm_init(cfg, k, dtype), ks[4], groups
        )
    else:
        params["blocks"] = _stacked(
            lambda k: _block_init(cfg, k, dtype), ks[3], cfg.n_layers
        )

    if cfg.encoder_layers:
        enc_cfg = cfg  # same width; bidirectional attention, no cross
        params["enc_blocks"] = _stacked(
            lambda k: {
                "attn_norm": _norm_init(k, d, dtype),
                **_attn_init(enc_cfg, k, dtype, n_kv=cfg.n_heads),
                "ffn_norm": _norm_init(jax.random.fold_in(k, 1), d, dtype),
                **_ffn_init(enc_cfg, jax.random.fold_in(k, 2), dtype),
            },
            ks[5],
            cfg.encoder_layers,
        )
        params["enc_final_norm"] = _norm_init(ks[6], d, dtype)
    return params


# ============================================================ forward pieces
def _project_qkv(x, p, cfg: ArchConfig, positions, *, rope=True, n_heads=None,
                 n_kv=None):
    b, s, _ = x.shape
    h = n_heads or cfg.n_heads
    hkv = n_kv or cfg.n_kv_heads
    hd = cfg.hd
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if "bq" in p:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = constrain(q.reshape(b, s, h, hd), "heads")
    k = constrain(k.reshape(b, s, hkv, hd), "heads")
    v = constrain(v.reshape(b, s, hkv, hd), "heads")
    if rope:
        q = layers.apply_rope(q, positions, cfg.rope_theta)
        k = layers.apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def _attn_train(x, p, cfg: ArchConfig, positions, *, window=0, causal=True,
                impl=None):
    b, s, _ = x.shape
    q, k, v = _project_qkv(x, p, cfg, positions)
    o = ops.flash_attention(q, k, v, causal=causal, window=window, impl=impl)
    return o.reshape(b, s, -1) @ p["wo"]


def _ffn(x, p):
    return layers.swiglu(x, p["w_gate"], p["w_up"], p["w_down"])


def _moe(x, p, cfg: ArchConfig, impl=None):
    def expert_fn(xin):  # (B, E, C, D) -> (B, E, C, D) via grouped matmul
        b, e, c, d = xin.shape
        flat = xin.transpose(1, 0, 2, 3).reshape(e, b * c, d)
        out = ops.moe_expert_ffn(
            flat, p["we_gate"], p["we_up"], p["we_down"], impl=impl
        )
        return out.reshape(e, b, c, d).transpose(1, 0, 2, 3)

    out, aux = layers.moe_ffn(
        x,
        p["router"],
        p["we_gate"],
        p["we_up"],
        p["we_down"],
        top_k=cfg.top_k,
        capacity_factor=cfg.moe_capacity_factor,
        expert_fn=expert_fn,
    )
    if cfg.shared_expert:
        out = out + layers.swiglu(x, p["ws_gate"], p["ws_up"], p["ws_down"])
    return out, aux


def _block_train(cfg: ArchConfig, x, p, positions, *, window=0, impl=None,
                 enc_out=None):
    """One block, training/prefill form. Returns (x, aux)."""
    aux = jnp.zeros((), jnp.float32)
    h = layers.rms_norm(x, p["attn_norm"], cfg.norm_eps)
    attn = _attn_train(h, p, cfg, positions, window=window, impl=impl)
    if cfg.family == "hybrid":
        ssm, _ = layers.mamba_block(h, p["mamba"], impl=impl)
        x = x + 0.5 * (attn + ssm)
    else:
        x = x + attn
    if enc_out is not None and "cross" in p:
        hc = layers.rms_norm(x, p["cross_norm"], cfg.norm_eps)
        b, s, _ = hc.shape
        q = (hc @ p["cross"]["wq"]).reshape(b, s, cfg.n_heads, cfg.hd)
        kk = (enc_out @ p["cross"]["wk"]).reshape(b, -1, cfg.n_heads, cfg.hd)
        vv = (enc_out @ p["cross"]["wv"]).reshape(b, -1, cfg.n_heads, cfg.hd)
        o = ops.flash_attention(q, kk, vv, causal=False, impl=impl)
        x = x + o.reshape(b, s, -1) @ p["cross"]["wo"]
    h2 = layers.rms_norm(x, p["ffn_norm"], cfg.norm_eps)
    if cfg.family == "moe":
        f, aux = _moe(h2, p, cfg, impl=impl)
    else:
        f = _ffn(h2, p)
    return x + f, aux


def _mlstm_forward(cfg: ArchConfig, x, p, state=None, *, decode=False):
    b, s, _ = x.shape
    h, hd = cfg.n_heads, cfg.hd
    xin = layers.rms_norm(x, p["norm"], cfg.norm_eps)
    q = (xin @ p["wq"]).reshape(b, s, h, hd)
    k = (xin @ p["wk"]).reshape(b, s, h, hd) / math.sqrt(hd)
    v = (xin @ p["wv"]).reshape(b, s, h, hd)
    gates = (xin @ p["w_if"]).reshape(b, s, 2, h)
    i_raw, f_raw = gates[:, :, 0], gates[:, :, 1]
    if decode:
        c, n, m = state
        c2, n2, m2, out = layers.mlstm_recurrent_step(
            c, n, m, q[:, 0] / math.sqrt(hd), k[:, 0], v[:, 0],
            i_raw[:, 0].astype(jnp.float32), f_raw[:, 0].astype(jnp.float32),
        )
        out = out[:, None].astype(x.dtype)
        new_state = (c2, n2, m2)
    else:
        out, new_state = layers.mlstm_sequence(q, k, v, i_raw, f_raw, state)
    y = out.reshape(b, s, h * hd) @ p["wo"]
    return x + y, new_state


def _slstm_forward(cfg: ArchConfig, x, p, state=None):
    b, s, _ = x.shape
    h, hd = cfg.n_heads, cfg.hd
    xin = layers.rms_norm(x, p["norm"], cfg.norm_eps)
    xg = (xin @ p["w_gates"]).reshape(b, s, 4, h, hd)
    out, new_state = layers.slstm_sequence(xg, p["r_weights"], state)
    y = out.reshape(b, s, h * hd) @ p["wo"]
    return x + y, new_state


def _logits(cfg: ArchConfig, params, x):
    x = layers.rms_norm(x, params["final_norm"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    out = x @ head
    if cfg.padded_vocab != cfg.vocab_size:
        # mask padded vocab columns out of the softmax
        col = jax.lax.broadcasted_iota(jnp.int32, out.shape, out.ndim - 1)
        out = jnp.where(col < cfg.vocab_size, out, -1e9)
    if out.ndim == 3:
        out = constrain(out, "logits")
    # decode-TP: a vocab-sharded lm_head leaves ``out`` sharded on V;
    # gather before the softmax reductions in sampling (no-op unsharded)
    return gather(out)


def _encode(cfg: ArchConfig, params, frames, impl=None):
    """Whisper-style encoder over stub frame embeddings (B, Senc, D)."""
    senc = frames.shape[1]
    pos = _sinusoidal(senc, cfg.d_model, frames.dtype)
    x = frames + pos[None]

    def body(x, p):
        # bidirectional attention, full heads (no GQA on the encoder)
        h = layers.rms_norm(x, p["attn_norm"], cfg.norm_eps)
        b, s, _ = h.shape
        q = (h @ p["wq"]).reshape(b, s, cfg.n_heads, cfg.hd)
        k = (h @ p["wk"]).reshape(b, s, cfg.n_heads, cfg.hd)
        v = (h @ p["wv"]).reshape(b, s, cfg.n_heads, cfg.hd)
        o = ops.flash_attention(q, k, v, causal=False, impl=impl)
        x = x + o.reshape(b, s, -1) @ p["wo"]
        h2 = layers.rms_norm(x, p["ffn_norm"], cfg.norm_eps)
        return x + _ffn(h2, p), None

    x, _ = jax.lax.scan(
        body, x, params["enc_blocks"], unroll=runmode.inner_unroll()
    )
    return layers.rms_norm(x, params["enc_final_norm"], cfg.norm_eps)


def _sinusoidal(length: int, d: int, dtype) -> jax.Array:
    pos = jnp.arange(length, dtype=jnp.float32)[:, None]
    dim = jnp.arange(0, d, 2, dtype=jnp.float32)[None, :]
    ang = pos / jnp.power(10000.0, dim / d)
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1).astype(dtype)


# ================================================================== forward
def forward(
    cfg: ArchConfig,
    params: Params,
    tokens: jax.Array,                       # (B, S) int32
    *,
    frontend_embeds: Optional[jax.Array] = None,  # vlm patches / audio frames
    impl: Optional[str] = None,
    remat: bool = False,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Full-sequence training forward. Returns (logits (B,S,V), aux)."""
    b, s = tokens.shape
    x = params["embed"][tokens]

    enc_out = None
    if cfg.family == "audio":
        assert frontend_embeds is not None, "audio needs stub frame embeddings"
        enc_out = _encode(cfg, params, frontend_embeds.astype(x.dtype), impl=impl)
        x = x + _sinusoidal(s, cfg.d_model, x.dtype)[None]
        positions = jnp.arange(s)
    elif cfg.family == "vlm":
        assert frontend_embeds is not None, "vlm needs stub patch embeddings"
        x = jnp.concatenate([frontend_embeds.astype(x.dtype), x], axis=1)
        positions = jnp.arange(x.shape[1])
    else:
        positions = jnp.arange(s)

    window = (
        cfg.sliding_window
        if cfg.sliding_window and x.shape[1] > cfg.long_context_threshold
        else 0
    )

    aux_total = jnp.zeros((), jnp.float32)
    if cfg.family == "ssm":

        def group_body(x, gp):
            def m_body(x, mp):
                x, _ = _mlstm_forward(cfg, x, mp)
                return x, None

            x, _ = jax.lax.scan(
                m_body, x, gp["mlstm"], unroll=runmode.inner_unroll()
            )
            x, _ = _slstm_forward(cfg, x, gp["slstm"])
            return x, None

        body = jax.checkpoint(group_body) if remat else group_body
        x, _ = jax.lax.scan(
            body, x, {"mlstm": params["mlstm"], "slstm": params["slstm"]},
            unroll=runmode.outer_unroll(),
        )
    else:
        def body(carry, p):
            x, aux = carry
            x, a = _block_train(
                cfg, x, p, positions, window=window, impl=impl, enc_out=enc_out
            )
            x = constrain(x, "boundary")  # SP: boundary activations
            return (x, aux + a), None

        body_fn = jax.checkpoint(body) if remat else body
        (x, aux_total), _ = jax.lax.scan(
            body_fn, (x, aux_total), params["blocks"],
            unroll=runmode.outer_unroll(),
        )

    if cfg.family == "vlm":
        x = x[:, -s:]  # only text positions produce logits
    logits = _logits(cfg, params, x)
    return logits, {"moe_aux": aux_total}


# ==================================================================== cache
def init_cache(
    cfg: ArchConfig, batch: int, max_len: int, dtype=jnp.float32
) -> Cache:
    """Decode cache sized for ``max_len`` total positions (prompt+generated).

    Sub-quadratic archs cap their attention cache at the sliding window once
    ``max_len`` crosses the long-context threshold; SSM state is O(1).
    NOTE: for ``vlm`` archs, ``max_len`` must include ``cfg.n_patches``
    (patch embeddings occupy the leading cache positions).
    """
    cache: Cache = {"pos": jnp.zeros((batch,), jnp.int32)}
    l, hkv, hd, h = cfg.n_layers, cfg.n_kv_heads, cfg.hd, cfg.n_heads
    if cfg.family == "ssm":
        p = xlstm_period(cfg)
        g = cfg.n_layers // p
        dk = hd
        cache["mlstm"] = (
            jnp.zeros((g, p - 1, batch, h, dk, dk), jnp.float32),
            jnp.zeros((g, p - 1, batch, h, dk), jnp.float32),
            jnp.full((g, p - 1, batch, h), -1e30, jnp.float32),
        )
        cache["slstm"] = (
            jnp.zeros((g, batch, h, hd), jnp.float32),
            jnp.zeros((g, batch, h, hd), jnp.float32),
            jnp.ones((g, batch, h, hd), jnp.float32),
            jnp.zeros((g, batch, h, hd), jnp.float32),
        )
        return cache

    kv_len = max_len
    if cfg.sliding_window and max_len > cfg.long_context_threshold:
        kv_len = cfg.sliding_window
    cache["k"] = jnp.zeros((l, batch, kv_len, hkv, hd), dtype)
    cache["v"] = jnp.zeros((l, batch, kv_len, hkv, hd), dtype)

    if cfg.family == "hybrid":
        inner = cfg.ssm_expand * cfg.d_model
        cache["conv"] = jnp.zeros((l, batch, cfg.ssm_conv - 1, inner), dtype)
        cache["ssm"] = jnp.zeros((l, batch, inner, cfg.ssm_state), jnp.float32)
    if cfg.family == "audio":
        cache["xk"] = jnp.zeros((l, batch, cfg.encoder_seq, h, hd), dtype)
        cache["xv"] = jnp.zeros((l, batch, cfg.encoder_seq, h, hd), dtype)
    return cache


def init_paged_cache(
    cfg: ArchConfig,
    batch: int,
    max_len: int,
    n_blocks: int,
    block_size: int,
    dtype=jnp.float32,
) -> Cache:
    """Decode cache with the KV laid out as a shared block pool.

    ``k``/``v`` become ``(layers, n_blocks, block_size, Hkv, hd)`` pools
    addressed through per-slot block tables (``repro.rollout.kv_allocator``)
    instead of ``(layers, batch, max_len, ...)`` dense rows — HBM scales
    with *allocated* tokens, not ``batch * max_len``. All other per-slot
    state (``pos``, hybrid conv/ssm, audio cross caches) keeps the dense
    per-slot layout: it is O(1) per slot and batch-indexed by the runners.

    Constraints: ``block_size`` must divide ``max_len`` (so a full table
    spans exactly the dense cache width — bit-for-bit equivalence with the
    dense path), sliding-window ring caches are not paged, and the SSM
    family has no KV cache to page.
    """
    if cfg.family == "ssm":
        raise ValueError("ssm family has no KV cache to page")
    if cfg.sliding_window and max_len > cfg.long_context_threshold:
        raise ValueError("paged cache does not support ring (windowed) KV")
    if max_len % block_size:
        raise ValueError(
            f"block_size {block_size} must divide max_len {max_len}"
        )
    cache: Cache = {"pos": jnp.zeros((batch,), jnp.int32)}
    l, hkv, hd, h = cfg.n_layers, cfg.n_kv_heads, cfg.hd, cfg.n_heads
    cache["k"] = jnp.zeros((l, n_blocks, block_size, hkv, hd), dtype)
    cache["v"] = jnp.zeros((l, n_blocks, block_size, hkv, hd), dtype)
    if cfg.family == "hybrid":
        inner = cfg.ssm_expand * cfg.d_model
        cache["conv"] = jnp.zeros((l, batch, cfg.ssm_conv - 1, inner), dtype)
        cache["ssm"] = jnp.zeros((l, batch, inner, cfg.ssm_state), jnp.float32)
    if cfg.family == "audio":
        cache["xk"] = jnp.zeros((l, batch, cfg.encoder_seq, h, hd), dtype)
        cache["xv"] = jnp.zeros((l, batch, cfg.encoder_seq, h, hd), dtype)
    return cache


def copy_kv_blocks(
    cache: Cache,
    src: jax.Array,           # (C,) int32 source pool blocks
    dst: jax.Array,           # (C,) int32 destination pool blocks
    *,
    impl: Optional[str] = None,
) -> Cache:
    """Duplicate pool blocks ``src[c] -> dst[c]`` in a paged cache's K/V.

    The copy-on-write step of prefix sharing: after a group prompt is
    prefilled once, its partially-filled tail block is copied into each
    member's private block so decode appends never alias. Dispatches
    through ``kernels.ops`` (Pallas in-place block move on TPU; XLA
    gather/scatter on the ref path). Only ``k``/``v`` change; per-slot
    state is untouched."""
    new_cache = dict(cache)
    new_cache["k"], new_cache["v"] = ops.copy_pool_blocks(
        cache["k"], cache["v"], src, dst, impl=impl
    )
    return new_cache


# ================================================================== prefill
def prefill(
    cfg: ArchConfig,
    params: Params,
    tokens: jax.Array,                 # (B, S) right-padded prompts
    prompt_lengths: jax.Array,         # (B,) valid lengths
    cache: Cache,
    *,
    frontend_embeds: Optional[jax.Array] = None,
    impl: Optional[str] = None,
) -> Tuple[jax.Array, Cache]:
    """Run the prompt through the model, filling the cache. Returns
    (next-token logits (B, V) at each prompt's last valid position, cache)."""
    b, s = tokens.shape
    x = params["embed"][tokens]
    offset = 0

    enc_out = None
    if cfg.family == "audio":
        enc_out = _encode(cfg, params, frontend_embeds.astype(x.dtype), impl=impl)
        x = x + _sinusoidal(s, cfg.d_model, x.dtype)[None]
    elif cfg.family == "vlm":
        x = jnp.concatenate([frontend_embeds.astype(x.dtype), x], axis=1)
        offset = frontend_embeds.shape[1]

    positions = jnp.arange(x.shape[1])
    seq = x.shape[1]

    if cfg.family == "ssm":
        def group_body(x, gp_and_state):
            gp, (mc, sc) = gp_and_state

            def m_body(x, pstate):
                mp, st = pstate
                x, new_st = _mlstm_forward(cfg, x, mp, state=st)
                return x, new_st

            x, new_m = jax.lax.scan(
                m_body, x, (gp["mlstm"], mc), unroll=runmode.inner_unroll()
            )
            x, new_s = _slstm_forward(cfg, x, gp["slstm"], state=sc)
            return x, (new_m, new_s)

        mc0 = cache["mlstm"]
        sc0 = cache["slstm"]
        # regroup stacked states as scan xs
        x, states = jax.lax.scan(
            group_body,
            x,
            (
                {"mlstm": params["mlstm"], "slstm": params["slstm"]},
                (mc0, sc0),
            ),
            unroll=runmode.outer_unroll(),
        )
        new_cache = dict(cache)
        new_cache["mlstm"], new_cache["slstm"] = states
        new_cache["pos"] = prompt_lengths.astype(jnp.int32)
        # NOTE: recurrent prefill processes padded positions too; for the
        # smoke/runtime path all prompts in a batch share a length (the
        # rollout engine pads per-instance batches to a common prompt len).
        idx = prompt_lengths - 1
        last = x[jnp.arange(b), idx]
        return _logits(cfg, params, last), new_cache

    kv_len = cache["k"].shape[2]  # static (shape-derived), never a tracer
    window = cfg.sliding_window if kv_len == cfg.sliding_window else 0

    def body(carry, pc):
        x, aux = carry
        p, (k_slot, v_slot, conv_slot, ssm_slot, xk_slot, xv_slot) = pc
        h = layers.rms_norm(x, p["attn_norm"], cfg.norm_eps)
        q, k, v = _project_qkv(h, p, cfg, positions)
        o = ops.flash_attention(q, k, v, causal=True, window=window, impl=impl)
        # decode-TP: heads are computed per shard; gather exact per-head
        # values before the full-width wo contraction (no-op unsharded)
        attn = gather(o).reshape(b, seq, -1) @ p["wo"]
        new_conv, new_ssm = conv_slot, ssm_slot
        if cfg.family == "hybrid":
            ssm_out, (new_conv, new_ssm) = layers.mamba_block(
                h, p["mamba"], impl=impl
            )
            x = x + 0.5 * (attn + ssm_out)
        else:
            x = x + attn
        new_xk, new_xv = xk_slot, xv_slot
        if enc_out is not None and "cross" in p:
            hc = layers.rms_norm(x, p["cross_norm"], cfg.norm_eps)
            qc = (hc @ p["cross"]["wq"]).reshape(b, seq, cfg.n_heads, cfg.hd)
            new_xk = (enc_out @ p["cross"]["wk"]).reshape(
                b, -1, cfg.n_heads, cfg.hd
            ).astype(xk_slot.dtype)
            new_xv = (enc_out @ p["cross"]["wv"]).reshape(
                b, -1, cfg.n_heads, cfg.hd
            ).astype(xv_slot.dtype)
            oc = ops.flash_attention(qc, new_xk, new_xv, causal=False, impl=impl)
            x = x + gather(oc).reshape(b, seq, -1) @ p["cross"]["wo"]
        h2 = layers.rms_norm(x, p["ffn_norm"], cfg.norm_eps)
        if cfg.family == "moe":
            f, a = _moe(h2, p, cfg, impl=impl)
            aux = aux + a
        else:
            f = _ffn(h2, p)
        x = constrain(x + f, "boundary")  # SP: RS+AG instead of all-reduce
        # write KV into the cache (ring-aware for windowed caches)
        if kv_len >= seq:
            new_k = jax.lax.dynamic_update_slice(
                k_slot, k.astype(k_slot.dtype), (0, 0, 0, 0)
            )
            new_v = jax.lax.dynamic_update_slice(
                v_slot, v.astype(v_slot.dtype), (0, 0, 0, 0)
            )
        else:
            # windowed long-context: keep the last kv_len positions, placed
            # at their ring slots (position p -> index p % kv_len) so decode
            # continues writing consistently. Requires uniform prompt
            # lengths within the batch (the rollout engine guarantees this).
            shift = seq % kv_len
            new_k = jnp.roll(k[:, -kv_len:], shift, axis=1).astype(k_slot.dtype)
            new_v = jnp.roll(v[:, -kv_len:], shift, axis=1).astype(v_slot.dtype)
        return (x, aux), (new_k, new_v, new_conv, new_ssm, new_xk, new_xv)

    aux0 = jnp.zeros((), jnp.float32)
    slots = (
        cache["k"], cache["v"],
        cache.get("conv", jnp.zeros((cfg.n_layers, 0))),
        cache.get("ssm", jnp.zeros((cfg.n_layers, 0))),
        cache.get("xk", jnp.zeros((cfg.n_layers, 0))),
        cache.get("xv", jnp.zeros((cfg.n_layers, 0))),
    )
    (x, _), outs = jax.lax.scan(
        body, (x, aux0), (params["blocks"], slots),
        unroll=runmode.outer_unroll(),
    )
    new_cache = dict(cache)
    new_cache["k"], new_cache["v"] = outs[0], outs[1]
    if cfg.family == "hybrid":
        new_cache["conv"], new_cache["ssm"] = outs[2], outs[3]
    if cfg.family == "audio":
        new_cache["xk"], new_cache["xv"] = outs[4], outs[5]
    new_cache["pos"] = (prompt_lengths + offset).astype(jnp.int32)

    idx = prompt_lengths - 1 + offset
    last = x[jnp.arange(b), idx]
    return _logits(cfg, params, last), new_cache


# =============================================================== decode step
def decode_step(
    cfg: ArchConfig,
    params: Params,
    tokens: jax.Array,        # (B,) next input token per sequence
    cache: Cache,
    *,
    impl: Optional[str] = None,
) -> Tuple[jax.Array, Cache]:
    """One autoregressive step. Returns (logits (B, V), updated cache)."""
    b = tokens.shape[0]
    x = params["embed"][tokens][:, None]          # (B, 1, D)
    pos = cache["pos"]                            # (B,)

    if cfg.family == "audio":
        # sinusoidal positional encoding at dynamic positions
        d = cfg.d_model
        dim = jnp.arange(0, d, 2, dtype=jnp.float32)[None, :]
        ang = pos[:, None].astype(jnp.float32) / jnp.power(10000.0, dim / d)
        pe = jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1).astype(x.dtype)
        x = x + pe[:, None]

    if cfg.family == "ssm":
        def group_body(x, gp_state):
            gp, (mc, sc) = gp_state

            def m_body(x, pstate):
                mp, st = pstate
                x, new_st = _mlstm_forward(cfg, x, mp, state=st, decode=True)
                return x, new_st

            x, new_m = jax.lax.scan(
                m_body, x, (gp["mlstm"], mc), unroll=runmode.inner_unroll()
            )
            x, new_s = _slstm_forward(cfg, x, gp["slstm"], state=sc)
            return x, (new_m, new_s)

        x, states = jax.lax.scan(
            group_body,
            x,
            (
                {"mlstm": params["mlstm"], "slstm": params["slstm"]},
                (cache["mlstm"], cache["slstm"]),
            ),
            unroll=runmode.outer_unroll(),
        )
        new_cache = dict(cache)
        new_cache["mlstm"], new_cache["slstm"] = states
        new_cache["pos"] = pos + 1
        return _logits(cfg, params, x[:, 0]), new_cache

    kv_len = cache["k"].shape[2]  # static (shape-derived)
    ring = kv_len == cfg.sliding_window and bool(cfg.sliding_window)
    write_pos = (pos % kv_len) if ring else pos
    lengths = jnp.minimum(pos + 1, kv_len).astype(jnp.int32)

    def body(x, pc):
        p, (k_slot, v_slot, conv_slot, ssm_slot, xk_slot, xv_slot) = pc
        h = layers.rms_norm(x, p["attn_norm"], cfg.norm_eps)
        q, k, v = _project_qkv(h, p, cfg, pos[:, None])
        # fused attention + ring write (ops dispatch): the Pallas path
        # writes the row in place; the XLA path lowers a one-hot select —
        # a per-row scatter cannot be partitioned across the sharded cache
        # sequence axis (GSPMD replicates the cache: 431 GB/chip/step
        # observed) while the select partitions on every axis. See
        # EXPERIMENTS.md §Perf A1/A3.
        o, new_k, new_v = ops.decode_attention_update(
            q[:, 0], k_slot, v_slot, k[:, 0], v[:, 0], write_pos, lengths,
            impl=impl,
        )
        attn = gather(o).reshape(b, 1, -1) @ p["wo"]
        new_conv, new_ssm = conv_slot, ssm_slot
        if cfg.family == "hybrid":
            ssm_out, (new_conv, new_ssm) = layers.mamba_block(
                h, p["mamba"], state=(conv_slot, ssm_slot), decode=True
            )
            x = x + 0.5 * (attn + ssm_out)
        else:
            x = x + attn
        if cfg.cross_attention and xk_slot.ndim > 2:
            hc = layers.rms_norm(x, p["cross_norm"], cfg.norm_eps)
            qc = (hc @ p["cross"]["wq"]).reshape(b, cfg.n_heads, cfg.hd)
            senc = xk_slot.shape[1]
            oc = ops.decode_attention(
                qc, xk_slot, xv_slot,
                jnp.full((b,), senc, jnp.int32), impl=impl,
            )
            x = x + gather(oc).reshape(b, 1, -1) @ p["cross"]["wo"]
        h2 = layers.rms_norm(x, p["ffn_norm"], cfg.norm_eps)
        if cfg.family == "moe":
            f, _ = _moe(h2, p, cfg, impl=impl)
        else:
            f = _ffn(h2, p)
        return x + f, (new_k, new_v, new_conv, new_ssm, xk_slot, xv_slot)

    slots = (
        cache["k"], cache["v"],
        cache.get("conv", jnp.zeros((cfg.n_layers, 0))),
        cache.get("ssm", jnp.zeros((cfg.n_layers, 0))),
        cache.get("xk", jnp.zeros((cfg.n_layers, 0))),
        cache.get("xv", jnp.zeros((cfg.n_layers, 0))),
    )
    x, outs = jax.lax.scan(
        body, x, (params["blocks"], slots), unroll=runmode.outer_unroll()
    )
    new_cache = dict(cache)
    new_cache["k"], new_cache["v"] = outs[0], outs[1]
    if cfg.family == "hybrid":
        new_cache["conv"], new_cache["ssm"] = outs[2], outs[3]
    new_cache["pos"] = pos + 1
    return _logits(cfg, params, x[:, 0]), new_cache


# ========================================================= paged decode step
def paged_decode_step(
    cfg: ArchConfig,
    params: Params,
    tokens: jax.Array,        # (B,) next input token per sequence
    cache: Cache,             # paged layout (``init_paged_cache``), with the
                              # per-slot entries already gathered to B rows
    block_tables: jax.Array,  # (B, nb) int32 per-sequence block tables
    *,
    impl: Optional[str] = None,
) -> Tuple[jax.Array, Cache]:
    """One autoregressive step over a block-paged KV cache.

    Identical math to ``decode_step``: the new token's K/V row is written at
    logical position ``pos`` (pool block ``block_tables[b, pos // bs]``) and
    attention runs over the table-gathered window, so for equal valid values
    the two paths produce bit-for-bit equal logits. Returns
    (logits (B, V), updated cache)."""
    b = tokens.shape[0]
    x = params["embed"][tokens][:, None]          # (B, 1, D)
    pos = cache["pos"]                            # (B,)

    if cfg.family == "audio":
        d = cfg.d_model
        dim = jnp.arange(0, d, 2, dtype=jnp.float32)[None, :]
        ang = pos[:, None].astype(jnp.float32) / jnp.power(10000.0, dim / d)
        pe = jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1).astype(x.dtype)
        x = x + pe[:, None]

    def body(x, pc):
        p, (k_pool, v_pool, conv_slot, ssm_slot, xk_slot, xv_slot) = pc
        h = layers.rms_norm(x, p["attn_norm"], cfg.norm_eps)
        q, k, v = _project_qkv(h, p, cfg, pos[:, None])
        o, new_k, new_v = ops.paged_decode_attention_update(
            q[:, 0], k_pool, v_pool, k[:, 0], v[:, 0], block_tables, pos,
            impl=impl,
        )
        # decode-TP: q and the pool are head-sharded, so each shard holds
        # its heads' exact outputs; gather before the wo contraction keeps
        # the reduction full-width and bitwise (no-op unsharded)
        attn = gather(o).reshape(b, 1, -1) @ p["wo"]
        new_conv, new_ssm = conv_slot, ssm_slot
        if cfg.family == "hybrid":
            ssm_out, (new_conv, new_ssm) = layers.mamba_block(
                h, p["mamba"], state=(conv_slot, ssm_slot), decode=True
            )
            x = x + 0.5 * (attn + ssm_out)
        else:
            x = x + attn
        if cfg.cross_attention and xk_slot.ndim > 2:
            hc = layers.rms_norm(x, p["cross_norm"], cfg.norm_eps)
            qc = (hc @ p["cross"]["wq"]).reshape(b, cfg.n_heads, cfg.hd)
            senc = xk_slot.shape[1]
            oc = ops.decode_attention(
                qc, xk_slot, xv_slot,
                jnp.full((b,), senc, jnp.int32), impl=impl,
            )
            x = x + gather(oc).reshape(b, 1, -1) @ p["cross"]["wo"]
        h2 = layers.rms_norm(x, p["ffn_norm"], cfg.norm_eps)
        if cfg.family == "moe":
            f, _ = _moe(h2, p, cfg, impl=impl)
        else:
            f = _ffn(h2, p)
        return x + f, (new_k, new_v, new_conv, new_ssm, xk_slot, xv_slot)

    slots = (
        cache["k"], cache["v"],
        cache.get("conv", jnp.zeros((cfg.n_layers, 0))),
        cache.get("ssm", jnp.zeros((cfg.n_layers, 0))),
        cache.get("xk", jnp.zeros((cfg.n_layers, 0))),
        cache.get("xv", jnp.zeros((cfg.n_layers, 0))),
    )
    x, outs = jax.lax.scan(
        body, x, (params["blocks"], slots), unroll=runmode.outer_unroll()
    )
    new_cache = dict(cache)
    new_cache["k"], new_cache["v"] = outs[0], outs[1]
    if cfg.family == "hybrid":
        new_cache["conv"], new_cache["ssm"] = outs[2], outs[3]
    new_cache["pos"] = pos + 1
    return _logits(cfg, params, x[:, 0]), new_cache


# ======================================================== paged suffix prefill
def paged_prefill_step(
    cfg: ArchConfig,
    params: Params,
    tokens: jax.Array,        # (B, S) right-padded SUFFIX tokens
    cache: Cache,             # paged layout, per-slot entries gathered to B
    block_tables: jax.Array,  # (B, nb) int32 full tables (prefix + suffix)
    q_offsets: jax.Array,     # (B,) int32 absolute position of tokens[:, 0]
    resident: jax.Array,      # (B,) int32 pool positions already written
                              # (the shared prefix) — never re-written
    lengths: jax.Array,       # (B,) int32 total valid positions
    *,
    impl: Optional[str] = None,
) -> Tuple[jax.Array, Cache]:
    """Prefill only a trajectory's *suffix* against KV already resident in
    the paged pool — the shared-prefix fork admission path.

    The transformer runs over the suffix positions only (O(suffix) FLOPs
    instead of O(prompt)); each layer scatters the suffix K/V rows into
    the pool, then attends causally over the table-gathered prefix+suffix
    window. Causal masking makes prefix activations independent of the
    suffix, so the pool rows the donor's full prefill wrote are bit-for-bit
    the rows this trajectory's own full prefill would have produced —
    logits and cache match the full path exactly (equivalence-tested).

    ``resident`` may be below ``q_offsets`` only in the block-aligned-
    prompt case, where the last prompt token is re-forwarded for its
    logits: its K/V write is redirected to the null sink (position already
    resident) while attention reads the donor's row. Suffix rows past
    ``lengths`` are padding: writes hit the null block, outputs are zero.
    Families with recurrent state (ssm/hybrid) or cross attention carry
    per-position state a suffix run cannot reconstruct — callers gate to
    dense/moe."""
    if cfg.family not in ("dense", "moe"):
        raise ValueError(f"suffix prefill unsupported for family {cfg.family}")
    b, s = tokens.shape
    bs = cache["k"].shape[2]
    nb = block_tables.shape[1]
    x = params["embed"][tokens]
    positions = q_offsets[:, None] + jnp.arange(s)            # (B, S)
    valid = (positions >= resident[:, None]) & (positions < lengths[:, None])
    bi = jnp.clip(positions // bs, 0, nb - 1)
    # invalid rows (padding / already-resident) write the null garbage sink
    blk = jnp.where(valid, block_tables[jnp.arange(b)[:, None], bi], 0)
    off = positions % bs

    def body(carry, pc):
        x, aux = carry
        p, (k_pool, v_pool) = pc
        h = layers.rms_norm(x, p["attn_norm"], cfg.norm_eps)
        q, k, v = _project_qkv(h, p, cfg, positions)
        new_k = k_pool.at[blk, off].set(k.astype(k_pool.dtype))
        new_v = v_pool.at[blk, off].set(v.astype(v_pool.dtype))
        o = ops.paged_prefill_attention(
            q, new_k, new_v, block_tables, q_offsets, lengths, impl=impl
        )
        attn = gather(o).reshape(b, s, -1) @ p["wo"]
        x = x + attn
        h2 = layers.rms_norm(x, p["ffn_norm"], cfg.norm_eps)
        if cfg.family == "moe":
            f, a = _moe(h2, p, cfg, impl=impl)
            aux = aux + a
        else:
            f = _ffn(h2, p)
        x = constrain(x + f, "boundary")  # SP: RS+AG instead of all-reduce
        return (x, aux), (new_k, new_v)

    aux0 = jnp.zeros((), jnp.float32)
    (x, _), outs = jax.lax.scan(
        body, (x, aux0), (params["blocks"], (cache["k"], cache["v"])),
        unroll=runmode.outer_unroll(),
    )
    new_cache = dict(cache)
    new_cache["k"], new_cache["v"] = outs
    new_cache["pos"] = lengths.astype(jnp.int32)

    idx = lengths - 1 - q_offsets
    last = x[jnp.arange(b), idx]
    return _logits(cfg, params, last), new_cache
