"""Execution-mode context for lowering.

Default mode lowers the deployment-faithful program: layers under
``lax.scan`` (O(1) HLO in depth), chunked mamba scan, chunked long-sequence
reference attention. That program is what the memory gate measures.

``roofline_mode(outer_unroll=u)`` changes lowering for COST ACCOUNTING:
XLA's HloCostAnalysis counts a while-loop body ONCE (not x trip count), so
the dry-run lowers twice (u=1, u=2) and linearly extrapolates
``total = f(1) + (trip - 1) * (f(2) - f(1))`` to recover true FLOPs /
bytes / collective totals. For that to isolate exactly one layer body:

* inner loops (mLSTM stack inside an xLSTM group, whisper encoder, mamba
  chunk scan, chunked attention) are fully unrolled/disabled in BOTH
  passes, leaving the outer layer scan as the only trip-counted loop.
"""
from __future__ import annotations

import threading
from contextlib import contextmanager

_state = threading.local()


def _ctx():
    return getattr(_state, "mode", None)


@contextmanager
def roofline_mode(outer_unroll: int = 1):
    prev = _ctx()
    _state.mode = {"outer_unroll": outer_unroll}
    try:
        yield
    finally:
        _state.mode = prev


def active() -> bool:
    return _ctx() is not None


def outer_unroll() -> int:
    c = _ctx()
    return c["outer_unroll"] if c else 1


def inner_unroll():
    """Inner scans: fully unrolled under roofline mode, scanned otherwise."""
    return True if active() else 1


def mamba_chunk(seq_len: int, default: int = 256) -> int:
    """Roofline mode: single chunk so the selective scan is fully counted."""
    return seq_len if active() else min(default, seq_len)


def attention_chunked(skv: int, threshold: int = 16384) -> bool:
    """Long-KV reference attention runs chunked... except under roofline
    mode, where the unchunked einsum keeps all FLOPs visible."""
    return (not active()) and skv >= threshold
