"""Unified observability plane: metrics registry, trajectory lifecycle
tracing, Perfetto trace export, fleet sampling, structured logging.

Opt-in end to end: ``RuntimeConfig.observability=True`` (or setting
``trace_path``) attaches a :class:`MetricsRegistry` + a
:class:`TrajectoryTracer` to the lifecycle bus; disabled (the default)
every instrumentation site goes through ``NOOP_REGISTRY`` / ``None``
guards and the seed paths stay byte-identical.

See ``docs/architecture.md`` "Observability" for the span model, the
exporter track layout, and how to open a trace in Perfetto.
"""
from repro.obs.metrics import (
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    NOOP_REGISTRY,
)
from repro.obs.stats import Ring, percentile, percentiles
from repro.obs.tracer import Activity, Segment, TrajSpan, TrajectoryTracer
from repro.obs.export import (
    export_chrome_trace,
    load_trace,
    validate_chrome_trace,
)
from repro.obs.sampler import FleetSampler
from repro.obs.logs import get_logger, setup_logging

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "NOOP_REGISTRY",
    "Ring",
    "percentile",
    "percentiles",
    "Activity",
    "Segment",
    "TrajSpan",
    "TrajectoryTracer",
    "export_chrome_trace",
    "load_trace",
    "validate_chrome_trace",
    "FleetSampler",
    "get_logger",
    "setup_logging",
]
