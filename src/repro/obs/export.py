"""Chrome-trace-format exporter (Perfetto-loadable JSON).

Lays a :class:`repro.obs.tracer.TrajectoryTracer` out as the Trace Event
Format that ``chrome://tracing`` and https://ui.perfetto.dev open
directly:

* **pid 1 "trajectories"** — one thread track per rollout instance;
  every trajectory segment is a complete (``ph:"X"``) event named
  ``queue``/``decode`` carrying ``traj``/``group``/``v_route``/``hops``/
  ``staleness`` args, so a trajectory's migration across instance tracks
  and its realized staleness are visible by clicking any slice;
* **pid 2 "scheduler"** — one track per service thread (instance decode
  loops, coordinator cycles, trainer steps, reward workers, background
  PS push) from the tracer's activity ring;
* **pid 3 "fleet"** — counter (``ph:"C"``) tracks from the periodic
  fleet sampler: per-instance occupancy and KV fill, staleness-buffer
  reserve/occupy state, TS depth.

Timestamps are microseconds relative to the tracer epoch (its clock may
be wall time or simulated seconds — the layout is identical).
``otherData`` carries the text-report summary inputs (latency
percentiles, staleness histogram, conservation status) so
``repro.obs.report`` can summarize a trace file without the live tracer.

``validate_chrome_trace`` is the schema gate CI runs on the smoke
artifact: structural errors (missing ph/ts, negative durations,
non-numeric counters) are returned as strings, empty list == valid.
"""
from __future__ import annotations

import json
from typing import Dict, List, Optional

from repro.obs.stats import percentiles
from repro.obs.tracer import TrajectoryTracer

PID_TRAJ = 1
PID_SCHED = 2
PID_FLEET = 3


def _meta(pid: int, name: str) -> dict:
    return {"name": "process_name", "ph": "M", "pid": pid, "tid": 0,
            "args": {"name": name}}


def _thread_meta(pid: int, tid: int, name: str) -> dict:
    return {"name": "thread_name", "ph": "M", "pid": pid, "tid": tid,
            "args": {"name": name}}


def export_chrome_trace(
    tracer: TrajectoryTracer, path: Optional[str] = None
) -> dict:
    """Build (and optionally write) the trace dict for ``tracer``."""
    t0 = tracer.t0
    us = lambda t: max(0.0, (t - t0) * 1e6)  # noqa: E731
    end = tracer.now()
    events: List[dict] = [
        _meta(PID_TRAJ, "trajectories"),
        _meta(PID_SCHED, "scheduler"),
        _meta(PID_FLEET, "fleet"),
    ]

    # ---- trajectory spans: instance id == tid on the trajectories process
    with tracer._lock:
        spans = list(tracer.spans.values())
        activities = list(tracer.activities)
        counter_samples = list(tracer.counter_samples)
    inst_ids = sorted({
        seg.inst for span in spans for seg in span.segments
    })
    for inst in inst_ids:
        label = "ts-pending" if inst < 0 else f"instance-{inst}"
        events.append(_thread_meta(PID_TRAJ, inst, label))
    for span in spans:
        args = {
            "traj": span.traj_id,
            "group": span.group_id,
            "v_route": span.v_route,
            "hops": span.hops,
            "preemptions": span.preemptions,
            "terminal": span.terminal,
            "staleness": span.staleness,
        }
        for seg in span.segments:
            t1 = seg.t1 if seg.t1 is not None else end
            events.append({
                "name": seg.kind,
                "cat": "trajectory",
                "ph": "X",
                "pid": PID_TRAJ,
                "tid": seg.inst,
                "ts": us(seg.t0),
                "dur": max(0.0, (t1 - seg.t0) * 1e6),
                "args": args,
            })

    # ---- scheduler-thread activity: one tid per track name
    track_tids: Dict[str, int] = {}
    for act in activities:
        tid = track_tids.get(act.track)
        if tid is None:
            tid = len(track_tids)
            track_tids[act.track] = tid
            events.append(_thread_meta(PID_SCHED, tid, act.track))
        ev = {
            "name": act.name,
            "cat": "scheduler",
            "ph": "X",
            "pid": PID_SCHED,
            "tid": tid,
            "ts": us(act.t0),
            "dur": max(0.0, (act.t1 - act.t0) * 1e6),
        }
        if act.args:
            ev["args"] = act.args
        events.append(ev)

    # ---- fleet counter tracks
    counter_tids: Dict[str, int] = {}
    for track, ts, values in counter_samples:
        tid = counter_tids.get(track)
        if tid is None:
            tid = len(counter_tids)
            counter_tids[track] = tid
        events.append({
            "name": track,
            "cat": "fleet",
            "ph": "C",
            "pid": PID_FLEET,
            "tid": tid,
            "ts": us(ts),
            "args": {k: float(v) for k, v in values.items()},
        })

    qs = (0.5, 0.95, 0.99)
    latencies = {
        name: {
            f"p{int(q * 100)}": v
            for q, v in percentiles(ring.values(), qs, default=0.0).items()
        }
        for name, ring in (
            ("route_s", tracer.route_lat),
            ("queue_s", tracer.queue_lat),
            ("reward_s", tracer.reward_lat),
            ("consume_s", tracer.consume_lat),
        )
    }
    decode_samples = [
        s.decode_time() for s in spans if s.terminal is not None
    ]
    latencies["decode_s"] = {
        f"p{int(q * 100)}": v
        for q, v in percentiles(decode_samples, qs, default=0.0).items()
    }
    trace = {
        "traceEvents": events,
        "displayTimeUnit": "ms",
        "otherData": {
            "tool": "repro.obs",
            "spans": len(spans),
            "open_spans": sum(1 for s in spans if s.terminal is None),
            "staleness_hist": {
                str(k): v for k, v in tracer.staleness_histogram().items()
            },
            "max_realized_staleness": tracer.realized_max_staleness(),
            "latencies": latencies,
            "busy_s_by_instance": {
                str(k): v
                for k, v in tracer.busy_seconds_by_instance().items()
            },
            "wall_s": max(0.0, end - t0),
            "conservation_violations": tracer.check_conservation(
                allow_open=True
            ),
        },
    }
    if path:
        with open(path, "w") as f:
            json.dump(trace, f, indent=1, sort_keys=True)
    return trace


# ------------------------------------------------------------- validation
_PHASES_REQ_TS = {"X", "C", "I", "B", "E"}


def validate_chrome_trace(trace: object) -> List[str]:
    """Structural schema check for the exported trace (CI gate).

    Checks the subset of the Trace Event Format this exporter emits:
    top-level shape, per-event required fields by phase, non-negative
    times, numeric counter args. Returns error strings; [] == valid.
    """
    errors: List[str] = []
    if not isinstance(trace, dict):
        return ["top level: expected an object"]
    events = trace.get("traceEvents")
    if not isinstance(events, list):
        return ["traceEvents: expected a list"]
    if not events:
        errors.append("traceEvents: empty")
    for i, ev in enumerate(events):
        where = f"traceEvents[{i}]"
        if not isinstance(ev, dict):
            errors.append(f"{where}: expected an object")
            continue
        ph = ev.get("ph")
        if not isinstance(ph, str) or not ph:
            errors.append(f"{where}: missing ph")
            continue
        if not isinstance(ev.get("name"), str):
            errors.append(f"{where}: missing name")
        if not isinstance(ev.get("pid"), int):
            errors.append(f"{where}: missing pid")
        if not isinstance(ev.get("tid"), int):
            errors.append(f"{where}: missing tid")
        if ph in _PHASES_REQ_TS:
            ts = ev.get("ts")
            if not isinstance(ts, (int, float)):
                errors.append(f"{where}: ph={ph} missing numeric ts")
            elif ts < 0:
                errors.append(f"{where}: negative ts {ts}")
        if ph == "X":
            dur = ev.get("dur")
            if not isinstance(dur, (int, float)):
                errors.append(f"{where}: ph=X missing numeric dur")
            elif dur < 0:
                errors.append(f"{where}: negative dur {dur}")
        if ph == "C":
            args = ev.get("args")
            if not isinstance(args, dict) or not args:
                errors.append(f"{where}: ph=C needs non-empty args")
            else:
                for k, v in args.items():
                    if not isinstance(v, (int, float)):
                        errors.append(
                            f"{where}: counter series {k!r} non-numeric"
                        )
        if ph == "M":
            if ev.get("name") not in ("process_name", "thread_name"):
                errors.append(f"{where}: unknown metadata {ev.get('name')!r}")
            args = ev.get("args")
            if not isinstance(args, dict) or not isinstance(
                args.get("name"), str
            ):
                errors.append(f"{where}: metadata needs args.name")
        if len(errors) > 50:
            errors.append("... (truncated)")
            break
    return errors


def load_trace(path: str) -> dict:
    with open(path) as f:
        return json.load(f)
