"""Structured logging for the launchers (and any long-running service).

``setup_logging(json_mode=...)`` configures the root ``repro`` logger
once: human-readable single-line records by default, or newline-
delimited JSON (``--log-json``) so long threaded runs are greppable /
machine-parseable (one object per line: ts, level, logger, msg, plus
any ``extra={...}`` fields the call site attached).
"""
from __future__ import annotations

import json
import logging
import time
from typing import Optional

_RESERVED = frozenset(logging.LogRecord(
    "", 0, "", 0, "", (), None
).__dict__) | {"message", "asctime", "taskName"}


class JsonFormatter(logging.Formatter):
    """One JSON object per record; ``extra`` kwargs become fields."""

    def format(self, record: logging.LogRecord) -> str:
        out = {
            "ts": round(record.created, 6),
            "level": record.levelname,
            "logger": record.name,
            "msg": record.getMessage(),
        }
        for k, v in record.__dict__.items():
            if k not in _RESERVED and not k.startswith("_"):
                try:
                    json.dumps(v)
                    out[k] = v
                except (TypeError, ValueError):
                    out[k] = repr(v)
        if record.exc_info:
            out["exc"] = self.formatException(record.exc_info)
        return json.dumps(out, sort_keys=True)


class HumanFormatter(logging.Formatter):
    """``HH:MM:SS.mmm LEVEL logger: msg`` with extras appended k=v."""

    def format(self, record: logging.LogRecord) -> str:
        t = time.strftime("%H:%M:%S", time.localtime(record.created))
        ms = int((record.created % 1) * 1000)
        extras = " ".join(
            f"{k}={v}"
            for k, v in record.__dict__.items()
            if k not in _RESERVED and not k.startswith("_")
        )
        base = (
            f"{t}.{ms:03d} {record.levelname[0]} "
            f"{record.name}: {record.getMessage()}"
        )
        if extras:
            base = f"{base}  [{extras}]"
        if record.exc_info:
            base = f"{base}\n{self.formatException(record.exc_info)}"
        return base


def setup_logging(
    json_mode: bool = False,
    level: int = logging.INFO,
    logger_name: str = "repro",
    stream=None,
) -> logging.Logger:
    """Idempotent: reconfigures the handler on repeat calls."""
    logger = logging.getLogger(logger_name)
    logger.setLevel(level)
    logger.propagate = False
    for h in list(logger.handlers):
        logger.removeHandler(h)
    handler = logging.StreamHandler(stream)
    handler.setFormatter(JsonFormatter() if json_mode else HumanFormatter())
    logger.addHandler(handler)
    return logger


def get_logger(name: Optional[str] = None) -> logging.Logger:
    return logging.getLogger(
        f"repro.{name}" if name and not name.startswith("repro") else
        (name or "repro")
    )
