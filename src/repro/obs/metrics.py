"""Thread-safe metrics registry: Counter / Gauge / Histogram with labels.

The single query surface for telemetry that was previously smeared across
plain attributes on a dozen components (engine preemption/prefix/CoW
counters, ``CoordinatorStats``, reward-server rings, PS push counts,
scheduler busy-seconds). Components keep their cheap plain counters as
the source of truth on hot paths — several are *functional* (the
coordinator differences ``preemptions`` into its routing penalty) — and
the registry mirrors them two ways:

* **scrape**: ``RuntimeCore.scrape_metrics`` (and the fleet sampler)
  periodically copies the scattered totals into labeled instruments, so
  one ``registry.snapshot()`` answers "what happened" without knowing
  which component owns which attribute;
* **direct observation** for distributions a total can't carry: the
  reward server observes submit->rewarded latency into a histogram, the
  trainer observes per-entry realized staleness.

Disabled mode (``MetricsRegistry(enabled=False)``, and the module-level
``NOOP_REGISTRY``): every instrument request returns a shared no-op
singleton whose methods do nothing — call sites stay unconditional and
cost one attribute lookup + an empty call, so the default (observability
off) path stays effectively free and, critically, allocation-free after
the first lookup.

Histograms use fixed bucket upper bounds (default: exponential decades
from 100 us to ~100 s). ``Histogram.percentile`` answers from bucket
counts — the bucket upper bound at the quantile rank — which is the
usual fixed-bucket estimate: exact enough for p50/p99 latency reporting
at zero per-observation allocation.
"""
from __future__ import annotations

from typing import Dict, Iterable, List, Optional, Sequence, Tuple

from repro.analysis.witness import make_lock

LabelSet = Tuple[Tuple[str, str], ...]

DEFAULT_BUCKETS: Tuple[float, ...] = (
    1e-4, 2.5e-4, 5e-4, 1e-3, 2.5e-3, 5e-3, 1e-2, 2.5e-2, 5e-2,
    0.1, 0.25, 0.5, 1.0, 2.5, 5.0, 10.0, 30.0, 100.0,
)


def _labelset(labels: Dict[str, object]) -> LabelSet:
    return tuple(sorted((k, str(v)) for k, v in labels.items()))


class Counter:
    """Monotone counter. ``inc`` only; ``set_total`` exists for scrapes
    that mirror an externally-owned monotone total."""

    __slots__ = ("name", "labels", "_value", "_lock")

    def __init__(self, name: str, labels: LabelSet = ()):
        self.name = name
        self.labels = labels
        self._value = 0.0
        self._lock = make_lock("metric")

    def inc(self, n: float = 1.0) -> None:
        with self._lock:
            self._value += n

    def set_total(self, total: float) -> None:
        with self._lock:
            if total > self._value:
                self._value = total

    @property
    def value(self) -> float:
        with self._lock:
            return self._value


class Gauge:
    """Last-write-wins scalar."""

    __slots__ = ("name", "labels", "_value", "_lock")

    def __init__(self, name: str, labels: LabelSet = ()):
        self.name = name
        self.labels = labels
        self._value = 0.0
        self._lock = make_lock("metric")

    def set(self, value: float) -> None:
        with self._lock:
            self._value = float(value)

    def inc(self, n: float = 1.0) -> None:
        with self._lock:
            self._value += n

    @property
    def value(self) -> float:
        with self._lock:
            return self._value


class Histogram:
    """Fixed-bucket histogram with percentile estimation.

    ``buckets`` are inclusive upper bounds; observations above the last
    bound land in a +inf overflow bucket. No per-observation allocation.
    """

    __slots__ = ("name", "labels", "buckets", "_counts", "_sum", "_count",
                 "_min", "_max", "_lock")

    def __init__(
        self,
        name: str,
        labels: LabelSet = (),
        buckets: Optional[Sequence[float]] = None,
    ):
        self.name = name
        self.labels = labels
        self.buckets: Tuple[float, ...] = tuple(buckets or DEFAULT_BUCKETS)
        self._counts = [0] * (len(self.buckets) + 1)  # +1: overflow
        self._sum = 0.0
        self._count = 0
        self._min: Optional[float] = None
        self._max: Optional[float] = None
        self._lock = make_lock("metric")

    def observe(self, value: float) -> None:
        # linear scan: bucket lists are short (<= ~20) and observations
        # are off the per-token hot path
        idx = len(self.buckets)
        for i, ub in enumerate(self.buckets):
            if value <= ub:
                idx = i
                break
        with self._lock:
            self._counts[idx] += 1
            self._sum += value
            self._count += 1
            if self._min is None or value < self._min:
                self._min = value
            if self._max is None or value > self._max:
                self._max = value

    @property
    def count(self) -> int:
        with self._lock:
            return self._count

    @property
    def sum(self) -> float:
        with self._lock:
            return self._sum

    def bucket_counts(self) -> List[int]:
        with self._lock:
            return list(self._counts)

    def percentile(self, q: float) -> Optional[float]:
        """Bucket-upper-bound estimate at quantile ``q`` (None if empty).
        Overflow-bucket hits answer with the observed max."""
        with self._lock:
            if self._count == 0:
                return None
            rank = min(self._count - 1, int(q * self._count))
            acc = 0
            for i, c in enumerate(self._counts):
                acc += c
                if rank < acc:
                    if i < len(self.buckets):
                        return self.buckets[i]
                    return self._max
            return self._max  # unreachable; defensive

    def summary(self) -> Dict[str, Optional[float]]:
        with self._lock:
            count, total = self._count, self._sum
            mn, mx = self._min, self._max
        return {
            "count": count,
            "sum": total,
            "mean": (total / count) if count else None,
            "min": mn,
            "max": mx,
            "p50": self.percentile(0.5),
            "p99": self.percentile(0.99),
        }


class _Noop:
    """Shared do-nothing instrument handed out by a disabled registry."""

    __slots__ = ()
    name = "noop"
    labels: LabelSet = ()
    value = 0.0
    count = 0
    sum = 0.0
    buckets: Tuple[float, ...] = ()

    def inc(self, n: float = 1.0) -> None:
        pass

    def set(self, value: float) -> None:
        pass

    def set_total(self, total: float) -> None:
        pass

    def observe(self, value: float) -> None:
        pass

    def bucket_counts(self) -> List[int]:
        return []

    def percentile(self, q: float) -> Optional[float]:
        return None

    def summary(self) -> Dict[str, Optional[float]]:
        return {"count": 0, "sum": 0.0, "mean": None, "min": None,
                "max": None, "p50": None, "p99": None}


_NOOP_INSTRUMENT = _Noop()


class MetricsRegistry:
    """Instrument factory + store, keyed by ``(name, labelset)``.

    ``counter``/``gauge``/``histogram`` are get-or-create and cheap to
    call repeatedly, but hot paths should hold the returned instrument.
    A disabled registry returns the shared no-op singleton from every
    factory call.
    """

    def __init__(self, enabled: bool = True):
        self.enabled = enabled
        self._lock = make_lock("metrics")
        self._instruments: Dict[Tuple[str, str, LabelSet], object] = {}

    def _get(self, kind: str, name: str, labels: Dict[str, object], factory):
        if not self.enabled:
            return _NOOP_INSTRUMENT
        key = (kind, name, _labelset(labels))
        with self._lock:
            inst = self._instruments.get(key)
            if inst is None:
                inst = factory(name, key[2])
                self._instruments[key] = inst
            return inst

    def counter(self, name: str, **labels) -> Counter:
        return self._get("counter", name, labels, Counter)

    def gauge(self, name: str, **labels) -> Gauge:
        return self._get("gauge", name, labels, Gauge)

    def histogram(
        self, name: str, buckets: Optional[Sequence[float]] = None, **labels
    ) -> Histogram:
        return self._get(
            "histogram", name, labels,
            lambda n, ls: Histogram(n, ls, buckets=buckets),
        )

    def find(self, name: str) -> List[object]:
        """Every instrument registered under ``name`` (any labels)."""
        with self._lock:
            return [
                inst for (kind, n, ls), inst in self._instruments.items()
                if n == name
            ]

    def snapshot(self) -> Dict[str, Dict[str, object]]:
        """``{name{labels}: {...}}`` — counters/gauges report ``value``,
        histograms their ``summary()``."""
        with self._lock:
            items = list(self._instruments.items())
        out: Dict[str, Dict[str, object]] = {}
        for (kind, name, labels), inst in sorted(
            items, key=lambda kv: (kv[0][1], kv[0][2])
        ):
            label_s = ",".join(f"{k}={v}" for k, v in labels)
            full = f"{name}{{{label_s}}}" if label_s else name
            if kind == "histogram":
                out[full] = {"kind": kind, **inst.summary()}
            else:
                out[full] = {"kind": kind, "value": inst.value}
        return out


#: Module-level disabled registry: components default their ``metrics``
#: parameter to this so instrumentation is unconditional at call sites.
NOOP_REGISTRY = MetricsRegistry(enabled=False)
