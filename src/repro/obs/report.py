"""Text summary of an observability run.

Two entry points:

* ``summarize(trace)`` — render the report from an exported Chrome
  trace dict (``repro.obs.export.export_chrome_trace``), so it works on
  a trace file long after the run;
* CLI: ``PYTHONPATH=src python -m repro.obs.report TRACE.json``
  (optionally ``--validate`` to schema-check first).

Reported: realized-staleness histogram, per-instance decode busy
fraction, and p50/p95/p99 of the pipeline latencies (route = capacity
freed -> next ROUTED on that instance; queue = routed/preempted ->
admitted into a decode slot; decode = total generating seconds per
trajectory; reward = COMPLETED -> REWARDED; consume = REWARDED ->
CONSUMED), plus span conservation status.
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import List


def _fmt_s(v) -> str:
    if v is None:
        return "-"
    if v >= 1.0:
        return f"{v:7.2f}s "
    return f"{v * 1e3:7.2f}ms"


def summarize(trace: dict) -> str:
    other = trace.get("otherData", {})
    lines: List[str] = []
    wall = other.get("wall_s", 0.0)
    lines.append(
        f"observability report: {other.get('spans', 0)} trajectory spans "
        f"({other.get('open_spans', 0)} open) over {wall:.2f}s"
    )

    hist = other.get("staleness_hist", {})
    if hist:
        total = sum(hist.values()) or 1
        lines.append("realized staleness (consumed trajectories):")
        for k in sorted(hist, key=int):
            n = hist[k]
            bar = "#" * max(1, round(40 * n / total))
            lines.append(f"  s={k:>2}  {n:6d}  {bar}")
        lines.append(
            f"  max realized staleness: "
            f"{other.get('max_realized_staleness', 0)}"
        )

    busy = other.get("busy_s_by_instance", {})
    if busy and wall:
        lines.append("per-instance decode busy fraction:")
        for inst in sorted(busy, key=int):
            frac = busy[inst] / wall
            bar = "#" * max(0, round(40 * min(frac, 1.0)))
            lines.append(
                f"  instance-{inst}: {frac * 100:5.1f}%  {bar}"
            )

    lat = other.get("latencies", {})
    if lat:
        lines.append("pipeline latencies:")
        lines.append(f"  {'stage':<10} {'p50':>9} {'p95':>9} {'p99':>9}")
        for stage in ("route_s", "queue_s", "decode_s", "reward_s",
                      "consume_s"):
            p = lat.get(stage)
            if p is None:
                continue
            lines.append(
                f"  {stage[:-2]:<10} {_fmt_s(p.get('p50'))} "
                f"{_fmt_s(p.get('p95'))} {_fmt_s(p.get('p99'))}"
            )

    violations = other.get("conservation_violations", [])
    if violations:
        lines.append(f"CONSERVATION VIOLATIONS ({len(violations)}):")
        lines.extend(f"  {v}" for v in violations[:10])
    else:
        lines.append("span conservation: OK "
                     "(every closed span has exactly one terminal)")
    return "\n".join(lines)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="Summarize a repro.obs Chrome trace"
    )
    ap.add_argument("trace", help="path to the exported trace JSON")
    ap.add_argument(
        "--validate", action="store_true",
        help="schema-validate the trace first (non-zero exit on errors)",
    )
    args = ap.parse_args(argv)
    with open(args.trace) as f:
        trace = json.load(f)
    if args.validate:
        from repro.obs.export import validate_chrome_trace

        errors = validate_chrome_trace(trace)
        if errors:
            for e in errors:
                print(f"SCHEMA ERROR: {e}", file=sys.stderr)
            return 1
        print(f"schema OK ({len(trace['traceEvents'])} events)")
    print(summarize(trace))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
