"""Periodic fleet sampler: occupancy / KV fill / staleness-buffer state.

A daemon thread that, every ``interval_s``, takes lock-free (or
leaf-locked) telemetry reads across the runtime and records them as
counter-track samples on the tracer — rendered as stacked counter
charts under the "fleet" process in the exported Chrome trace — while
also mirroring the scattered component counters into the metrics
registry via ``RuntimeCore.scrape_metrics``.

Sampled per tick:

* per instance: active decode slots, waiting-queue depth, KV fill
  fraction (bytes / budget);
* staleness manager: reserved/occupied entries in the train-floor
  buffer, total in-flight protocol entries, current train version;
* trajectory server: available (unrouted) trajectories;
* reward server: queue depth.

Reads are cheap snapshots of internally-locked state — the sampler
never takes an instance's command lock, so a 10 ms cadence does not
perturb decode. Works under both schedulers (the cooperative tick loop
simply gets sampled from outside its thread).
"""
from __future__ import annotations

import threading
from typing import Optional


class FleetSampler:
    def __init__(self, core, interval_s: float = 0.01):
        self.core = core
        self.interval_s = max(0.001, float(interval_s))
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None
        self.samples = 0

    def start(self) -> "FleetSampler":
        if self._thread is None:
            self._thread = threading.Thread(
                target=self._loop, name="fleet-sampler", daemon=True
            )
            self._thread.start()
        return self

    def stop(self) -> None:
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=5.0)
            self._thread = None

    def sample_once(self) -> None:
        core = self.core
        tracer = core.tracer
        if tracer is None:
            return
        ts = tracer.now()
        with core._instances_lock:
            handles = dict(core.instances)
        for inst_id, h in sorted(handles.items()):
            try:
                kv = h.kv_bytes()
                budget = getattr(h, "kv_budget", 0.0) or 0.0
                tracer.sample(
                    f"instance-{inst_id}",
                    {
                        "active": h.n_active(),
                        "waiting": len(h.waiting),
                        "kv_fill": (kv / budget) if budget else 0.0,
                    },
                    ts=ts,
                )
            except Exception:
                # a replica failing mid-sample is an expected race under
                # the elasticity tests; skip it this tick
                continue
        snap = core.manager.snapshot()
        floor = core.manager.train_version
        floor_buf = snap.get(floor, {})
        tracer.sample(
            "staleness-buffers",
            {
                "floor_reserved": floor_buf.get("reserved", 0),
                "floor_occupied": floor_buf.get("occupied", 0),
                "in_flight": core.manager.in_flight(),
                "train_version": floor,
            },
            ts=ts,
        )
        tracer.sample(
            "servers",
            {
                "ts_available": core.ts.n_available,
                "reward_queue": core.reward_server.queue_depth(),
            },
            ts=ts,
        )
        core.scrape_metrics()
        self.samples += 1

    def _loop(self) -> None:
        while not self._stop.is_set():
            try:
                self.sample_once()
            except Exception:
                pass  # telemetry must never take the run down
            self._stop.wait(self.interval_s)
