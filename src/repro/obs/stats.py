"""Shared summary-statistics helpers for the observability plane.

One home for the percentile convention and the fixed-size ring buffer
that were independently reimplemented by ``RewardServer`` (submit->
rewarded latency telemetry) and ``bench_throughput`` (lifecycle-probe
route/consume latencies). Both now import from here, so every latency
number the repo reports is computed the same way:

    percentile(samples, q) == sorted(samples)[min(len - 1, int(q * len))]

(the seed convention — nearest-rank, no interpolation — kept so
longitudinal benchmark JSONs stay comparable across PRs).
"""
from __future__ import annotations

from typing import Dict, Iterable, List, Optional, Sequence

from repro.analysis.witness import make_lock


def percentile(
    samples: Sequence[float], q: float, default: Optional[float] = None
):
    """Nearest-rank percentile with the repo-wide seed convention.

    Returns ``default`` (``None`` unless overridden) on an empty sample
    set — callers that want the old bench behavior pass ``default=0.0``.
    """
    if not samples:
        return default
    s = sorted(samples)
    return s[min(len(s) - 1, int(q * len(s)))]


def percentiles(
    samples: Sequence[float],
    qs: Iterable[float] = (0.5, 0.95, 0.99),
    default: Optional[float] = None,
) -> Dict[float, Optional[float]]:
    """``{q: percentile(samples, q)}`` — sorts once for all quantiles."""
    s = sorted(samples)
    out: Dict[float, Optional[float]] = {}
    for q in qs:
        if not s:
            out[q] = default
        else:
            out[q] = s[min(len(s) - 1, int(q * len(s)))]
    return out


class Ring:
    """Fixed-capacity overwrite-oldest sample buffer (thread-safe).

    Once full, new samples overwrite the oldest so percentiles track
    steady state (not warm-up) on long runs — the exact semantics the
    reward server's hand-rolled ``_latencies``/``_lat_pos`` pair had.
    """

    def __init__(self, capacity: int = 4096):
        self.capacity = max(1, int(capacity))
        self._items: List[float] = []
        self._pos = 0
        self._total = 0
        self._lock = make_lock("stats")

    def append(self, value: float) -> None:
        with self._lock:
            self._total += 1
            if len(self._items) < self.capacity:
                self._items.append(value)
            else:
                self._items[self._pos] = value
                self._pos = (self._pos + 1) % self.capacity

    def values(self) -> List[float]:
        """Snapshot of the retained samples (unordered semantics)."""
        with self._lock:
            return list(self._items)

    def __len__(self) -> int:
        with self._lock:
            return len(self._items)

    @property
    def total(self) -> int:
        """Samples ever appended (retained + overwritten)."""
        with self._lock:
            return self._total

    def percentiles(
        self,
        qs: Iterable[float] = (0.5, 0.95, 0.99),
        default: Optional[float] = None,
    ) -> Dict[float, Optional[float]]:
        return percentiles(self.values(), qs, default)
