"""TrajectoryTracer: per-trajectory spans off the lifecycle bus, plus
scheduler-thread activity spans and fleet counter samples.

The lifecycle bus (PR 5) already carries every trajectory transition::

    ROUTED -> (INTERRUPTED ->)* COMPLETED -> REWARDED -> CONSUMED
                                                      \\-> ABORTED

The tracer subscribes to all six kinds and folds them into one
``TrajSpan`` per trajectory:

* **instance timeline segments** — ``queue`` (routed/preempted, waiting
  for a slot) vs ``decode`` (admitted, generating), split by the engine
  admission/preemption hooks (``RolloutInstance.on_admit`` /
  ``on_preempt``), with the instance id on every segment so migration
  hops are visible;
* **PS version at route vs consume** — ``v_route`` is the min version
  over the span's ROUTED events (a group entry's protocol version is the
  min over members, lowered on late joins), and at CONSUMED the realized
  staleness is ``train_floor - v_route``. CONSUMED events are published
  synchronously under the coordinator lock *after*
  ``StalenessManager.consume`` advanced ``train_version``, so the floor
  of the batch just consumed is ``floor_source() - 1`` — which makes the
  per-span max provably equal to ``manager.max_consumed_staleness()``;
* **conservation accounting** — every ROUTED span must end in exactly
  one terminal event (CONSUMED or ABORTED); ``check_conservation``
  returns the violations (stress-tested under mid-run fail/add
  instance).

Beyond trajectories, the tracer records **activity spans** for service
threads (decode batches, coordinator cycles, reward scoring, background
PS pushes, train steps) keyed by thread name, and **counter samples**
from the fleet sampler (occupancy, KV fill, staleness-buffer state).
``repro.obs.export`` lays all three out as a Chrome trace.

Thread safety: one leaf lock around tracer state; handlers are called
synchronously from emitter threads (bus dispatch) and engine hooks run
under instance locks — the tracer never calls out while holding its
lock. Clock is injectable so the discrete-event simulator can trace in
sim seconds with the same machinery.
"""
from __future__ import annotations

import threading
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Callable, Deque, Dict, List, Optional, Sequence, Tuple

from repro.core.lifecycle import (
    LifecycleEvent,
    LifecycleEventKind,
    TrajectoryLifecycle,
)
from repro.analysis.witness import make_rlock
from repro.obs.stats import Ring

K = LifecycleEventKind


@dataclass
class Segment:
    kind: str                 # "queue" | "decode"
    inst: int
    t0: float
    t1: Optional[float] = None

    @property
    def duration(self) -> float:
        return (self.t1 - self.t0) if self.t1 is not None else 0.0


@dataclass
class TrajSpan:
    traj_id: int
    group_id: int = -1
    t_open: float = 0.0
    v_route: Optional[int] = None        # min PS version over ROUTED events
    segments: List[Segment] = field(default_factory=list)
    hops: int = 0                        # re-routes beyond the first
    preemptions: int = 0
    instances: List[int] = field(default_factory=list)  # visit order
    t_completed: Optional[float] = None
    t_rewarded: Optional[float] = None
    t_terminal: Optional[float] = None
    terminal: Optional[str] = None       # "consumed" | "aborted"
    terminal_events: int = 0             # conservation: must end at 1
    staleness: Optional[int] = None      # realized, set at CONSUMED

    def queue_wait(self) -> float:
        return sum(s.duration for s in self.segments if s.kind == "queue")

    def decode_time(self) -> float:
        return sum(s.duration for s in self.segments if s.kind == "decode")

    @property
    def open_segment(self) -> Optional[Segment]:
        if self.segments and self.segments[-1].t1 is None:
            return self.segments[-1]
        return None


@dataclass
class Activity:
    track: str
    name: str
    t0: float
    t1: float
    args: Optional[dict] = None


class TrajectoryTracer:
    """Lifecycle-bus subscriber building per-trajectory spans (+ thread
    activity and counter tracks). See module docstring."""

    def __init__(
        self,
        lifecycle: Optional[TrajectoryLifecycle] = None,
        *,
        clock: Callable[[], float] = time.perf_counter,
        floor_source: Optional[Callable[[], int]] = None,
        registry=None,
        max_activities: int = 200_000,
        max_counter_samples: int = 200_000,
        latency_samples: int = 65_536,
    ):
        self._clock = clock
        self._floor = floor_source
        self._lifecycle = lifecycle
        self._lock = make_rlock("tracer")
        self.t0 = clock()
        self.spans: Dict[int, TrajSpan] = {}
        self.activities: Deque[Activity] = deque(maxlen=max_activities)
        # (track, ts, {series: value})
        self.counter_samples: Deque[Tuple[str, float, Dict[str, float]]] = (
            deque(maxlen=max_counter_samples)
        )
        # pipeline latencies (same definitions the old bench probe used)
        self.route_lat = Ring(latency_samples)    # capacity freed -> ROUTED
        self.queue_lat = Ring(latency_samples)    # routed/preempt -> admit
        self.reward_lat = Ring(latency_samples)   # COMPLETED -> REWARDED
        self.consume_lat = Ring(latency_samples)  # REWARDED -> CONSUMED
        self._freed: Dict[int, float] = {}        # inst -> freed-at ts
        self.unrouted_events = 0                  # events with no open span
        self.staleness_samples: List[int] = []
        # optional registry mirror for realized staleness / queue waits
        self._m_staleness = (
            registry.histogram(
                "trace_staleness", buckets=tuple(range(0, 17))
            )
            if registry is not None else None
        )
        if lifecycle is not None:
            self._handlers = {
                K.ROUTED: self._on_routed,
                K.INTERRUPTED: self._on_interrupted,
                K.COMPLETED: self._on_completed,
                K.REWARDED: self._on_rewarded,
                K.CONSUMED: self._on_consumed,
                K.ABORTED: self._on_aborted,
            }
            for kind, fn in self._handlers.items():
                lifecycle.subscribe(kind, fn)
        else:
            self._handlers = {}

    def detach(self) -> None:
        if self._lifecycle is not None:
            for kind, fn in self._handlers.items():
                self._lifecycle.unsubscribe(kind, fn)
            self._handlers = {}

    # ------------------------------------------------------------- helpers
    def now(self) -> float:
        return self._clock()

    def _close_segment(self, span: TrajSpan, t: float) -> None:
        seg = span.open_segment
        if seg is not None:
            seg.t1 = t

    # ---------------------------------------------------- lifecycle events
    def _on_routed(self, e: LifecycleEvent) -> None:
        t = self._clock()
        with self._lock:
            t_free = self._freed.pop(e.inst, None) if e.inst is not None else None
            if t_free is not None:
                self.route_lat.append(t - t_free)
            span = self.spans.get(e.traj_id)
            if span is None:
                span = TrajSpan(
                    traj_id=e.traj_id,
                    group_id=(e.traj.group_id if e.traj is not None else -1),
                    t_open=t,
                )
                self.spans[e.traj_id] = span
            else:
                span.hops += 1
            if e.version is not None:
                span.v_route = (
                    e.version if span.v_route is None
                    else min(span.v_route, e.version)
                )
            self._close_segment(span, t)  # defensive: should be closed
            inst = e.inst if e.inst is not None else -1
            span.segments.append(Segment("queue", inst, t))
            if not span.instances or span.instances[-1] != inst:
                span.instances.append(inst)

    def _on_interrupted(self, e: LifecycleEvent) -> None:
        t = self._clock()
        with self._lock:
            span = self.spans.get(e.traj_id)
            if span is None:
                self.unrouted_events += 1
                return
            self._close_segment(span, t)

    def _on_completed(self, e: LifecycleEvent) -> None:
        t = self._clock()
        with self._lock:
            if e.inst is not None:
                self._freed.setdefault(e.inst, t)
            span = self.spans.get(e.traj_id)
            if span is None:
                self.unrouted_events += 1
                return
            self._close_segment(span, t)
            span.t_completed = t

    def _on_rewarded(self, e: LifecycleEvent) -> None:
        t = self._clock()
        with self._lock:
            span = self.spans.get(e.traj_id)
            if span is None:
                self.unrouted_events += 1
                return
            span.t_rewarded = t
            if span.t_completed is not None:
                self.reward_lat.append(t - span.t_completed)

    def _on_consumed(self, e: LifecycleEvent) -> None:
        t = self._clock()
        with self._lock:
            span = self.spans.get(e.traj_id)
            if span is None:
                self.unrouted_events += 1
                return
            span.terminal_events += 1
            if span.terminal is None:
                span.terminal = "consumed"
                span.t_terminal = t
            self._close_segment(span, t)
            if span.t_rewarded is not None:
                self.consume_lat.append(t - span.t_rewarded)
            if self._floor is not None and span.v_route is not None:
                # CONSUMED is published under the coordinator lock right
                # after consume() advanced train_version past the batch's
                # floor buffer — the consumed floor is floor_source() - 1
                span.staleness = max(0, self._floor() - 1 - span.v_route)
                self.staleness_samples.append(span.staleness)
                if self._m_staleness is not None:
                    self._m_staleness.observe(span.staleness)

    def _on_aborted(self, e: LifecycleEvent) -> None:
        t = self._clock()
        with self._lock:
            if e.inst is not None:
                self._freed.setdefault(e.inst, t)
            span = self.spans.get(e.traj_id)
            if span is None:
                # protocol abort of a never-routed trajectory (e.g. a
                # surplus group member still waiting in the TS): no span
                self.unrouted_events += 1
                return
            span.terminal_events += 1
            if span.terminal is None:
                span.terminal = "aborted"
                span.t_terminal = t
            self._close_segment(span, t)

    # ------------------------------------------------- engine admission hooks
    def on_admit(self, inst_id: int, traj_ids: Sequence[int]) -> None:
        """Engine hook: waiting trajectories entered decode slots — close
        their queue segments, open decode segments."""
        t = self._clock()
        with self._lock:
            for tid in traj_ids:
                span = self.spans.get(tid)
                if span is None:
                    continue  # standalone engine use without ROUTED events
                seg = span.open_segment
                if seg is not None and seg.kind == "queue":
                    seg.t1 = t
                    self.queue_lat.append(seg.duration)
                span.segments.append(Segment("decode", inst_id, t))

    def on_preempt(self, inst_id: int, traj_id: int) -> None:
        """Engine hook: a decoding trajectory was evicted back to the
        waiting queue (KV exhaustion)."""
        t = self._clock()
        with self._lock:
            span = self.spans.get(traj_id)
            if span is None:
                return
            span.preemptions += 1
            self._close_segment(span, t)
            span.segments.append(Segment("queue", inst_id, t))

    # -------------------------------------------------- activity + counters
    def activity(
        self,
        name: str,
        t0: float,
        t1: float,
        track: Optional[str] = None,
        args: Optional[dict] = None,
    ) -> None:
        """Record one scheduler-thread work interval. ``track`` defaults to
        the current thread's name, so the threaded scheduler's named
        service threads (instance-N, coordinator, trainer, reward-N,
        ps-push) each get their own exporter track for free."""
        if track is None:
            track = threading.current_thread().name
        with self._lock:
            self.activities.append(Activity(track, name, t0, t1, args))

    def sample(
        self, track: str, values: Dict[str, float], ts: Optional[float] = None
    ) -> None:
        """Record one counter-track sample (fleet sampler)."""
        if ts is None:
            ts = self._clock()
        with self._lock:
            self.counter_samples.append((track, ts, dict(values)))

    # ----------------------------------------------------------- accounting
    def finished_spans(self) -> List[TrajSpan]:
        with self._lock:
            return [s for s in self.spans.values() if s.terminal is not None]

    def open_spans(self) -> List[TrajSpan]:
        with self._lock:
            return [s for s in self.spans.values() if s.terminal is None]

    def check_conservation(self, allow_open: bool = False) -> List[str]:
        """Every ROUTED span must close with exactly one terminal event.

        Returns human-readable violations (empty == conserved). With
        ``allow_open`` spans still in flight are tolerated (mid-run
        checks); after a drained run nothing may remain open.
        """
        problems: List[str] = []
        with self._lock:
            for span in self.spans.values():
                if span.terminal is None:
                    if not allow_open:
                        problems.append(
                            f"traj {span.traj_id}: routed but never "
                            f"consumed/aborted"
                        )
                    continue
                if span.terminal_events != 1:
                    problems.append(
                        f"traj {span.traj_id}: {span.terminal_events} "
                        f"terminal events (want exactly 1)"
                    )
                if span.open_segment is not None:
                    problems.append(
                        f"traj {span.traj_id}: dangling open segment after "
                        f"terminal {span.terminal}"
                    )
        return problems

    def realized_max_staleness(self) -> int:
        """Max realized staleness over consumed spans (0 when none)."""
        with self._lock:
            return max(self.staleness_samples, default=0)

    def staleness_histogram(self) -> Dict[int, int]:
        with self._lock:
            hist: Dict[int, int] = {}
            for s in self.staleness_samples:
                hist[s] = hist.get(s, 0) + 1
            return dict(sorted(hist.items()))

    def busy_seconds_by_instance(self) -> Dict[int, float]:
        """Total decode-segment seconds per instance id."""
        with self._lock:
            out: Dict[int, float] = {}
            for span in self.spans.values():
                for seg in span.segments:
                    if seg.kind == "decode" and seg.t1 is not None:
                        out[seg.inst] = out.get(seg.inst, 0.0) + seg.duration
            return dict(sorted(out.items()))
