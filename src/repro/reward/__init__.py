"""Reward hub: per-task verifier routing with failure handling.

``RewardModel``/``FnVerifier`` (in-process), ``HttpVerifier`` (remote
submit-then-poll judge), and ``SandboxVerifier`` (resource-limited
subprocess) all speak one scoring protocol; :class:`RewardHub` routes
trajectories between them by task tag and resolves terminal failures to
a deterministic fallback score or a clean ABORTED. ``retry`` holds the
shared retry/breaker machinery, ``faults`` the deterministic fault
injector, ``stub_judge`` the hermetic loopback judge used by tests, the
benchmark, and the ``reward-hub`` CI job.
"""
from repro.reward.faults import (
    Fault,
    FaultInjectingVerifier,
    FaultSchedule,
    InjectedCrash,
)
from repro.reward.http_verifier import HttpVerifier
from repro.reward.hub import DEFAULT_ROUTE, RewardHub
from repro.reward.retry import (
    BreakerState,
    CircuitBreaker,
    RetryPolicy,
    RetryingVerifier,
    VerificationAbort,
    VerifierError,
    VerifierTimeout,
    VerifierUnavailable,
    run_with_retries,
)
from repro.reward.sandbox import SandboxVerifier
from repro.reward.stub_judge import StubJudge
from repro.reward.verifier import RewardModel, verify_arithmetic

__all__ = [
    "BreakerState",
    "CircuitBreaker",
    "DEFAULT_ROUTE",
    "Fault",
    "FaultInjectingVerifier",
    "FaultSchedule",
    "HttpVerifier",
    "InjectedCrash",
    "RetryPolicy",
    "RetryingVerifier",
    "RewardHub",
    "RewardModel",
    "SandboxVerifier",
    "StubJudge",
    "VerificationAbort",
    "VerifierError",
    "VerifierTimeout",
    "VerifierUnavailable",
    "run_with_retries",
    "verify_arithmetic",
]
