"""Deterministic fault injection for verifiers.

The tentpole's provability requirement: under a *seeded schedule* of
verifier faults — transient errors, latency spikes, dropped requests,
hard crashes — every ROUTED trajectory must still reach exactly one
terminal lifecycle event, staleness must stay ≤ η, and no reward worker
thread may die. :class:`FaultInjectingVerifier` wraps any verifier and
injects those faults on a schedule that is a **pure function of the
call index**, so the same seed produces the same fault for call *i*
regardless of thread interleaving — totals are reproducible even under
the threaded scheduler.

Fault kinds:

* ``ok``    — pass through to the inner verifier;
* ``error`` — raise ``VerifierError`` (transient; retry wrappers eat it);
* ``crash`` — raise a non-verifier ``InjectedCrash`` (models the verifier
  process itself blowing up — the worker-survival bugfix's regression
  vector);
* ``delay`` — sleep ``delay_s`` then pass through (latency spike);
* ``drop``  — hang ``drop_hang_s`` then raise ``VerifierTimeout`` (the
  request vanished; models a judge that never answers).
"""
from __future__ import annotations

import random
import time
from dataclasses import dataclass
from typing import Callable, List, Optional, Sequence, Union

from repro.analysis.witness import make_lock
from repro.reward.retry import VerifierError, VerifierTimeout

FAULT_KINDS = ("ok", "error", "crash", "delay", "drop")


class InjectedCrash(RuntimeError):
    """A non-verifier exception: the verifier itself blew up."""


@dataclass(frozen=True)
class Fault:
    kind: str = "ok"
    delay_s: float = 0.0

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}")


class FaultSchedule:
    """Per-call fault plan, deterministic in the call index.

    Two modes, composable into neither:

    * **explicit**: ``FaultSchedule(["ok", "error", "drop"])`` — the
      sequence is consumed by call index; past the end it is either
      cycled (``cycle=True``) or everything is ``ok``.
    * **seeded rates**: ``FaultSchedule(seed=7, error_rate=0.2, ...)`` —
      call *i* draws its fault from ``random.Random((seed, i))``, so the
      decision for a given call index never depends on which thread got
      there first.
    """

    def __init__(
        self,
        faults: Optional[Sequence[Union[Fault, str]]] = None,
        *,
        cycle: bool = False,
        seed: int = 0,
        error_rate: float = 0.0,
        crash_rate: float = 0.0,
        drop_rate: float = 0.0,
        delay_rate: float = 0.0,
        delay_s: float = 0.01,
    ):
        self._explicit: Optional[List[Fault]] = None
        if faults is not None:
            self._explicit = [
                f if isinstance(f, Fault) else Fault(f) for f in faults
            ]
        self._cycle = cycle
        self._seed = seed
        self._rates = (
            ("error", error_rate),
            ("crash", crash_rate),
            ("drop", drop_rate),
            ("delay", delay_rate),
        )
        self._delay_s = delay_s

    def at(self, i: int) -> Fault:
        if self._explicit is not None:
            if i < len(self._explicit):
                return self._explicit[i]
            if self._cycle and self._explicit:
                return self._explicit[i % len(self._explicit)]
            return Fault("ok")
        # seeded-rate mode: one draw per call index, order-independent.
        # Integer seed mix (not a tuple: tuple seeding is hash-based and
        # varies with PYTHONHASHSEED — faults must reproduce across runs)
        u = random.Random(self._seed * 0x9E3779B1 + i).random()
        edge = 0.0
        for kind, rate in self._rates:
            edge += rate
            if u < edge:
                return Fault(kind, delay_s=self._delay_s)
        return Fault("ok")


class FaultInjectingVerifier:
    """Wrap a verifier with a deterministic fault schedule.

    Call indices are assigned atomically; each index's fault comes from
    ``schedule.at(i)``. Per-kind counts are kept so tests can assert the
    faults actually fired (a fault-injection test that injected nothing
    proves nothing).
    """

    def __init__(
        self,
        inner,
        schedule: FaultSchedule,
        *,
        drop_hang_s: float = 0.02,
        sleep: Callable[[float], None] = time.sleep,
        name: Optional[str] = None,
    ):
        self.inner = inner
        self.schedule = schedule
        self.drop_hang_s = drop_hang_s
        self.name = name or f"faulty[{type(inner).__name__}]"
        self._sleep = sleep
        self._lock = make_lock("faults")
        self._next = 0
        self.counts = {k: 0 for k in FAULT_KINDS}

    def _fault(self) -> Fault:
        with self._lock:
            i = self._next
            self._next += 1
        f = self.schedule.at(i)
        with self._lock:
            self.counts[f.kind] += 1
        return f

    def _call(self, fn: Callable[[], float]) -> float:
        f = self._fault()
        if f.kind == "error":
            raise VerifierError("injected transient error")
        if f.kind == "crash":
            raise InjectedCrash("injected verifier crash")
        if f.kind == "drop":
            self._sleep(self.drop_hang_s)
            raise VerifierTimeout("injected drop: request never answered")
        if f.kind == "delay":
            self._sleep(f.delay_s)
        return fn()

    def score(self, prompt_ids: List[int], response_ids: List[int]) -> float:
        return self._call(lambda: self.inner.score(prompt_ids, response_ids))

    def score_trajectory(self, traj) -> float:
        fn = getattr(self.inner, "score_trajectory", None)
        if fn is None:
            return self._call(
                lambda: self.inner.score(
                    list(traj.prompt), list(traj.response)
                )
            )
        return self._call(lambda: fn(traj))

    def injected(self) -> int:
        """Total non-ok faults fired so far."""
        with self._lock:
            return sum(v for k, v in self.counts.items() if k != "ok")

    def stats(self) -> dict:
        with self._lock:
            out = {f"fault_{k}": v for k, v in self.counts.items()}
            out["calls"] = self._next
        return out
