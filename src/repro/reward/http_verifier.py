"""HTTP verifier client: submit-then-poll against a remote judge.

The remote-judge protocol (the shape of slime's ``remote_code_judge``):

* ``POST {base_url}/submit`` with ``{"prompt_ids": [...], "response_ids":
  [...], "task": "..."}``. The judge replies either with an immediate
  ``{"score": s}`` (synchronous judges) or with ``{"job_id": "..."}``.
* ``GET {base_url}/result/{job_id}`` replies ``{"status": "pending"}``
  until the job finishes, then ``{"status": "done", "score": s}`` (or
  ``{"status": "failed", "error": "..."}``).

Every request carries a per-request socket timeout and runs through the
shared retry state machine (:func:`repro.reward.retry.run_with_retries`):
capped exponential backoff with seeded jitter, bounded attempts, and an
optional circuit breaker that opens on consecutive failures so a dead
judge fails fast instead of stalling every reward worker. On top of the
per-request machinery sits one end-to-end deadline (``total_timeout_s``)
bounding submit + all polls; crossing it raises ``VerifierTimeout`` and
the hub's failure policy (fallback score or clean ABORTED) takes over.

stdlib only (``urllib``): no new dependencies, and the hermetic CI job
talks to a stdlib ``http.server`` stub judge on the loopback interface.
"""
from __future__ import annotations

import json
import random
import time
import urllib.error
import urllib.request
from typing import Callable, List, Optional

from repro.analysis.witness import make_lock
from repro.reward.retry import (
    CircuitBreaker,
    RetryPolicy,
    VerifierError,
    VerifierTimeout,
    run_with_retries,
)


class HttpVerifier:
    """Submit-then-poll remote judge client with retries + breaker."""

    def __init__(
        self,
        base_url: str,
        *,
        policy: Optional[RetryPolicy] = None,
        breaker: Optional[CircuitBreaker] = None,
        total_timeout_s: float = 30.0,
        poll_interval_s: float = 0.02,
        seed: int = 0,
        name: str = "http",
        clock: Callable[[], float] = time.monotonic,
        sleep: Callable[[float], None] = time.sleep,
    ):
        self.base_url = base_url.rstrip("/")
        self.policy = policy or RetryPolicy()
        self.breaker = breaker
        self.total_timeout_s = total_timeout_s
        self.poll_interval_s = poll_interval_s
        self.name = name
        self._rng = random.Random(seed)
        self._clock = clock
        self._sleep = sleep
        self._lock = make_lock("http")
        # telemetry
        self.calls = 0
        self.requests = 0        # HTTP round trips attempted
        self.retries = 0         # round trips beyond the first per step
        self.timeouts = 0        # end-to-end deadlines crossed
        self.failures = 0        # calls that raised terminally

    # ------------------------------------------------------------- plumbing
    def _http(self, method: str, path: str, payload: Optional[dict]) -> dict:
        """One HTTP round trip -> decoded JSON body; raises VerifierError."""
        with self._lock:
            self.requests += 1
        url = f"{self.base_url}{path}"
        data = None
        headers = {"Accept": "application/json"}
        if payload is not None:
            data = json.dumps(payload).encode("utf-8")
            headers["Content-Type"] = "application/json"
        req = urllib.request.Request(
            url, data=data, headers=headers, method=method
        )
        try:
            with urllib.request.urlopen(
                req, timeout=self.policy.request_timeout_s
            ) as resp:
                body = resp.read()
        except urllib.error.HTTPError as exc:
            raise VerifierError(
                f"judge returned HTTP {exc.code} for {method} {path}"
            ) from exc
        except Exception as exc:  # URLError, socket.timeout, conn reset
            raise VerifierError(
                f"judge unreachable for {method} {path}: {exc!r}"
            ) from exc
        try:
            return json.loads(body.decode("utf-8"))
        except Exception as exc:
            raise VerifierError(
                f"judge returned non-JSON body for {method} {path}"
            ) from exc

    def _step(self, method: str, path: str, payload: Optional[dict]) -> dict:
        """One protocol step (submit or poll) through the retry machinery."""

        def note_retry(attempt: int, exc: BaseException) -> None:
            with self._lock:
                self.retries += 1

        return run_with_retries(
            lambda: self._http(method, path, payload),
            self.policy,
            breaker=self.breaker,
            rng=self._rng,
            sleep=self._sleep,
            on_retry=note_retry,
        )

    # ------------------------------------------------------------- protocol
    def score(self, prompt_ids: List[int], response_ids: List[int],
              task: str = "") -> float:
        with self._lock:
            self.calls += 1
        deadline = self._clock() + self.total_timeout_s
        try:
            reply = self._step("POST", "/submit", {
                "prompt_ids": list(prompt_ids),
                "response_ids": list(response_ids),
                "task": task,
            })
            if "score" in reply:          # synchronous judge
                return float(reply["score"])
            job_id = reply.get("job_id")
            if job_id is None:
                raise VerifierError(
                    f"judge submit reply carries neither score nor "
                    f"job_id: {reply!r}"
                )
            while True:
                if self._clock() >= deadline:
                    with self._lock:
                        self.timeouts += 1
                    raise VerifierTimeout(
                        f"judge job {job_id} still pending after "
                        f"{self.total_timeout_s}s"
                    )
                reply = self._step("GET", f"/result/{job_id}", None)
                status = reply.get("status")
                if status == "done":
                    return float(reply["score"])
                if status == "failed":
                    raise VerifierError(
                        f"judge job {job_id} failed: "
                        f"{reply.get('error', '?')}"
                    )
                self._sleep(self.poll_interval_s)
        except Exception:
            with self._lock:
                self.failures += 1
            raise

    def score_trajectory(self, traj) -> float:
        return self.score(
            list(traj.prompt), list(traj.response),
            task=getattr(traj, "task", ""),
        )

    def stats(self) -> dict:
        with self._lock:
            out = {
                "calls": self.calls,
                "requests": self.requests,
                "retries": self.retries,
                "timeouts": self.timeouts,
                "failures": self.failures,
            }
        if self.breaker is not None:
            out["breaker_state"] = self.breaker.state.value
            out["breaker_opened"] = self.breaker.opened
            out["breaker_fast_failures"] = self.breaker.fast_failures
        return out
