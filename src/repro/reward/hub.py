"""Reward hub: per-task routing of trajectories to registered verifiers.

ROLL-Flash-style asynchronous reward routing: each trajectory carries a
task tag (``Trajectory.task``) and the hub dispatches it to the verifier
registered for that tag — an in-process ``RewardModel``/``FnVerifier``
for math, a subprocess :class:`~repro.reward.sandbox.SandboxVerifier`
for code, an :class:`~repro.reward.http_verifier.HttpVerifier` for a
remote judge — all behind the one scoring protocol the
:class:`~repro.core.reward_server.RewardServer` already consumes. The
hub *is* a verifier (``score`` / ``score_trajectory``), so it drops into
the server unchanged and composes with the retry / breaker / fault
-injection wrappers.

Failure policy — the tentpole's invariant. A verifier that fails
terminally (retries exhausted, breaker open, sandbox killed, no route)
must never leave a ROUTED trajectory without a terminal lifecycle event:

* ``on_failure="fallback"`` (default): the hub swallows the failure and
  returns the deterministic ``fallback_score`` — the trajectory proceeds
  to REWARDED like any other (counted per route as ``fallbacks``).
* ``on_failure="abort"``: the hub raises
  :class:`~repro.reward.retry.VerificationAbort`; the RewardServer
  publishes a clean ABORTED through the coordinator instead of REWARDED,
  releasing the staleness entry and (for groups) the whole group.

Observability: per-route latency histograms + failure/fallback counters
on the metrics registry, and per-score ``verify[tag]`` activity segments
on the tracer's reward track.
"""
from __future__ import annotations

import time
from typing import Callable, Dict, List, Optional

from repro.analysis.witness import make_lock
from repro.obs.stats import Ring, percentiles
from repro.reward.retry import VerificationAbort

DEFAULT_ROUTE = ""   # tag of the default route; also matches untagged work


class _Route:
    """A registered verifier + its per-route telemetry."""

    def __init__(self, tag: str, verifier, max_latency_samples: int = 2048):
        self.tag = tag
        self.verifier = verifier
        self.lock = make_lock("route")
        self.calls = 0
        self.failures = 0    # terminal verifier failures seen by the hub
        self.fallbacks = 0   # failures resolved to the fallback score
        self.aborts = 0      # failures escalated to VerificationAbort
        self.latencies = Ring(max_latency_samples)

    def name(self) -> str:
        return getattr(self.verifier, "name", type(self.verifier).__name__)

    def stats(self) -> dict:
        with self.lock:
            out = {
                "verifier": self.name(),
                "calls": self.calls,
                "failures": self.failures,
                "fallbacks": self.fallbacks,
                "aborts": self.aborts,
            }
        out["latency"] = percentiles(self.latencies.values(), (0.5, 0.99))
        inner = getattr(self.verifier, "stats", None)
        if callable(inner):
            out["inner"] = inner()
        return out


class RewardHub:
    """Route trajectories by task tag to registered verifiers."""

    def __init__(
        self,
        default=None,
        routes: Optional[Dict[str, object]] = None,
        *,
        on_failure: str = "fallback",
        fallback_score: float = 0.0,
        task_of: Optional[Callable[[object], str]] = None,
        metrics=None,
        tracer=None,
        clock: Callable[[], float] = time.perf_counter,
    ):
        if on_failure not in ("fallback", "abort"):
            raise ValueError(
                f"on_failure must be 'fallback' or 'abort', "
                f"got {on_failure!r}"
            )
        self.on_failure = on_failure
        self.fallback_score = fallback_score
        self._task_of = task_of
        self._clock = clock
        self._tracer = tracer
        self._metrics = metrics
        self._routes: Dict[str, _Route] = {}
        self._lock = make_lock("hub")
        self.unrouted = 0    # trajectories whose tag matched no route
        if default is not None:
            self.register(DEFAULT_ROUTE, default)
        for tag, verifier in (routes or {}).items():
            self.register(tag, verifier)
    def _m(self, kind: str, name: str, tag: str):
        """Labeled instrument for a route (get-or-create is cheap)."""
        if self._metrics is None:
            return None
        factory = getattr(self._metrics, kind)
        return factory(name, route=tag or "default")

    # -------------------------------------------------------------- routing
    def register(self, tag: str, verifier) -> "RewardHub":
        """Register (or replace) the verifier for ``tag``; chains."""
        with self._lock:
            self._routes[tag] = _Route(tag, verifier)
        return self

    def tags(self) -> List[str]:
        with self._lock:
            return sorted(self._routes)

    def route_for(self, tag: str) -> Optional[_Route]:
        """The route for ``tag``, falling back to the default route."""
        with self._lock:
            route = self._routes.get(tag)
            if route is None:
                route = self._routes.get(DEFAULT_ROUTE)
            return route

    def _tag_of(self, traj) -> str:
        if self._task_of is not None:
            return self._task_of(traj)
        return getattr(traj, "task", "") or DEFAULT_ROUTE

    # -------------------------------------------------------------- scoring
    def score(self, prompt_ids: List[int], response_ids: List[int]) -> float:
        """Verifier-protocol entry: untagged work takes the default route."""
        return self._dispatch(
            DEFAULT_ROUTE, None,
            lambda v: v.score(prompt_ids, response_ids),
        )

    def score_trajectory(self, traj) -> float:
        tag = self._tag_of(traj)

        def call(verifier) -> float:
            fn = getattr(verifier, "score_trajectory", None)
            if fn is not None and verifier is not self:
                return fn(traj)
            return verifier.score(list(traj.prompt), list(traj.response))

        return self._dispatch(tag, getattr(traj, "traj_id", None), call)

    def _dispatch(
        self,
        tag: str,
        traj_id: Optional[int],
        call: Callable[[object], float],
    ) -> float:
        route = self.route_for(tag)
        if route is None:
            with self._lock:
                self.unrouted += 1
            return self._resolve_failure(
                tag, traj_id, None,
                RuntimeError(f"no verifier registered for task {tag!r} "
                             f"and no default route"),
            )
        with route.lock:
            route.calls += 1
        t0 = self._clock()
        try:
            score = call(route.verifier)
        except VerificationAbort:
            # an inner hub/wrapper already decided: count + propagate
            with route.lock:
                route.failures += 1
                route.aborts += 1
            raise
        except Exception as exc:
            with route.lock:
                route.failures += 1
            m = self._m("counter", "reward_hub_failures", route.tag)
            if m is not None:
                m.inc()
            return self._resolve_failure(tag, traj_id, route, exc)
        now = self._clock()
        route.latencies.append(now - t0)
        m = self._m("counter", "reward_hub_scores", route.tag)
        if m is not None:
            m.inc()
        m = self._m("histogram", "reward_hub_verify_s", route.tag)
        if m is not None:
            m.observe(now - t0)
        if self._tracer is not None:
            self._tracer.activity(
                f"verify[{route.tag or 'default'}]", t0, now,
                args={} if traj_id is None else {"traj": traj_id},
            )
        return score

    def _resolve_failure(
        self,
        tag: str,
        traj_id: Optional[int],
        route: Optional[_Route],
        exc: BaseException,
    ) -> float:
        """Terminal failure -> deterministic fallback score, or abort."""
        if self.on_failure == "abort":
            if route is not None:
                with route.lock:
                    route.aborts += 1
            raise VerificationAbort(tag, traj_id, cause=exc)
        if route is not None:
            with route.lock:
                route.fallbacks += 1
        return self.fallback_score

    # ----------------------------------------------------------- telemetry
    def stats(self) -> dict:
        with self._lock:
            routes = dict(self._routes)
            unrouted = self.unrouted
        return {
            "on_failure": self.on_failure,
            "unrouted": unrouted,
            "routes": {
                (tag or "default"): route.stats()
                for tag, route in sorted(routes.items())
            },
        }
