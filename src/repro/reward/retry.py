"""Retry state machine for unreliable verifiers: bounded retries with
capped exponential backoff + deterministic jitter, and a circuit breaker.

The paper's disaggregated reward phase assumes verifiers are slow, flaky,
*external* services (remote judges, sandboxed executors). This module is
the failure-handling vocabulary every such verifier shares:

* :class:`RetryPolicy` — how many attempts, how long each may take, and
  how long to back off between them. Jitter is drawn from a seeded RNG so
  a fixed seed reproduces the exact retry schedule (the fault-injection
  suites depend on this).
* :class:`CircuitBreaker` — consecutive-failure trip wire. After
  ``failure_threshold`` consecutive failures the breaker *opens* and
  every call fails fast (``VerifierUnavailable``) without touching the
  backend; after ``reset_timeout_s`` it *half-opens* and admits exactly
  one probe — success closes it, failure re-opens it.
* :func:`run_with_retries` — the attempt loop both the generic
  :class:`RetryingVerifier` wrapper and the HTTP client drive.

Exception taxonomy (shared by the whole reward hub):

* ``VerifierError``       — the verifier failed (transient or final).
* ``VerifierTimeout``     — a deadline expired (request or end-to-end).
* ``VerifierUnavailable`` — the breaker is open; no attempt was made.
* ``VerificationAbort``   — terminal *decision*: the trajectory cannot be
  scored and must leave the pipeline via a clean ABORTED (raised by the
  hub when ``on_failure="abort"``), never a stuck REWARDED-pending span.
"""
from __future__ import annotations

import enum
import random
import time
from dataclasses import dataclass
from typing import Callable, List, Optional, TypeVar

from repro.analysis.witness import make_lock

T = TypeVar("T")


class VerifierError(RuntimeError):
    """A verifier attempt (or all of them) failed."""


class VerifierTimeout(VerifierError):
    """A per-request or end-to-end verification deadline expired."""


class VerifierUnavailable(VerifierError):
    """The circuit breaker is open: the call failed fast, untried."""


class VerificationAbort(RuntimeError):
    """Terminal verification failure: abort the trajectory cleanly.

    Carries the route tag and the underlying cause so telemetry can say
    *which* verifier gave up on *what*.
    """

    def __init__(self, tag: str, traj_id: Optional[int] = None,
                 cause: Optional[BaseException] = None):
        super().__init__(
            f"verification aborted (route {tag!r}"
            + (f", traj {traj_id}" if traj_id is not None else "")
            + (f"): {cause!r}" if cause is not None else ")")
        )
        self.tag = tag
        self.traj_id = traj_id
        self.cause = cause


@dataclass(frozen=True)
class RetryPolicy:
    """Bounded retries with capped exponential backoff + seeded jitter.

    ``backoff(attempt)`` for attempt ``k`` (0-based) is
    ``min(base * 2**k, cap) * (1 + U[0, jitter))`` — capped exponential
    with multiplicative jitter, the standard shape for not synchronizing
    a fleet of retriers onto a struggling backend.
    """

    max_attempts: int = 3
    request_timeout_s: float = 5.0   # per-attempt deadline (HTTP/subprocess)
    backoff_base_s: float = 0.05
    backoff_cap_s: float = 2.0
    jitter: float = 0.5              # fraction of the backoff, uniform

    def backoff(self, attempt: int, rng: random.Random) -> float:
        base = min(self.backoff_base_s * (2.0 ** attempt), self.backoff_cap_s)
        if self.jitter <= 0.0:
            return base
        return base * (1.0 + rng.random() * self.jitter)


class BreakerState(enum.Enum):
    CLOSED = "closed"
    OPEN = "open"
    HALF_OPEN = "half_open"


class CircuitBreaker:
    """Consecutive-failure circuit breaker (closed -> open -> half-open).

    Thread-safe; the clock is injectable so tests drive state transitions
    without sleeping. ``allow()`` is the gate callers consult *before*
    each attempt; ``record_success``/``record_failure`` feed it back.
    """

    def __init__(
        self,
        failure_threshold: int = 5,
        reset_timeout_s: float = 30.0,
        *,
        clock: Callable[[], float] = time.monotonic,
    ):
        if failure_threshold <= 0:
            raise ValueError("failure_threshold must be positive")
        self.failure_threshold = failure_threshold
        self.reset_timeout_s = reset_timeout_s
        self._clock = clock
        self._lock = make_lock("breaker")
        self._state = BreakerState.CLOSED
        self._consecutive = 0
        self._opened_at = 0.0
        self._probe_in_flight = False
        # telemetry
        self.opened = 0          # times the breaker tripped open
        self.fast_failures = 0   # calls rejected while open

    @property
    def state(self) -> BreakerState:
        with self._lock:
            return self._state

    def allow(self) -> bool:
        """May an attempt proceed right now? Half-open admits one probe."""
        with self._lock:
            if self._state is BreakerState.CLOSED:
                return True
            if self._state is BreakerState.OPEN:
                if self._clock() - self._opened_at >= self.reset_timeout_s:
                    self._state = BreakerState.HALF_OPEN
                    self._probe_in_flight = True
                    return True
                self.fast_failures += 1
                return False
            # HALF_OPEN: exactly one probe at a time
            if self._probe_in_flight:
                self.fast_failures += 1
                return False
            self._probe_in_flight = True
            return True

    def record_success(self) -> None:
        with self._lock:
            self._state = BreakerState.CLOSED
            self._consecutive = 0
            self._probe_in_flight = False

    def record_failure(self) -> None:
        with self._lock:
            self._consecutive += 1
            if (
                self._state is BreakerState.HALF_OPEN
                or self._consecutive >= self.failure_threshold
            ):
                if self._state is not BreakerState.OPEN:
                    self.opened += 1
                self._state = BreakerState.OPEN
                self._opened_at = self._clock()
                self._probe_in_flight = False


def run_with_retries(
    fn: Callable[[], T],
    policy: RetryPolicy,
    *,
    breaker: Optional[CircuitBreaker] = None,
    rng: Optional[random.Random] = None,
    sleep: Callable[[float], None] = time.sleep,
    on_retry: Optional[Callable[[int, BaseException], None]] = None,
) -> T:
    """Drive ``fn`` through the retry state machine.

    Each attempt consults the breaker first (``VerifierUnavailable`` when
    open — the caller decides fallback vs abort); failures back off per
    ``policy`` and are reported to ``on_retry(attempt, exc)`` before the
    next attempt. ``VerificationAbort`` passes straight through: it is a
    terminal decision, not a failure to retry.
    """
    rng = rng or random.Random(0)
    last: Optional[BaseException] = None
    for attempt in range(max(1, policy.max_attempts)):
        if breaker is not None and not breaker.allow():
            raise VerifierUnavailable(
                f"circuit breaker open (after {breaker.opened} trips)"
            )
        try:
            out = fn()
        except VerificationAbort:
            raise
        except Exception as exc:
            if breaker is not None:
                breaker.record_failure()
            last = exc
            if attempt + 1 < policy.max_attempts:
                if on_retry is not None:
                    on_retry(attempt, exc)
                sleep(policy.backoff(attempt, rng))
            continue
        if breaker is not None:
            breaker.record_success()
        return out
    raise VerifierError(
        f"verifier failed after {policy.max_attempts} attempts: {last!r}"
    ) from last


class RetryingVerifier:
    """Retry + breaker wrapper around any verifier.

    Satisfies both scoring protocols (``score`` and ``score_trajectory``)
    and delegates to whichever the inner verifier provides, so it can wrap
    an ``FnVerifier``, an ``HttpVerifier``, or a fault-injected stack
    transparently. Terminal failure raises ``VerifierError`` /
    ``VerifierUnavailable`` for the hub's failure policy to resolve.
    """

    def __init__(
        self,
        inner,
        policy: Optional[RetryPolicy] = None,
        breaker: Optional[CircuitBreaker] = None,
        *,
        seed: int = 0,
        sleep: Callable[[float], None] = time.sleep,
        name: Optional[str] = None,
    ):
        self.inner = inner
        self.policy = policy or RetryPolicy()
        self.breaker = breaker
        self.name = name or type(inner).__name__
        self._rng = random.Random(seed)
        self._sleep = sleep
        self._lock = make_lock("retry")
        # telemetry
        self.calls = 0
        self.retries = 0
        self.failures = 0        # attempts that raised
        self.exhausted = 0       # calls that ran out of attempts

    def _drive(self, fn: Callable[[], float]) -> float:
        with self._lock:
            self.calls += 1

        def note_retry(attempt: int, exc: BaseException) -> None:
            with self._lock:
                self.retries += 1
                self.failures += 1

        try:
            return run_with_retries(
                fn, self.policy, breaker=self.breaker, rng=self._rng,
                sleep=self._sleep, on_retry=note_retry,
            )
        except VerificationAbort:
            raise
        except VerifierError:
            with self._lock:
                self.failures += 1
                self.exhausted += 1
            raise

    def score(self, prompt_ids: List[int], response_ids: List[int]) -> float:
        return self._drive(lambda: self.inner.score(prompt_ids, response_ids))

    def score_trajectory(self, traj) -> float:
        fn = getattr(self.inner, "score_trajectory", None)
        if fn is None:
            return self.score(list(traj.prompt), list(traj.response))
        return self._drive(lambda: fn(traj))

    def stats(self) -> dict:
        with self._lock:
            out = {
                "calls": self.calls,
                "retries": self.retries,
                "failures": self.failures,
                "exhausted": self.exhausted,
            }
        if self.breaker is not None:
            out["breaker_state"] = self.breaker.state.value
            out["breaker_opened"] = self.breaker.opened
            out["breaker_fast_failures"] = self.breaker.fast_failures
        return out
