"""Subprocess-sandboxed code-execution verifier.

Runs an *untrusted* scoring program in a separate, resource-limited
Python subprocess and kills it on timeout — the sandbox every
code-execution reward path needs before trajectories can carry
model-written programs.

Contract with the sandboxed program: it must define

    def score(prompt_ids, response_ids):
        return <float>

The harness feeds ``{"program", "prompt_ids", "response_ids", "task"}``
as JSON on stdin, executes the program in a bare namespace, calls its
``score`` and prints ``{"score": s}`` as the *last* line of stdout (the
program may print freely before that).

Isolation, in decreasing order of hardness:

* ``python -I`` (isolated mode): no user site-packages, no cwd on
  ``sys.path``, environment-variable hooks ignored;
* a scrubbed environment (only ``PATH``) — no proxy variables, tokens,
  or credentials leak in;
* ``resource.setrlimit`` in the child pre-exec hook: CPU seconds
  (``RLIMIT_CPU``), address space (``RLIMIT_AS``), no core dumps;
* own session (``setsid``) so a timeout kill takes the whole process
  group, including anything the program spawned;
* wall-clock timeout enforced by the parent: ``SIGKILL`` to the group,
  then ``VerifierTimeout`` — the hub's failure policy decides fallback
  vs ABORTED.

"No network" is enforced by construction on the judge side (nothing is
listening for it) and by the scrubbed environment; a true network
namespace requires privileges this runtime does not assume — see
``docs/architecture.md``.
"""
from __future__ import annotations

import json
import os
import signal
import subprocess
import sys
import time
from typing import Callable, List, Optional

from repro.analysis.witness import make_lock
from repro.reward.retry import VerifierError, VerifierTimeout

_RUNNER = r"""
import json, sys
payload = json.loads(sys.stdin.read())
ns = {}
exec(compile(payload["program"], "<sandboxed-verifier>", "exec"), ns)
fn = ns.get("score")
if fn is None:
    raise SystemExit("sandboxed program defines no score()")
out = fn(payload["prompt_ids"], payload["response_ids"])
print(json.dumps({"score": float(out)}))
"""


def _make_preexec(cpu_seconds: Optional[int], memory_bytes: Optional[int]):
    """Child-side pre-exec hook: new session + rlimits (best effort)."""

    def preexec() -> None:
        os.setsid()
        try:
            import resource

            if cpu_seconds is not None:
                resource.setrlimit(
                    resource.RLIMIT_CPU, (cpu_seconds, cpu_seconds + 1)
                )
            if memory_bytes is not None:
                resource.setrlimit(
                    resource.RLIMIT_AS, (memory_bytes, memory_bytes)
                )
            resource.setrlimit(resource.RLIMIT_CORE, (0, 0))
        except Exception:
            pass  # platform without resource limits: wall timeout still holds

    return preexec


class SandboxVerifier:
    """Resource/time-limited subprocess verifier with kill-on-timeout."""

    def __init__(
        self,
        program: str,
        *,
        timeout_s: float = 5.0,
        cpu_seconds: Optional[int] = 5,
        memory_bytes: Optional[int] = 512 * 1024 * 1024,
        python: str = sys.executable,
        name: str = "sandbox",
        clock: Callable[[], float] = time.perf_counter,
    ):
        self.program = program
        self.timeout_s = timeout_s
        self.cpu_seconds = cpu_seconds
        self.memory_bytes = memory_bytes
        self.python = python
        self.name = name
        self._clock = clock
        self._lock = make_lock("sandbox")
        # telemetry
        self.calls = 0
        self.kills = 0           # wall-timeout SIGKILLs
        self.failures = 0        # nonzero exit / bad output / rlimit death
        self.exec_time = 0.0

    @classmethod
    def from_spec(cls, spec: str, **kw) -> "SandboxVerifier":
        """Build from a CLI spec: ``@path/to/program.py`` or inline source."""
        if spec.startswith("@"):
            with open(spec[1:], "r", encoding="utf-8") as f:
                return cls(f.read(), **kw)
        return cls(spec, **kw)

    def score(self, prompt_ids: List[int], response_ids: List[int],
              task: str = "") -> float:
        with self._lock:
            self.calls += 1
        payload = json.dumps({
            "program": self.program,
            "prompt_ids": list(prompt_ids),
            "response_ids": list(response_ids),
            "task": task,
        })
        t0 = self._clock()
        proc = subprocess.Popen(
            [self.python, "-I", "-c", _RUNNER],
            stdin=subprocess.PIPE,
            stdout=subprocess.PIPE,
            stderr=subprocess.PIPE,
            env={"PATH": os.environ.get("PATH", "/usr/bin:/bin")},
            preexec_fn=_make_preexec(self.cpu_seconds, self.memory_bytes),
            text=True,
        )
        try:
            out, err = proc.communicate(payload, timeout=self.timeout_s)
        except subprocess.TimeoutExpired:
            self._kill(proc)
            with self._lock:
                self.kills += 1
                self.failures += 1
                self.exec_time += self._clock() - t0
            raise VerifierTimeout(
                f"sandboxed verifier exceeded {self.timeout_s}s wall "
                f"clock; process group killed"
            )
        with self._lock:
            self.exec_time += self._clock() - t0
        if proc.returncode != 0:
            with self._lock:
                self.failures += 1
            raise VerifierError(
                f"sandboxed verifier exited {proc.returncode}: "
                f"{(err or '').strip()[-200:]!r}"
            )
        # the score is the last stdout line; anything before is program noise
        lines = [ln for ln in (out or "").splitlines() if ln.strip()]
        try:
            return float(json.loads(lines[-1])["score"])
        except Exception as exc:
            with self._lock:
                self.failures += 1
            raise VerifierError(
                f"sandboxed verifier produced no score line: "
                f"{(out or '').strip()[-200:]!r}"
            ) from exc

    def score_trajectory(self, traj) -> float:
        return self.score(
            list(traj.prompt), list(traj.response),
            task=getattr(traj, "task", ""),
        )

    def _kill(self, proc: subprocess.Popen) -> None:
        """SIGKILL the whole process group, then reap."""
        try:
            os.killpg(proc.pid, signal.SIGKILL)
        except Exception:
            try:
                proc.kill()
            except Exception:
                pass
        try:
            proc.communicate(timeout=5.0)
        except Exception:
            pass

    def stats(self) -> dict:
        with self._lock:
            return {
                "calls": self.calls,
                "kills": self.kills,
                "failures": self.failures,
                "exec_time_s": self.exec_time,
            }
