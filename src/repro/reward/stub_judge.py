"""Hermetic stub judge: a stdlib HTTP server speaking the remote-judge
protocol on the loopback interface.

This is the other end of :class:`repro.reward.http_verifier.HttpVerifier`
for tests, benchmarks, the demo, and the ``reward-hub`` CI job — all of
which must run with **no external network access**. It binds
``127.0.0.1`` on an ephemeral port (never an external interface), serves
from a daemon thread, and is fully scriptable:

* ``score_fn(prompt_ids, response_ids, task)`` computes the verdict
  (default: constant 1.0);
* ``pending_polls=N`` makes each job answer ``pending`` N times before
  ``done`` — exercises the poll loop and end-to-end deadline;
* ``fail_first=N`` makes the first N submit requests return HTTP 500 —
  exercises timeout→retry→success and breaker trips;
* ``inline=True`` returns ``{"score": ...}`` straight from submit —
  exercises the synchronous-judge path.

Counters (``submits``, ``polls``, ``errors_served``) let tests assert the
client actually retried/polled rather than silently short-circuiting.
"""
from __future__ import annotations

import json
import threading
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Callable, List, Optional
from repro.analysis.witness import make_lock


class StubJudge:
    """Scriptable submit-then-poll judge on ``127.0.0.1:<ephemeral>``."""

    def __init__(
        self,
        score_fn: Optional[
            Callable[[List[int], List[int], str], float]
        ] = None,
        *,
        pending_polls: int = 0,
        fail_first: int = 0,
        inline: bool = False,
    ):
        self.score_fn = score_fn or (lambda p, r, task: 1.0)
        self.pending_polls = pending_polls
        self.inline = inline
        self._lock = make_lock("judge")
        self._fail_remaining = fail_first
        self._jobs: dict = {}       # job_id -> {"score": s, "polls": n}
        self._next_job = 0
        # telemetry
        self.submits = 0
        self.polls = 0
        self.errors_served = 0

        judge = self

        class Handler(BaseHTTPRequestHandler):
            def log_message(self, fmt, *args):  # silence request log
                pass

            def _reply(self, code: int, body: dict) -> None:
                data = json.dumps(body).encode("utf-8")
                self.send_response(code)
                self.send_header("Content-Type", "application/json")
                self.send_header("Content-Length", str(len(data)))
                self.end_headers()
                self.wfile.write(data)

            def do_POST(self):
                if self.path != "/submit":
                    self._reply(404, {"error": "not found"})
                    return
                n = int(self.headers.get("Content-Length", 0))
                payload = json.loads(self.rfile.read(n) or b"{}")
                with judge._lock:
                    judge.submits += 1
                    if judge._fail_remaining > 0:
                        judge._fail_remaining -= 1
                        judge.errors_served += 1
                        self._reply(500, {"error": "injected submit failure"})
                        return
                score = float(judge.score_fn(
                    payload.get("prompt_ids", []),
                    payload.get("response_ids", []),
                    payload.get("task", ""),
                ))
                if judge.inline:
                    self._reply(200, {"score": score})
                    return
                with judge._lock:
                    job_id = f"job-{judge._next_job}"
                    judge._next_job += 1
                    judge._jobs[job_id] = {"score": score, "polls": 0}
                self._reply(200, {"job_id": job_id})

            def do_GET(self):
                if not self.path.startswith("/result/"):
                    self._reply(404, {"error": "not found"})
                    return
                job_id = self.path[len("/result/"):]
                with judge._lock:
                    judge.polls += 1
                    job = judge._jobs.get(job_id)
                    if job is None:
                        self._reply(404, {"error": f"unknown job {job_id}"})
                        return
                    job["polls"] += 1
                    if job["polls"] <= judge.pending_polls:
                        self._reply(200, {"status": "pending"})
                        return
                    self._reply(
                        200, {"status": "done", "score": job["score"]}
                    )

        # loopback only: hermetic by construction, no external egress
        self._server = ThreadingHTTPServer(("127.0.0.1", 0), Handler)
        self._server.daemon_threads = True
        self._thread: Optional[threading.Thread] = None

    # ------------------------------------------------------------ lifecycle
    @property
    def url(self) -> str:
        host, port = self._server.server_address[:2]
        return f"http://{host}:{port}"

    def start(self) -> "StubJudge":
        self._thread = threading.Thread(
            target=self._server.serve_forever, name="stub-judge", daemon=True
        )
        self._thread.start()
        return self

    def stop(self) -> None:
        self._server.shutdown()
        self._server.server_close()
        if self._thread is not None:
            self._thread.join(timeout=5.0)
            self._thread = None

    def __enter__(self) -> "StubJudge":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    def stats(self) -> dict:
        with self._lock:
            return {
                "submits": self.submits,
                "polls": self.polls,
                "errors_served": self.errors_served,
                "jobs": len(self._jobs),
            }
