"""Rule-based verifiable reward (paper's reward phase, §2.1).

CPU-only, stateless: score = 1.0 iff the decoded response begins with the
exact expected answer (everything after '=' up to EOS). Mirrors the
verifiable-reward setting (DAPO-Math / AIME) at toy scale.

This module is the *verifier*; the reward **service** is
``repro.core.reward_server.RewardServer``, which wraps any object exposing
``score(prompt_ids, response_ids) -> float`` with a bounded queue + worker
pool on the trajectory-lifecycle bus (plus optional simulated verification
latency, so the overlap behavior of the disaggregated architecture is
observable in benchmarks). ``RewardModel`` below satisfies that protocol.
"""
from __future__ import annotations

from typing import List

from repro.data import tokenizer as tok


def verify_arithmetic(response_ids: List[int], answer: str) -> float:
    text = tok.decode(response_ids)
    text = text.strip()
    if not text:
        return 0.0
    # accept the exact answer, optionally followed by whitespace/EOS garbage
    candidate = text.split()[0] if text.split() else ""
    return 1.0 if candidate == answer else 0.0


class RewardModel:
    """Pluggable scorer: rule-based by default; subclass for other tasks."""

    def __init__(self, answer_lookup):
        self._lookup = answer_lookup  # prompt_ids -> answer string

    def score(self, prompt_ids: List[int], response_ids: List[int]) -> float:
        answer = self._lookup(prompt_ids)
        if answer is None:
            return 0.0
        return verify_arithmetic(response_ids, answer)
