"""Advantage estimation for group-sampled RL (GRPO / DAPO, §2.1).

Group-relative advantages: for a group G of responses to one prompt,
``A_i = (r_i - mean(r_G)) / (std(r_G) + eps)`` (GRPO). DAPO additionally
*filters* zero-signal groups (all rewards identical -> no gradient), which
is exactly the proactive-filtering hook of the staleness protocol (§4.3
Fig. 8c): the runtime aborts such groups instead of training on them.
"""
from __future__ import annotations

from typing import List, Sequence

import numpy as np


def group_advantages(
    rewards: Sequence[float], group_ids: Sequence[int], *, eps: float = 1e-6,
    normalize_std: bool = True,
) -> np.ndarray:
    r = np.asarray(rewards, dtype=np.float64)
    g = np.asarray(group_ids)
    adv = np.zeros_like(r)
    for gid in np.unique(g):
        m = g == gid
        mean = r[m].mean()
        std = r[m].std() if normalize_std else 1.0
        adv[m] = (r[m] - mean) / (std + eps)
    return adv.astype(np.float32)


def zero_signal_groups(
    rewards: Sequence[float], group_ids: Sequence[int]
) -> List[int]:
    """Groups whose rewards are all identical (DAPO filtering candidates)."""
    r = np.asarray(rewards, dtype=np.float64)
    g = np.asarray(group_ids)
    out = []
    for gid in np.unique(g):
        m = g == gid
        if np.ptp(r[m]) == 0.0:
            out.append(int(gid))
    return out
