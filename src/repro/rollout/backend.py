"""Engine backend contract: one instance interface for runtime + simulator.

The coordinator (``repro.core.coordinator``) is pure control plane — it
emits ``Route / Interrupt / Abort / Pull`` commands against instance
*snapshots* and never touches an engine. This module pins down the data
plane those commands land on:

``EngineBackend``
    The protocol every rollout instance implements:
    ``route / interrupt / abort / pull / step / snapshot``.  Three
    implementations ship:

    * ``repro.rollout.engine.RolloutInstance`` — the real JAX engine
      (slot-based continuous batching, batched prefill + compacted decode
      via ``repro.rollout.runners``);
    * ``repro.rollout.sharded.ShardedBackend`` — the same engine spanning
      a pod: params and the paged KV pool head-sharded over a
      ``("tensor",)`` mesh, per-device memory accounting
      (``shard_count``), bit-for-bit equal to the single-device engine;
    * ``SimBackend`` (here) — the cost-model-driven replica the
      discrete-event simulator and the baselines run on.  Token payloads
      are tracked as counts (``Trajectory.sim_generated``); timing follows
      the paper's Eq. 2 cost model.

    Real backends ignore the simulated-clock arguments (``now``/``dt``);
    simulated backends ignore the parameter payload of ``pull``.  That
    asymmetry is exactly what lets one coordinator drive a *mixed* cluster
    of real and simulated instances (``examples/mixed_cluster.py``).

``execute_commands``
    The single, backend-agnostic command executor.  The live runtime, the
    simulator, and the mixed example all route coordinator output through
    it, so command semantics (TS take/put_back/drop, PS pull) cannot drift
    between deployments.

``create_backend``
    Factory/registry keyed by backend name (``"jax"`` / ``"sim"``); the JAX
    engine is imported lazily so simulator-only workloads never pay the JAX
    import.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import (
    Any,
    Dict,
    List,
    Optional,
    Protocol,
    Sequence,
    Tuple,
    runtime_checkable,
)

from repro.core.commands import Abort, Command, Interrupt, Pull, Route
from repro.core.cost_model import CostModel
from repro.core.snapshot import InstanceSnapshot
from repro.core.types import Trajectory, TrajStatus
from repro.rollout.prefix_cache import PrefixRegistry, shareable_run


@runtime_checkable
class ParamSource(Protocol):
    """Where a backend pulls parameters from (the PS, or a version stub)."""

    @property
    def version(self) -> int: ...

    def pull(self) -> Tuple[Any, int]: ...


class VersionSource:
    """Parameter-less ``ParamSource`` for simulated backends: tracks only
    the published model version (the simulator's ``ps_version``)."""

    def __init__(self, version: int = 0):
        self.version = version

    def pull(self) -> Tuple[Any, int]:
        return None, self.version


@runtime_checkable
class EngineBackend(Protocol):
    """One rollout instance, as seen by the coordinator's command stream.

    Contract (conformance-tested in ``tests/test_backend.py``):

    * ``route(traj, now)``     — enqueue; admit when slots/KV allow. Sets
      ``traj.instance`` to this instance's id.
    * ``route_many(trajs, now)`` — enqueue a whole wave, then admit once:
      the real engine prefills every admissible trajectory in one batched
      forward per length bucket (``execute_commands`` coalesces each command
      batch's Routes per instance into one wave).
    * ``interrupt(ids, now)``  — remove matching resident trajectories and
      return them with ``status=INTERRUPTED`` and ``instance=None``; the
      payload travels on the Trajectory object (migration is metadata-only).
    * ``abort(ids, now)``      — like interrupt but ``status=ABORTED``.
    * ``pull(params, version, now)`` — adopt a new parameter version and
      clear ``complete_trajs`` accounting. Simulated backends ignore
      ``params``.
    * ``step(now, dt)``        — advance generation; returns trajectories
      completed during the step. Real backends perform one decode step and
      ignore the clock; simulated backends integrate ``dt`` sim-seconds.
    * ``snapshot()``           — the paper's five-field instance snapshot.
    """

    inst_id: int
    inst_version: int

    def route(self, traj: Trajectory, now: float = 0.0) -> None: ...

    def route_many(
        self, trajs: Sequence[Trajectory], now: float = 0.0
    ) -> None: ...

    def interrupt(
        self, traj_ids: Sequence[int], now: float = 0.0
    ) -> List[Trajectory]: ...

    def abort(
        self, traj_ids: Sequence[int], now: float = 0.0
    ) -> List[Trajectory]: ...

    def pull(self, params: Any, version: int, now: float = 0.0) -> None: ...

    def step(self, now: float = 0.0, dt: float = 0.0) -> List[Trajectory]: ...

    def snapshot(self) -> InstanceSnapshot: ...


# ============================================================== sim backend
class SimBackend:
    """Cost-model-driven rollout replica (the simulator's data plane).

    Decode progress follows ``CostModel.step_latency`` (paper Eq. 2);
    admission respects the KV budget; routing/migration re-prefill stalls
    the instance for ``length / prefill_tps`` and Pull for ``pull_time``.
    """

    def __init__(
        self,
        inst_id: int,
        cost_model: CostModel,
        version: int = 0,
        *,
        prefill_tps: float = 50000.0,
        pull_time: float = 0.0,
        admission_headroom_tokens: int = 64,
        share_prefix: bool = True,
        lazy_cow: bool = True,
    ):
        self.inst_id = inst_id
        self.cm = cost_model
        self.inst_version = version
        self._prefill_tps = prefill_tps
        self.pull_time = pull_time
        # decode-growth tokens charged on top of a trajectory's current
        # length at admission (see RolloutInstance.admission_headroom_tokens;
        # the sim's coarser dt steps warrant a larger default)
        self.admission_headroom_tokens = admission_headroom_tokens
        # prefix sharing mirrors the paged engine's group admission: a run
        # of same-group, same-prompt, nothing-generated members at the
        # waiting-queue head admits as one unit — one prefill stall, shared
        # prompt blocks charged once. Inert at block_size 1 (dense model).
        self.share_prefix = bool(share_prefix and cost_model.block_size > 1)
        # lazy CoW mirror: a group's partial-tail block is charged once
        # until members diverge (first decode progress), matching the
        # engine's copy-at-first-divergence pool accounting
        self.lazy_cow = bool(lazy_cow and self.share_prefix)
        self.running: Dict[int, Trajectory] = {}
        self.progress: Dict[int, float] = {}   # fractional generated tokens
        self.waiting: List[Trajectory] = []
        self.stall_until = 0.0
        self.complete_since_sync: set = set()
        self.decode_tokens = 0.0
        self.prefill_tokens = 0.0
        self.preemptions = 0                   # sim pools never preempt
        self.shared_prefix_hits = 0
        self.block_copies = 0                  # mirrored CoW tail copies
        # observability hook (same protocol as RolloutInstance.on_admit)
        self.on_admit = None
        # shared-prefix registry — the same class the engine maintains, so
        # both admission pictures and snapshot exports come from one
        # implementation and cannot drift
        self._prefix = PrefixRegistry()

    # ------------------------------------------------------------- geometry
    @property
    def version(self) -> int:  # legacy alias
        return self.inst_version

    def kv_bytes(self) -> float:
        """Per-device KV bytes in use, at the cost model's allocation
        granularity (block-rounded when ``cm.block_size`` > 1 — the same
        accounting the paged RolloutInstance reports, so mixed real/sim
        clusters give the coordinator one consistent memory picture; at
        ``cm.shard_count`` > 1 the same per-device basis the sharded
        backend reports). Shared prefix blocks are charged once per
        group, like the engine's refcounted pool."""
        bs = self.cm.block_size
        total = self.cm.token_bytes(float(self._prefix.shared_token_total()))
        tails = self._prefix.export_tails() if self.lazy_cow else {}
        # each prefix with undiverged members holds ONE shared tail block
        total += self.cm.token_bytes(float(bs * len(tails)))
        for t in self.running.values():
            pk = self._prefix.lookup(t.traj_id)
            if pk is None:
                total += self.cm.kv_bytes_for(t.length)
            else:
                n_full = self._prefix.tokens(pk) // bs
                excl = max(0, -(-t.length // bs) - n_full)
                if t.traj_id in tails.get(pk, ()):
                    # undiverged: the tail block is the shared one above
                    excl = max(0, excl - 1)
                total += self.cm.token_bytes(bs * excl)
        return total

    def n_active(self) -> int:
        return len(self.running)

    def _share_run(self) -> int:
        """Shareable same-group run length at the queue head — the same
        scan the engine runs (``prefix_cache.shareable_run``)."""
        if not self.share_prefix:
            return 1
        return shareable_run(self.waiting)

    def _admit_one(self, traj: Trajectory, now: float, prefill: int) -> None:
        self.running[traj.traj_id] = traj
        self.progress[traj.traj_id] = float(traj.sim_generated)
        if prefill:
            self.stall_until = (
                max(self.stall_until, now) + prefill / self._prefill_tps
            )
            self.prefill_tokens += prefill
        if self.on_admit is not None:
            self.on_admit(self.inst_id, [traj.traj_id])

    def _admit(self, now: float) -> None:
        while self.waiting:
            g = self._share_run()
            if g >= 2:
                head = self.waiting[0]
                plen = len(head.prompt)
                pad = plen + self.admission_headroom_tokens
                while g >= 2:
                    charge = self.cm.group_kv_bytes_for(plen, [pad] * g)
                    if self.kv_bytes() + charge <= self.cm.kv_budget:
                        break
                    g -= 1
                if g >= 2:
                    members = [self.waiting.pop(0) for _ in range(g)]
                    bs = self.cm.block_size
                    n_full, tail = divmod(plen, bs)
                    lazy_tail = self.lazy_cow and tail > 0
                    if n_full or lazy_tail:
                        ids = [m.traj_id for m in members]
                        self._prefix.register(
                            head.group_id, ids, n_full * bs, head.prompt,
                            tail_members=ids if lazy_tail else (),
                        )
                    # one shared prompt prefill for the whole group
                    self._admit_one(members[0], now, prefill=plen)
                    for m in members[1:]:
                        self._admit_one(m, now, prefill=0)
                    self.shared_prefix_hits += g - 1
                    continue
            nxt = self.waiting[0]
            # cross-wave join: a straggler member of a still-resident
            # prefix is charged only its exclusive blocks (the engine
            # forks the sibling prefix the same way)
            fork_pk = None
            if (
                self.share_prefix
                and nxt.group_id >= 0
                and not nxt.sim_generated
            ):
                h, tp = nxt.prompt_key()
                fork_pk = self._prefix.find(
                    nxt.group_id, tp, prompt_hash=h
                )
                if (
                    fork_pk is not None
                    and self._prefix.tokens(fork_pk) == 0
                ):
                    fork_pk = None  # tail-only registration: no prefix
            charge = self.cm.kv_bytes_for(
                nxt.length + self.admission_headroom_tokens
            )
            if fork_pk is not None:
                charge = max(
                    0.0,
                    charge - self.cm.token_bytes(self._prefix.tokens(fork_pk)),
                )
            if self.kv_bytes() + charge > self.cm.kv_budget:
                return
            self.waiting.pop(0)
            if fork_pk is not None:
                self._prefix.join(fork_pk, nxt.traj_id)
                self.shared_prefix_hits += 1
            # re-prefill stall (prompt + already-generated tokens)
            self._admit_one(nxt, now, prefill=nxt.length)

    # ------------------------------------------------------------- commands
    def route(self, traj: Trajectory, now: float = 0.0) -> None:
        traj.instance = self.inst_id
        traj.status = TrajStatus.RUNNING
        self.waiting.append(traj)
        self._admit(now)

    def route_many(
        self, trajs: Sequence[Trajectory], now: float = 0.0
    ) -> None:
        for traj in trajs:
            traj.instance = self.inst_id
            traj.status = TrajStatus.RUNNING
            self.waiting.append(traj)
        self._admit(now)

    def _remove(self, traj_ids: Sequence[int], now: float) -> List[Trajectory]:
        out = []
        for tid in list(traj_ids):
            if tid in self.running:
                t = self.running.pop(tid)
                t.sim_generated = int(self.progress.pop(tid))
                self._prefix.drop(tid)
                out.append(t)
            else:
                for i, t in enumerate(self.waiting):
                    if t.traj_id == tid:
                        out.append(self.waiting.pop(i))
                        break
        self._admit(now)
        return out

    def interrupt(
        self, traj_ids: Sequence[int], now: float = 0.0
    ) -> List[Trajectory]:
        out = self._remove(traj_ids, now)
        for t in out:
            t.status = TrajStatus.INTERRUPTED
            t.instance = None
        return out

    def abort(self, traj_ids: Sequence[int], now: float = 0.0) -> List[Trajectory]:
        out = self._remove(traj_ids, now)
        for t in out:
            t.status = TrajStatus.ABORTED
            t.instance = None
        return out

    def pull(self, params: Any, version: int, now: float = 0.0) -> None:
        del params  # simulated replicas carry no real weights
        self.inst_version = version
        self.complete_since_sync.clear()
        self.stall_until = max(self.stall_until, now) + self.pull_time

    # ----------------------------------------------------------------- step
    def step(self, now: float = 0.0, dt: float = 0.0) -> List[Trajectory]:
        """Generate tokens for ``dt`` sim-seconds; return completed trajs."""
        if not self.running:
            return []
        t0 = max(now, self.stall_until)
        avail = now + dt - t0
        if avail <= 0:
            return []
        lat = self.cm.step_latency(self.kv_bytes(), len(self.running))
        steps = avail / lat
        if self.lazy_cow:
            # divergence mirror: every running member writes its first
            # decode token this step, copying the shared tail into a
            # private block (the last undiverged owner writes in place)
            for tid in self.running:
                if self._prefix.in_shared_tail(tid):
                    pk = self._prefix.lookup(tid)
                    if pk is not None and self._prefix.undiverged(pk) > 1:
                        self.block_copies += 1
                    self._prefix.mark_diverged(tid)
        done = []
        for tid, traj in list(self.running.items()):
            self.progress[tid] += steps
            self.decode_tokens += steps
            traj.sim_generated = int(self.progress[tid])
            if self.progress[tid] >= traj.sim_target_len:
                traj.sim_generated = traj.sim_target_len
                traj.finished = True
                traj.status = TrajStatus.GENERATED
                del self.running[tid]
                del self.progress[tid]
                self._prefix.drop(tid)
                self.complete_since_sync.add(tid)
                done.append(traj)
        if done:
            self._admit(now + dt)
        return done

    # ------------------------------------------------------------- snapshot
    def snapshot(self) -> InstanceSnapshot:
        lengths = {t.traj_id: t.length for t in self.running.values()}
        lengths.update({t.traj_id: t.length for t in self.waiting})
        prefix_groups, prefix_tokens = self._prefix.export()
        return InstanceSnapshot(
            inst_id=self.inst_id,
            kv_cache=self.kv_bytes(),
            run_trajs=set(self.running),
            wait_trajs={t.traj_id for t in self.waiting},
            complete_trajs=set(self.complete_since_sync),
            inst_version=self.inst_version,
            traj_lengths=lengths,
            preemptions=0,  # sim pools admit by budget, never preempt
            prefix_groups=prefix_groups,
            prefix_tokens=prefix_tokens,
            prefix_tail_members=self._prefix.export_tails(),
            shard_count=self.cm.shard_count,
        )


# ================================================================= executor
@dataclass
class ExecResult:
    """What a command batch did — shared telemetry for runtime and sim."""

    routed: int = 0
    interrupted: int = 0
    aborted: int = 0
    pulls: List[Tuple[int, int]] = field(default_factory=list)  # (inst, version)
    returned: List[int] = field(default_factory=list)           # put_back ids
    # Routes whose trajectory left the routable pool between issuance and
    # execution (possible only under concurrent schedulers); the caller
    # must rebalance the speculative state for these (inst, traj_id) pairs
    skipped_routes: List[Tuple[int, int]] = field(default_factory=list)
    # Interrupt/Abort targets the engine no longer held at execution time
    # (completed or already removed since the snapshot — possible only
    # under relaxed/streaming snapshot collection). The command had no
    # data-plane effect, so the caller must undo its speculative decrement
    # unless a later Pull in the same batch re-zeroed the expectation.
    missed_removals: List[Tuple[int, int]] = field(default_factory=list)


def execute_commands(
    commands: Sequence[Command],
    instances: Dict[int, EngineBackend],
    ts,                                   # TrajectoryServer
    param_source: ParamSource,
    *,
    now: float = 0.0,
    timers: Optional[Dict[str, float]] = None,
    lifecycle=None,                       # TrajectoryLifecycle (optional)
) -> ExecResult:
    """Apply coordinator commands to any mix of engine backends.

    Missing instances (failed since command issuance) are skipped, matching
    the live runtime's fault-tolerance semantics.

    Consecutive Route commands are coalesced per instance and applied as
    one ``route_many`` wave, letting the real engine admit every routed
    trajectory in one batched prefill per length bucket. Pending waves are
    flushed before any non-Route command executes, so semantics match the
    strictly in-order executor for *arbitrary* command sequences — with
    the coordinator's ordering (Alg. 1 emits Routes last within a cycle)
    the whole cycle still lands as one wave per instance.

    With a ``lifecycle`` bus, command execution *publishes* the trajectory
    transitions (``ROUTED`` / ``INTERRUPTED`` / ``ABORTED``, with ``inst``
    set to mark the data plane as already handled) and the TS applies its
    side as a subscriber; without one, the executor calls the TS directly
    (legacy standalone mode).
    """
    res = ExecResult()

    def _timed(name: str, t0: float) -> None:
        if timers is not None:
            timers[name] = timers.get(name, 0.0) + time.perf_counter() - t0

    route_waves: Dict[int, List[Trajectory]] = {}

    def _flush_waves() -> None:
        for inst_id, wave in route_waves.items():
            t0 = time.perf_counter()
            # publish ROUTED before the data-plane route: ``route_many``
            # may admit synchronously, and admission-time observers (the
            # tracer's on_admit hook) need the span opened first
            if lifecycle is not None:
                for traj in wave:
                    lifecycle.routed(traj, inst_id, traj.v_traj)
            instances[inst_id].route_many(wave, now)
            _timed("route", t0)
        route_waves.clear()

    for cmd in commands:
        inst = instances.get(cmd.inst)
        if inst is None:
            continue  # instance failed since issuance
        if isinstance(cmd, Route):
            t0 = time.perf_counter()
            for tid in cmd.traj_ids:
                if lifecycle is not None:
                    traj = ts.try_take(tid)
                    if traj is None:
                        res.skipped_routes.append((cmd.inst, tid))
                        continue
                else:
                    traj = ts.take(tid)
                if traj.v_traj is None:
                    traj.v_traj = cmd.v_traj
                route_waves.setdefault(cmd.inst, []).append(traj)
                res.routed += 1
            _timed("route", t0)
            continue
        _flush_waves()
        if isinstance(cmd, Interrupt):
            t0 = time.perf_counter()
            removed = set()
            for traj in inst.interrupt(cmd.traj_ids, now):
                removed.add(traj.traj_id)
                if lifecycle is not None:
                    lifecycle.interrupted(traj, cmd.inst)
                else:
                    ts.put_back(traj.traj_id)
                res.returned.append(traj.traj_id)
            res.interrupted += len(cmd.traj_ids)
            res.missed_removals.extend(
                (cmd.inst, tid) for tid in cmd.traj_ids if tid not in removed
            )
            _timed("interrupt", t0)
        elif isinstance(cmd, Abort):
            removed = {t.traj_id for t in inst.abort(cmd.traj_ids, now)}
            for tid in cmd.traj_ids:
                if lifecycle is not None:
                    lifecycle.aborted(tid, inst=cmd.inst)
                else:
                    ts.drop(tid)
            res.aborted += len(cmd.traj_ids)
            res.missed_removals.extend(
                (cmd.inst, tid) for tid in cmd.traj_ids if tid not in removed
            )
        elif isinstance(cmd, Pull):
            t0 = time.perf_counter()
            params, version = param_source.pull()
            inst.pull(params, version, now)
            res.pulls.append((cmd.inst, version))
            _timed("pull", t0)

    _flush_waves()
    return res


# ================================================================== factory
def _make_sim_backend(inst_id: int, **kw) -> SimBackend:
    return SimBackend(inst_id, **kw)


def _make_jax_backend(inst_id: int, **kw) -> "EngineBackend":
    from repro.rollout.engine import RolloutInstance  # lazy: needs jax

    return RolloutInstance(inst_id, **kw)


def _make_sharded_backend(inst_id: int, **kw) -> "EngineBackend":
    from repro.rollout.sharded import ShardedBackend  # lazy: needs jax

    return ShardedBackend(inst_id, **kw)


BACKENDS = {
    "sim": _make_sim_backend,
    "jax": _make_jax_backend,
    "sharded": _make_sharded_backend,
}


def create_backend(kind: str, inst_id: int, **kw) -> EngineBackend:
    """Construct a rollout instance by backend name
    (``"jax"`` / ``"sim"`` / ``"sharded"``).

    Keyword arguments are backend-specific: the JAX engine takes
    ``cfg/params/version/max_slots/...`` (see ``RolloutInstance``), the
    sharded engine additionally ``shard_count``/``mesh``
    (see ``ShardedBackend``), the sim backend
    ``cost_model/version/prefill_tps/pull_time``.
    """
    try:
        factory = BACKENDS[kind]
    except KeyError:
        raise ValueError(
            f"unknown backend {kind!r}; available: {sorted(BACKENDS)}"
        ) from None
    return factory(inst_id, **kw)
