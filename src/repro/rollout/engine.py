"""Rollout instance: a model replica with slot-based continuous batching.

This is the JAX stand-in for a vLLM instance in the paper's rollout service
(DESIGN.md hardware-adaptation table). One instance owns:

* a parameter snapshot + its model version (``inst_version``),
* a decode cache with ``max_slots`` rows (continuous batching: trajectories
  occupy slots; finished/interrupted slots are reused),
* an engine-internal waiting queue (the paper's ``wait_trajs``, Fig. 11) —
  trajectories routed to the instance but not yet admitted to a slot
  (KV budget or slot exhaustion), and
* a jit'd single-row prefill + batched decode step.

Command execution (the data-plane side of §5.1):
* ``route``     — enqueue; admit into a free slot if the KV budget allows
                  (re-)prefilling prompt+partial response (partial rollout
                  re-prefill, Fig. 5a).
* ``interrupt`` — release slots/queue entries; the Trajectory object already
                  carries its generated tokens + behavior logprobs, so
                  returning it to the TS is metadata-only.
* ``abort``     — like interrupt, but the trajectory is discarded upstream.
* ``pull``      — swap parameters/version. The coordinator interrupts
                  residents first (Alg. 1), so slots are empty by contract.

Behavior logprobs: every sampled token's logprob under the *generating*
version is recorded on the trajectory — this is the importance-sampling
denominator for staleness correction (``repro.rl.losses``) and survives
interrupts/migrations untouched.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.core.snapshot import InstanceSnapshot
from repro.core.types import Trajectory, TrajStatus
from repro.data.tokenizer import EOS
from repro.models import model as M
from repro.rollout.sampler import sample


def _round_up(n: int, mult: int) -> int:
    return ((n + mult - 1) // mult) * mult


class RolloutInstance:
    def __init__(
        self,
        inst_id: int,
        cfg: ArchConfig,
        params: Any,
        version: int,
        *,
        max_slots: int = 4,
        max_len: int = 256,
        kv_bytes_per_token: float = 0.0,
        kv_budget: float = float("inf"),
        temperature: float = 1.0,
        eos_id: int = EOS,
        seed: int = 0,
        prefill_bucket: int = 16,
        frontend_fn: Optional[Callable[[int], jax.Array]] = None,
    ):
        self.inst_id = inst_id
        self.cfg = cfg
        self.params = params
        self.inst_version = version
        self.max_slots = max_slots
        self.max_len = max_len
        self.k5 = kv_bytes_per_token or (
            2 * cfg.n_layers * cfg.n_kv_heads * cfg.hd * 4
        )
        self.kv_budget = kv_budget
        self.temperature = temperature
        self.eos_id = eos_id
        self.prefill_bucket = prefill_bucket
        self.frontend_fn = frontend_fn
        self._key = jax.random.PRNGKey(seed + 7919 * inst_id)

        self.cache = M.init_cache(cfg, max_slots, max_len)
        self.slots: List[Optional[Trajectory]] = [None] * max_slots
        self.waiting: List[Trajectory] = []
        self.complete_since_sync: set = set()
        self._last_tokens = jnp.zeros((max_slots,), jnp.int32)
        # telemetry
        self.decode_steps = 0
        self.prefill_tokens = 0
        self.decode_tokens = 0

        self._jit_decode = jax.jit(partial(M.decode_step, cfg))
        self._jit_prefill = jax.jit(partial(M.prefill, cfg))
        self._overflow_done: List[Trajectory] = []

    # ------------------------------------------------------------- geometry
    def _slot_len(self, t: Trajectory) -> int:
        return t.length

    def kv_bytes(self) -> float:
        return sum(
            self.k5 * self._slot_len(t) for t in self.slots if t is not None
        )

    def n_active(self) -> int:
        return sum(1 for t in self.slots if t is not None)

    # ------------------------------------------------------------- commands
    def route(self, traj: Trajectory) -> None:
        traj.instance = self.inst_id
        self.waiting.append(traj)
        self._admit()

    def interrupt(self, traj_ids) -> List[Trajectory]:
        ids = set(traj_ids)
        out: List[Trajectory] = []
        for i, t in enumerate(self.slots):
            if t is not None and t.traj_id in ids:
                self.slots[i] = None
                t.status = TrajStatus.INTERRUPTED
                out.append(t)
        keep = []
        for t in self.waiting:
            if t.traj_id in ids:
                t.status = TrajStatus.INTERRUPTED
                out.append(t)
            else:
                keep.append(t)
        self.waiting = keep
        self._admit()
        return out

    def abort(self, traj_ids) -> List[Trajectory]:
        out = self.interrupt(traj_ids)
        for t in out:
            t.status = TrajStatus.ABORTED
        return out

    def pull(self, params: Any, version: int) -> None:
        self.params = params
        self.inst_version = version
        self.complete_since_sync.clear()
        # residents were interrupted by the coordinator beforehand (Alg. 1);
        # anything left is re-prefilled lazily on its next admit
        self._admit()

    # ---------------------------------------------------------------- admit
    def _admit(self) -> None:
        """Move waiting trajectories into free slots within the KV budget."""
        for i in range(self.max_slots):
            if not self.waiting:
                return
            if self.slots[i] is not None:
                continue
            nxt = self.waiting[0]
            need = self.k5 * min(self._slot_len(nxt) + 16, self.max_len)
            if self.kv_bytes() + need > self.kv_budget:
                return
            self.waiting.pop(0)
            self._prefill_slot(i, nxt)

    # batch-axis index per cache entry (single-row scatter targets)
    _BATCH_AXIS = {
        "pos": 0, "k": 1, "v": 1, "conv": 1, "ssm": 1, "xk": 1, "xv": 1,
        "mlstm": 2, "slstm": 1,
    }

    def _scatter_row(self, row_cache: Dict[str, Any], slot: int) -> None:
        """Write a freshly prefilled single-row cache into batch ``slot``."""
        for name, row_val in row_cache.items():
            axis = self._BATCH_AXIS[name]

            def put(full, row):
                idx = (slice(None),) * axis + (slot,)
                ridx = (slice(None),) * axis + (0,)
                return full.at[idx].set(row[ridx])

            self.cache[name] = jax.tree_util.tree_map(
                put, self.cache[name], row_val
            )

    def _prefill_slot(self, slot: int, traj: Trajectory) -> None:
        """(Re-)prefill prompt + already-generated response into ``slot``."""
        tokens = list(traj.prompt) + list(traj.response)
        if len(tokens) >= self.max_len - 1:
            # no room to generate: finish immediately (engine-level cap)
            traj.finished = True
            traj.status = TrajStatus.GENERATED
            self.complete_since_sync.add(traj.traj_id)
            self._overflow_done.append(traj)
            return
        bucket = min(_round_up(len(tokens), self.prefill_bucket), self.max_len)
        padded = tokens + [0] * (bucket - len(tokens))
        row_tokens = jnp.asarray([padded], jnp.int32)
        lengths = jnp.asarray([len(tokens)], jnp.int32)
        fe = self.frontend_fn(1) if self.frontend_fn is not None else None
        row_cache = M.init_cache(self.cfg, 1, self.max_len)
        logits, row_cache = self._jit_prefill(
            self.params, row_tokens, lengths, row_cache, frontend_embeds=fe
        )
        self._scatter_row(row_cache, slot)
        self._key, sub = jax.random.split(self._key)
        tok, blp = sample(logits, sub, temperature=self.temperature)
        self._record_token(traj, int(tok[0]), float(blp[0]))
        self._last_tokens = self._last_tokens.at[slot].set(tok[0])
        self.prefill_tokens += len(tokens)
        traj.status = TrajStatus.RUNNING
        self.slots[slot] = traj

    # ----------------------------------------------------------------- step
    def _record_token(self, traj: Trajectory, token: int, blp: float) -> None:
        traj.response.append(token)
        traj.behavior_logprobs.append(blp)
        traj.record_segment(self.inst_version, 1)
        if token == self.eos_id or traj.n_generated >= traj.max_new_tokens:
            traj.finished = True

    def step(self) -> List[Trajectory]:
        """One batched decode step for all active slots. Returns completed
        trajectories (removed from their slots)."""
        done: List[Trajectory] = []
        if self._overflow_done:
            done.extend(self._overflow_done)
            self._overflow_done.clear()
        active = [i for i, t in enumerate(self.slots) if t is not None]
        if not active:
            return done
        prev_pos = self.cache["pos"]
        logits, new_cache = self._jit_decode(
            self.params, self._last_tokens, self.cache
        )
        # only active slots advance; inactive rows keep their old position
        mask = np.zeros((self.max_slots,), bool)
        mask[active] = True
        mask_j = jnp.asarray(mask)
        new_cache["pos"] = jnp.where(mask_j, new_cache["pos"], prev_pos)
        self.cache = new_cache
        self._key, sub = jax.random.split(self._key)
        tokens, blps = sample(logits, sub, temperature=self.temperature)
        self._last_tokens = jnp.where(mask_j, tokens, self._last_tokens)
        self.decode_steps += 1
        self.decode_tokens += len(active)

        tokens_np = np.asarray(tokens)
        blps_np = np.asarray(blps)
        for i in active:
            traj = self.slots[i]
            self._record_token(traj, int(tokens_np[i]), float(blps_np[i]))
            if traj.finished or int(self.cache["pos"][i]) >= self.max_len - 1:
                traj.finished = True
                traj.status = TrajStatus.GENERATED
                self.complete_since_sync.add(traj.traj_id)
                done.append(traj)
                self.slots[i] = None
        if done:
            self._admit()
        return done

    # ------------------------------------------------------------- snapshot
    def snapshot(self) -> InstanceSnapshot:
        run = {t.traj_id for t in self.slots if t is not None}
        lengths = {
            t.traj_id: self._slot_len(t)
            for t in list(self.slots) + list(self.waiting)
            if t is not None
        }
        return InstanceSnapshot(
            inst_id=self.inst_id,
            kv_cache=self.kv_bytes(),
            run_trajs=run,
            wait_trajs={t.traj_id for t in self.waiting},
            complete_trajs=set(self.complete_since_sync),
            inst_version=self.inst_version,
            traj_lengths=lengths,
        )
