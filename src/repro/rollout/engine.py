"""Rollout instance: a model replica with slot-based continuous batching.

This is the JAX stand-in for a vLLM instance in the paper's rollout service
(DESIGN.md hardware-adaptation table). One instance owns:

* a parameter snapshot + its model version (``inst_version``),
* a decode cache with ``max_slots`` rows (continuous batching: trajectories
  occupy slots; finished/interrupted slots are reused),
* an engine-internal waiting queue (the paper's ``wait_trajs``, Fig. 11) —
  trajectories routed to the instance but not yet admitted to a slot
  (KV budget or slot exhaustion), and
* a prefill/decode runner pair (``repro.rollout.runners``): admission
  prefills **all** eligible waiting trajectories in one padded forward per
  length bucket and scatters the row caches in one fused jitted write;
  decode gathers only the **active** slots into a power-of-two compaction
  bucket instead of always stepping ``max_slots`` rows.

``RolloutInstance`` implements the ``EngineBackend`` protocol
(``repro.rollout.backend``): ``route / interrupt / abort / pull / step /
snapshot``. The simulated-clock arguments of ``step``/``pull`` are accepted
and ignored — a real replica advances one decode step per ``step()`` call.

Command execution (the data-plane side of §5.1):
* ``route``     — enqueue; admit into a free slot if the KV budget allows
                  (re-)prefilling prompt+partial response (partial rollout
                  re-prefill, Fig. 5a).
* ``interrupt`` — release slots/queue entries; the Trajectory object already
                  carries its generated tokens + behavior logprobs, so
                  returning it to the TS is metadata-only.
* ``abort``     — like interrupt, but the trajectory is discarded upstream.
* ``pull``      — swap parameters/version. The coordinator interrupts
                  residents first (Alg. 1), so slots are empty by contract.

Behavior logprobs: every sampled token's logprob under the *generating*
version is recorded on the trajectory — this is the importance-sampling
denominator for staleness correction (``repro.rl.losses``) and survives
interrupts/migrations untouched.

Legacy mode: ``batched_prefill=False`` forces single-row prefill groups and
``compact_decode=False`` forces full-``max_slots`` decode — together they
reproduce the seed engine's execution exactly, which the equivalence tests
(``tests/test_engine_equivalence.py``) compare the batched path against.
"""
from __future__ import annotations

from typing import Any, Callable, List, Optional, Sequence

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.core.snapshot import InstanceSnapshot
from repro.core.types import Trajectory, TrajStatus
from repro.data.tokenizer import EOS
from repro.models import model as M
from repro.rollout.runners import DecodeRunner, PrefillJob, PrefillRunner


class RolloutInstance:
    def __init__(
        self,
        inst_id: int,
        cfg: ArchConfig,
        params: Any,
        version: int,
        *,
        max_slots: int = 4,
        max_len: int = 256,
        kv_bytes_per_token: float = 0.0,
        kv_budget: float = float("inf"),
        temperature: float = 1.0,
        eos_id: int = EOS,
        seed: int = 0,
        prefill_bucket: int = 16,
        frontend_fn: Optional[Callable[[int], jax.Array]] = None,
        batched_prefill: bool = True,
        compact_decode: bool = True,
    ):
        self.inst_id = inst_id
        self.cfg = cfg
        self.params = params
        self.inst_version = version
        self.max_slots = max_slots
        self.max_len = max_len
        self.k5 = kv_bytes_per_token or (
            2 * cfg.n_layers * cfg.n_kv_heads * cfg.hd * 4
        )
        self.kv_budget = kv_budget
        self.temperature = temperature
        self.eos_id = eos_id
        self.compact_decode = compact_decode
        self._key = jax.random.PRNGKey(seed + 7919 * inst_id)

        self.cache = M.init_cache(cfg, max_slots, max_len)
        self.slots: List[Optional[Trajectory]] = [None] * max_slots
        self.waiting: List[Trajectory] = []
        self.complete_since_sync: set = set()
        self._last_tokens = jnp.zeros((max_slots,), jnp.int32)
        # telemetry
        self.decode_steps = 0
        self.prefill_tokens = 0
        self.decode_tokens = 0

        self.prefill_runner = PrefillRunner(
            cfg,
            max_len=max_len,
            prefill_bucket=prefill_bucket,
            batch_limit=0 if batched_prefill else 1,
            temperature=temperature,
            frontend_fn=frontend_fn,
        )
        self.decode_runner = DecodeRunner(
            cfg, max_slots=max_slots, temperature=temperature
        )
        self._overflow_done: List[Trajectory] = []

    # ------------------------------------------------------------- geometry
    def _slot_len(self, t: Trajectory) -> int:
        return t.length

    def kv_bytes(self) -> float:
        return sum(
            self.k5 * self._slot_len(t) for t in self.slots if t is not None
        )

    def n_active(self) -> int:
        return sum(1 for t in self.slots if t is not None)

    # ------------------------------------------------------------- commands
    def route(self, traj: Trajectory, now: float = 0.0) -> None:
        traj.instance = self.inst_id
        self.waiting.append(traj)
        self._admit()

    def route_many(
        self, trajs: Sequence[Trajectory], now: float = 0.0
    ) -> None:
        """Enqueue a wave of trajectories, then admit once — every
        admissible trajectory prefills in one batched forward per bucket."""
        for traj in trajs:
            traj.instance = self.inst_id
            self.waiting.append(traj)
        self._admit()

    def interrupt(
        self, traj_ids: Sequence[int], now: float = 0.0
    ) -> List[Trajectory]:
        ids = set(traj_ids)
        out: List[Trajectory] = []
        for i, t in enumerate(self.slots):
            if t is not None and t.traj_id in ids:
                self.slots[i] = None
                t.status = TrajStatus.INTERRUPTED
                t.instance = None
                out.append(t)
        keep = []
        for t in self.waiting:
            if t.traj_id in ids:
                t.status = TrajStatus.INTERRUPTED
                t.instance = None
                out.append(t)
            else:
                keep.append(t)
        self.waiting = keep
        self._admit()
        return out

    def abort(self, traj_ids: Sequence[int], now: float = 0.0) -> List[Trajectory]:
        out = self.interrupt(traj_ids)
        for t in out:
            t.status = TrajStatus.ABORTED
        return out

    def pull(self, params: Any, version: int, now: float = 0.0) -> None:
        self.params = params
        self.inst_version = version
        self.complete_since_sync.clear()
        # residents were interrupted by the coordinator beforehand (Alg. 1);
        # anything left is re-prefilled lazily on its next admit
        self._admit()

    # ---------------------------------------------------------------- admit
    def _admit(self) -> None:
        """Admit waiting trajectories into free slots within the KV budget —
        all eligible admissions run as ONE batched prefill per length bucket.

        Admission policy matches the seed engine decision-for-decision: the
        waiting queue is FIFO, each admission charges ``k5 * (length + 1)``
        against the budget (the +1 is the token prefill samples), and a
        trajectory too long to generate consumes its candidate slot index
        exactly as the seed's slot-scan did.
        """
        free = [i for i, t in enumerate(self.slots) if t is None]
        jobs: List[PrefillJob] = []
        trajs: List[Trajectory] = []
        planned_bytes = self.kv_bytes()
        while self.waiting and free:
            nxt = self.waiting[0]
            need = self.k5 * min(self._slot_len(nxt) + 16, self.max_len)
            if planned_bytes + need > self.kv_budget:
                break
            self.waiting.pop(0)
            slot = free.pop(0)
            tokens = list(nxt.prompt) + list(nxt.response)
            if len(tokens) >= self.max_len - 1:
                # no room to generate: finish immediately (engine-level cap)
                nxt.finished = True
                nxt.status = TrajStatus.GENERATED
                self.complete_since_sync.add(nxt.traj_id)
                self._overflow_done.append(nxt)
                continue
            self._key, sub = jax.random.split(self._key)
            jobs.append(PrefillJob(slot=slot, tokens=tokens, key=sub))
            trajs.append(nxt)
            planned_bytes += self.k5 * (self._slot_len(nxt) + 1)
        if not jobs:
            return
        # the decode runner may hold active rows compacted out of the batch
        # cache; sync them back before the prefill scatter writes new rows
        self.cache = self.decode_runner.flush(self.cache)
        self.cache, result = self.prefill_runner.run(
            self.params, self.cache, jobs
        )
        self.prefill_tokens += result.prefill_tokens
        last = self._last_tokens
        for job, traj, tok, blp in zip(
            jobs, trajs, result.tokens, result.logprobs
        ):
            self._record_token(traj, tok, blp)
            last = last.at[job.slot].set(tok)
            traj.status = TrajStatus.RUNNING
            self.slots[job.slot] = traj
        self._last_tokens = last

    # ----------------------------------------------------------------- step
    def _record_token(self, traj: Trajectory, token: int, blp: float) -> None:
        traj.response.append(token)
        traj.behavior_logprobs.append(blp)
        traj.record_segment(self.inst_version, 1)
        if token == self.eos_id or traj.n_generated >= traj.max_new_tokens:
            traj.finished = True

    def step(self, now: float = 0.0, dt: float = 0.0) -> List[Trajectory]:
        """One batched decode step over the active slots. Returns completed
        trajectories (removed from their slots)."""
        done: List[Trajectory] = []
        if self._overflow_done:
            done.extend(self._overflow_done)
            self._overflow_done.clear()
        active = [i for i, t in enumerate(self.slots) if t is not None]
        if not active:
            return done
        self._key, sub = jax.random.split(self._key)
        self.cache, self._last_tokens, result = self.decode_runner.run(
            self.params,
            self.cache,
            active,
            self._last_tokens,
            sub,
            compact=self.compact_decode,
        )
        self.decode_steps += 1
        self.decode_tokens += len(active)

        for slot, token, blp, pos in zip(
            result.slots, result.tokens, result.logprobs, result.positions
        ):
            traj = self.slots[slot]
            self._record_token(traj, int(token), float(blp))
            if traj.finished or int(pos) >= self.max_len - 1:
                traj.finished = True
                traj.status = TrajStatus.GENERATED
                self.complete_since_sync.add(traj.traj_id)
                done.append(traj)
                self.slots[slot] = None
        if done:
            self._admit()
        return done

    # ------------------------------------------------------------- snapshot
    def snapshot(self) -> InstanceSnapshot:
        run = {t.traj_id for t in self.slots if t is not None}
        lengths = {
            t.traj_id: self._slot_len(t)
            for t in list(self.slots) + list(self.waiting)
            if t is not None
        }
        return InstanceSnapshot(
            inst_id=self.inst_id,
            kv_cache=self.kv_bytes(),
            run_trajs=run,
            wait_trajs={t.traj_id for t in self.waiting},
            complete_trajs=set(self.complete_since_sync),
            inst_version=self.inst_version,
            traj_lengths=lengths,
        )
