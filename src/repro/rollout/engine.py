"""Rollout instance: a model replica with slot-based continuous batching.

This is the JAX stand-in for a vLLM instance in the paper's rollout service
(DESIGN.md hardware-adaptation table). One instance owns:

* a parameter snapshot + its model version (``inst_version``),
* a decode cache with ``max_slots`` rows (continuous batching: trajectories
  occupy slots; finished/interrupted slots are reused),
* an engine-internal waiting queue (the paper's ``wait_trajs``, Fig. 11) —
  trajectories routed to the instance but not yet admitted to a slot
  (KV budget or slot exhaustion), and
* a prefill/decode runner pair (``repro.rollout.runners``): admission
  prefills **all** eligible waiting trajectories in one padded forward per
  length bucket and scatters the row caches in one fused jitted write;
  decode gathers only the **active** slots into a power-of-two compaction
  bucket instead of always stepping ``max_slots`` rows.

``RolloutInstance`` implements the ``EngineBackend`` protocol
(``repro.rollout.backend``): ``route / interrupt / abort / pull / step /
snapshot``. The simulated-clock arguments of ``step``/``pull`` are accepted
and ignored — a real replica advances one decode step per ``step()`` call.

Command execution (the data-plane side of §5.1):
* ``route``     — enqueue; admit into a free slot if the KV budget allows
                  (re-)prefilling prompt+partial response (partial rollout
                  re-prefill, Fig. 5a).
* ``interrupt`` — release slots/queue entries; the Trajectory object already
                  carries its generated tokens + behavior logprobs, so
                  returning it to the TS is metadata-only.
* ``abort``     — like interrupt, but the trajectory is discarded upstream.
* ``pull``      — swap parameters/version. The coordinator interrupts
                  residents first (Alg. 1), so slots are empty by contract.

Behavior logprobs: every sampled token's logprob under the *generating*
version is recorded on the trajectory — this is the importance-sampling
denominator for staleness correction (``repro.rl.losses``) and survives
interrupts/migrations untouched.

Paged KV mode (``paged=True``): the dense ``(max_slots, max_len)`` cache
rows are replaced by a shared block pool + per-trajectory block tables
(``repro.rollout.kv_allocator``). Admission charges the budget by *actual
allocated blocks* instead of worst-case rows, decode extends tables on the
fly as trajectories cross block boundaries, and block exhaustion preempts
the youngest resident back to the waiting queue (it re-admits via the
normal re-prefill path — the same interrupt semantics the coordinator
uses). Greedy decode is bit-for-bit equal to the dense path
(``tests/test_engine_equivalence.py``).

Prefix sharing (``share_prefix=True``, paged mode): group-sampled
trajectories (GRPO/DAPO) that share one prompt and arrive together admit as
a **group**: the prompt is prefilled once, its full KV blocks are mapped
read-only into every member's block table (refcounted,
``repro.rollout.prefix_cache``), and the partially-filled tail block is
device-copied per member (eager copy-on-write) so decode appends never
alias. Frees and preemption decrement refcounts; ``kv_bytes()`` charges
shared blocks once. Greedy (and same-occupancy stochastic) decode stays
bit-for-bit equal to ``group_size`` independent prefills.

Legacy mode: ``batched_prefill=False`` forces single-row prefill groups and
``compact_decode=False`` forces full-``max_slots`` decode — together they
reproduce the seed engine's execution exactly, which the equivalence tests
(``tests/test_engine_equivalence.py``) compare the batched path against.
"""
from __future__ import annotations

from collections import deque
from typing import Any, Callable, Deque, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.core.snapshot import InstanceSnapshot
from repro.core.types import Trajectory, TrajStatus
from repro.data.tokenizer import EOS
from repro.models import model as M
from repro.rollout.kv_allocator import (
    NULL_BLOCK,
    BlockExhausted,
    blocks_for_tokens,
)
from repro.rollout.prefix_cache import (
    PrefixRegistry,
    RefcountedBlockAllocator,
    shareable_run,
)
from repro.rollout.runners import (
    DecodeRunner,
    PagedDecodeRunner,
    PrefillJob,
    PrefillRunner,
)
from repro.rollout.sampler import stream_key, stream_keys


class RolloutInstance:
    def __init__(
        self,
        inst_id: int,
        cfg: ArchConfig,
        params: Any,
        version: int,
        *,
        max_slots: int = 4,
        max_len: int = 256,
        kv_bytes_per_token: float = 0.0,
        kv_budget: float = float("inf"),
        temperature: float = 1.0,
        eos_id: int = EOS,
        seed: int = 0,
        prefill_bucket: int = 16,
        frontend_fn: Optional[Callable[[int], jax.Array]] = None,
        batched_prefill: bool = True,
        compact_decode: bool = True,
        paged: bool = False,
        kv_block_size: int = 16,
        kv_pool_blocks: Optional[int] = None,
        admission_headroom_tokens: int = 16,
        share_prefix: bool = True,
        lazy_cow: bool = True,
        shard_count: int = 1,
    ):
        self.inst_id = inst_id
        self.cfg = cfg
        self.params = params
        self.inst_version = version
        self.max_slots = max_slots
        self.max_len = max_len
        self.k5 = kv_bytes_per_token or (
            2 * cfg.n_layers * cfg.n_kv_heads * cfg.hd * 4
        )
        # Devices this instance spans (ShardedBackend sets > 1). ``k5``
        # stays the *total* per-token KV footprint across the pod; memory
        # accounting and ``kv_budget`` are per-device, so every charge
        # uses ``k5_local`` and the coordinator sees one device's HBM.
        self.shard_count = shard_count
        self.k5_local = self.k5 / shard_count
        self.kv_budget = kv_budget
        self.temperature = temperature
        self.eos_id = eos_id
        self.compact_decode = compact_decode
        # Admission over-provisioning: besides its current tokens, a routed
        # trajectory is charged this many future decode tokens against the
        # KV budget, so freshly admitted trajectories have room to grow
        # before the next coordinator cycle rebalances (avoids immediate
        # OOM-thrash at full budget). The charge is capped at ``max_len``.
        self.admission_headroom_tokens = admission_headroom_tokens
        self.paged = paged
        self.kv_block_size = kv_block_size
        # Per-slot PRNG key streams: the key for a trajectory's p-th
        # sampled token is fold_in(fold_in(base, traj_id), p) — a pure
        # function of (seed, traj_id, position). Deliberately NOT mixed
        # with inst_id: a trajectory's stochastic stream must be identical
        # wherever it decodes, so migration/compaction are invariant.
        self._base_key = jax.random.PRNGKey(seed)

        # vlm caches lead with ``n_patches`` frontend positions per slot
        self._pos_offset = (
            cfg.n_patches
            if (cfg.family == "vlm" and frontend_fn is not None)
            else 0
        )
        # prefix sharing needs the paged pool and a plain token frontend
        # (frontend embeddings would have to be proven identical per row)
        self.share_prefix = bool(share_prefix and paged and frontend_fn is None)
        # lazy CoW: group tails stay shared until each member's first
        # decode write (copy-at-first-divergence) instead of being copied
        # eagerly at admission
        self.lazy_cow = bool(lazy_cow and self.share_prefix)
        # suffix prefill: fork admissions forward only the tokens past the
        # resident shared prefix. Gated to families whose forward carries
        # no per-position recurrent/cross state (see paged_prefill_step).
        self._suffix_ok = self.share_prefix and cfg.family in ("dense", "moe")
        self.allocator: Optional[RefcountedBlockAllocator] = None
        if paged:
            bs = kv_block_size
            blocks_per_seq = blocks_for_tokens(max_len, bs)
            if kv_pool_blocks is not None:
                n_blocks = kv_pool_blocks
            elif kv_budget != float("inf"):
                # per-device budget over per-device block bytes
                n_blocks = int(kv_budget // (self.k5_local * bs))
            else:
                n_blocks = max_slots * blocks_per_seq
            # at least one max-length trajectory must always fit, so block
            # exhaustion can only strike when there is a victim to preempt
            n_blocks = max(n_blocks, blocks_per_seq)
            # refcounted allocator: identical to the plain pool without
            # sharing, and the substrate for group-admission prefix reuse
            self.allocator = RefcountedBlockAllocator(n_blocks + 1, bs)
            self.cache = M.init_paged_cache(
                cfg, max_slots, max_len, n_blocks + 1, bs
            )
        else:
            self.cache = M.init_cache(cfg, max_slots, max_len)
        self.slots: List[Optional[Trajectory]] = [None] * max_slots
        # deque: admission pops the head and preemption pushes the head on
        # hot loops — both O(1) (a list pays O(n) per pop(0)/insert(0))
        self.waiting: Deque[Trajectory] = deque()
        self.complete_since_sync: set = set()
        self._last_tokens = jnp.zeros((max_slots,), jnp.int32)
        # incrementally maintained byte counter (exact under paging via the
        # allocator; on the dense path updated at admission / per recorded
        # token / slot release) — admission is O(1) per trajectory instead
        # of O(active slots)
        self._kv_bytes = 0.0
        # per-slot cache position (host mirror of cache["pos"] rows) and
        # admission sequence number (preemption picks the youngest resident)
        self._slot_pos: List[int] = [0] * max_slots
        self._slot_seq: List[int] = [0] * max_slots
        self._admit_seq = 0
        # shared-prefix registry (shared with SimBackend): prefix id ->
        # member traj ids still holding the shared full prompt blocks +
        # their token capacity. Exported in snapshots so the coordinator's
        # discard releases shared bytes once per group, and consulted by
        # single admissions to fork a still-resident sibling prefix.
        self._prefix = PrefixRegistry()
        # telemetry
        self.decode_steps = 0
        self.prefill_tokens = 0
        self.decode_tokens = 0
        self.preemptions = 0
        self.shared_prefix_hits = 0       # members admitted off a shared prompt
        self.prefill_tokens_saved = 0     # prompt tokens not re-prefilled
        self.block_copies = 0             # CoW pool-block copies issued
        # observability hooks (set by the runtime when tracing is on):
        # on_admit(inst_id, traj_ids) after waiting trajectories enter
        # decode slots; on_preempt(inst_id, traj_id) on KV eviction
        self.on_admit = None
        self.on_preempt = None

        # runner construction goes through overridable factories so the
        # sharded backend swaps in its SPMD variants without duplicating
        # the argument plumbing (one construction site for both backends)
        self.prefill_runner = self._make_prefill_runner(
            cfg,
            max_len=max_len,
            prefill_bucket=prefill_bucket,
            batch_limit=0 if batched_prefill else 1,
            temperature=temperature,
            frontend_fn=frontend_fn,
            paged_block_size=kv_block_size if paged else 0,
        )
        if paged:
            self.paged_decode_runner = self._make_paged_decode_runner(
                cfg,
                max_slots=max_slots,
                blocks_per_seq=blocks_for_tokens(max_len, kv_block_size),
                temperature=temperature,
            )
            self.decode_runner = None
        else:
            self.paged_decode_runner = None
            self.decode_runner = DecodeRunner(
                cfg, max_slots=max_slots, temperature=temperature
            )
        self._overflow_done: List[Trajectory] = []

    # --------------------------------------------------- runner factories
    def _make_prefill_runner(self, cfg: ArchConfig, **kw) -> PrefillRunner:
        return PrefillRunner(cfg, **kw)

    def _make_paged_decode_runner(
        self, cfg: ArchConfig, **kw
    ) -> PagedDecodeRunner:
        return PagedDecodeRunner(cfg, **kw)

    # ------------------------------------------------------------- geometry
    def _slot_len(self, t: Trajectory) -> int:
        return t.length

    def kv_bytes(self) -> float:
        """Bytes of KV in use *per device* — O(1).

        Paged: exact block-granular usage (allocated blocks x block bytes,
        divided across the pod's head shards). Dense: token-granular sum
        over resident trajectories, maintained incrementally (dense mode
        is single-device only).
        """
        if self.paged:
            return self.k5_local * self.allocator.used_tokens()
        return self._kv_bytes

    def _recompute_kv_bytes(self) -> float:
        """O(active-slots) dense recomputation — invariant checks in tests."""
        return sum(
            self.k5 * self._slot_len(t) for t in self.slots if t is not None
        )

    def n_active(self) -> int:
        return sum(1 for t in self.slots if t is not None)

    # ------------------------------------------------------------- commands
    def route(self, traj: Trajectory, now: float = 0.0) -> None:
        traj.instance = self.inst_id
        self.waiting.append(traj)
        self._admit()

    def route_many(
        self, trajs: Sequence[Trajectory], now: float = 0.0
    ) -> None:
        """Enqueue a wave of trajectories, then admit once — every
        admissible trajectory prefills in one batched forward per bucket."""
        for traj in trajs:
            traj.instance = self.inst_id
            self.waiting.append(traj)
        self._admit()

    def _release_slot(self, slot: int) -> Trajectory:
        """Vacate ``slot`` and release its KV (blocks or byte counter).

        Under paging the free *decrements refcounts*: blocks shared with
        surviving group members stay allocated until the last member
        releases them."""
        t = self.slots[slot]
        self.slots[slot] = None
        if self.paged:
            self.allocator.free(t.traj_id)
            self._prefix.drop(t.traj_id)
        else:
            self._kv_bytes = max(
                0.0, self._kv_bytes - self.k5 * self._slot_len(t)
            )
        return t

    def interrupt(
        self, traj_ids: Sequence[int], now: float = 0.0
    ) -> List[Trajectory]:
        ids = set(traj_ids)
        out: List[Trajectory] = []
        for i, t in enumerate(self.slots):
            if t is not None and t.traj_id in ids:
                self._release_slot(i)
                t.status = TrajStatus.INTERRUPTED
                t.instance = None
                out.append(t)
        keep = []
        for t in self.waiting:
            if t.traj_id in ids:
                t.status = TrajStatus.INTERRUPTED
                t.instance = None
                out.append(t)
            else:
                keep.append(t)
        self.waiting = deque(keep)
        self._admit()
        return out

    def abort(self, traj_ids: Sequence[int], now: float = 0.0) -> List[Trajectory]:
        out = self.interrupt(traj_ids)
        for t in out:
            t.status = TrajStatus.ABORTED
        return out

    def pull(self, params: Any, version: int, now: float = 0.0) -> None:
        self.params = params
        self.inst_version = version
        self.complete_since_sync.clear()
        # residents were interrupted by the coordinator beforehand (Alg. 1);
        # anything left is re-prefilled lazily on its next admit
        self._admit()

    # ---------------------------------------------------------------- admit
    def _admission_charge(self, length: int) -> float:
        """Bytes a routed trajectory of ``length`` tokens is charged at
        admission (current tokens + ``admission_headroom_tokens`` of growth,
        capped at ``max_len``; block-rounded under paging).

        The paged charge is on the *cache-position* basis the allocator
        draws from — including the vlm patch offset — so the budget check
        matches what ``alloc`` will actually take. Dense keeps the seed's
        token basis (its ``kv_bytes`` excludes patches too)."""
        tokens = min(length + self.admission_headroom_tokens, self.max_len)
        if self.paged:
            bs = self.kv_block_size
            return self.k5_local * bs * blocks_for_tokens(
                min(tokens + self._pos_offset, self.max_len), bs
            )
        return self.k5_local * tokens

    def _share_run(self) -> int:
        """Shareable same-group run length at the waiting-queue head (the
        scan itself is shared with SimBackend; prompts at the engine-level
        overflow cap finish immediately instead)."""
        if not self.share_prefix:
            return 1
        return shareable_run(self.waiting, self.max_len - 1)

    def _admit_group(
        self,
        g: int,
        free: List[int],
        jobs: List["PrefillJob"],
        trajs: List[Trajectory],
        planned_bytes: float,
    ) -> Optional[float]:
        """Try to admit the first ``g`` waiting trajectories (one group,
        one prompt) as a shared-prefix unit. Shrinks ``g`` until budget and
        pool fit; returns updated ``planned_bytes``, or ``None`` when even
        the shrunken unit cannot admit (caller falls back to the single
        path, whose FIFO break semantics then apply)."""
        bs = self.kv_block_size
        prompt = self.waiting[0].prompt
        cache_len = len(prompt)
        n_full, tail = divmod(cache_len, bs)
        pad_tokens = min(cache_len + self.admission_headroom_tokens,
                         self.max_len)
        member_excl = blocks_for_tokens(pad_tokens, bs) - n_full
        while g >= 2:
            # the budget/pool decision stays worst-case (every member
            # eventually diverges and owns a private tail) so lazy and
            # eager CoW admit identical schedules
            charge = self.k5_local * bs * (n_full + g * member_excl)
            need_now = n_full + (g if tail else 0)
            if (
                planned_bytes + charge <= self.kv_budget
                and need_now <= self.allocator.n_free
            ):
                break
            g -= 1
        if g < 2:
            return None
        members = [self.waiting.popleft() for _ in range(g)]
        slots = [free.pop(0) for _ in range(g)]
        # per-member stream keys in one batched dispatch (position =
        # n_generated, 0 for fresh members)
        karr = stream_keys(
            self._base_key,
            jnp.asarray([m.traj_id for m in members], jnp.uint32),
            jnp.asarray([m.n_generated for m in members], jnp.uint32),
        )
        keys = [karr[i] for i in range(g)]
        ids = [m.traj_id for m in members]
        shared, tails = self.allocator.alloc_group(
            ids, cache_len, lazy_tail=self.lazy_cow
        )
        planned_bytes += self.k5_local * bs * (len(shared) + len(tails))
        lazy_tail = bool(tails) and self.lazy_cow
        if shared or lazy_tail:
            # a lazy shared tail must be registered even with zero full
            # shared blocks — divergence tracking hangs off the registry
            self._prefix.register(
                members[0].group_id, ids, len(shared) * bs, prompt,
                tail_members=ids if lazy_tail else (),
            )
        jobs.append(PrefillJob(
            slot=slots[0],
            tokens=list(prompt),
            key=keys[0],
            blocks=shared + tails[:1],
            extra_slots=slots[1:],
            extra_keys=keys[1:],
            tail_src=tails[0] if tails else None,
            tail_dsts=tails[1:],
        ))
        trajs.extend(members)
        self.shared_prefix_hits += g - 1
        self.prefill_tokens_saved += (g - 1) * cache_len
        return planned_bytes

    def _admit(self) -> None:
        """Admit waiting trajectories into free slots within the KV budget —
        all eligible admissions run as ONE batched prefill per length bucket.

        Admission policy matches the seed engine decision-for-decision on
        the dense path: the waiting queue is FIFO, each admission charges
        its headroom-padded current length against the budget and then
        accumulates ``k5 * (length + 1)`` of planned usage (the +1 is the
        token prefill samples), and a trajectory too long to generate
        consumes its candidate slot index exactly as the seed's slot-scan
        did. Under paging the charge is the trajectory's *actual block
        allocation*, and admission additionally requires the pool to hold
        enough free blocks for the (re-)prefill.

        Prefix sharing: a contiguous run of same-group, same-prompt,
        nothing-generated members at the queue head admits as one unit —
        one prompt prefill, full blocks shared (refcounted), private tail
        copies — charging the shared blocks once.
        """
        free = [i for i, t in enumerate(self.slots) if t is None]
        jobs: List[PrefillJob] = []
        trajs: List[Trajectory] = []
        planned_bytes = self.kv_bytes()
        while self.waiting and free:
            run = min(self._share_run(), len(free))
            if run >= 2:
                planned = self._admit_group(
                    run, free, jobs, trajs, planned_bytes
                )
                if planned is not None:
                    planned_bytes = planned
                    continue
            nxt = self.waiting[0]
            tokens = list(nxt.prompt) + list(nxt.response)
            cache_len = len(tokens) + self._pos_offset
            # cross-wave prefix join: a straggler group member admitted
            # after its siblings forks their still-resident prefix blocks
            # instead of duplicating them. On suffix-capable families only
            # the tokens past the resident prefix are forwarded; otherwise
            # the full forward runs with its full-block KV writes discarded
            # into the null sink. A preempted member re-admitting with a
            # partial response forks too — the shared prefix covers its
            # prompt, and the suffix is the prompt tail plus the response.
            fork_pk = None
            shared_blocks = 0
            if (
                self.paged
                and self.share_prefix
                and len(tokens) < self.max_len - 1
                and nxt.group_id >= 0
                and not nxt.sim_generated
            ):
                h, tp = nxt.prompt_key()
                fork_pk = self._prefix.find(
                    nxt.group_id, tp, prompt_hash=h
                )
                if fork_pk is not None:
                    shared_blocks = (
                        self._prefix.tokens(fork_pk) // self.kv_block_size
                    )
                    if shared_blocks == 0:
                        fork_pk = None  # tail-only registration: no prefix
            charge = self._admission_charge(self._slot_len(nxt))
            charge -= self.k5_local * self.kv_block_size * shared_blocks
            if planned_bytes + max(charge, 0.0) > self.kv_budget:
                break
            if self.paged:
                # ``alloc`` below draws down ``n_free`` as this pass admits,
                # so the availability check is against the live free count
                need_blocks = (
                    blocks_for_tokens(cache_len, self.kv_block_size)
                    - shared_blocks
                )
                if (
                    len(tokens) < self.max_len - 1
                    and need_blocks > self.allocator.n_free
                ):
                    break  # pool exhausted: wait for releases
            self.waiting.popleft()
            slot = free.pop(0)
            if len(tokens) >= self.max_len - 1:
                # no room to generate: finish immediately (engine-level cap)
                nxt.finished = True
                nxt.status = TrajStatus.GENERATED
                self.complete_since_sync.add(nxt.traj_id)
                self._overflow_done.append(nxt)
                continue
            sub = self._sample_key(nxt)
            blocks = None
            suffix_start: Optional[int] = None
            resident_tokens = 0
            if self.paged:
                if fork_pk is not None:
                    shared = self.allocator.table(
                        self._prefix.member_of(fork_pk)
                    )[:shared_blocks]
                    own = self.allocator.fork(nxt.traj_id, shared, cache_len)
                    self._prefix.join(fork_pk, nxt.traj_id)
                    if self._suffix_ok:
                        # suffix prefill: forward only the tokens past the
                        # resident prefix — the real block table is passed
                        # so attention reads the donor's resident KV.
                        # Block-aligned forks re-forward one prompt token
                        # for logits; its redundant K/V write is redirected
                        # to the null sink inside paged_prefill_step.
                        resident_tokens = shared_blocks * self.kv_block_size
                        suffix_start = min(resident_tokens, len(tokens) - 1)
                        blocks = shared + own
                        self.prefill_tokens_saved += suffix_start
                    else:
                        # scatter target: the shared blocks are already
                        # written (identical prompt KV) — aim those rows at
                        # the null garbage block, keep only tail/own writes
                        blocks = [NULL_BLOCK] * shared_blocks + own
                    planned_bytes += self.k5_local * self.kv_block_size * len(own)
                    self.shared_prefix_hits += 1
                else:
                    blocks = self.allocator.alloc(nxt.traj_id, cache_len)
                    planned_bytes += (
                        self.k5_local * self.kv_block_size * len(blocks)
                    )
            else:
                planned_bytes += self.k5_local * (self._slot_len(nxt) + 1)
            jobs.append(PrefillJob(
                slot=slot, tokens=tokens, key=sub, blocks=blocks,
                suffix_start=suffix_start, resident_tokens=resident_tokens,
            ))
            trajs.append(nxt)
        if not jobs:
            return
        if not self.paged:
            # the decode runner may hold active rows compacted out of the
            # batch cache; sync them back before the prefill scatter writes
            # new rows (the paged pool needs no such coherence step)
            self.cache = self.decode_runner.flush(self.cache)
        self.cache, result = self.prefill_runner.run(
            self.params, self.cache, jobs
        )
        self.prefill_tokens += result.prefill_tokens
        self.block_copies += result.tail_copies
        member_slots: List[int] = []
        member_lens: List[int] = []
        for job in jobs:
            member_slots.append(job.slot)
            member_slots.extend(job.extra_slots)
            member_lens.extend([len(job.tokens)] * job.n_members)
        last = self._last_tokens
        for slot, n_tok, traj, tok, blp in zip(
            member_slots, member_lens, trajs, result.tokens, result.logprobs
        ):
            self._record_token(traj, tok, blp)
            last = last.at[slot].set(tok)
            traj.status = TrajStatus.RUNNING
            self.slots[slot] = traj
            self._slot_pos[slot] = n_tok + self._pos_offset
            self._slot_seq[slot] = self._admit_seq
            self._admit_seq += 1
            if not self.paged:
                self._kv_bytes += self.k5 * self._slot_len(traj)
        self._last_tokens = last
        if self.on_admit is not None:
            self.on_admit(self.inst_id, [t.traj_id for t in trajs])

    # ----------------------------------------------------------------- step
    def _sample_key(self, traj: Trajectory) -> jax.Array:
        """Stream key for the trajectory's NEXT sampled token (position =
        tokens generated so far, so a re-prefilled partial rollout resumes
        its stream exactly where the interrupt cut it)."""
        return stream_key(self._base_key, traj.traj_id, traj.n_generated)

    def _record_token(self, traj: Trajectory, token: int, blp: float) -> None:
        traj.response.append(token)
        traj.behavior_logprobs.append(blp)
        traj.record_segment(self.inst_version, 1)
        if token == self.eos_id or traj.n_generated >= traj.max_new_tokens:
            traj.finished = True

    def _preempt(self, slot: int) -> None:
        """Evict ``slot``'s trajectory to the head of the waiting queue,
        releasing its blocks (it re-prefills prompt + partial response on
        re-admission — the standard partial-rollout path)."""
        t = self._release_slot(slot)
        t.status = TrajStatus.INTERRUPTED
        self.waiting.appendleft(t)
        self.preemptions += 1
        if self.on_preempt is not None:
            self.on_preempt(self.inst_id, t.traj_id)

    def _ensure_decode_blocks(self) -> None:
        """Grow each resident's block table to cover its next write
        position; on pool exhaustion preempt the *youngest* resident
        (vLLM-style LIFO preemption — the oldest trajectories, closest to
        completion, keep their blocks).

        Lazy CoW: a group member still pointing at its group's shared tail
        block diverges here, at its first decode write — the tail is copied
        into a private block *before* the decode dispatch so the write
        cannot clobber siblings. The last undiverged owner writes in place
        (no copy needed: nobody else reads the block anymore)."""
        copies: List[Tuple[int, int]] = []
        for slot in sorted(
            (i for i, t in enumerate(self.slots) if t is not None),
            key=lambda i: self._slot_seq[i],
        ):
            t = self.slots[slot]
            if t is None:  # preempted earlier in this pass
                continue
            while True:
                try:
                    self.allocator.extend_to(t.traj_id, self._slot_pos[slot] + 1)
                    if self.lazy_cow and self._prefix.in_shared_tail(
                        t.traj_id
                    ):
                        # first write lands in the shared tail block
                        # (tail member => no decode writes yet => next
                        # write position is inside the prompt's tail)
                        pair = self.allocator.cow(
                            t.traj_id,
                            self._slot_pos[slot] // self.kv_block_size,
                        )
                        self._prefix.mark_diverged(t.traj_id)
                        if pair is not None:
                            copies.append(pair)
                    break
                except BlockExhausted:
                    victims = [
                        i
                        for i, v in enumerate(self.slots)
                        if v is not None and i != slot
                    ]
                    if not victims:
                        # unreachable by construction: the pool holds >= one
                        # full-length trajectory's worth of blocks, and with
                        # every victim preempted this owner is the sole
                        # surviving table (shared refcounts drop to 1 with
                        # it), so free >= blocks_per_seq - len(table) >=
                        # the <= 1 block the extension needs. A preempted
                        # sharer may free 0 blocks, but the loop then moves
                        # to the next victim rather than re-preempting it.
                        raise
                    self._preempt(max(victims, key=lambda i: self._slot_seq[i]))
        if copies:
            self.block_copies += len(copies)
            self.cache = self.prefill_runner.copy_blocks(self.cache, copies)

    def step(self, now: float = 0.0, dt: float = 0.0) -> List[Trajectory]:
        """One batched decode step over the active slots. Returns completed
        trajectories (removed from their slots)."""
        done: List[Trajectory] = []
        if self._overflow_done:
            done.extend(self._overflow_done)
            self._overflow_done.clear()
        if self.paged:
            self._ensure_decode_blocks()
        active = [i for i, t in enumerate(self.slots) if t is not None]
        if not active:
            return done
        keys = stream_keys(
            self._base_key,
            jnp.asarray(
                [self.slots[s].traj_id for s in active], jnp.uint32
            ),
            jnp.asarray(
                [self.slots[s].n_generated for s in active], jnp.uint32
            ),
        )
        if self.paged:
            tables = {
                s: self.allocator.table(self.slots[s].traj_id) for s in active
            }
            self.cache, self._last_tokens, result = (
                self.paged_decode_runner.run(
                    self.params, self.cache, active, tables,
                    self._last_tokens, keys,
                )
            )
        else:
            self.cache, self._last_tokens, result = self.decode_runner.run(
                self.params,
                self.cache,
                active,
                self._last_tokens,
                keys,
                compact=self.compact_decode,
            )
        self.decode_steps += 1
        self.decode_tokens += len(active)

        for slot, token, blp, pos in zip(
            result.slots, result.tokens, result.logprobs, result.positions
        ):
            traj = self.slots[slot]
            self._record_token(traj, int(token), float(blp))
            self._slot_pos[slot] = int(pos)
            if not self.paged:
                self._kv_bytes += self.k5
            if traj.finished or int(pos) >= self.max_len - 1:
                traj.finished = True
                traj.status = TrajStatus.GENERATED
                self.complete_since_sync.add(traj.traj_id)
                done.append(traj)
                self._release_slot(slot)
        if done:
            self._admit()
        return done

    # ------------------------------------------------------------- snapshot
    def snapshot(self) -> InstanceSnapshot:
        run = {t.traj_id for t in self.slots if t is not None}
        lengths = {
            t.traj_id: self._slot_len(t)
            for t in list(self.slots) + list(self.waiting)
            if t is not None
        }
        # cumulative preemption count — snapshot() stays a pure read; the
        # coordinator differences consecutive snapshots into the per-cycle
        # rate the routing penalty wants
        prefix_groups, prefix_tokens = self._prefix.export()
        return InstanceSnapshot(
            inst_id=self.inst_id,
            kv_cache=self.kv_bytes(),
            run_trajs=run,
            wait_trajs={t.traj_id for t in self.waiting},
            complete_trajs=set(self.complete_since_sync),
            inst_version=self.inst_version,
            traj_lengths=lengths,
            preemptions=self.preemptions,
            prefix_groups=prefix_groups,
            prefix_tokens=prefix_tokens,
            prefix_tail_members=self._prefix.export_tails(),
            shard_count=self.shard_count,
        )
