"""Fixed-size KV block allocator (vLLM-style paging, rollout side).

The dense engine reserves ``max_len`` contiguous cache rows per slot, so a
replica's concurrency is bounded by worst-case trajectory length even when
most trajectories are short (the heavy-tail skew of Fig. 4). Paging breaks
the cache into fixed-size token blocks drawn from one shared pool:

* each resident trajectory owns an ordered **block table** — block ``i``
  of the table holds cache positions ``[i*block_size, (i+1)*block_size)``;
* blocks are allocated at admission (prompt re-prefill) and **extended on
  the fly** as decode crosses block boundaries;
* freeing (finish / interrupt / abort / preemption) returns every owned
  block to the free list.

Block 0 is the **null block**: a garbage sink that is never allocated.
Block-table paddings point at it, so padded scatters/gathers in the jitted
data plane have a harmless, always-valid target (reads of it are masked by
per-sequence lengths downstream).

The allocator is host-side bookkeeping only — it never touches device
memory. Invariants (enforced by ``check()``, property-tested in
``tests/test_kv_allocator.py``):

* a block is owned by at most one trajectory and is either owned or free;
* the null block is never owned and never free;
* ``n_free + sum(len(table) for table in tables) + 1 == n_blocks``.
"""
from __future__ import annotations

from typing import Dict, List, Tuple

NULL_BLOCK = 0


def blocks_for_tokens(n_tokens: int, block_size: int) -> int:
    """Blocks needed to hold ``n_tokens`` cache positions."""
    return max(0, -(-n_tokens // block_size))


class BlockExhausted(RuntimeError):
    """The pool cannot satisfy an allocation (caller should preempt)."""


class BlockAllocator:
    """Free-list allocator over ``n_blocks`` fixed-size KV blocks.

    ``n_blocks`` counts the null block, so ``n_blocks - 1`` blocks are
    actually allocatable.
    """

    def __init__(self, n_blocks: int, block_size: int):
        if n_blocks < 2:
            raise ValueError("need at least one allocatable block + null")
        if block_size < 1:
            raise ValueError("block_size must be >= 1")
        self.n_blocks = n_blocks
        self.block_size = block_size
        # LIFO free list: recently freed (still-warm) blocks are reused first
        self._free: List[int] = list(range(n_blocks - 1, NULL_BLOCK, -1))
        self._tables: Dict[int, List[int]] = {}

    # ------------------------------------------------------------- geometry
    @property
    def n_free(self) -> int:
        return len(self._free)

    @property
    def used_blocks(self) -> int:
        return (self.n_blocks - 1) - len(self._free)

    def used_tokens(self) -> int:
        """Token *capacity* of allocated blocks (block-granular accounting)."""
        return self.used_blocks * self.block_size

    def owners(self) -> Tuple[int, ...]:
        return tuple(self._tables)

    def table(self, owner: int) -> List[int]:
        """The owner's ordered block table (a copy)."""
        return list(self._tables[owner])

    def capacity(self, owner: int) -> int:
        """Cache positions currently backed for ``owner``."""
        return len(self._tables[owner]) * self.block_size

    # ----------------------------------------------------------- allocation
    def _take(self, n: int) -> List[int]:
        """Pop ``n`` fresh blocks off the free list (ownership hook — the
        refcounted subclass also stamps refcounts here)."""
        return [self._free.pop() for _ in range(n)]

    def _release_table(self, table: List[int]) -> int:
        """Return a table's blocks to the free list; returns the number
        physically freed (the refcounted subclass frees only last-owner
        blocks)."""
        self._free.extend(table)
        return len(table)

    def alloc(self, owner: int, n_tokens: int) -> List[int]:
        """Allocate a fresh table covering ``n_tokens`` positions.

        Raises ``BlockExhausted`` (allocating nothing) if the free list is
        short, ``ValueError`` if ``owner`` already holds a table.
        """
        if owner in self._tables:
            raise ValueError(f"owner {owner} already has a block table")
        need = blocks_for_tokens(n_tokens, self.block_size)
        if need > len(self._free):
            raise BlockExhausted(
                f"need {need} blocks, {len(self._free)} free"
            )
        self._tables[owner] = self._take(need)
        return list(self._tables[owner])

    def extend_to(self, owner: int, n_tokens: int) -> List[int]:
        """Grow the owner's table to cover ``n_tokens`` positions.

        Returns the newly appended blocks (empty if already covered).
        Raises ``BlockExhausted`` without partial allocation on shortfall.
        """
        table = self._tables[owner]
        need = blocks_for_tokens(n_tokens, self.block_size) - len(table)
        if need <= 0:
            return []
        if need > len(self._free):
            raise BlockExhausted(
                f"need {need} more blocks, {len(self._free)} free"
            )
        new = self._take(need)
        table.extend(new)
        return new

    def free(self, owner: int) -> int:
        """Release every block owned by ``owner``. Returns the number of
        blocks physically freed (equal to the table length here; smaller
        under sharing, where co-owned blocks persist).

        Double-free (an unknown owner) raises ``KeyError`` — leaks and
        double-frees must fail loudly, not corrupt the pool.
        """
        return self._release_table(self._tables.pop(owner))

    # ------------------------------------------------------------ invariants
    def check(self) -> None:
        """Validate pool invariants; raises ``AssertionError`` on violation."""
        owned: List[int] = [b for t in self._tables.values() for b in t]
        owned_set = set(owned)
        free_set = set(self._free)
        assert len(owned) == len(owned_set), "block owned twice"
        assert len(self._free) == len(free_set), "block freed twice"
        assert not (owned_set & free_set), "block both owned and free"
        assert NULL_BLOCK not in owned_set, "null block allocated"
        assert NULL_BLOCK not in free_set, "null block on the free list"
        universe = owned_set | free_set | {NULL_BLOCK}
        assert universe == set(range(self.n_blocks)), "blocks leaked"
        assert all(0 < b < self.n_blocks for b in owned_set | free_set)
