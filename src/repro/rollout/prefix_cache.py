"""Copy-on-write prefix sharing over the paged KV block pool.

The paper's workload is group sampling (GRPO/DAPO, §2.1): every dataset
prompt expands into ``group_size`` member trajectories that differ only in
their responses. The plain ``BlockAllocator`` stores each member's identical
prompt KV independently, multiplying prefill FLOPs and pool pressure by the
group size. This module adds the sharing layer:

* ``RefcountedBlockAllocator`` — the same free-list pool, but a block may
  now appear in *several* owners' tables. A per-block refcount tracks the
  co-owners; ``free`` decrements and returns a block to the free list only
  when its last owner releases it.
* ``alloc_group(owners, n_tokens)`` — the group-admission primitive: the
  prompt's **full** blocks are allocated once and mapped read-only into
  every member's table. The partially-filled tail block (if the prompt
  does not end on a block boundary) is the only prompt block decode will
  ever write into. Eager mode gives each member a private tail copy at
  admission; **lazy mode** (``lazy_tail=True``) maps ONE shared tail into
  every table and defers the copy to each member's first write
  (``cow``) — members that finish, preempt, or abort before writing
  never pay for a private tail, in blocks or in copy bandwidth.
* ``cow(owner, idx)`` — copy-at-first-divergence: swap table entry
  ``idx`` to a fresh private block (refcount on the old one decremented)
  and return ``(old, new)`` so the caller device-copies the KV. The last
  undiverged co-owner returns ``None`` and keeps writing the original in
  place — nothing else reads positions past the prompt.
* ``fork(owner, shared, n_tokens)`` — join an existing shared prefix:
  refcounts on ``shared`` are bumped and fresh exclusive blocks cover the
  remainder. Used when members admit against a still-resident prefix.

Safety argument for the read-only full blocks: block ``i`` of a table backs
cache positions ``[i*bs, (i+1)*bs)`` and decode only ever writes position
``pos`` (monotonically increasing, ``pos >= prompt_len``). A *full* prompt
block ends at ``prompt_len - tail <= prompt_len``, so no decode write can
land in it — sharing is sound without write tracking. The tail block spans
``prompt_len`` itself, hence the per-member copy — eagerly at admission,
or lazily at the first decode write (``PrefixRegistry`` tracks which
members still alias the shared tail; the engine copies before dispatching
the write).

Accounting: ``used_blocks``/``used_tokens`` count **distinct** allocated
blocks, so shared prefix blocks are charged once per group — the property
the engine's ``kv_bytes()``, the cost model, and the snapshots all rely on.

Invariants (``check()``, property-tested in ``tests/test_kv_allocator.py``):

* a block's refcount equals the number of tables that contain it;
* a block appears at most once within any single table;
* refcounted and free blocks partition the pool (minus the null block);
* ``n_free + distinct owned + 1 == n_blocks`` — no leaks, no double frees.
"""
from __future__ import annotations

from collections import Counter
from typing import Dict, List, Optional, Sequence, Set, Tuple

from repro.rollout.kv_allocator import (
    NULL_BLOCK,
    BlockAllocator,
    BlockExhausted,
    blocks_for_tokens,
)

__all__ = [
    "NULL_BLOCK",
    "BlockExhausted",
    "PrefixRegistry",
    "RefcountedBlockAllocator",
    "blocks_for_tokens",
    "shareable_run",
]


def shareable_run(waiting: Sequence, max_prompt_len: Optional[int] = None) -> int:
    """Length of the contiguous run of group members at the head of a
    waiting queue that can admit off one shared prompt prefill: same
    group, identical prompt, nothing generated yet (a partial response
    makes a member's KV diverge — it re-prefills exclusively).

    Shared by the real engine and the sim backend so their admission
    pictures cannot drift. ``max_prompt_len`` excludes prompts that the
    caller's overflow path finishes immediately.
    """
    it = iter(waiting)  # deque-friendly: no slicing
    head = next(it)
    if head.group_id < 0 or head.response or head.sim_generated:
        return 1
    if max_prompt_len is not None and len(head.prompt) >= max_prompt_len:
        return 1
    n = 1
    for t in it:
        if (
            t.group_id == head.group_id
            and not t.response
            and not t.sim_generated
            and t.prompt == head.prompt
        ):
            n += 1
        else:
            break
    return n


class PrefixRegistry:
    """Live shared prefixes on one instance: an opaque prefix id maps to
    the member trajectory ids still holding the shared full prompt blocks
    and those blocks' token capacity.

    Both ``RolloutInstance`` and ``SimBackend`` maintain one and export it
    verbatim in snapshots (``prefix_groups`` / ``prefix_tokens``), which
    is what lets the coordinator's ``discard`` release shared bytes once
    per group. ``find`` supports cross-wave joining: a straggler member
    admitted after its siblings can locate their still-resident prefix
    and fork it instead of duplicating the blocks.
    """

    def __init__(self):
        self._members: Dict[int, Set[int]] = {}
        self._tokens: Dict[int, int] = {}
        self._by_member: Dict[int, int] = {}
        self._by_group: Dict[int, int] = {}   # group_id -> latest live pk
        self._prompt: Dict[int, tuple] = {}
        self._hash: Dict[int, int] = {}       # pk -> hash(prompt tuple)
        # lazy CoW: members still aliasing the group's SHARED tail block
        # (their first decode write must copy-then-diverge)
        self._tail_members: Dict[int, Set[int]] = {}
        self._seq = 0

    def register(
        self, group_id: int, member_ids: Sequence[int],
        shared_tokens: int, prompt: Sequence[int],
        *, tail_members: Sequence[int] = (),
    ) -> int:
        """Record a freshly admitted shared prefix. Returns its id.

        ``tail_members`` names the members admitted aliasing one shared
        tail block (lazy CoW); empty under eager CoW or block-aligned
        prompts."""
        pk = self._seq
        self._seq += 1
        self._members[pk] = set(member_ids)
        self._tokens[pk] = shared_tokens
        self._by_group[group_id] = pk
        tp = tuple(prompt)
        self._prompt[pk] = tp
        self._hash[pk] = hash(tp)
        if tail_members:
            self._tail_members[pk] = set(tail_members)
        for tid in member_ids:
            self._by_member[tid] = pk
        return pk

    def join(self, pk: int, tid: int) -> None:
        """A straggler member forked the prefix and co-owns it now."""
        self._members[pk].add(tid)
        self._by_member[tid] = pk

    def drop(self, tid: int) -> None:
        """A member released its blocks; forget the prefix with the last."""
        pk = self._by_member.pop(tid, None)
        if pk is None:
            return
        self.mark_diverged_pk(pk, tid)
        members = self._members[pk]
        members.discard(tid)
        if not members:
            del self._members[pk]
            del self._tokens[pk]
            del self._prompt[pk]
            del self._hash[pk]
            for gid, live in list(self._by_group.items()):
                if live == pk:
                    del self._by_group[gid]

    # -------------------------------------------------- lazy CoW tail state
    def in_shared_tail(self, tid: int) -> bool:
        """True while ``tid`` still aliases its group's shared tail block —
        its next decode write must trigger the divergence copy first."""
        pk = self._by_member.get(tid)
        return pk is not None and tid in self._tail_members.get(pk, ())

    def mark_diverged(self, tid: int) -> None:
        """``tid`` got (or no longer needs) a private tail."""
        pk = self._by_member.get(tid)
        if pk is not None:
            self.mark_diverged_pk(pk, tid)

    def mark_diverged_pk(self, pk: int, tid: int) -> None:
        tails = self._tail_members.get(pk)
        if tails is not None:
            tails.discard(tid)
            if not tails:
                del self._tail_members[pk]

    def undiverged(self, pk: int) -> int:
        """Members of ``pk`` still aliasing the shared tail block."""
        return len(self._tail_members.get(pk, ()))

    def export_tails(self) -> Dict[int, Set[int]]:
        """Snapshot-ready copy of the shared-tail membership."""
        return {pk: set(m) for pk, m in self._tail_members.items()}

    def find(
        self, group_id: int, prompt: Sequence[int],
        *, prompt_hash: Optional[int] = None,
    ) -> Optional[int]:
        """The live prefix id for ``group_id`` if its prompt matches.

        ``prompt_hash`` (pass ``hash(tuple(prompt))``, e.g. a trajectory's
        cached ``prompt_key()``) short-circuits the comparison: the full
        tuple is only compared on a hash match, so the admission-loop hot
        path stops rebuilding and comparing whole prompt tuples."""
        pk = self._by_group.get(group_id)
        if pk is None:
            return None
        if prompt_hash is not None and self._hash[pk] != prompt_hash:
            return None
        if self._prompt[pk] == (
            prompt if isinstance(prompt, tuple) else tuple(prompt)
        ):
            return pk
        return None

    def lookup(self, tid: int) -> Optional[int]:
        """The prefix id a member co-owns, if any."""
        return self._by_member.get(tid)

    def member_of(self, pk: int) -> int:
        """Any member currently co-owning ``pk`` (its table holds the
        shared blocks as its leading entries)."""
        return next(iter(self._members[pk]))

    def tokens(self, pk: int) -> int:
        return self._tokens[pk]

    def shared_token_total(self) -> int:
        """Sum of all live prefixes' shared token capacity — the bytes-
        accounting hot path (no copies, unlike ``export``)."""
        return sum(self._tokens.values())

    def export(self) -> Tuple[Dict[int, Set[int]], Dict[int, int]]:
        """Snapshot-ready copies of (prefix_groups, prefix_tokens)."""
        return (
            {pk: set(m) for pk, m in self._members.items()},
            dict(self._tokens),
        )


class RefcountedBlockAllocator(BlockAllocator):
    """Block pool with shared (refcounted) blocks for prefix reuse.

    With only ``alloc``/``extend_to``/``free`` (no sharing), behavior is
    identical to ``BlockAllocator`` — every refcount is 1 — so the paged
    engine uses this allocator unconditionally.
    """

    def __init__(self, n_blocks: int, block_size: int):
        super().__init__(n_blocks, block_size)
        self._ref: Dict[int, int] = {}

    # ------------------------------------------------------------- geometry
    def refcount(self, block: int) -> int:
        """Co-owners of ``block`` (0 = free or null)."""
        return self._ref.get(block, 0)

    @property
    def shared_blocks(self) -> int:
        """Distinct blocks currently owned by more than one table."""
        return sum(1 for r in self._ref.values() if r > 1)

    def shared_tokens(self) -> int:
        """Token capacity whose physical blocks are deduplicated away —
        what dense per-member storage would cost *extra*."""
        return sum(r - 1 for r in self._ref.values() if r > 1) * self.block_size

    # ----------------------------------------------------------- allocation
    # ``alloc`` / ``extend_to`` / ``free`` are inherited unchanged: the
    # base allocator routes block ownership through these two hooks, and
    # refcounting lives entirely in them. ``free`` therefore decrements:
    # only last-owner blocks return to the free list.
    def _take(self, n: int) -> List[int]:
        blocks = super()._take(n)
        for b in blocks:
            self._ref[b] = 1
        return blocks

    def _release_table(self, table: List[int]) -> int:
        released = 0
        for b in table:
            self._ref[b] -= 1
            if self._ref[b] == 0:
                del self._ref[b]
                self._free.append(b)
                released += 1
        return released

    # -------------------------------------------------------------- sharing
    def fork(
        self, owner: int, shared: Sequence[int], n_tokens: int
    ) -> List[int]:
        """Create ``owner``'s table as ``shared`` (refcounts bumped) plus
        fresh exclusive blocks covering ``n_tokens`` total positions.
        Returns the exclusive blocks. Atomic: raises ``BlockExhausted``
        without side effects on shortfall."""
        if owner in self._tables:
            raise ValueError(f"owner {owner} already has a block table")
        for b in shared:
            if self._ref.get(b, 0) < 1:
                raise ValueError(f"cannot share unowned block {b}")
        need = blocks_for_tokens(n_tokens, self.block_size) - len(shared)
        if need < 0:
            raise ValueError("shared prefix longer than the forked table")
        if need > len(self._free):
            raise BlockExhausted(f"need {need} blocks, {len(self._free)} free")
        for b in shared:
            self._ref[b] += 1
        own = self._take(need)
        self._tables[owner] = list(shared) + own
        return own

    def alloc_group(
        self, owners: Sequence[int], n_tokens: int, *, lazy_tail: bool = False
    ) -> Tuple[List[int], List[int]]:
        """Allocate tables for a group of owners sharing one ``n_tokens``
        prompt. Full blocks are allocated once and mapped into every table.
        A partial tail gets one private block per owner (the caller copies
        the prefilled tail KV into them — eager CoW), or with
        ``lazy_tail`` ONE shared block mapped into every table whose
        private copies are deferred to each owner's first write (``cow``).

        Returns ``(shared_full_blocks, tail_blocks)`` with ``tail_blocks``
        aligned with ``owners`` — or a single shared entry under
        ``lazy_tail`` — and empty when the prompt is block-aligned.
        Atomic: raises ``BlockExhausted`` allocating nothing on shortfall.
        """
        owners = list(owners)
        if len(set(owners)) != len(owners):
            raise ValueError("duplicate owners in group")
        for o in owners:
            if o in self._tables:
                raise ValueError(f"owner {o} already has a block table")
        n_full, tail = divmod(n_tokens, self.block_size)
        n_tails = (1 if lazy_tail else len(owners)) if tail else 0
        need = n_full + n_tails
        if need > len(self._free):
            raise BlockExhausted(f"need {need} blocks, {len(self._free)} free")
        shared = [self._free.pop() for _ in range(n_full)]
        for b in shared:
            self._ref[b] = len(owners)
        tails: List[int] = [self._free.pop() for _ in range(n_tails)]
        for b in tails:
            self._ref[b] = len(owners) if lazy_tail else 1
        for i, o in enumerate(owners):
            own = ([tails[0]] if lazy_tail else [tails[i]]) if tail else []
            self._tables[o] = list(shared) + own
        return shared, tails

    def cow(self, owner: int, idx: int) -> Optional[Tuple[int, int]]:
        """Copy-at-first-divergence: give ``owner`` a private copy of the
        shared block at table index ``idx`` before its first write there.

        Returns ``(old_block, new_block)`` for the caller to device-copy,
        or ``None`` if the block is already exclusive (the last undiverged
        co-owner writes the original in place — nothing else reads
        positions past the prompt, so skipping the copy is bitwise
        identical). Raises ``BlockExhausted`` without side effects on
        shortfall."""
        table = self._tables[owner]
        old = table[idx]
        if self._ref[old] <= 1:
            return None
        if not self._free:
            raise BlockExhausted("need 1 block, 0 free")
        new = self._take(1)[0]
        table[idx] = new
        self._ref[old] -= 1
        return old, new

    # ------------------------------------------------------------ invariants
    def check(self) -> None:
        counts: Counter = Counter()
        for owner, table in self._tables.items():
            assert len(table) == len(set(table)), (
                f"block repeated within owner {owner}'s table"
            )
            counts.update(table)
        assert dict(counts) == self._ref, "refcounts out of sync with tables"
        owned_set = set(counts)
        free_set = set(self._free)
        assert len(self._free) == len(free_set), "block freed twice"
        assert not (owned_set & free_set), "block both owned and free"
        assert NULL_BLOCK not in owned_set, "null block allocated"
        assert NULL_BLOCK not in free_set, "null block on the free list"
        universe = owned_set | free_set | {NULL_BLOCK}
        assert universe == set(range(self.n_blocks)), "blocks leaked"
        assert all(r >= 1 for r in self._ref.values())
