"""Prefill / decode runners: the batched data plane of a rollout instance.

Production engines (vLLM, SGLang, TensorRT-LLM) split the generation loop
into two phases with very different batching economics:

* **Prefill** is compute-bound and benefits from batching whole prompts —
  ``PrefillRunner`` admits *all* eligible waiting trajectories in one padded
  forward pass per length bucket and writes the resulting row caches into
  the instance's batch cache with a single jitted scatter (replacing the
  seed engine's per-trajectory ``init_cache(cfg, 1, ...)`` forward +
  tensor-by-tensor ``tree_map(.at[].set)`` loop).
* **Decode** is memory/parameter-bound and pays for every batch row whether
  or not a trajectory occupies it — ``DecodeRunner`` gathers only the
  *active* slots into a power-of-two compaction bucket, decodes that, and
  scatters the updated rows back, instead of always decoding ``max_slots``
  rows.

Equivalence contract (tested in ``tests/test_engine_equivalence.py``): on
the CPU/TPU XLA backends both runners are **bitwise** equivalent per row to
the seed single-row path — batched matmul rows do not interact (MoE expert
capacity is the one documented exception: capacity is a function of batch
size, so compaction can change token dropping at capacity limits; the
runtime's reduced configs are dense). Sampling keys are per-trajectory
*stream keys* (``repro.rollout.sampler.stream_keys``): token ``p`` of
trajectory ``t`` always draws from ``fold_in(fold_in(base, t), p)``, so
both greedy AND stochastic decoding are bit-for-bit invariant under slot
compaction, batch composition, and migration.

Both runners are pure data-plane helpers: they know nothing about the
waiting queue, KV budget, or the coordination protocol — that policy stays
in ``RolloutInstance`` (``repro.rollout.engine``).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from functools import partial
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.distributed.ctx import gather_params
from repro.models import model as M
from repro.rollout.sampler import sample_rows

Cache = Dict[str, Any]

# batch-axis index per cache entry (gather/scatter targets)
BATCH_AXIS = {
    "pos": 0, "k": 1, "v": 1, "conv": 1, "ssm": 1, "xk": 1, "xv": 1,
    "mlstm": 2, "slstm": 1,
}


def round_up(n: int, mult: int) -> int:
    return ((n + mult - 1) // mult) * mult


def next_pow2(n: int) -> int:
    p = 1
    while p < n:
        p *= 2
    return p


def pad_keys(keys: jax.Array, rows: int) -> jax.Array:
    """Pad a (n, 2) per-slot key batch to ``rows`` rows by repeating the
    first key (pad rows' draws are never read)."""
    n = keys.shape[0]
    if n >= rows:
        return keys
    return jnp.concatenate(
        [keys, jnp.broadcast_to(keys[:1], (rows - n, keys.shape[1]))]
    )


def scatter_keys(keys: jax.Array, active: Sequence[int], rows: int) -> jax.Array:
    """Place per-active-slot keys at their slot rows of a (rows, 2) key
    batch (inactive rows repeat the first key; their draws are masked)."""
    full = jnp.broadcast_to(keys[:1], (rows, keys.shape[1]))
    return full.at[jnp.asarray(list(active), jnp.int32)].set(keys)


def _row_index(name: str, rows: jax.Array) -> Tuple:
    return (slice(None),) * BATCH_AXIS[name] + (rows,)


def gather_rows(cache: Cache, rows: jax.Array) -> Cache:
    """Extract batch rows ``rows`` of every cache entry (compact view)."""
    return {
        name: jax.tree_util.tree_map(lambda f: f[_row_index(name, rows)], val)
        for name, val in cache.items()
    }


def scatter_rows(cache: Cache, row_cache: Cache, rows: jax.Array) -> Cache:
    """Write batch rows of ``row_cache`` into ``cache`` at indices ``rows``.

    ``row_cache`` leaves must carry exactly ``len(rows)`` entries on their
    batch axis. One fused scatter over the whole cache pytree.
    """
    out = {}
    for name, full in cache.items():
        idx = _row_index(name, rows)
        out[name] = jax.tree_util.tree_map(
            lambda f, r: f.at[idx].set(r.astype(f.dtype)), full, row_cache[name]
        )
    return out


@dataclass
class PrefillJob:
    """One planned admission: trajectory tokens destined for a cache slot.

    Group admission (prefix sharing, paged mode only): ``extra_slots`` /
    ``extra_keys`` name additional group members that decode off this job's
    prompt. The prompt is prefilled **once**; its full blocks (already
    mapped into every member's table by the allocator) are written once via
    ``blocks``, the per-slot small state is scattered to every member slot,
    and the partially-filled tail block — the only prompt block decode will
    ever write — is device-copied from ``tail_src`` into each member's
    private ``tail_dsts`` block (eager copy-on-write). Each member samples
    its own first token from the shared last-position logits with its own
    key, in admission order.
    """

    slot: int
    tokens: List[int]          # prompt + partial response (re-prefill)
    key: jax.Array             # per-trajectory stream key (sampler.stream_key)
    blocks: Optional[List[int]] = None  # paged mode: the slot's block table
    # --- group admission (prefix sharing) ---
    extra_slots: List[int] = field(default_factory=list)
    extra_keys: List[jax.Array] = field(default_factory=list)
    tail_src: Optional[int] = None       # prefill-written partial tail block
    tail_dsts: List[int] = field(default_factory=list)  # one per extra member
    # --- suffix mode (shared-prefix fork, paged mode only) ---
    # ``suffix_start`` set => only tokens[suffix_start:] run through the
    # model; positions below it are already resident in the pool via the
    # leading shared entries of ``blocks`` (the donor's prefix blocks).
    # ``resident_tokens`` is the pool-resident position count — the write
    # boundary (== suffix_start except block-aligned forks, which re-read
    # the last resident position for its logits without re-writing it).
    suffix_start: Optional[int] = None
    resident_tokens: int = 0

    @property
    def bucket_len(self) -> int:
        return len(self.tokens)

    @property
    def n_members(self) -> int:
        return 1 + len(self.extra_slots)


@dataclass
class PrefillResult:
    """Per-member sampled continuations, aligned with the submitted jobs
    flattened member-wise (a job's primary member first, then its
    ``extra_slots`` in order; plain jobs contribute one entry).
    ``prefill_tokens`` counts tokens actually run through the model — a
    shared group prompt counts once and suffix jobs count only their
    suffix, which is the saving. ``tail_copies`` counts pool-block copies
    issued for eager CoW tails (zero under lazy CoW)."""

    tokens: List[int] = field(default_factory=list)
    logprobs: List[float] = field(default_factory=list)
    prefill_tokens: int = 0
    tail_copies: int = 0


class PrefillRunner:
    """Bucketed multi-row batched prefill + fused cache scatter.

    ``batch_limit`` caps rows per forward; ``batch_limit=1`` degenerates to
    the seed engine's single-row path exactly (same shapes, same calls, same
    key order), which is what the equivalence tests compare against.
    """

    def __init__(
        self,
        cfg: ArchConfig,
        *,
        max_len: int,
        prefill_bucket: int = 16,
        batch_limit: int = 0,            # 0 = unlimited (one pass per bucket)
        temperature: float = 1.0,
        frontend_fn: Optional[Callable[[int], jax.Array]] = None,
        paged_block_size: int = 0,       # 0 = dense slot-row scatter
        paged_null_block: int = 0,
        impl: Optional[str] = None,      # kernels.ops dispatch override
        pool_sharding: Optional[Any] = None,   # pin paged K/V layout (TP)
    ):
        self.cfg = cfg
        self.max_len = max_len
        self.prefill_bucket = prefill_bucket
        self.batch_limit = batch_limit
        self.temperature = temperature
        self.frontend_fn = frontend_fn
        self.paged_block_size = paged_block_size
        self.paged_null_block = paged_null_block
        self.impl = impl
        # NamedSharding for the (l, n_blocks, bs, hkv, hd) pools: the
        # sharded backend pins scatter/copy outputs so GSPMD can never
        # decide to replicate the pool (which would silently void the
        # per-device memory accounting)
        self.pool_sharding = pool_sharding
        # shard-stored params are gathered replicated inside the step
        # (ctx.gather_params: ZeRO-3-style JIT materialization, no-op on
        # single-device instances) so matmul widths never change
        self._jit_prefill = jax.jit(
            lambda params, *a, **kw: M.prefill(
                cfg, gather_params(params), *a, impl=impl, **kw
            )
        )
        self._jit_scatter = jax.jit(scatter_rows)
        self._jit_paged_scatter = jax.jit(self._paged_scatter)
        # donate the cache: the copy is always fed a fresh intermediate (a
        # scatter output) and donating lets the inner Pallas aliasing move
        # only the touched blocks instead of round-tripping the whole pool
        self._jit_block_copy = jax.jit(
            M.copy_kv_blocks, static_argnames=("impl",), donate_argnums=(0,)
        )
        # per-row sampling with per-trajectory stream keys: each member's
        # first token is a function of (its key, its logits row) only
        self._jit_sample = jax.jit(
            lambda lg, ks: sample_rows(lg, ks, temperature=self.temperature)
        )
        # suffix-prefill dispatches, one per (suffix bucket, batch rows)
        self._suffix_steps: Dict[Tuple[int, int], Any] = {}

    def bucket_of(self, n_tokens: int) -> int:
        return min(round_up(max(n_tokens, 1), self.prefill_bucket), self.max_len)

    def _paged_scatter(self, cache, row_cache, slots, row_ids, flat_blocks):
        """Scatter a contiguous prefill row cache into the paged layout:
        per-slot entries land at their slot rows, K/V rows are re-blocked
        and written to the pool at the jobs' block tables (padding entries
        target the null block — a masked garbage sink).

        ``slots``/``row_ids`` are member-expanded: group admission writes
        one prefill row's small state (``pos``, hybrid/audio slot caches)
        to *every* member slot (``row_ids`` names each member's source
        row); plain waves pass the identity mapping."""
        small = {n: v for n, v in cache.items() if n not in ("k", "v")}
        rows = gather_rows(
            {n: v for n, v in row_cache.items() if n not in ("k", "v")},
            row_ids,
        )
        out = scatter_rows(small, rows, slots)
        l, r, s, hkv, hd = row_cache["k"].shape
        bs = cache["k"].shape[2]
        rk = row_cache["k"].reshape(l, r * (s // bs), bs, hkv, hd)
        rv = row_cache["v"].reshape(l, r * (s // bs), bs, hkv, hd)
        out["k"] = cache["k"].at[:, flat_blocks].set(rk.astype(cache["k"].dtype))
        out["v"] = cache["v"].at[:, flat_blocks].set(rv.astype(cache["v"].dtype))
        if self.pool_sharding is not None:
            out["k"] = jax.lax.with_sharding_constraint(
                out["k"], self.pool_sharding
            )
            out["v"] = jax.lax.with_sharding_constraint(
                out["v"], self.pool_sharding
            )
        return out

    def copy_blocks(
        self, cache: Cache, copies: Sequence[Tuple[int, int]]
    ) -> Cache:
        """Device-copy pool blocks ``src -> dst``, padded to a power-of-two
        copy count aimed at the null garbage block to bound compiled
        shapes. Used for eager CoW tails at admission and by the engine's
        lazy copy-at-first-divergence."""
        pad = next_pow2(len(copies)) - len(copies)
        src = [s for s, _ in copies] + [self.paged_null_block] * pad
        dst = [d for _, d in copies] + [self.paged_null_block] * pad
        return self._jit_block_copy(
            cache,
            jnp.asarray(src, jnp.int32),
            jnp.asarray(dst, jnp.int32),
            impl=self.impl,
        )

    def _suffix_step(self, bucket: int, n: int):
        """Jitted suffix prefill for ``n`` single-member fork jobs whose
        padded suffixes fit ``bucket`` tokens: gather the members' small
        state rows, run ``paged_prefill_step`` against the shared pools,
        scatter the advanced positions back."""
        fn = self._suffix_steps.get((bucket, n))
        if fn is None:
            def step(params, cache, rows, slots, tables, q_off, res, lens):
                view = {
                    "pos": cache["pos"][slots],
                    "k": cache["k"],
                    "v": cache["v"],
                }
                logits, new = M.paged_prefill_step(
                    self.cfg, gather_params(params), rows, view,
                    tables, q_off, res, lens, impl=self.impl,
                )
                out = {
                    nm: v for nm, v in cache.items() if nm not in ("k", "v")
                }
                out["pos"] = cache["pos"].at[slots].set(new["pos"])
                out["k"], out["v"] = new["k"], new["v"]
                if self.pool_sharding is not None:
                    out["k"] = jax.lax.with_sharding_constraint(
                        out["k"], self.pool_sharding
                    )
                    out["v"] = jax.lax.with_sharding_constraint(
                        out["v"], self.pool_sharding
                    )
                return logits, out

            fn = jax.jit(step)
            self._suffix_steps[(bucket, n)] = fn
        return fn

    def _run_suffix(
        self, params: Any, cache: Cache, jobs: Sequence[PrefillJob],
        offsets: Dict[int, int], result: PrefillResult,
    ) -> Cache:
        """Admit suffix-mode fork jobs: forward only each job's suffix
        against its donor's resident prefix blocks. Jobs are bucketed by
        (padded suffix length, table width) — one dispatch per bucket.

        The table width is ``ceil(full-prompt bucket / block_size)``, NOT
        the pool-wide ``max_len // block_size``: the gathered attention
        window must reduce over exactly as many K/V rows as the regular
        prefill's flash attention does for the same prompt, or the float
        summation grouping (softmax denominator, probs@V contraction)
        differs at the ulp level and the fork is no longer bit-for-bit
        equal to the full-prefill path."""
        by_bucket: Dict[Tuple[int, int], List[PrefillJob]] = {}
        order: List[Tuple[int, int]] = []
        for job in jobs:
            sb = self.bucket_of(len(job.tokens) - job.suffix_start)
            nbw = -(-self.bucket_of(len(job.tokens)) // self.paged_block_size)
            key = (sb, nbw)
            if key not in by_bucket:
                by_bucket[key] = []
                order.append(key)
            by_bucket[key].append(job)
        for b, nb in order:
            group = by_bucket[(b, nb)]
            n = len(group)
            rows = np.zeros((n, b), np.int32)
            q_off = np.zeros((n,), np.int32)
            res = np.zeros((n,), np.int32)
            lens = np.zeros((n,), np.int32)
            tables = np.full((n, nb), self.paged_null_block, np.int32)
            for r, job in enumerate(group):
                sfx = job.tokens[job.suffix_start:]
                rows[r, : len(sfx)] = sfx
                q_off[r] = job.suffix_start
                res[r] = job.resident_tokens
                lens[r] = len(job.tokens)
                tables[r, : len(job.blocks)] = job.blocks
            slots = jnp.asarray([job.slot for job in group], jnp.int32)
            logits, cache = self._suffix_step(b, n)(
                params, cache, jnp.asarray(rows), slots,
                jnp.asarray(tables), jnp.asarray(q_off),
                jnp.asarray(res), jnp.asarray(lens),
            )
            keys = jnp.stack([job.key for job in group])
            toks, blps = self._jit_sample(logits, keys)
            toks_np = np.asarray(toks)
            blps_np = np.asarray(blps)
            for r, job in enumerate(group):
                base = offsets[id(job)]
                result.tokens[base] = int(toks_np[r])
                result.logprobs[base] = float(blps_np[r])
                result.prefill_tokens += len(job.tokens) - job.suffix_start
        return cache

    def _groups(self, jobs: Sequence[PrefillJob]) -> List[List[PrefillJob]]:
        """Group jobs by padded bucket length, preserving admission order,
        splitting groups at ``batch_limit`` rows."""
        by_bucket: Dict[int, List[PrefillJob]] = {}
        order: List[int] = []
        for job in jobs:
            b = self.bucket_of(len(job.tokens))
            if b not in by_bucket:
                by_bucket[b] = []
                order.append(b)
            by_bucket[b].append(job)
        limit = self.batch_limit if self.batch_limit > 0 else len(jobs)
        groups: List[List[PrefillJob]] = []
        for b in order:
            g = by_bucket[b]
            groups.extend(g[i : i + limit] for i in range(0, len(g), limit))
        return groups

    def run(
        self, params: Any, cache: Cache, jobs: Sequence[PrefillJob]
    ) -> Tuple[Cache, PrefillResult]:
        """Prefill every job into its slot(s). Returns (cache, samples).

        The result lists are aligned with ``jobs`` flattened member-wise
        (not with the internal bucket grouping). Group jobs run their
        prompt through the model once; every member then samples its own
        first token from the shared logits row with its own key.
        """
        offsets: Dict[int, int] = {}
        total = 0
        for job in jobs:
            offsets[id(job)] = total
            total += job.n_members
        result = PrefillResult(tokens=[0] * total, logprobs=[0.0] * total)
        suffix_jobs = [j for j in jobs if j.suffix_start is not None]
        for job in suffix_jobs:
            if not self.paged_block_size or job.extra_slots:
                raise ValueError(
                    "suffix prefill requires the paged cache and "
                    "single-member jobs"
                )
        jobs = [j for j in jobs if j.suffix_start is None]
        copies: List[Tuple[int, int]] = []
        for group in self._groups(jobs):
            bucket = self.bucket_of(max(len(j.tokens) for j in group))
            rows = np.zeros((len(group), bucket), np.int32)
            lengths = np.zeros((len(group),), np.int32)
            for r, job in enumerate(group):
                rows[r, : len(job.tokens)] = job.tokens
                lengths[r] = len(job.tokens)
            fe = (
                self.frontend_fn(len(group))
                if self.frontend_fn is not None
                else None
            )
            row_cache = M.init_cache(self.cfg, len(group), self.max_len)
            logits, row_cache = self._jit_prefill(
                params,
                jnp.asarray(rows),
                jnp.asarray(lengths),
                row_cache,
                frontend_embeds=fe,
            )
            # member expansion: group jobs scatter one row's small state to
            # every member slot and sample per member off the shared row
            member_rows: List[int] = []
            member_slots: List[int] = []
            member_keys: List[jax.Array] = []
            for r, job in enumerate(group):
                if job.extra_slots and not self.paged_block_size:
                    raise ValueError("group prefill requires the paged cache")
                member_rows.extend([r] * job.n_members)
                member_slots.append(job.slot)
                member_slots.extend(job.extra_slots)
                member_keys.append(job.key)
                member_keys.extend(job.extra_keys)
                if job.tail_src is not None:
                    copies.extend((job.tail_src, d) for d in job.tail_dsts)
            expanded = len(member_rows) != len(group)
            slots = jnp.asarray(member_slots, jnp.int32)
            if self.paged_block_size:
                nb = self.max_len // self.paged_block_size
                flat = np.full((len(group) * nb,), self.paged_null_block,
                               np.int32)
                for r, job in enumerate(group):
                    flat[r * nb : r * nb + len(job.blocks)] = job.blocks
                cache = self._jit_paged_scatter(
                    cache, row_cache, slots,
                    jnp.asarray(member_rows, jnp.int32), jnp.asarray(flat),
                )
            else:
                cache = self._jit_scatter(cache, row_cache, slots)
            if expanded:
                logits = logits[jnp.asarray(member_rows, jnp.int32)]
            keys = jnp.stack(member_keys)
            toks, blps = self._jit_sample(logits, keys)
            toks_np = np.asarray(toks)
            blps_np = np.asarray(blps)
            m = 0
            for job in group:
                base = offsets[id(job)]
                for i in range(job.n_members):
                    result.tokens[base + i] = int(toks_np[m])
                    result.logprobs[base + i] = float(blps_np[m])
                    m += 1
                result.prefill_tokens += len(job.tokens)
        if copies:
            # eager CoW: duplicate prefilled tail blocks into each member's
            # private block
            cache = self.copy_blocks(cache, copies)
            result.tail_copies = len(copies)
        if suffix_jobs:
            cache = self._run_suffix(params, cache, suffix_jobs, offsets, result)
        return cache, result


@dataclass
class DecodeResult:
    """One decode step's outputs for the active slots (aligned lists)."""

    slots: List[int]
    tokens: np.ndarray           # (n_active,)
    logprobs: np.ndarray         # (n_active,)
    positions: np.ndarray        # (n_active,) post-step cache positions


class DecodeRunner:
    """Active-slot decode via *persistent* power-of-two compaction buckets.

    When every slot is active (or ``compact=False``) this is the seed
    engine's full-batch decode: all ``max_slots`` rows in place, inactive
    rows masked. When fewer are active, the active rows are gathered into a
    ``next_pow2(n_active)`` bucket **once** and decoded there step after
    step — decode FLOPs, cache-update traffic, and sampling all scale with
    the bucket, not ``max_slots``. The compact state is written back into
    the full cache only at structural changes (occupancy change, or an
    explicit ``flush`` before a prefill scatters new rows), so the steady
    state pays one jitted dispatch per step with bucket-sized buffers.

    Coherence contract: while compact state is live, the *active* rows of
    the full cache handed back by ``run`` are stale — callers that read or
    write cache rows directly (the prefill scatter) must call ``flush``
    first. ``run`` itself re-syncs automatically whenever the active-slot
    set changes.
    """

    def __init__(self, cfg: ArchConfig, *, max_slots: int, temperature: float = 1.0):
        self.cfg = cfg
        self.max_slots = max_slots
        self.temperature = temperature
        self._jit_decode = jax.jit(partial(M.decode_step, cfg))
        self._jit_gather = jax.jit(gather_rows)
        self._jit_sample = jax.jit(
            lambda lg, ks: sample_rows(lg, ks, temperature=self.temperature)
        )
        # fused row-gather + decode per (bucket, n_active): one dispatch
        # per steady-state step
        self._compact_steps: Dict[Tuple[int, int], Any] = {}
        self._flushes: Dict[Tuple[int, int], Any] = {}
        # persistent compact state: (ordered active slots, compact cache)
        self._rows: Optional[Tuple[int, ...]] = None
        self._rows_arr: Optional[jax.Array] = None   # padded device copy
        self._live_arr: Optional[jax.Array] = None
        self._compact: Optional[Cache] = None

    def bucket_of(self, n_active: int) -> int:
        return min(next_pow2(max(n_active, 1)), self.max_slots)

    # ------------------------------------------------------------ coherence
    def flush(self, cache: Cache) -> Cache:
        """Write live compact rows back into ``cache`` and drop the compact
        state. Call before touching cache rows externally (prefill scatter);
        a no-op when no compact state is held."""
        if self._compact is None:
            return cache
        n = len(self._rows)
        bucket = self.bucket_of(n)
        fn = self._flushes.get((bucket, n))
        if fn is None:
            def _flush(cache, compact, live):
                live_rows = {
                    name: jax.tree_util.tree_map(
                        lambda f: jax.lax.slice_in_dim(
                            f, 0, n, axis=BATCH_AXIS[name]
                        ),
                        val,
                    )
                    for name, val in compact.items()
                }
                return scatter_rows(cache, live_rows, live)

            fn = jax.jit(_flush)
            self._flushes[(bucket, n)] = fn
        cache = fn(cache, self._compact, self._live_arr)
        self._rows = self._rows_arr = self._live_arr = None
        self._compact = None
        return cache

    def _compact_step(self, bucket: int, n: int):
        key = (bucket, n)
        fn = self._compact_steps.get(key)
        if fn is None:
            def step(params, last_tokens, compact, rows):
                logits, new_compact = M.decode_step(
                    self.cfg, params, last_tokens[rows], compact
                )
                return logits, new_compact, new_compact["pos"][:n]

            fn = jax.jit(step)
            self._compact_steps[key] = fn
        return fn

    # ----------------------------------------------------------------- step
    def run(
        self,
        params: Any,
        cache: Cache,
        active: Sequence[int],
        last_tokens: jax.Array,      # (max_slots,)
        keys: jax.Array,             # (n_active, 2) per-slot stream keys
        *,
        compact: bool = True,
    ) -> Tuple[Cache, jax.Array, DecodeResult]:
        """One decode step over ``active`` slots.

        Returns (cache, last_tokens, result); ``last_tokens`` rows of
        inactive slots are preserved, as are their cache positions.
        ``keys`` are per-slot trajectory stream keys aligned with
        ``active`` — pad/inactive rows reuse the first key, their draws
        are discarded.
        """
        active = list(active)
        n = len(active)
        bucket = self.max_slots if not compact else self.bucket_of(n)
        if bucket >= self.max_slots:
            cache = self.flush(cache)
            return self._run_full(params, cache, active, last_tokens, keys)

        rows_key = tuple(active)
        if self._rows != rows_key:
            # occupancy changed: sync the old compact state back, gather the
            # new active rows (padded with duplicates of the first row; the
            # pads decode too but are never written back)
            cache = self.flush(cache)
            self._rows_arr = jnp.asarray(
                active + [active[0]] * (bucket - n), jnp.int32
            )
            self._live_arr = jnp.asarray(active, jnp.int32)
            self._compact = self._jit_gather(cache, self._rows_arr)
            self._rows = rows_key
        logits, self._compact, pos_live = self._compact_step(bucket, n)(
            params, last_tokens, self._compact, self._rows_arr
        )
        keys_pad = pad_keys(keys, bucket)
        tokens, blps = self._jit_sample(logits, keys_pad)
        last_tokens = last_tokens.at[self._live_arr].set(tokens[:n])
        return cache, last_tokens, DecodeResult(
            slots=active,
            tokens=np.asarray(tokens[:n]),
            logprobs=np.asarray(blps[:n]),
            positions=np.asarray(pos_live),
        )

    def _run_full(self, params, cache, active, last_tokens, keys):
        """Seed path: decode all ``max_slots`` rows, mask inactive ones."""
        prev_pos = cache["pos"]
        logits, new_cache = self._jit_decode(params, last_tokens, cache)
        mask = np.zeros((self.max_slots,), bool)
        mask[active] = True
        mask_j = jnp.asarray(mask)
        new_cache["pos"] = jnp.where(mask_j, new_cache["pos"], prev_pos)
        keys_full = scatter_keys(keys, active, self.max_slots)
        tokens, blps = self._jit_sample(logits, keys_full)
        last_tokens = jnp.where(mask_j, tokens, last_tokens)
        tokens_np = np.asarray(tokens)
        blps_np = np.asarray(blps)
        pos_np = np.asarray(new_cache["pos"])
        return new_cache, last_tokens, DecodeResult(
            slots=list(active),
            tokens=tokens_np[active],
            logprobs=blps_np[active],
            positions=pos_np[active],
        )


class PagedDecodeRunner:
    """Active-slot decode over a block-paged KV pool.

    The pool is shared by every slot, so — unlike ``DecodeRunner`` — no
    cache rows need gathering or persistent compaction for the KV itself:
    the per-step block-table array *is* the compaction. Active slots are
    still bucketed to ``next_pow2(n_active)`` rows so matmul cost scales
    with occupancy; only the small per-slot entries (``pos``, hybrid
    conv/ssm, audio cross caches) are gathered/scattered each step, inside
    the same jitted dispatch. There is no compact state held between steps,
    hence no ``flush`` coherence protocol either.

    Pad rows duplicate the first active slot's token/position but point
    their block tables at the null block, so their writes land in the
    garbage sink and their outputs are sliced away.
    """

    def __init__(
        self,
        cfg: ArchConfig,
        *,
        max_slots: int,
        blocks_per_seq: int,
        null_block: int = 0,
        temperature: float = 1.0,
        impl: Optional[str] = None,            # kernels.ops dispatch override
        pool_sharding: Optional[Any] = None,   # pin paged K/V layout (TP)
    ):
        self.cfg = cfg
        self.max_slots = max_slots
        self.nb = blocks_per_seq
        self.null_block = null_block
        self.temperature = temperature
        self.impl = impl
        self.pool_sharding = pool_sharding
        self._steps: Dict[Tuple[int, int], Any] = {}
        self._jit_sample = jax.jit(
            lambda lg, ks: sample_rows(lg, ks, temperature=self.temperature)
        )

    def bucket_of(self, n_active: int) -> int:
        return min(next_pow2(max(n_active, 1)), self.max_slots)

    def _step(self, bucket: int, n: int):
        fn = self._steps.get((bucket, n))
        if fn is None:
            def step(params, last_tokens, cache, rows, live, tables):
                small = {
                    nm: v for nm, v in cache.items() if nm not in ("k", "v")
                }
                view = gather_rows(small, rows)
                view["k"], view["v"] = cache["k"], cache["v"]
                logits, new = M.paged_decode_step(
                    self.cfg, gather_params(params), last_tokens[rows],
                    view, tables, impl=self.impl,
                )
                live_rows = {
                    nm: jax.tree_util.tree_map(
                        lambda f: jax.lax.slice_in_dim(
                            f, 0, n, axis=BATCH_AXIS[nm]
                        ),
                        new[nm],
                    )
                    for nm in small
                }
                out = scatter_rows(small, live_rows, live)
                out["k"], out["v"] = new["k"], new["v"]
                if self.pool_sharding is not None:
                    out["k"] = jax.lax.with_sharding_constraint(
                        out["k"], self.pool_sharding
                    )
                    out["v"] = jax.lax.with_sharding_constraint(
                        out["v"], self.pool_sharding
                    )
                return logits, out, new["pos"][:n]

            fn = jax.jit(step)
            self._steps[(bucket, n)] = fn
        return fn

    def run(
        self,
        params: Any,
        cache: Cache,
        active: Sequence[int],
        block_tables: Dict[int, Sequence[int]],   # slot -> block table
        last_tokens: jax.Array,                   # (max_slots,)
        keys: jax.Array,                          # (n_active, 2) stream keys
    ) -> Tuple[Cache, jax.Array, DecodeResult]:
        """One decode step over ``active`` slots. Returns
        (cache, last_tokens, result)."""
        active = list(active)
        n = len(active)
        bucket = self.bucket_of(n)
        rows = active + [active[0]] * (bucket - n)
        tables = np.full((bucket, self.nb), self.null_block, np.int32)
        for r, slot in enumerate(active):
            bt = block_tables[slot]
            tables[r, : len(bt)] = bt
        live = jnp.asarray(active, jnp.int32)
        logits, cache, pos_live = self._step(bucket, n)(
            params, last_tokens, cache,
            jnp.asarray(rows, jnp.int32), live, jnp.asarray(tables),
        )
        tokens, blps = self._jit_sample(logits, pad_keys(keys, bucket))
        last_tokens = last_tokens.at[live].set(tokens[:n])
        return cache, last_tokens, DecodeResult(
            slots=active,
            tokens=np.asarray(tokens[:n]),
            logprobs=np.asarray(blps[:n]),
            positions=np.asarray(pos_live),
        )
