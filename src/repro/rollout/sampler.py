"""Token sampling for the rollout engine.

Two layers:

* ``sample`` / ``sample_rows`` — logits -> (token, behavior logprob), one
  key per row.
* **Per-trajectory key streams** (``stream_key`` / ``stream_keys``): the
  key for a trajectory's ``p``-th sampled token is
  ``fold_in(fold_in(base_key, traj_id), p)`` — a pure function of
  ``(seed, traj_id, position)``. Stochastic decode is therefore invariant
  under batch composition (slot compaction), instance placement, and
  interrupt/migrate re-prefill: wherever and with whomever a trajectory is
  batched, token ``p`` draws from the same key. (The seed engine instead
  split one engine-global key per step across the whole batch, so a
  trajectory's tokens depended on its slot index and on every admission
  that ever advanced the engine key.)
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def sample(
    logits: jax.Array,          # (B, V)
    key: jax.Array,
    *,
    temperature: float = 1.0,
    top_k: int = 0,
) -> Tuple[jax.Array, jax.Array]:
    """Returns (tokens (B,), behavior logprobs (B,)).

    Behavior logprobs are ALWAYS from the untempered distribution the policy
    gradient targets (log softmax of raw logits at the sampled token) — the
    temperature only shapes exploration, matching standard RLHF practice.
    """
    lp_raw = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    if temperature <= 0.0:
        tokens = jnp.argmax(logits, axis=-1)
    else:
        scaled = logits.astype(jnp.float32) / temperature
        if top_k > 0:
            kth = jnp.sort(scaled, axis=-1)[:, -top_k][:, None]
            scaled = jnp.where(scaled < kth, -jnp.inf, scaled)
        tokens = jax.random.categorical(key, scaled, axis=-1)
    blp = jnp.take_along_axis(lp_raw, tokens[:, None], axis=-1)[:, 0]
    return tokens.astype(jnp.int32), blp


def sample_rows(
    logits: jax.Array,          # (B, V)
    keys: jax.Array,            # (B, 2) one PRNG key per row
    *,
    temperature: float = 1.0,
    top_k: int = 0,
) -> Tuple[jax.Array, jax.Array]:
    """Row-wise ``sample`` with an independent key per row.

    Each row's draw is a function of its own key only, so the result for a
    given (logits row, key) pair is identical no matter which other rows
    share the batch — the property per-slot key streams rely on.
    """
    toks, blps = jax.vmap(
        lambda lg, k: sample(lg[None], k, temperature=temperature, top_k=top_k)
    )(logits, keys)
    return toks[:, 0], blps[:, 0]


# --------------------------------------------------- per-trajectory streams
def stream_key(
    base_key: jax.Array, traj_id: int, position: int
) -> jax.Array:
    """Key for trajectory ``traj_id``'s ``position``-th sampled token."""
    return _fold2(base_key, jnp.uint32(traj_id), jnp.uint32(position))


def stream_keys(
    base_key: jax.Array,
    traj_ids: jax.Array,        # (B,)
    positions: jax.Array,       # (B,)
) -> jax.Array:
    """Batched ``stream_key``: (B, 2) keys, one per (trajectory, position)."""
    return _fold2_v(base_key, traj_ids, positions)


@jax.jit
def _fold2(base_key, traj_id, position):
    return jax.random.fold_in(jax.random.fold_in(base_key, traj_id), position)


@jax.jit
def _fold2_v(base_key, traj_ids, positions):
    return jax.vmap(lambda i, p: _fold2(base_key, i, p))(traj_ids, positions)
