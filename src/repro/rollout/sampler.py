"""Token sampling for the rollout engine."""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def sample(
    logits: jax.Array,          # (B, V)
    key: jax.Array,
    *,
    temperature: float = 1.0,
    top_k: int = 0,
) -> Tuple[jax.Array, jax.Array]:
    """Returns (tokens (B,), behavior logprobs (B,)).

    Behavior logprobs are ALWAYS from the untempered distribution the policy
    gradient targets (log softmax of raw logits at the sampled token) — the
    temperature only shapes exploration, matching standard RLHF practice.
    """
    lp_raw = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    if temperature <= 0.0:
        tokens = jnp.argmax(logits, axis=-1)
    else:
        scaled = logits.astype(jnp.float32) / temperature
        if top_k > 0:
            kth = jnp.sort(scaled, axis=-1)[:, -top_k][:, None]
            scaled = jnp.where(scaled < kth, -jnp.inf, scaled)
        tokens = jax.random.categorical(key, scaled, axis=-1)
    blp = jnp.take_along_axis(lp_raw, tokens[:, None], axis=-1)[:, 0]
    return tokens.astype(jnp.int32), blp
