"""Multi-device sharded rollout backend: one instance = a pod, not a chip.

StaleFlow's rollout "instances" are resource pools behind data servers
(PAPER.md §4) — a single replica of the serving engine can span many
accelerators, the way Laminar/AsyncFlow deploy multi-GPU rollout replicas.
``ShardedBackend`` makes that real for this engine: it is the paged
``RolloutInstance`` with its data plane laid out SPMD over a 1-D
``("tensor",)`` mesh (``repro.launch.mesh.make_rollout_mesh``):

* **params** — *stored* column-sharded where output dimensions split
  cleanly (attention heads on wq/wk/wv, SwiGLU hidden on w_gate/w_up,
  vocab on lm_head; specs from
  ``repro.distributed.sharding.rollout_param_spec``) and gathered
  replicated just-in-time inside each jitted step
  (``ctx.gather_params``, ZeRO-3 style): per-device parameter HBM
  shrinks, while every matmul still runs full-width — a column-sharded
  matmul is not bitwise-stable against its full-width counterpart (XLA
  picks micro-kernels per output width), and bitwise is the contract
  here.
* **paged K/V pool** — sharded on its KV-head axis
  (``paged_pool_spec``): every device holds the full block structure but
  only ``Hkv / shard_count`` heads per block. Block tables, the
  refcounted allocator, CoW prefix sharing, and preemption stay host-side
  and *unchanged* — sharding is invisible to the control plane.
* **compute** — prefill/decode run through ``ShardedPrefillRunner`` /
  ``ShardedPagedDecodeRunner``, which enter ``ctx.rollout_sharding`` so
  the traced model gathers activations to replicated form before any
  contraction would cross a sharded dimension (``ctx.gather``).

Bitwise contract: no reduction is ever partitioned — attention is
per-head, softmax runs over the (unsharded) sequence axis, and every
matmul contracts over a replicated dimension — so greedy decode is
**bit-for-bit** equal to the single-device paged engine (tokens *and*
behavior logprobs), across batched admission, CoW prefix sharing, and
preemption. ``tests/test_sharded_backend.py`` pins this on 8 forced host
devices.

Memory plane: ``kv_budget`` and every reported byte figure are
*per-device* — the engine charges ``k5 / shard_count`` per token, and
``snapshot().kv_cache`` matches what ``SimBackend``/``CostModel`` compute
at the same ``shard_count``, so the coordinator balances pods and chips
with one consistent HBM picture.

The runners force ``impl="ref"`` through the kernels dispatch: the
jnp reference paths are pure XLA and partition automatically under
GSPMD, while the Pallas TPU kernels would need an explicit shard_map
wrapping (future work — on CPU CI this is the default path anyway).
"""

from __future__ import annotations

from typing import Any, Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.distributed import ctx
from repro.distributed.sharding import (
    ROLLOUT_AXIS,
    paged_cache_shardings,
    paged_pool_spec,
    rollout_params_shardings,
    validate_rollout_shards,
)
from repro.rollout.engine import RolloutInstance
from repro.rollout.runners import PagedDecodeRunner, PrefillRunner


class ShardedPrefillRunner(PrefillRunner):
    """``PrefillRunner`` traced under the rollout tensor-parallel context.

    The prompt forward itself is replicated work (its inputs are host
    token ids and column-sharded weights — the ``ctx.gather`` boundaries
    keep activations replicated between projections); the paged re-block
    scatter and the CoW tail copy land on the head-sharded pool, pinned
    by ``pool_sharding`` so each device writes only its head slice.
    """

    def __init__(self, *args: Any, mesh: Mesh, **kw: Any):
        super().__init__(*args, impl="ref", **kw)
        self.mesh = mesh

    def run(self, params, cache, jobs):
        with ctx.rollout_sharding(self.mesh):
            return super().run(params, cache, jobs)


class ShardedPagedDecodeRunner(PagedDecodeRunner):
    """``PagedDecodeRunner`` traced under the rollout tensor-parallel
    context: per-shard paged attention over the head-sharded pool (block
    tables replicate to every device), head outputs gathered at the
    ``wo`` boundary, K/V writes pinned to the pool layout."""

    def __init__(self, *args: Any, mesh: Mesh, **kw: Any):
        super().__init__(*args, impl="ref", **kw)
        self.mesh = mesh

    def run(self, params, cache, active, block_tables, last_tokens, keys):
        with ctx.rollout_sharding(self.mesh):
            return super().run(
                params, cache, active, block_tables, last_tokens, keys
            )


def _check_mesh(mesh: Mesh, shard_count: int) -> None:
    if ROLLOUT_AXIS not in mesh.shape:
        raise ValueError(
            f"rollout mesh must carry a {ROLLOUT_AXIS!r} axis, got "
            f"{dict(mesh.shape)}"
        )
    if mesh.shape[ROLLOUT_AXIS] != shard_count:
        raise ValueError(
            f"mesh {ROLLOUT_AXIS!r} axis has {mesh.shape[ROLLOUT_AXIS]} "
            f"devices but shard_count is {shard_count}"
        )


class ShardedBackend(RolloutInstance):
    """A paged ``RolloutInstance`` spanning ``shard_count`` devices.

    Drop-in ``EngineBackend``: the coordinator command stream, admission
    policy, group prefix sharing, and preemption semantics are inherited
    unchanged — only array placement and the runner data plane differ.
    ``kv_budget`` is **per device**; pass ``mesh`` to colocate several
    instances on one device set, otherwise a fresh
    ``make_rollout_mesh(shard_count)`` over the first ``shard_count``
    local devices is built.
    """

    def __init__(
        self,
        inst_id: int,
        cfg: Any,
        params: Any,
        version: int,
        *,
        shard_count: int,
        mesh: Optional[Mesh] = None,
        paged: bool = True,
        **kw: Any,
    ):
        if not paged:
            raise ValueError(
                "ShardedBackend shards the paged K/V pool; paged=False has "
                "no pool to shard (use the 'jax' backend instead)"
            )
        validate_rollout_shards(
            shard_count, n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads
        )
        if mesh is None:
            from repro.launch.mesh import make_rollout_mesh

            mesh = make_rollout_mesh(shard_count)
        _check_mesh(mesh, shard_count)
        self.mesh = mesh
        super().__init__(
            inst_id,
            cfg,
            params,
            version,
            paged=True,
            shard_count=shard_count,
            **kw,
        )
        self._replicated = NamedSharding(mesh, P())
        self.params = self._place_params(params)
        cache_sh = paged_cache_shardings(mesh, self.cache)
        self.cache = jax.device_put(self.cache, cache_sh)
        self._last_tokens = jax.device_put(self._last_tokens, self._replicated)

    # ----------------------------------------------------- runner factories
    # called from RolloutInstance.__init__ (self.mesh and self.cache are
    # already set): one construction site, sharded variants swapped in
    def _pool_sharding(self) -> NamedSharding:
        return NamedSharding(
            self.mesh, paged_pool_spec(self.mesh, self.cache["k"].shape)
        )

    def _make_prefill_runner(self, cfg: Any, **kw: Any) -> ShardedPrefillRunner:
        return ShardedPrefillRunner(
            cfg, mesh=self.mesh, pool_sharding=self._pool_sharding(), **kw
        )

    def _make_paged_decode_runner(
        self, cfg: Any, **kw: Any
    ) -> ShardedPagedDecodeRunner:
        return ShardedPagedDecodeRunner(
            cfg, mesh=self.mesh, pool_sharding=self._pool_sharding(), **kw
        )

    # ------------------------------------------------------------ placement
    def _place_params(self, params: Any) -> Any:
        return jax.device_put(params, rollout_params_shardings(self.mesh, params))

    def pull(self, params: Any, version: int, now: float = 0.0) -> None:
        """Adopt a new parameter version, re-sharding it onto the pod
        (the PS publishes host/replicated trees)."""
        super().pull(self._place_params(params), version, now)

    # ------------------------------------------------------------- geometry
    def shard_sizes(self) -> Sequence[Tuple[int, ...]]:
        """Per-device K-pool shard shapes — test/debug introspection."""
        return [s.data.shape for s in self.cache["k"].addressable_shards]
