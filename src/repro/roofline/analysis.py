"""Three-term roofline analysis from compiled dry-run artifacts.

This container is CPU-only; TPU v5e is the TARGET. Wall-clock MFU cannot be
measured, so the report derives the three roofline terms from the compiled
module (per §Roofline of the assignment):

    compute    = FLOPs_per_chip / peak_FLOPs        [s]
    memory     = HBM_bytes_per_chip / HBM_bw        [s]
    collective = collective_bytes_per_chip / ICI_bw [s]

Sources: ``compiled.cost_analysis()`` supplies FLOPs and bytes accessed of
the (SPMD-partitioned, hence per-chip) module; collective bytes are parsed
from ``compiled.as_text()`` by summing operand sizes of every all-gather /
all-reduce / reduce-scatter / all-to-all / collective-permute op.

The dominant term is the bottleneck the perf loop (§Perf) iterates on.
``MODEL_FLOPS`` (6·N·D dense / 6·N_active·D MoE for training; 2·N·D for
inference) over total HLO FLOPs measures how much compiled compute is
"useful" — catching remat/redundancy waste.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict

# TPU v5e hardware constants (per chip)
PEAK_FLOPS_BF16 = 197e12      # FLOP/s
HBM_BW = 819e9                # B/s
ICI_BW = 50e9                 # B/s per link

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8,
    "f8e4m3": 1, "f8e5m2": 1, "bf16": 2, "f16": 2, "f32": 4, "f64": 8,
    "c64": 8, "c128": 16,
}

_COLLECTIVES = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

# matches a typed operand like  bf16[8,128,4096]{2,1,0}  or  f32[]
_TYPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    if dtype not in _DTYPE_BYTES:
        return 0
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


_COLL_LINE_RE = re.compile(
    r"=\s*(?P<result>\([^)]*\)|[a-z0-9]+\[[0-9,]*\][^\s]*)\s+"
    r"(?P<kind>all-gather|all-reduce|reduce-scatter|all-to-all|"
    r"collective-permute)(?P<variant>-start|-done)?\("
)
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_EXPLICIT_RE = re.compile(r"replica_groups=\{\{([0-9, ]+)\}")


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_RE.search(line)
    if m:
        return int(m.group(2))  # [n_groups, group_size]<=iota
    m = _GROUPS_EXPLICIT_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return default


def collective_bytes(hlo_text: str, *, n_devices: int = 1) -> Dict[str, int]:
    """Per-device collective operand bytes, from post-partitioning HLO.

    Post-optimization HLO prints operands as bare names, so sizes come from
    the RESULT type + the replica group size:
      all-gather:         operand = result / group
      reduce-scatter:     operand = result * group
      all-reduce / all-to-all / collective-permute: operand = result.
    Async (-start/-done) pairs are counted once (at -start).
    """
    out = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        m = _COLL_LINE_RE.search(line)
        if not m or m.group("variant") == "-done":
            continue
        kind = m.group("kind")
        result_bytes = sum(
            _shape_bytes(d, dims) for d, dims in _TYPE_RE.findall(m.group("result"))
        )
        group = _group_size(line, n_devices)
        if kind == "all-gather":
            nbytes = result_bytes // max(group, 1)
        elif kind == "reduce-scatter":
            nbytes = result_bytes * group
        else:
            nbytes = result_bytes
        out[kind] += nbytes
    return out


@dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    flops_per_chip: float
    hbm_bytes_per_chip: float
    coll_bytes_per_chip: float
    coll_breakdown: Dict[str, int] = field(default_factory=dict)
    model_flops_total: float = 0.0
    peak_flops: float = PEAK_FLOPS_BF16
    hbm_bw: float = HBM_BW
    ici_bw: float = ICI_BW

    @property
    def t_compute(self) -> float:
        return self.flops_per_chip / self.peak_flops

    @property
    def t_memory(self) -> float:
        return self.hbm_bytes_per_chip / self.hbm_bw

    @property
    def t_collective(self) -> float:
        return self.coll_bytes_per_chip / self.ici_bw

    @property
    def dominant(self) -> str:
        terms = {
            "compute": self.t_compute,
            "memory": self.t_memory,
            "collective": self.t_collective,
        }
        return max(terms, key=terms.get)

    @property
    def step_time_lower_bound(self) -> float:
        """Perfect-overlap execution: bounded by the max term."""
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def useful_flops_ratio(self) -> float:
        total_hlo = self.flops_per_chip * self.chips
        return self.model_flops_total / total_hlo if total_hlo else 0.0

    @property
    def roofline_fraction(self) -> float:
        """How close perfect execution of the *useful* math would be to the
        dominant-resource bound: useful_time / step_lower_bound."""
        useful_t = (self.model_flops_total / self.chips) / self.peak_flops
        lb = self.step_time_lower_bound
        return useful_t / lb if lb else 0.0

    def to_dict(self) -> Dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "chips": self.chips,
            "flops_per_chip": self.flops_per_chip,
            "hbm_bytes_per_chip": self.hbm_bytes_per_chip,
            "coll_bytes_per_chip": self.coll_bytes_per_chip,
            "coll_breakdown": self.coll_breakdown,
            "model_flops_total": self.model_flops_total,
            "t_compute": self.t_compute,
            "t_memory": self.t_memory,
            "t_collective": self.t_collective,
            "dominant": self.dominant,
            "useful_flops_ratio": self.useful_flops_ratio,
            "roofline_fraction": self.roofline_fraction,
        }


def model_flops(cfg, kind: str, global_batch: int, seq_len: int) -> float:
    """6·N_active·D train; 2·N_active·D prefill; 2·N_active·B decode."""
    n = cfg.n_active_params
    if kind == "train":
        return 6.0 * n * global_batch * seq_len
    if kind == "prefill":
        return 2.0 * n * global_batch * seq_len
    return 2.0 * n * global_batch  # decode: one token per sequence
