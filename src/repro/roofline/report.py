"""Render the §Dry-run / §Roofline markdown tables from dry-run JSON records.

    PYTHONPATH=src python -m repro.roofline.report results/dryrun_final
"""
from __future__ import annotations

import json
import os
import sys
from typing import Dict, List


def load(out_dir: str) -> List[Dict]:
    recs = []
    for name in sorted(os.listdir(out_dir)):
        if name.endswith(".json"):
            with open(os.path.join(out_dir, name)) as f:
                recs.append(json.load(f))
    return recs


def fmt_bytes(b: float) -> str:
    if b >= 1e9:
        return f"{b/1e9:.2f}GB"
    if b >= 1e6:
        return f"{b/1e6:.1f}MB"
    return f"{b/1e3:.0f}KB"


def dryrun_table(recs: List[Dict]) -> str:
    rows = [
        "| arch | shape | mesh | status | compile | temp/chip | args/chip | fits 16GB | accum |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in recs:
        if r["status"] == "unsupported":
            rows.append(
                f"| {r['arch']} | {r['shape']} | {r['mesh']} | skip (documented) "
                f"| - | - | - | - | - |"
            )
            continue
        if r["status"] != "ok":
            rows.append(
                f"| {r['arch']} | {r['shape']} | {r['mesh']} | **FAILED** | - | - | - | - | - |"
            )
            continue
        m = r["memory"]
        rows.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | ok "
            f"| {r.get('compile_s','-')}s "
            f"| {fmt_bytes(m.get('temp_bytes', 0))} "
            f"| {fmt_bytes(m.get('argument_bytes', 0))} "
            f"| {'yes' if r.get('fits_hbm') else 'NO'} "
            f"| {r.get('options', {}).get('accum_steps', 1)} |"
        )
    return "\n".join(rows)


def roofline_table(recs: List[Dict]) -> str:
    rows = [
        "| arch | shape | t_compute | t_memory | t_collective | dominant "
        "| useful FLOPs | roofline frac |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for r in recs:
        if r["status"] != "ok" or not r.get("roofline"):
            continue
        rl = r["roofline"]
        rows.append(
            f"| {r['arch']} | {r['shape']} "
            f"| {rl['t_compute']*1e3:.1f}ms "
            f"| {rl['t_memory']*1e3:.1f}ms "
            f"| {rl['t_collective']*1e3:.1f}ms "
            f"| {rl['dominant']} "
            f"| {rl['useful_flops_ratio']:.2f} "
            f"| {rl['roofline_fraction']:.3f} |"
        )
    return "\n".join(rows)


def summary(recs: List[Dict]) -> str:
    ok = sum(1 for r in recs if r["status"] == "ok")
    skip = sum(1 for r in recs if r["status"] == "unsupported")
    fail = sum(1 for r in recs if r["status"] not in ("ok", "unsupported"))
    fits = sum(1 for r in recs if r.get("fits_hbm"))
    return (
        f"cells: {ok} ok, {skip} documented skips, {fail} failed; "
        f"{fits}/{ok} fit the 16 GB/chip gate"
    )


def main() -> None:
    out_dir = sys.argv[1] if len(sys.argv) > 1 else "results/dryrun"
    recs = load(out_dir)
    print("## Summary\n")
    print(summary(recs))
    print("\n## Dry-run table (both meshes)\n")
    print(dryrun_table(recs))
    print("\n## Roofline table (single-pod, per-chip terms)\n")
    print(roofline_table([r for r in recs if r.get("mesh") == "single"]))


if __name__ == "__main__":
    main()
