"""Splice generated dry-run/roofline tables into EXPERIMENTS.md markers.

    PYTHONPATH=src python -m repro.roofline.splice results/dryrun_final
"""
from __future__ import annotations

import re
import sys

from repro.roofline.report import dryrun_table, load, roofline_table, summary


def main() -> None:
    out_dir = sys.argv[1] if len(sys.argv) > 1 else "results/dryrun_final"
    path = sys.argv[2] if len(sys.argv) > 2 else "EXPERIMENTS.md"
    recs = load(out_dir)
    singles = [r for r in recs if r.get("mesh") == "single"]

    with open(path) as f:
        text = f.read()

    dr = (
        f"**{summary(recs)}** (source: `{out_dir}/`)\n\n"
        + dryrun_table(recs)
    )
    rl = roofline_table(singles)
    text = re.sub(
        r"<!-- DRYRUN_TABLE -->(.|\n)*?(?=\n## §Roofline)",
        "<!-- DRYRUN_TABLE -->\n" + dr + "\n",
        text,
    )
    text = re.sub(
        r"<!-- ROOFLINE_TABLE -->(.|\n)*?(?=\n---\n\n## §Perf)",
        "<!-- ROOFLINE_TABLE -->\n" + rl + "\n",
        text,
    )
    with open(path, "w") as f:
        f.write(text)
    print(f"spliced tables from {out_dir} into {path}")


if __name__ == "__main__":
    main()
