"""End-to-end asynchronous RL runtime (the live counterpart of Fig. 6).

``AsyncRLRuntime`` is the user-facing facade over the service-oriented data
plane:

* ``repro.runtime.core.RuntimeCore`` — the wired service graph (trajectory
  server, parameter server, staleness manager, coordinator, reward server,
  N rollout instances, trainer) connected by the trajectory-lifecycle
  event bus;
* ``repro.runtime.schedulers`` — the control loop, selected by
  ``RuntimeConfig.scheduler``:

  - ``"tick"`` (default): the deterministic cooperative loop whose
    interleaving mirrors the disaggregated deployment::

        tick := [instances decode] -> [rewards] -> [coordinator cycle]
                -> [trainer consume/step/push] -> [TS refill]

  - ``"threaded"``: rollout instances, reward workers, the coordinator,
    and the trainer each on their own thread, with Push overlapped behind
    the next training step — the actually-asynchronous shape of the
    paper's architecture, with the same staleness guarantees.

Rollout instances only sync parameters when the coordinator issues Pull
(synchronization strategy), so training-vs-rollout version gaps — i.e.
data staleness — arise exactly as in the real system and are bounded by
the protocol. Convergence experiments (Fig. 3/14 analogs) run on this
runtime with tiny models; cluster-scale *throughput* claims use the
discrete-event simulator instead (repro.sim).

Fault tolerance & elasticity (DESIGN.md §3):
* ``fail_instance``  — drop a replica (legal mid-decode under the threaded
  scheduler); its resident trajectories return to the TS via INTERRUPTED
  lifecycle events and their protocol reservations survive untouched.
* ``add_instance``   — elastic scale-up; the newcomer Pulls from the PS
  and (threaded) gets its own decode thread at the next supervisor pass.
* ``checkpoint``/``restore`` — params + optimizer + protocol + service
  state (reward queue, retired payloads); restart may change instance
  count (elastic).
"""
from __future__ import annotations

from typing import Callable, List, Optional

from repro.configs.base import ArchConfig
from repro.runtime.config import RuntimeConfig, StepRecord
from repro.runtime.core import RuntimeCore
from repro.runtime.schedulers import (
    CooperativeScheduler,
    ThreadedScheduler,
    make_scheduler,
)

__all__ = [
    "AsyncRLRuntime",
    "RuntimeConfig",
    "StepRecord",
    "CooperativeScheduler",
    "ThreadedScheduler",
]


class AsyncRLRuntime(RuntimeCore):
    """RuntimeCore + the scheduler named by ``rcfg.scheduler``."""

    def __init__(self, cfg: ArchConfig, rcfg: RuntimeConfig):
        super().__init__(cfg, rcfg)
        self.scheduler = make_scheduler(rcfg.scheduler, self)

    # ------------------------------------------------------------- main loop
    def run(
        self,
        max_ticks: int = 100000,
        progress: Optional[Callable[[StepRecord], None]] = None,
    ) -> List[StepRecord]:
        sampler = None
        if self.tracer is not None:
            from repro.obs import FleetSampler

            sampler = FleetSampler(
                self, interval_s=self.rcfg.obs_sample_interval_s
            ).start()
        try:
            return self.scheduler.run(max_ticks, progress)
        finally:
            if sampler is not None:
                sampler.stop()
            if self.rcfg.trace_path:
                self.export_trace(self.rcfg.trace_path)

    def tick(self) -> None:
        """One cooperative tick (deterministic single-thread semantics).

        Only meaningful on the ``"tick"`` scheduler — the threaded
        scheduler owns its loops and cannot be single-stepped.
        """
        if not isinstance(self.scheduler, CooperativeScheduler):
            raise RuntimeError(
                "tick() requires the cooperative scheduler "
                "(RuntimeConfig.scheduler='tick')"
            )
        self.scheduler.tick()

    # back-compat alias (pre-service-layer name)
    def _train_once(self) -> Optional[StepRecord]:
        return self.train_once()
