"""End-to-end asynchronous RL runtime (the live counterpart of Fig. 6).

Wires every component: trajectory server, parameter server, staleness
manager, rollout coordinator, N rollout instances (real JAX engines),
rule-based reward, and the training worker — and drives them with a
cooperative scheduler whose interleaving mirrors the disaggregated
deployment:

  tick := [instances decode] -> [rewards] -> [coordinator cycle]
          -> [trainer consume/step/push] -> [TS refill]

Rollout instances only sync parameters when the coordinator issues Pull
(synchronization strategy), so training-vs-rollout version gaps — i.e.
data staleness — arise exactly as in the real system and are bounded by
the protocol. Convergence experiments (Fig. 3/14 analogs) run on this
runtime with tiny models; cluster-scale *throughput* claims use the
discrete-event simulator instead (repro.sim).

Fault tolerance & elasticity (DESIGN.md §3):
* ``fail_instance``  — drop a replica; its resident trajectories return to
  the TS (payloads live in Trajectory objects, migration is metadata-only)
  and their protocol reservations survive untouched.
* ``add_instance``   — elastic scale-up; the newcomer Pulls from the PS.
* ``checkpoint``/``restore_runtime`` — params + optimizer + protocol +
  in-flight TS payloads; restart may change instance count (elastic).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.core import (
    CostModel,
    ParameterServer,
    RolloutCoordinator,
    StalenessManager,
    StrategyConfig,
    StrategySuite,
    TrajectoryServer,
    prefix_routing_strategy,
    routing_strategy,
)
from repro.core.types import Trajectory, TrajStatus
from repro.data.tasks import ArithmeticDataset
from repro.models import model as M
from repro.reward.verifier import RewardModel
from repro.rl.advantages import group_advantages
from repro.rollout.backend import EngineBackend, create_backend, execute_commands
from repro.training import checkpoint as ckpt_lib
from repro.training.optimizer import AdamWConfig, init_opt_state
from repro.training.train_step import make_rl_train_step


@dataclass
class RuntimeConfig:
    eta: int = 1
    batch_size: int = 4                # protocol entries (groups) per step
    group_size: int = 2
    n_instances: int = 2
    max_slots: int = 4
    max_len: int = 64
    max_new_tokens: int = 12
    total_steps: int = 8
    lr: float = 1e-3
    temperature: float = 1.0
    seed: int = 0
    n_prompts: int = 4096
    objective: str = "dapo"
    filter_zero_signal: bool = False   # DAPO group filtering (Fig. 8c)
    suite: StrategySuite = field(default_factory=StrategySuite.staleflow)
    strategy_cfg: StrategyConfig = field(default_factory=StrategyConfig)
    snapshot_every: int = 1            # coordinator cycle cadence (ticks)
    decode_steps_per_tick: int = 4
    reward_fn: Optional[Callable] = None  # (prompt_ids, response_ids) -> float
    paged_kv: bool = False             # block-paged KV cache on the engines
    kv_block_size: int = 16            # tokens per KV block when paged
    # Prefix sharing (paged only): group members prefill their shared
    # prompt once, full prompt blocks are refcount-shared across member
    # block tables, and routing turns group-affine so members land where
    # the prefix lives (StrategySuite.prefix_sharing routing).
    share_prefix: bool = True
    # Devices per rollout instance (paged only): > 1 spans each instance
    # across a ("tensor",) mesh via the sharded backend — params and the
    # paged K/V pool head-sharded, per-device memory accounting. All
    # instances share one mesh over the first ``rollout_shards`` local
    # devices (the same way single-device instances share device 0).
    rollout_shards: int = 1


@dataclass
class StepRecord:
    step: int
    mean_reward: float
    loss: float
    mean_is_ratio: float
    staleness_hist: List[int]
    wall_time: float


class AsyncRLRuntime:
    def __init__(self, cfg: ArchConfig, rcfg: RuntimeConfig):
        self.cfg = cfg
        self.rcfg = rcfg
        key = jax.random.PRNGKey(rcfg.seed)
        self.params = M.init_params(cfg, key)
        self.opt_state = init_opt_state(self.params)
        self.train_step = jax.jit(
            make_rl_train_step(cfg, AdamWConfig(lr=rcfg.lr), objective=rcfg.objective)
        )

        self.dataset = ArithmeticDataset(rcfg.n_prompts, seed=rcfg.seed)
        if rcfg.reward_fn is not None:
            self.reward_model = type(
                "CustomReward", (), {"score": staticmethod(rcfg.reward_fn)}
            )()
        else:
            self.reward_model = RewardModel(
                lambda prompt: self.dataset.answer_for(prompt)
            )
        self.manager = StalenessManager(batch_size=rcfg.batch_size, eta=rcfg.eta)
        self.ts = TrajectoryServer(
            self.dataset.prompt_source(),
            capacity_groups=(rcfg.eta + 1) * rcfg.batch_size,
            group_size=rcfg.group_size,
            max_new_tokens=rcfg.max_new_tokens,
        )
        self.ps = ParameterServer()
        self.ps.push(self.params, 0)

        if rcfg.rollout_shards > 1 and not rcfg.paged_kv:
            raise ValueError(
                "rollout_shards > 1 requires paged_kv=True (the sharded "
                "backend shards the paged K/V pool)"
            )
        self._rollout_mesh = None
        if rcfg.rollout_shards > 1:
            from repro.launch.mesh import make_rollout_mesh

            self._rollout_mesh = make_rollout_mesh(rcfg.rollout_shards)
        k5 = 2.0 * cfg.n_layers * cfg.n_kv_heads * cfg.hd * 4
        # kv_budget is per device: the pod-wide pool (max_len * max_slots
        # worth of k5-sized tokens) spreads evenly over the head shards
        self.cost_model = CostModel(
            k1=1e-12, k2=1e-3, k3=1e-4, k4=5e-3, k5=k5,
            kv_budget=k5 * rcfg.max_len * rcfg.max_slots
            / rcfg.rollout_shards,
            block_size=rcfg.kv_block_size if rcfg.paged_kv else 1,
            shard_count=rcfg.rollout_shards,
        )
        group_filter = None
        if rcfg.filter_zero_signal:
            def group_filter(members: List[Trajectory]) -> bool:
                rs = [m.reward for m in members if m.reward is not None]
                return len(set(rs)) > 1
        suite = rcfg.suite
        if (
            rcfg.share_prefix
            and rcfg.paged_kv
            and rcfg.group_size > 1
            and suite.routing is routing_strategy
        ):
            # group-affine routing: members of one sampling group land on a
            # single instance so its paged engine prefills the prompt once
            import dataclasses as _dc

            suite = _dc.replace(suite, routing=prefix_routing_strategy)
        self.coordinator = RolloutCoordinator(
            self.manager,
            self.ts,
            cost_model=self.cost_model,
            cfg=rcfg.strategy_cfg,
            suite=suite,
            group_sampling=rcfg.group_size > 1,
            group_filter=group_filter,
        )

        self.instances: Dict[int, EngineBackend] = {}
        for i in range(rcfg.n_instances):
            self.instances[i] = self._new_instance(i)
        self.coordinator.spec.resync(self._snapshots())

        self.history: List[StepRecord] = []
        self.model_version = 0
        self._tick = 0
        self._retired: Dict[int, Trajectory] = {}
        self.ts.refill()
        # telemetry for the time-breakdown benchmark
        self.timers: Dict[str, float] = {
            "decode": 0.0, "prefill": 0.0, "reward": 0.0, "train": 0.0,
            "coordinator": 0.0, "pull": 0.0, "route": 0.0, "interrupt": 0.0,
        }

    # -------------------------------------------------------------- plumbing
    def _new_instance(self, inst_id: int) -> EngineBackend:
        kw = dict(
            cfg=self.cfg,
            params=self.ps.pull()[0],
            version=self.ps.version,
            max_slots=self.rcfg.max_slots,
            max_len=self.rcfg.max_len,
            kv_bytes_per_token=self.cost_model.k5,
            kv_budget=self.cost_model.kv_budget,
            temperature=self.rcfg.temperature,
            seed=self.rcfg.seed,
            paged=self.rcfg.paged_kv,
            kv_block_size=self.rcfg.kv_block_size,
            share_prefix=self.rcfg.share_prefix,
        )
        if self.rcfg.rollout_shards > 1:
            return create_backend(
                "sharded",
                inst_id,
                shard_count=self.rcfg.rollout_shards,
                mesh=self._rollout_mesh,
                **kw,
            )
        return create_backend("jax", inst_id, **kw)

    def _snapshots(self):
        return {i: inst.snapshot() for i, inst in self.instances.items()}

    # ------------------------------------------------------------- commands
    def _execute(self, commands) -> None:
        execute_commands(
            commands, self.instances, self.ts, self.ps, timers=self.timers
        )

    # ----------------------------------------------------------- the trainer
    def _train_once(self) -> Optional[StepRecord]:
        t0 = time.perf_counter()
        if not self.manager.ready():
            return None
        batch_ids = self.coordinator.try_consume()
        if batch_ids is None:
            return None
        # consume retires trajectories from the TS registry; payloads were
        # retained in ``self._retired`` at reward time
        trajs = [self._retired.pop(tid) for tid in batch_ids if tid in self._retired]
        batch = self._batch_from_trajs(trajs)
        if batch is None:
            return None
        self.params, self.opt_state, metrics = self.train_step(
            self.params, self.opt_state, batch
        )
        self.model_version += 1
        self.ps.push(self.params, self.model_version)
        self.timers["train"] += time.perf_counter() - t0
        rec = StepRecord(
            step=self.model_version,
            mean_reward=float(np.mean(batch["_rewards"])),
            loss=float(metrics["loss"]),
            mean_is_ratio=float(metrics.get("mean_is_ratio", 1.0)),
            staleness_hist=list(self.manager.consumed_staleness[-1]),
            wall_time=time.perf_counter(),
        )
        self.history.append(rec)
        return rec

    def _batch_from_trajs(self, trajs: List[Trajectory]) -> Optional[Dict[str, Any]]:
        trajs = [t for t in trajs if t is not None and t.response]
        if not trajs:
            return None
        max_t = max(t.length for t in trajs)
        b = len(trajs)
        tokens = np.zeros((b, max_t), np.int32)
        blp = np.zeros((b, max_t), np.float32)
        mask = np.zeros((b, max_t), np.float32)
        groups, rewards = [], []
        for i, t in enumerate(trajs):
            seq = list(t.prompt) + list(t.response)
            tokens[i, : len(seq)] = seq
            plen = len(t.prompt)
            for j, lp in enumerate(t.behavior_logprobs):
                if plen + j < max_t:
                    blp[i, plen + j] = lp
                    mask[i, plen + j] = 1.0
            groups.append(t.group_id)
            rewards.append(t.reward or 0.0)
        return {
            "tokens": jnp.asarray(tokens),
            "behavior_logprobs": jnp.asarray(blp),
            "mask": jnp.asarray(mask),
            "advantages": jnp.asarray(group_advantages(rewards, groups)),
            "_rewards": rewards,
        }

    # ------------------------------------------------------------- main loop
    def run(self, max_ticks: int = 100000, progress: Optional[Callable] = None):
        seen = len(self.history)
        while self.model_version < self.rcfg.total_steps and self._tick < max_ticks:
            self.tick()
            while progress and seen < len(self.history):
                progress(self.history[seen])
                seen += 1
        return self.history

    def tick(self) -> None:
        self._tick += 1
        rcfg = self.rcfg

        # 1) rollout: each instance advances a few decode steps
        for inst in list(self.instances.values()):
            t0 = time.perf_counter()
            done: List[Trajectory] = []
            for _ in range(rcfg.decode_steps_per_tick):
                done.extend(inst.step())
            self.timers["decode"] += time.perf_counter() - t0
            # 2) reward + protocol Occupy
            for traj in done:
                if self.ts.get(traj.traj_id) is None:
                    continue  # aborted earlier this tick (surplus/filtering)
                t1 = time.perf_counter()
                self.ts.complete(traj.traj_id)
                traj.reward = self.reward_model.score(
                    list(traj.prompt), list(traj.response)
                )
                self.timers["reward"] += time.perf_counter() - t1
                self._retired[traj.traj_id] = traj
                to_abort = self.coordinator.on_trajectory_rewarded(traj)
                for tid in to_abort:
                    for other in self.instances.values():
                        other.abort([tid])
                    self.ts.drop(tid)

        # 3) coordinator snapshot->command cycle
        if self._tick % rcfg.snapshot_every == 0:
            t0 = time.perf_counter()
            commands = self.coordinator.step(self._snapshots(), self.ps.version)
            self.timers["coordinator"] += time.perf_counter() - t0
            self._execute(commands)

        # 4) trainer
        self._train_once()

        # 5) keep the TS full
        self.ts.refill()

    # --------------------------------------------------------- fault/elastic
    def fail_instance(self, inst_id: int) -> List[int]:
        """Simulate a replica failure. Returns trajectory IDs returned to TS."""
        inst = self.instances.pop(inst_id)
        snap = inst.snapshot()
        resident = sorted(snap.run_trajs) + sorted(snap.wait_trajs)
        for tid in resident:
            traj = self.ts.get(tid)
            if traj is not None:
                # the replica is gone: clear the dead-instance affinity and
                # the RUNNING status, or _abort_members would mistake these
                # TS-resident payloads for live residents of the dead id
                traj.status = TrajStatus.INTERRUPTED
                traj.instance = None
            self.ts.put_back(tid)
        # speculative state must forget the dead instance
        self.coordinator.spec.expectations.pop(inst_id, None)
        return resident

    def add_instance(self, inst_id: int) -> None:
        self.instances[inst_id] = self._new_instance(inst_id)
        self.coordinator.spec.resync({inst_id: self.instances[inst_id].snapshot()})

    # ------------------------------------------------------------ checkpoint
    def checkpoint(self, directory: str) -> str:
        return ckpt_lib.save_checkpoint(
            directory,
            self.model_version,
            self.params,
            self.opt_state,
            extra_meta={"model_version": self.model_version, "tick": self._tick},
            protocol_state=ckpt_lib.dump_protocol_state(self.manager),
        )

    def restore(self, directory: str) -> None:
        params, opt, meta = ckpt_lib.restore_checkpoint(
            directory, self.params, self.opt_state
        )
        self.params = jax.tree_util.tree_map(jnp.asarray, params)
        self.opt_state = jax.tree_util.tree_map(jnp.asarray, opt)
        self.model_version = meta["extra"]["model_version"]
        self.manager = ckpt_lib.load_protocol_state(meta["protocol"])
        self.coordinator.manager = self.manager
        self.coordinator.verifier.manager = self.manager
        # In-flight payloads (TS / rollout slots / reward queue) died with
        # the old process; their protocol entries would leave buffers Stuck
        # forever. Abort them — the work is simply re-generated, and the
        # staleness bound is unaffected (fresh trajectories get fresh
        # reservations). Consumed history is preserved.
        for key in self.manager.tracked_keys():
            self.manager.abort(key)
        self._retired.clear()
        self.manager.check_invariants()
        self.ps.push(self.params, self.model_version)
        for inst in self.instances.values():
            inst.pull(self.params, self.model_version)
        self.coordinator.spec.resync(self._snapshots())
