"""Runtime configuration + per-step record (shared by core & schedulers)."""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, List, Optional

from repro.core import StrategyConfig, StrategySuite


@dataclass
class RuntimeConfig:
    eta: int = 1
    batch_size: int = 4                # protocol entries (groups) per step
    group_size: int = 2
    n_instances: int = 2
    max_slots: int = 4
    max_len: int = 64
    max_new_tokens: int = 12
    total_steps: int = 8
    lr: float = 1e-3
    temperature: float = 1.0
    seed: int = 0
    n_prompts: int = 4096
    objective: str = "dapo"
    filter_zero_signal: bool = False   # DAPO group filtering (Fig. 8c)
    suite: StrategySuite = field(default_factory=StrategySuite.staleflow)
    strategy_cfg: StrategyConfig = field(default_factory=StrategyConfig)
    snapshot_every: int = 1            # coordinator cycle cadence (ticks)
    decode_steps_per_tick: int = 4
    reward_fn: Optional[Callable] = None  # (prompt_ids, response_ids) -> float
    # ---------------------------------------------------------- reward hub
    # Explicit verifier override: any object with score(prompt, response)
    # or score_trajectory(traj) — e.g. a fully-wired repro.reward.RewardHub
    # or a FaultInjectingVerifier stack. Takes precedence over reward_fn
    # and the flags below.
    verifier: Optional[object] = None
    # Build a RewardHub automatically: score_url registers an HttpVerifier
    # (submit-then-poll remote judge) under the "remote" tag and makes it
    # the default route; score_sandbox registers a SandboxVerifier
    # (resource-limited subprocess; "@path.py" or inline source) under the
    # "code" tag. The in-process RewardModel keeps the "math" tag (and the
    # default route when no score_url).
    score_url: Optional[str] = None
    score_sandbox: Optional[str] = None
    # Terminal verifier failure policy: "fallback" scores the trajectory
    # reward_fallback_score and proceeds to REWARDED; "abort" releases the
    # protocol entry and publishes clean ABORTED (group-wide) instead.
    reward_on_failure: str = "fallback"
    reward_fallback_score: float = 0.0
    reward_timeout_s: float = 5.0      # per-request / sandbox wall deadline
    reward_retries: int = 3            # bounded attempts per protocol step
    paged_kv: bool = False             # block-paged KV cache on the engines
    kv_block_size: int = 16            # tokens per KV block when paged
    # Prefix sharing (paged only): group members prefill their shared
    # prompt once, full prompt blocks are refcount-shared across member
    # block tables, and routing turns group-affine so members land where
    # the prefix lives (StrategySuite.prefix_sharing routing).
    share_prefix: bool = True
    # Devices per rollout instance (paged only): > 1 spans each instance
    # across a ("tensor",) mesh via the sharded backend — params and the
    # paged K/V pool head-sharded, per-device memory accounting. All
    # instances share one mesh over the first ``rollout_shards`` local
    # devices (the same way single-device instances share device 0).
    rollout_shards: int = 1
    # ------------------------------------------------------ service layer
    # scheduler: "tick" = deterministic cooperative single-thread loop
    # (seed semantics, bit-for-bit reproducible); "threaded" = rollout
    # instances, reward workers, coordinator, and trainer on separate
    # threads (the paper's actually-asynchronous deployment shape).
    scheduler: str = "tick"
    reward_workers: int = 2            # threaded reward-server pool size
    reward_queue_capacity: int = 256   # bounded: full queue back-pressures
    reward_latency: float = 0.0        # simulated per-score verifier latency
    # threaded-scheduler pacing: seconds between coordinator cycles
    coordinator_interval_s: float = 0.002
    # threaded-scheduler wall-clock budget: run() stops (with a warning)
    # if total_steps has not landed by then
    threaded_wall_timeout_s: float = 300.0
    # ------------------------------------------------- streaming pipeline
    # Continuous per-trajectory streaming (opt-in; the tick scheduler's
    # seed path is bit-for-bit unchanged while this is False):
    #  * COMPLETED/ABORTED events trigger an incremental single-instance
    #    routing decision (RolloutCoordinator.route_instance) so freed KV
    #    blocks refill within one event dispatch,
    #  * the full coordinator_cycle rebalance becomes a rarer background
    #    pass whose per-instance snapshots are collected without the
    #    all-instance-locks barrier (races resolve at execute time),
    #  * the trainer consumes partial batches (see stream_min_fill).
    streaming: bool = False
    # minimum occupied entries in the train-floor buffer before a partial
    # consume ships (an entry hitting the eta bound also triggers); the
    # full batch_size still consumes immediately. <= 0 disables partial
    # consumption (full batches only).
    stream_min_fill: int = 1
    # background full-rebalance pacing under streaming (migration, sync,
    # surplus aborts); incremental admission handles routing in between
    stream_rebalance_interval_s: float = 0.02
    # ------------------------------------------------- observability plane
    # Attach the metrics registry + trajectory tracer (repro.obs): per-
    # trajectory lifecycle spans (queue vs decode segments, realized
    # staleness at consume), scheduler-thread activity spans, and the
    # periodic fleet sampler. Off by default: every instrumentation site
    # no-ops and the tick seed path stays byte-identical.
    observability: bool = False
    # write a Perfetto-loadable Chrome trace here after run() (implies
    # observability); open at https://ui.perfetto.dev
    trace_path: Optional[str] = None
    # fleet-sampler cadence (occupancy / KV fill / staleness buffers)
    obs_sample_interval_s: float = 0.01
    # runtime lock-order witness (repro.analysis.witness): every core lock
    # becomes a TrackedLock recording the acquisition graph; order
    # violations, graph cycles, and emit-under-lock events are reported
    # with offending stacks (lock_witness_* metrics + tracer activities).
    # Off by default: plain threading primitives, byte-identical seed path.
    # Can also be forced on via the REPRO_LOCK_WITNESS=1 environment var.
    lock_witness: bool = False


@dataclass
class StepRecord:
    step: int
    mean_reward: float
    loss: float
    mean_is_ratio: float
    staleness_hist: List[int]
    wall_time: float
