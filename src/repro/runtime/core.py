"""Scheduler-agnostic runtime core: the wired service graph of Fig. 6.

``RuntimeCore`` owns every component of the disaggregated deployment —
trajectory server, parameter server, staleness manager, coordinator, reward
server, N rollout instances, the training worker — and the **trajectory
lifecycle bus** that connects them, but no control loop. Control loops live
in ``repro.runtime.schedulers``:

* ``CooperativeScheduler`` — the deterministic single-threaded tick
  (decode -> reward -> coordinate -> train -> refill), preserving the seed
  runtime's interleaving bit-for-bit;
* ``ThreadedScheduler``    — rollout instances, reward workers, the
  coordinator, and the trainer on separate threads, which is what the
  paper's architecture actually runs.

Service wiring (everything below is a bus subscription, not a call chain):

    instance.step() completes T
      -> lifecycle.COMPLETED ─ TS marks GENERATED
                             └ RewardServer scores (inline or worker pool)
           -> lifecycle.REWARDED ─ RetiredPayloadStore retains payload
                                 └ coordinator: protocol Occupy; surplus ->
                -> lifecycle.ABORTED ─ TS drops
                                     ├ RetiredPayloadStore evicts
                                     └ core aborts on every instance
    coordinator.try_consume()
      -> lifecycle.CONSUMED ─ TS retires registry slots

Thread safety: every instance is wrapped in a ``LockedBackend``; the
coordinator's lock is held across a whole snapshot->command->execute cycle
(with all instance locks), so Eq. 1's speculative-state validation holds
under real concurrency exactly as it does cooperatively.
"""
from __future__ import annotations

import time
from contextlib import ExitStack
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis import witness as lock_witness
from repro.analysis.witness import make_lock, make_rlock
from repro.configs.base import ArchConfig
from repro.core import (
    Abort,
    Pull,
    CostModel,
    ParameterServer,
    RetiredPayloadStore,
    RewardServer,
    RewardServerConfig,
    RolloutCoordinator,
    StalenessManager,
    TrajectoryLifecycle,
    TrajectoryServer,
    prefix_routing_strategy,
    routing_strategy,
)
from repro.core.lifecycle import LifecycleEvent, LifecycleEventKind
from repro.core.snapshot import collect as collect_snapshots
from repro.data.tasks import ArithmeticDataset
from repro.models import model as M
from repro.reward.verifier import RewardModel
from repro.rl.advantages import group_advantages
from repro.rollout.backend import EngineBackend, create_backend, execute_commands
from repro.runtime.config import RuntimeConfig, StepRecord
from repro.training import checkpoint as ckpt_lib
from repro.training.optimizer import AdamWConfig, init_opt_state
from repro.training.train_step import make_rl_train_step


class LockedBackend:
    """An ``EngineBackend`` behind one RLock.

    Each rollout instance is single-threaded *internally* but is touched
    by several services (its decode thread, the coordinator's command
    executor, protocol-initiated aborts). The lock serializes those; every
    other attribute (telemetry counters, ``allocator`` etc.) passes through
    untouched.

    ``retire()`` marks a failed replica dead under its own lock: a decode
    thread still holding the handle (it fetched it before ``fail_instance``
    popped it from the fleet) sees its next ``step()`` return nothing
    instead of generating on trajectories the TS already reclaimed.
    """

    def __init__(self, inner: EngineBackend):
        self.inner = inner
        # order-keyed: barrier cycles enter several instance locks, always
        # in ascending inst_id order (the sorted ExitStack below) — the
        # witness checks the key ordering at runtime
        self.lock = make_rlock("instance", order_key=inner.inst_id)
        self._retired = False

    def __getattr__(self, name: str) -> Any:
        return getattr(self.inner, name)

    def retire(self) -> None:
        with self.lock:
            self._retired = True

    def route(self, *a, **kw):
        with self.lock:
            return self.inner.route(*a, **kw)

    def route_many(self, *a, **kw):
        with self.lock:
            return self.inner.route_many(*a, **kw)

    def interrupt(self, *a, **kw):
        with self.lock:
            return self.inner.interrupt(*a, **kw)

    def abort(self, *a, **kw):
        with self.lock:
            return self.inner.abort(*a, **kw)

    def pull(self, *a, **kw):
        with self.lock:
            return self.inner.pull(*a, **kw)

    def step(self, *a, **kw):
        with self.lock:
            if self._retired:
                return []
            return self.inner.step(*a, **kw)

    def snapshot(self, *a, **kw):
        with self.lock:
            return self.inner.snapshot(*a, **kw)


class RuntimeCore:
    """The wired, scheduler-agnostic async-RL system (see module docstring)."""

    def __init__(self, cfg: ArchConfig, rcfg: RuntimeConfig):
        self.cfg = cfg
        self.rcfg = rcfg
        # opt-in lock-order witness: must activate before any service
        # below constructs its locks, so every lock joins the tracked set
        if rcfg.lock_witness:
            lock_witness.enable()
        key = jax.random.PRNGKey(rcfg.seed)
        self.params = M.init_params(cfg, key)
        self.opt_state = init_opt_state(self.params)
        self.train_step = jax.jit(
            make_rl_train_step(cfg, AdamWConfig(lr=rcfg.lr), objective=rcfg.objective)
        )

        # ------------------------------------------------- the service bus
        self.lifecycle = TrajectoryLifecycle()
        # coordinator-cycle dirty flag: any lifecycle event (or decode
        # progress, marked in decode_instance) means the next cycle may
        # have work; a quiet system lets coordinator_cycle short-circuit
        # without re-sorting and re-entering every instance lock
        self._coord_dirty = True
        self._coord_last_ps_version = -1
        for _kind in LifecycleEventKind:
            self.lifecycle.subscribe(_kind, self._mark_coord_dirty)

        self.dataset = ArithmeticDataset(rcfg.n_prompts, seed=rcfg.seed)
        if rcfg.reward_fn is not None:
            self.reward_model = type(
                "CustomReward", (), {"score": staticmethod(rcfg.reward_fn)}
            )()
        else:
            self.reward_model = RewardModel(
                lambda prompt: self.dataset.answer_for(prompt)
            )
        self.manager = StalenessManager(batch_size=rcfg.batch_size, eta=rcfg.eta)
        # ------------------------------------------- observability plane
        # Opt-in registry + tracer (repro.obs). Disabled (default): the
        # registry is the shared no-op and the tracer stays None, so every
        # instrumentation site below is a None-check or a no-op call and
        # the seed paths are byte-identical. The tracer subscribes to the
        # lifecycle bus *before* the TS/reward/protocol handlers attach,
        # so its timestamps mark event publication, not dispatch tails.
        self.obs_enabled = bool(rcfg.observability or rcfg.trace_path)
        if self.obs_enabled:
            from repro.obs import MetricsRegistry, TrajectoryTracer

            self.metrics = MetricsRegistry()
            self.tracer: Optional[TrajectoryTracer] = TrajectoryTracer(
                self.lifecycle,
                # CONSUMED events are published under the coordinator lock
                # right after consume() advanced the floor: the consumed
                # batch's floor is train_version - 1 (see tracer docstring)
                floor_source=lambda: self.manager.train_version,
                registry=self.metrics,
            )
        else:
            from repro.obs.metrics import NOOP_REGISTRY

            self.metrics = NOOP_REGISTRY
            self.tracer = None
        self._m_staleness = self.metrics.histogram(
            "consumed_staleness", buckets=tuple(range(0, 17))
        )
        self.ts = TrajectoryServer(
            self.dataset.prompt_source(),
            capacity_groups=(rcfg.eta + 1) * rcfg.batch_size,
            group_size=rcfg.group_size,
            max_new_tokens=rcfg.max_new_tokens,
        )
        # subscription order fixes the per-event dispatch order; it mirrors
        # the seed runtime's call order (TS transition first, then payload
        # retention, then protocol, then instance cleanup)
        self.ts.attach(self.lifecycle)
        self.retired = RetiredPayloadStore(self.lifecycle)
        # ------------------------------------------------------ reward hub
        # Verifier resolution: an explicit rcfg.verifier wins; score_url /
        # score_sandbox auto-build a RewardHub around the in-process
        # RewardModel; otherwise the RewardModel scores directly (seed
        # behavior, bit-for-bit).
        verifier = rcfg.verifier
        if verifier is None and (rcfg.score_url or rcfg.score_sandbox):
            verifier = self._build_reward_hub()
        if verifier is None:
            verifier = self.reward_model
        from repro.reward.hub import RewardHub as _RewardHub

        self.reward_hub: Optional[_RewardHub] = (
            verifier if isinstance(verifier, _RewardHub) else None
        )
        self.reward_server = RewardServer(
            verifier,
            self.lifecycle,
            RewardServerConfig(
                n_workers=rcfg.reward_workers,
                queue_capacity=rcfg.reward_queue_capacity,
                simulated_latency=rcfg.reward_latency,
            ),
            # aborted-while-queued completions are dropped, not scored
            liveness=lambda t: self.ts.get(t.traj_id) is not None,
            metrics=self.metrics,
            tracer=self.tracer,
            # terminal verification failure (hub on_failure="abort"):
            # release the protocol entry + publish group-wide ABORTED.
            # Deferred attribute lookup: the coordinator is built below.
            on_abort=lambda traj: self.coordinator.abort_unverifiable(traj),
        )
        self.ps = ParameterServer()
        self.ps.push(self.params, 0)
        # schedulers may swap in a BackgroundPusher (overlapped Push)
        self._push_fn: Callable[[Any, int], None] = self.ps.push

        if rcfg.rollout_shards > 1 and not rcfg.paged_kv:
            raise ValueError(
                "rollout_shards > 1 requires paged_kv=True (the sharded "
                "backend shards the paged K/V pool)"
            )
        self._rollout_mesh = None
        if rcfg.rollout_shards > 1:
            from repro.launch.mesh import make_rollout_mesh

            self._rollout_mesh = make_rollout_mesh(rcfg.rollout_shards)
        k5 = 2.0 * cfg.n_layers * cfg.n_kv_heads * cfg.hd * 4
        # kv_budget is per device: the pod-wide pool (max_len * max_slots
        # worth of k5-sized tokens) spreads evenly over the head shards
        self.cost_model = CostModel(
            k1=1e-12, k2=1e-3, k3=1e-4, k4=5e-3, k5=k5,
            kv_budget=k5 * rcfg.max_len * rcfg.max_slots
            / rcfg.rollout_shards,
            block_size=rcfg.kv_block_size if rcfg.paged_kv else 1,
            shard_count=rcfg.rollout_shards,
            # admission stops at the engines' slot pool: short trajectories
            # would let the byte budget overcommit into engine wait queues,
            # and resident waiters zero marginal_gain for every later
            # routing decision (the streaming fast path in particular)
            max_concurrency=rcfg.max_slots,
        )
        group_filter = None
        if rcfg.filter_zero_signal:
            def group_filter(members) -> bool:
                rs = [m.reward for m in members if m.reward is not None]
                return len(set(rs)) > 1
        suite = rcfg.suite
        if (
            rcfg.share_prefix
            and rcfg.paged_kv
            and rcfg.group_size > 1
            and suite.routing is routing_strategy
        ):
            # group-affine routing: members of one sampling group land on a
            # single instance so its paged engine prefills the prompt once
            import dataclasses as _dc

            suite = _dc.replace(suite, routing=prefix_routing_strategy)
        self.coordinator = RolloutCoordinator(
            self.manager,
            self.ts,
            cost_model=self.cost_model,
            cfg=rcfg.strategy_cfg,
            suite=suite,
            group_sampling=rcfg.group_size > 1,
            group_filter=group_filter,
            lifecycle=self.lifecycle,
        )
        # protocol-initiated aborts (surplus / filtering, inst=None) must
        # release engine residency everywhere; command-executed aborts
        # (inst set) already did
        self.lifecycle.subscribe(LifecycleEventKind.ABORTED, self._on_aborted)
        # streaming pipeline: freed capacity (COMPLETED; ABORTED is handled
        # inside _on_aborted, which knows which instance actually released
        # the trajectory) triggers an incremental admission decision.
        # Subscribed after the TS/reward/protocol handlers so scoring and
        # Occupy have cascaded before the routing decision looks at the
        # staleness discriminator.
        if rcfg.streaming:
            self.lifecycle.subscribe(
                LifecycleEventKind.COMPLETED, self._on_stream_completed
            )

        self._instances_lock = make_rlock("instances")
        self.instances: Dict[int, LockedBackend] = {}
        for i in range(rcfg.n_instances):
            self.instances[i] = self._new_instance(i)
        self.coordinator.spec.resync(self._snapshots())

        self._history_lock = make_lock("history")
        self.history: List[StepRecord] = []
        self.model_version = 0
        self._tick = 0
        self.ts.refill()
        # telemetry for the time-breakdown benchmark; decode/reward are
        # updated from N instance threads, so those adds take a lock
        self.timers: Dict[str, float] = {
            "decode": 0.0, "prefill": 0.0, "reward": 0.0, "train": 0.0,
            "coordinator": 0.0, "pull": 0.0, "route": 0.0, "interrupt": 0.0,
        }
        self._timers_lock = make_lock("timers")
        # witness violations already projected onto the tracer (so each
        # offending stack becomes exactly one trace activity)
        self._witness_exported = 0

    # -------------------------------------------------------------- plumbing
    def _build_reward_hub(self):
        """Auto-wire a RewardHub from score_url / score_sandbox flags.

        Routes: "math" -> in-process RewardModel; "code" -> sandboxed
        subprocess verifier (when score_sandbox); "remote" -> HTTP
        submit-then-poll judge (when score_url), which also becomes the
        default route — otherwise the RewardModel keeps the default.
        """
        from repro.reward import (
            DEFAULT_ROUTE,
            CircuitBreaker,
            HttpVerifier,
            RetryPolicy,
            RewardHub,
            SandboxVerifier,
        )

        rcfg = self.rcfg
        hub = RewardHub(
            default=self.reward_model,
            on_failure=rcfg.reward_on_failure,
            fallback_score=rcfg.reward_fallback_score,
            metrics=self.metrics,
            tracer=self.tracer,
        )
        hub.register("math", self.reward_model)
        if rcfg.score_sandbox:
            hub.register("code", SandboxVerifier.from_spec(
                rcfg.score_sandbox, timeout_s=rcfg.reward_timeout_s,
            ))
        if rcfg.score_url:
            remote = HttpVerifier(
                rcfg.score_url,
                policy=RetryPolicy(
                    max_attempts=max(1, rcfg.reward_retries),
                    request_timeout_s=rcfg.reward_timeout_s,
                ),
                breaker=CircuitBreaker(),
                total_timeout_s=rcfg.reward_timeout_s * 4,
                seed=rcfg.seed,
            )
            hub.register("remote", remote)
            hub.register(DEFAULT_ROUTE, remote)
        return hub

    @property
    def _retired(self) -> Dict[int, Any]:
        """Back-compat view of the retired-payload store (tests/benchmarks
        inspected the runtime's old private dict)."""
        return self.retired.payloads()

    def _new_instance(self, inst_id: int) -> LockedBackend:
        kw = dict(
            cfg=self.cfg,
            params=self.ps.pull()[0],
            version=self.ps.version,
            max_slots=self.rcfg.max_slots,
            max_len=self.rcfg.max_len,
            kv_bytes_per_token=self.cost_model.k5,
            kv_budget=self.cost_model.kv_budget,
            temperature=self.rcfg.temperature,
            seed=self.rcfg.seed,
            paged=self.rcfg.paged_kv,
            kv_block_size=self.rcfg.kv_block_size,
            share_prefix=self.rcfg.share_prefix,
        )
        if self.rcfg.rollout_shards > 1:
            backend = create_backend(
                "sharded",
                inst_id,
                shard_count=self.rcfg.rollout_shards,
                mesh=self._rollout_mesh,
                **kw,
            )
        else:
            backend = create_backend("jax", inst_id, **kw)
        if self.tracer is not None:
            # admission/preemption hooks split each span's queue-wait from
            # its decode segments (set on the inner engine: LockedBackend
            # only forwards attribute *reads*)
            backend.on_admit = self.tracer.on_admit
            backend.on_preempt = self.tracer.on_preempt
        return LockedBackend(backend)

    def _snapshots(self):
        with self._instances_lock:
            return collect_snapshots(self.instances)

    def _mark_coord_dirty(self, e: LifecycleEvent) -> None:
        self._coord_dirty = True

    def _on_stream_completed(self, e: LifecycleEvent) -> None:
        self.stream_admit(e.inst)

    def _on_aborted(self, e: LifecycleEvent) -> None:
        if e.inst is not None:
            return  # executed as a command: the target instance is clean
        with self._instances_lock:
            handles = list(self.instances.values())
        freed: Optional[int] = None
        for h in handles:
            if h.abort([e.traj_id]):
                freed = h.inst_id
        if self.rcfg.streaming and freed is not None:
            # a protocol abort released KV blocks outside any cycle:
            # refill the freed instance within this event dispatch
            self.stream_admit(freed)

    # --------------------------------------------------------- rollout side
    def decode_instance(self, inst_id: int, n_steps: int = 1) -> int:
        """Advance one instance ``n_steps`` decode steps and push every
        completion into the lifecycle (reward phase onward). Returns the
        number of completed trajectories."""
        with self._instances_lock:
            handle = self.instances.get(inst_id)
        if handle is None:
            return 0
        t0 = time.perf_counter()
        done = []
        for _ in range(n_steps):
            done.extend(handle.step())
        t1 = time.perf_counter()
        with self._timers_lock:
            self.timers["decode"] += t1 - t0
        if self.tracer is not None:
            self.tracer.activity(f"decode[{inst_id}]", t0, t1)
        if handle.n_active() > 0:
            # resident KV grew: migration/routing inputs changed even
            # without a completion, so the next cycle must run
            self._coord_dirty = True
        for traj in done:
            self.complete_trajectory(traj)
        return len(done)

    def instance_busy(self, inst_id: int) -> bool:
        """Does the instance have active decode slots right now? (Lock-free
        telemetry read for the event-driven scheduler's idle decision.)"""
        with self._instances_lock:
            handle = self.instances.get(inst_id)
        return handle is not None and handle.n_active() > 0

    def complete_trajectory(self, traj) -> None:
        """Publish a completion; the reward phase (and everything behind
        it) hangs off the event. Silently skips trajectories aborted since
        generation finished (surplus/filtering race)."""
        if self.ts.get(traj.traj_id) is None:
            return
        t0 = time.perf_counter()
        s0 = self.reward_server.score_time
        self.lifecycle.completed(traj, traj.instance)
        # timers["reward"] keeps the seed runtime's meaning — time spent
        # *scoring* — not the whole dispatch (which also runs Occupy and
        # abort fan-out): inline mode charges the verifier's delta,
        # threaded mode the (tiny) enqueue cost
        if self.reward_server.threaded:
            dt = time.perf_counter() - t0
        else:
            dt = self.reward_server.score_time - s0
        with self._timers_lock:
            self.timers["reward"] += dt

    # ------------------------------------------------------ coordinator side
    def coordinator_cycle(self) -> int:
        """One snapshot->command->execute cycle. Returns the number of
        commands executed.

        Barrier mode (default): atomic under the coordinator lock AND every
        instance lock — decode, reward events, and elasticity cannot
        interleave between observation and effect (the live analog of the
        simulator's zero-time cycle).

        Streaming mode: the cycle is the rarer background *rebalance* pass
        (sync, migration, surplus aborts). Per-instance snapshots are
        collected without the all-locks barrier, so decode threads keep
        stepping while the coordinator deliberates; races are resolved at
        execute time — vanished Route targets via ``ts.try_take`` /
        ``skipped_routes``, vanished Interrupt/Abort targets via
        ``missed_removals`` — and the speculative state is compensated for
        both so Eq. 1 keeps validating.

        Short-circuit: with no routable work, no lifecycle event or decode
        progress since the last cycle, and no new parameter version, a full
        cycle is provably a no-op — skip it without re-sorting and
        re-entering every instance lock.
        """
        if (
            not self._coord_dirty
            and self.ts.n_available == 0
            and self.ps.version == self._coord_last_ps_version
        ):
            return 0
        t_cycle = time.perf_counter()
        with self.coordinator.lock:
            # reset *before* snapshotting: events landing mid-cycle re-mark
            # the flag, so their effects are observed by the next cycle
            self._coord_dirty = False
            ps_version = self.ps.version
            with self._instances_lock:
                handles = dict(self.instances)
            if self.rcfg.streaming:
                n = self._cycle_body(handles, ps_version)
            else:
                with ExitStack() as stack:
                    for i in sorted(handles):
                        stack.enter_context(handles[i].lock)
                    n = self._cycle_body(handles, ps_version)
            self._coord_last_ps_version = ps_version
            if self.tracer is not None:
                self.tracer.activity(
                    "cycle", t_cycle, time.perf_counter(),
                    args={"commands": n},
                )
            return n

    def _cycle_body(self, handles: Dict[int, LockedBackend], ps_version: int) -> int:
        t0 = time.perf_counter()
        snaps = collect_snapshots(handles)
        commands = self.coordinator.step(snaps, ps_version)
        # RPL003 fix: the coordinator thread's add races the instance
        # threads' locked decode/reward adds (and run()'s final read)
        with self._timers_lock:
            self.timers["coordinator"] += time.perf_counter() - t0
        res = execute_commands(
            commands, handles, self.ts, self.ps,
            timers=self.timers, lifecycle=self.lifecycle,
        )
        # a Route that found its trajectory already gone (cross-cycle
        # failure races; any concurrent mutation under streaming's relaxed
        # snapshots) must not skew P
        for inst, tid in res.skipped_routes:
            self.coordinator.spec.apply(Abort(inst, (tid,)))
        if res.missed_removals:
            # an Interrupt/Abort whose target completed between the relaxed
            # snapshot and execution had no data-plane effect: undo its
            # speculative decrement — unless a later Pull for the same
            # instance re-zeroed the expectation (sync interrupts), in
            # which case both sides already agree
            pulled = {c.inst for c in commands if isinstance(c, Pull)}
            for inst, tid in res.missed_removals:
                if inst not in pulled:
                    self.coordinator.spec.ensure(inst).accum_traj_num += 1
        return len(commands)

    def stream_admit(self, inst_id: Optional[int]) -> int:
        """Event-driven incremental admission (streaming fast path).

        An instance freed KV capacity (COMPLETED / protocol ABORTED): make
        a single-instance routing decision under only the coordinator lock
        plus that instance's lock and execute it within this event
        dispatch — the rest of the fleet never stops decoding. Returns the
        number of Route commands executed.
        """
        if inst_id is None or not self.rcfg.streaming:
            return 0
        if self.coordinator.in_cycle():
            # emitted from a running cycle's own command execution: that
            # cycle already routes against the freed capacity
            return 0
        with self._instances_lock:
            handle = self.instances.get(inst_id)
        if handle is None:
            return 0
        t0 = time.perf_counter()
        with self.coordinator.lock:
            with handle.lock:
                snap = handle.snapshot()
                commands = self.coordinator.route_instance(snap, self.ps.version)
                if commands:
                    res = execute_commands(
                        commands, {inst_id: handle}, self.ts, self.ps,
                        lifecycle=self.lifecycle,
                    )
                    for inst, tid in res.skipped_routes:
                        self.coordinator.spec.apply(Abort(inst, (tid,)))
        t1 = time.perf_counter()
        with self._timers_lock:
            self.timers["coordinator"] += t1 - t0
        if self.tracer is not None and commands:
            self.tracer.activity(
                "stream_admit", t0, t1, args={"routes": len(commands)}
            )
        return len(commands)

    # ----------------------------------------------------------- the trainer
    def train_once(self) -> Optional[StepRecord]:
        t0 = time.perf_counter()
        # streaming consumption: rewarded groups drain into the train-floor
        # buffer (the staleness-ordered ready queue, bounded at
        # (eta+1)*capacity entries) and a partial batch ships once
        # stream_min_fill occupied entries — or the eta bound — is reached
        min_fill = self.rcfg.stream_min_fill if self.rcfg.streaming else None
        if not self.manager.ready(min_fill):
            return None
        batch_ids = self.coordinator.try_consume(min_fill)
        if batch_ids is None:
            return None
        # consume retires trajectories from the TS registry; payloads were
        # retained by the RetiredPayloadStore at reward time
        staleness_hist = list(self.manager.consumed_staleness[-1])
        trajs = self.retired.take(batch_ids)
        batch = self._batch_from_trajs(trajs)
        if batch is None:
            return None
        self.params, self.opt_state, metrics = self.train_step(
            self.params, self.opt_state, batch
        )
        self.model_version += 1
        self._push_fn(self.params, self.model_version)
        t1 = time.perf_counter()
        with self._timers_lock:
            self.timers["train"] += t1 - t0
        for s in staleness_hist:
            self._m_staleness.observe(s)
        if self.tracer is not None:
            self.tracer.activity(
                "train_step", t0, t1, args={"step": self.model_version}
            )
        rec = StepRecord(
            step=self.model_version,
            mean_reward=float(np.mean(batch["_rewards"])),
            loss=float(metrics["loss"]),
            mean_is_ratio=float(metrics.get("mean_is_ratio", 1.0)),
            staleness_hist=staleness_hist,
            wall_time=time.perf_counter(),
        )
        with self._history_lock:
            self.history.append(rec)
        return rec

    def _batch_from_trajs(self, trajs) -> Optional[Dict[str, Any]]:
        trajs = [t for t in trajs if t is not None and t.response]
        if not trajs:
            return None
        max_t = max(t.length for t in trajs)
        b = len(trajs)
        tokens = np.zeros((b, max_t), np.int32)
        blp = np.zeros((b, max_t), np.float32)
        mask = np.zeros((b, max_t), np.float32)
        groups, rewards = [], []
        for i, t in enumerate(trajs):
            seq = list(t.prompt) + list(t.response)
            tokens[i, : len(seq)] = seq
            plen = len(t.prompt)
            for j, lp in enumerate(t.behavior_logprobs):
                if plen + j < max_t:
                    blp[i, plen + j] = lp
                    mask[i, plen + j] = 1.0
            groups.append(t.group_id)
            rewards.append(t.reward or 0.0)
        return {
            "tokens": jnp.asarray(tokens),
            "behavior_logprobs": jnp.asarray(blp),
            "mask": jnp.asarray(mask),
            "advantages": jnp.asarray(group_advantages(rewards, groups)),
            "_rewards": rewards,
        }

    # --------------------------------------------------------- fault/elastic
    def fail_instance(self, inst_id: int) -> List[int]:
        """Simulate a replica failure. Returns trajectory IDs returned to TS.

        Safe mid-decode under the threaded scheduler: the handle leaves the
        fleet first (its thread exits at the next loop check), then its
        final state is read under its lock and every still-generating
        resident re-enters the TS via INTERRUPTED events; protocol
        reservations survive untouched.
        """
        with self.coordinator.lock:
            with self._instances_lock:
                handle = self.instances.pop(inst_id)
            with handle.lock:
                # dead first: a decode thread that already fetched this
                # handle must not generate on reclaimed trajectories when
                # it resumes stepping after we release the lock
                handle.retire()
                snap = handle.snapshot()
                resident = sorted(snap.run_trajs) + sorted(snap.wait_trajs)
                for tid in resident:
                    traj = self.ts.get(tid)
                    if traj is not None:
                        # INTERRUPTED clears the dead-instance affinity and
                        # the RUNNING status via the TS subscriber
                        self.lifecycle.interrupted(traj)
            # speculative state must forget the dead instance
            self.coordinator.drop_instance(inst_id)
            return resident

    def add_instance(self, inst_id: int) -> None:
        handle = self._new_instance(inst_id)
        with self.coordinator.lock:
            with self._instances_lock:
                self.instances[inst_id] = handle
            self.coordinator.spec.resync({inst_id: handle.snapshot()})

    # --------------------------------------------------------- observability
    _ENGINE_COUNTERS = (
        "decode_steps",
        "prefill_tokens",
        "decode_tokens",
        "preemptions",
        "shared_prefix_hits",
        "prefill_tokens_saved",
        "block_copies",
    )

    def scrape_metrics(self) -> None:
        """Mirror the scattered component counters into the registry.

        The plain Python counters stay the source of truth (the engine's
        ``preemptions`` even feeds the coordinator's routing penalty);
        this just projects them onto the registry so one ``snapshot()``
        sees the whole fleet. Called by the FleetSampler each tick and
        by ``export_trace`` — a no-op when observability is off.
        """
        m = self.metrics
        if not m.enabled:
            return
        with self._instances_lock:
            handles = dict(self.instances)
        for inst_id, h in sorted(handles.items()):
            for name in self._ENGINE_COUNTERS:
                v = getattr(h, name, None)
                if v is not None:
                    m.counter(f"engine_{name}", instance=inst_id).set_total(v)
        st = self.coordinator.stats
        m.counter("coordinator_cycles").set_total(st.cycles)
        m.counter("coordinator_snapshots_rejected").set_total(
            st.snapshots_rejected
        )
        for kind, n in st.commands.items():
            m.counter("coordinator_commands", kind=kind).set_total(n)
        m.counter("coordinator_stream_cycles").set_total(st.stream_cycles)
        m.counter("coordinator_stream_routes").set_total(st.stream_routes)
        m.counter("coordinator_stream_rejected").set_total(st.stream_rejected)
        m.counter("ps_pushes").set_total(self.ps.push_count)
        m.counter("ps_pulls").set_total(self.ps.pull_count)
        for name, v in self.reward_server.stats().items():
            if isinstance(v, bool):
                continue
            m.gauge(f"reward_{name}").set(v)
        if self.reward_hub is not None:
            hs = self.reward_hub.stats()
            m.counter("reward_hub_unrouted").set_total(hs["unrouted"])
            for tag, rs in hs["routes"].items():
                for k in ("calls", "failures", "fallbacks", "aborts"):
                    m.counter(
                        f"reward_route_{k}", route=tag
                    ).set_total(rs[k])
                inner = rs.get("inner") or {}
                for k in ("retries", "timeouts", "kills"):
                    if k in inner:
                        m.counter(
                            f"reward_route_{k}", route=tag
                        ).set_total(inner[k])
                if "breaker_state" in inner:
                    m.gauge("reward_route_breaker_open", route=tag).set(
                        0.0 if inner["breaker_state"] == "closed" else 1.0
                    )
        for kind, n in self.lifecycle.counts.items():
            m.counter("lifecycle_events", kind=kind.name.lower()).set_total(n)
        m.gauge("model_version").set(self.model_version)
        m.gauge("staleness_in_flight").set(self.manager.in_flight())
        with self._timers_lock:
            timers = dict(self.timers)
        for name, v in timers.items():
            m.gauge(f"timer_{name}_s").set(v)
        sched = getattr(self, "scheduler", None)
        busy = getattr(sched, "busy", None)
        if busy is not None:
            lock = getattr(sched, "_busy_lock", None)
            if lock is not None:
                with lock:
                    busy = dict(busy)
            for name, v in busy.items():
                m.gauge("sched_busy_s", thread=name).set(v)
        # lock-order witness (when it ran): counters + one tracer activity
        # per violation, carrying the offending stack into the trace
        w = lock_witness.current()
        if w is not None:
            viol = w.violations()
            m.counter("lock_witness_acquires").set_total(w.acquires)
            m.counter("lock_witness_emits").set_total(w.emits)
            m.counter("lock_witness_edges").set_total(len(w.edges()))
            m.counter("lock_witness_order_violations").set_total(
                viol["order"]
            )
            m.counter("lock_witness_emit_under_lock").set_total(
                viol["emit_under_lock"]
            )
            m.counter("lock_witness_cycles").set_total(viol["cycles"])
            if self.tracer is not None:
                samples = w.order_violations + w.emit_under_lock
                now = time.perf_counter()
                for s in samples[self._witness_exported:]:
                    self.tracer.activity(
                        "lock_witness_violation", now, now,
                        args={k: v for k, v in s.items() if k != "stack"}
                        | {"stack": "".join(s.get("stack", [])[-4:])},
                    )
                self._witness_exported = len(samples)

    def export_trace(self, path: Optional[str] = None) -> Optional[dict]:
        """Final metrics scrape + Chrome-trace export (None when off)."""
        if self.tracer is None:
            return None
        from repro.obs.export import export_chrome_trace

        self.scrape_metrics()
        return export_chrome_trace(self.tracer, path)

    # ------------------------------------------------------------ checkpoint
    def checkpoint(self, directory: str) -> str:
        return ckpt_lib.save_checkpoint(
            directory,
            self.model_version,
            self.params,
            self.opt_state,
            extra_meta={"model_version": self.model_version, "tick": self._tick},
            protocol_state=ckpt_lib.dump_service_state(
                self.manager,
                reward_server=self.reward_server,
                retired=self.retired,
                lifecycle=self.lifecycle,
            ),
        )

    def restore(self, directory: str) -> None:
        params, opt, meta = ckpt_lib.restore_checkpoint(
            directory, self.params, self.opt_state
        )
        self.params = jax.tree_util.tree_map(jnp.asarray, params)
        self.opt_state = jax.tree_util.tree_map(jnp.asarray, opt)
        self.model_version = meta["extra"]["model_version"]
        self.manager, _services = ckpt_lib.load_service_state(meta["protocol"])
        self.coordinator.manager = self.manager
        self.coordinator.verifier.manager = self.manager
        # In-flight payloads (TS / rollout slots / reward queue) died with
        # the old process; their protocol entries would leave buffers Stuck
        # forever. Abort them — the work is simply re-generated, and the
        # staleness bound is unaffected (fresh trajectories get fresh
        # reservations). Consumed history is preserved.
        for key in self.manager.tracked_keys():
            self.manager.abort(key)
        self.retired.clear()
        self.manager.check_invariants()
        self.ps.push(self.params, self.model_version)
        with self._instances_lock:
            handles = dict(self.instances)
        for h in handles.values():
            h.pull(self.params, self.model_version)
        self.coordinator.spec.resync(self._snapshots())
