"""Schedulers: the control loops that drive a ``RuntimeCore``.

The paper's deployment (Fig. 6) runs rollout, reward, and training as
independent *concurrent* services; the seed runtime approximated that with
one cooperative tick. Both shapes now exist behind one interface:

``CooperativeScheduler``
    The seed loop, verbatim::

        tick := [instances decode] -> [rewards] -> [coordinator cycle]
                -> [trainer consume/step/push] -> [TS refill]

    Single thread, deterministic interleaving: on a fixed seed the
    ``StepRecord`` history (rewards, losses, staleness hists) is
    bit-for-bit reproducible — the convergence suites run here.

``ThreadedScheduler``
    One thread per rollout instance (decode + completion events), a reward
    worker pool (the ``RewardServer``), a coordinator thread (periodic
    snapshot->command cycles + TS refill), a trainer thread, and a
    background PS pusher — the writer-preference RW lock in
    ``parameter_server.py`` finally sees concurrent readers during a
    pending write, and Push genuinely overlaps the next training step.
    Protocol invariants (staleness <= eta on every consumed batch, Eq. 1
    snapshot validation) hold by construction: the consistency state is
    lock-protected, and the coordinator freezes the fleet for the duration
    of each cycle.

Elasticity: the threaded supervisor watches ``core.instances`` — replicas
added mid-run get a decode thread, failed replicas' threads exit on their
own at the next loop check.
"""
from __future__ import annotations

import threading
import time
from typing import Callable, List, Optional, Protocol

from repro.analysis.witness import make_condition, make_lock
from repro.core import BackgroundPusher
from repro.core.lifecycle import LifecycleEventKind
from repro.runtime.config import StepRecord
from repro.runtime.core import RuntimeCore


class EventGate:
    """Lost-wakeup-free sleep: a ``threading.Condition`` plus a monotone
    generation counter.

    A service loop snapshots ``seq()`` *before* doing (and checking for)
    work, then calls ``wait(seen, timeout)`` when idle: any ``notify`` that
    landed in between bumped the counter, so the wait returns immediately
    instead of losing the wakeup. ``notify`` accepts (and ignores) an
    argument so it can be subscribed to the lifecycle bus directly; the
    condition is a leaf lock — nothing is held while notifying subscribers'
    domain locks, so signaling from any service thread is deadlock-free.
    """

    def __init__(self) -> None:
        self._cond = make_condition("gate")
        self._seq = 0

    def seq(self) -> int:
        with self._cond:
            return self._seq

    def notify(self, _event=None) -> None:
        with self._cond:
            self._seq += 1
            self._cond.notify_all()

    def wait(self, seen: int, timeout: float) -> bool:
        """Block until the counter moves past ``seen`` (or ``timeout`` s);
        returns True if signaled."""
        with self._cond:
            if self._seq != seen:
                return True
            return self._cond.wait_for(lambda: self._seq != seen, timeout)


class Scheduler(Protocol):
    """A control loop over a ``RuntimeCore``."""

    def run(
        self,
        max_ticks: int = 100000,
        progress: Optional[Callable[[StepRecord], None]] = None,
    ) -> List[StepRecord]: ...


class CooperativeScheduler:
    """Deterministic single-threaded tick loop (seed semantics)."""

    def __init__(self, core: RuntimeCore):
        self.core = core

    def tick(self) -> None:
        core = self.core
        rcfg = core.rcfg
        core._tick += 1
        # 1) rollout + 2) reward (inline, via COMPLETED events)
        for inst_id in list(core.instances):
            core.decode_instance(inst_id, rcfg.decode_steps_per_tick)
        # 3) coordinator snapshot->command cycle
        if core._tick % rcfg.snapshot_every == 0:
            core.coordinator_cycle()
        # 4) trainer
        core.train_once()
        # 5) keep the TS full
        core.ts.refill()

    def run(
        self,
        max_ticks: int = 100000,
        progress: Optional[Callable[[StepRecord], None]] = None,
    ) -> List[StepRecord]:
        core = self.core
        seen = len(core.history)
        while (
            core.model_version < core.rcfg.total_steps
            and core._tick < max_ticks
        ):
            self.tick()
            while progress and seen < len(core.history):
                progress(core.history[seen])
                seen += 1
        return core.history


class ThreadedScheduler:
    """Truly asynchronous control: every service phase on its own thread."""

    def __init__(
        self, core: RuntimeCore, *, wall_timeout_s: Optional[float] = None
    ):
        self.core = core
        self.wall_timeout_s = (
            wall_timeout_s
            if wall_timeout_s is not None
            else core.rcfg.threaded_wall_timeout_s
        )
        self._stop = threading.Event()
        self._threads: dict = {}
        self.pusher: Optional[BackgroundPusher] = None
        self.timed_out = False
        # telemetry: per-phase busy seconds (overlap analysis); every loop
        # updates through the lock — instance threads are many, and the
        # coordinator/trainer adds race against run()'s final read
        self.busy = {"decode": 0.0, "train": 0.0, "coordinate": 0.0}
        self._busy_lock = make_lock("busy")
        # event-driven wakeups (no 0.5 ms polling): each service loop
        # sleeps on its gate and lifecycle events signal it — wake latency
        # is one dispatch, idle threads cost nothing. Timeouts below are
        # safety nets, not pacing.
        self.gates = {
            "instance": EventGate(),
            "coordinator": EventGate(),
            "trainer": EventGate(),
        }
        self._gate_subs: list = []

    def _wire_gates(self) -> None:
        """Signal routing: which lifecycle transitions can unblock whom.

        * instances: a ROUTED admits new work; an ABORTED frees KV budget
          so a starved instance may admit its waiters.
        * trainer: REWARDED occupies a buffer entry; ABORTED can
          forward-fill one — both can make the train floor consumable.
        * coordinator: completions / interrupts / consumes change routable
          work or capacity. Under streaming the incremental fast path
          already handles admission in the event dispatch, so the
          background rebalance stays interval-paced and only CONSUMED
          (registry slots retired -> refill can top up the TS) wakes it.
        """
        L = self.core.lifecycle
        K = LifecycleEventKind
        wiring = [
            ([K.ROUTED, K.ABORTED], self.gates["instance"].notify),
            ([K.REWARDED, K.ABORTED], self.gates["trainer"].notify),
        ]
        if self.core.rcfg.streaming:
            wiring.append(([K.CONSUMED], self.gates["coordinator"].notify))
        else:
            wiring.append((
                [K.COMPLETED, K.ABORTED, K.INTERRUPTED, K.REWARDED,
                 K.CONSUMED],
                self.gates["coordinator"].notify,
            ))
        for kinds, fn in wiring:
            L.subscribe_many(kinds, fn)
            self._gate_subs.append((kinds, fn))

    def _unwire_gates(self) -> None:
        for kinds, fn in self._gate_subs:
            self.core.lifecycle.unsubscribe_many(kinds, fn)
        self._gate_subs = []

    # ------------------------------------------------------------ workers
    def _instance_loop(self, inst_id: int) -> None:
        core = self.core
        gate = self.gates["instance"]
        while not self._stop.is_set():
            with core._instances_lock:
                alive = inst_id in core.instances
            if not alive:
                return  # failed / removed: the thread retires itself
            seen = gate.seq()
            t0 = time.perf_counter()
            n = core.decode_instance(inst_id, core.rcfg.decode_steps_per_tick)
            with self._busy_lock:
                self.busy["decode"] += time.perf_counter() - t0
            if n == 0 and not core.instance_busy(inst_id):
                # nothing decoding (empty or budget-starved): sleep until
                # a Route / freed budget signals. The pre-step seq read
                # means a signal during decode_instance wakes immediately.
                gate.wait(seen, timeout=0.05)

    def _coordinator_loop(self) -> None:
        core = self.core
        gate = self.gates["coordinator"]
        rcfg = core.rcfg
        if rcfg.streaming:
            # background rebalance pacing: incremental admission handles
            # per-event routing, so full passes are deliberately rare
            interval = max(rcfg.stream_rebalance_interval_s, 0.001)
        else:
            interval = (
                rcfg.coordinator_interval_s
                if rcfg.coordinator_interval_s > 0
                else 0.0005
            )
        while not self._stop.is_set():
            seen = gate.seq()
            t0 = time.perf_counter()
            core.ts.refill()
            core.coordinator_cycle()
            with self._busy_lock:
                self.busy["coordinate"] += time.perf_counter() - t0
            gate.wait(seen, timeout=interval)

    def _trainer_loop(self) -> None:
        core = self.core
        gate = self.gates["trainer"]
        while not self._stop.is_set():
            if core.model_version >= core.rcfg.total_steps:
                return
            seen = gate.seq()
            t0 = time.perf_counter()
            rec = core.train_once()
            with self._busy_lock:
                self.busy["train"] += time.perf_counter() - t0
            if rec is None:
                gate.wait(seen, timeout=0.05)

    def _spawn(self, name: str, target, *args) -> None:
        t = threading.Thread(target=target, args=args, name=name, daemon=True)
        self._threads[name] = t
        t.start()

    # ---------------------------------------------------------------- run
    def run(
        self,
        max_ticks: int = 100000,
        progress: Optional[Callable[[StepRecord], None]] = None,
    ) -> List[StepRecord]:
        """Run until ``total_steps`` training steps (or the wall timeout).

        ``max_ticks`` is accepted for interface parity with the cooperative
        scheduler; threaded progress is time-, not tick-, bounded.
        """
        del max_ticks
        core = self.core
        self._stop.clear()
        self._wire_gates()
        # overlapped parameter publication (Appendix A: Push hides behind
        # the next training step; FIFO worker keeps versions ordered)
        self.pusher = BackgroundPusher(
            core.ps, tracer=core.tracer, metrics=core.metrics
        ).start()
        core._push_fn = self.pusher.push
        core.reward_server.start()
        self._spawn("coordinator", self._coordinator_loop)
        self._spawn("trainer", self._trainer_loop)
        seen = len(core.history)
        deadline = time.perf_counter() + self.wall_timeout_s
        try:
            while (
                core.model_version < core.rcfg.total_steps
                and time.perf_counter() < deadline
            ):
                # supervisor: give every live instance a decode thread
                # (elastic scale-up spawns late threads; failed instances'
                # threads exit on their own)
                with core._instances_lock:
                    ids = list(core.instances)
                for inst_id in ids:
                    name = f"instance-{inst_id}"
                    t = self._threads.get(name)
                    if t is None or not t.is_alive():
                        self._spawn(name, self._instance_loop, inst_id)
                while progress and seen < len(core.history):
                    progress(core.history[seen])
                    seen += 1
                time.sleep(0.002)
            if core.model_version < core.rcfg.total_steps:
                self.timed_out = True
                print(
                    f"[ThreadedScheduler] WARNING: wall timeout "
                    f"({self.wall_timeout_s:.0f}s) hit at "
                    f"{core.model_version}/{core.rcfg.total_steps} steps — "
                    f"partial history returned "
                    f"(raise RuntimeConfig.threaded_wall_timeout_s)",
                    flush=True,
                )
        finally:
            self.shutdown()
        while progress and seen < len(core.history):
            progress(core.history[seen])
            seen += 1
        return core.history

    def shutdown(self) -> None:
        self._stop.set()
        for gate in self.gates.values():
            gate.notify()  # wake sleepers so they observe the stop flag
        for t in self._threads.values():
            t.join(timeout=10.0)
        self._threads = {}
        self._unwire_gates()
        core = self.core
        core.reward_server.stop(drain=False)
        if self.pusher is not None:
            self.pusher.stop()
            core._push_fn = core.ps.push
            self.pusher = None

def make_scheduler(kind: str, core: RuntimeCore, **kw):
    if kind in ("tick", "cooperative"):
        return CooperativeScheduler(core)
    if kind == "threaded":
        return ThreadedScheduler(core, **kw)
    raise ValueError(f"unknown scheduler {kind!r} (tick | threaded)")
