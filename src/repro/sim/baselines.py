"""Baseline RL systems for the Fig. 13/15 comparisons.

* ``SyncSim``     — VeRL-style synchronous shared-resource execution:
                    rollout the whole step batch to completion (training
                    waits for the longest trajectory), then train, then
                    sync every instance. No staleness (eta = 0 by
                    construction).
* ``OneStepSim``  — VeRL-Pipeline-style one-step asynchrony: disaggregated;
                    rollout generates batch k+1 while the trainer consumes
                    batch k (exactly one version behind). Global instance
                    sync at batch boundaries.
* in-flight-limit (VeRL-Async / AReaL / ROLL Flash) — NOT here: per the
  paper's own ablation (Fig. 16, all-vanilla == VeRL-Async), it is
  ``StaleFlowSim`` with ``suite=StrategySuite.vanilla()``.

All baselines construct their replicas through the engine-backend factory
(``repro.rollout.backend.create_backend("sim", ...)``) and share the
heavy-tail length sampler, so differences come from coordination, not
engine modeling.
"""
from __future__ import annotations

from typing import Dict, List, Optional

from repro.core.lifecycle import TrajectoryLifecycle
from repro.core.types import Trajectory
from repro.rollout.backend import EngineBackend, create_backend
from repro.sim.engine import SimConfig, SimResult, _length_sampler


def _make_instances(cfg: SimConfig) -> Dict[int, EngineBackend]:
    """Baselines construct replicas through the backend factory — same
    interface the StaleFlow sim and the live runtime use."""
    return {
        i: create_backend(
            "sim", i, cost_model=cfg.cost_model, prefill_tps=cfg.prefill_tps
        )
        for i in range(cfg.n_instances)
    }


def _make_batch(cfg: SimConfig, sampler, start_id: int) -> List[Trajectory]:
    out = []
    n = cfg.batch_size * cfg.group_size
    for i in range(n):
        t = Trajectory(
            traj_id=start_id + i,
            prompt=[0] * cfg.prompt_len,
            group_id=(start_id + i) // max(cfg.group_size, 1),
        )
        t.sim_target_len = sampler()
        out.append(t)
    return out


def _rollout_to_completion(
    cfg: SimConfig,
    instances: Dict[int, EngineBackend],
    batch: List[Trajectory],
    t_start: float,
    lifecycle: Optional[TrajectoryLifecycle] = None,
) -> float:
    """Round-robin assign and advance until every trajectory completes.
    Returns the finish time (>= t_start). Within-instance waiting queues
    model the KV budget exactly as the StaleFlow sim does. Completions are
    published on ``lifecycle`` when given, so baseline runs expose the
    same event stream the coordinated systems do."""
    for i, traj in enumerate(batch):
        inst = i % len(instances)
        instances[inst].route(traj, t_start)
        if lifecycle is not None:
            lifecycle.routed(traj, inst)
    now = t_start
    remaining = len(batch)
    while remaining > 0:
        for inst in instances.values():
            done = inst.step(now, cfg.dt)
            remaining -= len(done)
            if lifecycle is not None:
                for traj in done:
                    lifecycle.completed(traj, traj.instance)
        now += cfg.dt
        if now - t_start > cfg.max_sim_time:
            raise RuntimeError("rollout did not converge")
    return now


def _batch_tokens(cfg: SimConfig, batch: List[Trajectory]) -> int:
    return sum(cfg.prompt_len + t.sim_target_len for t in batch)


class SyncSim:
    def __init__(self, cfg: SimConfig):
        self.cfg = cfg
        self.lifecycle = TrajectoryLifecycle()  # event telemetry parity

    def run(self) -> SimResult:
        cfg = self.cfg
        sampler = _length_sampler(cfg)
        instances = _make_instances(cfg)
        now, tokens, next_id = 0.0, 0, 0
        loads = []
        for step in range(cfg.total_steps):
            batch = _make_batch(cfg, sampler, next_id)
            next_id += len(batch)
            end = _rollout_to_completion(
                cfg, instances, batch, now, self.lifecycle
            )
            loads.append((now, {i: len(inst.running) for i, inst in instances.items()}))
            bt = _batch_tokens(cfg, batch)
            train = cfg.train_fixed + cfg.train_per_token * bt
            # shared resources: training is sequential with rollout, plus a
            # full (non-overlapped) weight sync back into the rollout engine
            now = end + train + cfg.pull_time
            tokens += bt
            for inst in instances.values():
                inst.pull(None, step + 1, now)
        return SimResult(
            total_time=now,
            total_tokens=tokens,
            steps=cfg.total_steps,
            throughput=tokens / now,
            staleness_hists=[[0] * cfg.batch_size] * cfg.total_steps,
            instance_load=loads,
            sync_events=[],
        )


class OneStepSim:
    def run_impl(self, cfg: SimConfig) -> SimResult:
        sampler = _length_sampler(cfg)
        instances = _make_instances(cfg)
        now, tokens, next_id = 0.0, 0, 0
        loads = []
        pending = None  # completed batch awaiting training (one step behind)
        for step in range(cfg.total_steps):
            batch = _make_batch(cfg, sampler, next_id)
            next_id += len(batch)
            # rollout of batch k overlaps training of batch k-1
            roll_end = _rollout_to_completion(
                cfg, instances, batch, now, self.lifecycle
            )
            train_end = now
            if pending is not None:
                bt = _batch_tokens(cfg, pending)
                train_end = now + cfg.train_fixed + cfg.train_per_token * bt
                tokens += bt
            # batch boundary: both sides barrier, then a global sync
            # (rollout stays exactly one version behind)
            now = max(roll_end, train_end) + cfg.pull_time
            loads.append(
                (now, {i: len(inst.running) for i, inst in instances.items()})
            )
            for inst in instances.values():
                inst.pull(None, step + 1, now)
            pending = batch
        # drain: train the final rolled-out batch with nothing to overlap
        bt = _batch_tokens(cfg, pending)
        now += cfg.train_fixed + cfg.train_per_token * bt
        tokens += bt
        return SimResult(
            total_time=now,
            total_tokens=tokens,
            steps=cfg.total_steps,
            throughput=tokens / now,
            staleness_hists=[[1] * cfg.batch_size] * cfg.total_steps,
            instance_load=loads,
            sync_events=[],
        )

    def __init__(self, cfg: SimConfig):
        self.cfg = cfg
        self.lifecycle = TrajectoryLifecycle()  # event telemetry parity

    def run(self) -> SimResult:
        return self.run_impl(self.cfg)


SYSTEMS = {
    "staleflow": "StaleFlowSim (suite=staleflow)",
    "inflight": "StaleFlowSim (suite=vanilla) == VeRL-Async/AReaL/ROLL-Flash",
    "onestep": "OneStepSim == VeRL-Pipeline",
    "sync": "SyncSim == VeRL",
}
