"""Discrete-event (time-stepped) cluster simulator.

The control plane is REAL: the simulator drives the actual
``StalenessManager``, ``TrajectoryServer`` and ``RolloutCoordinator`` with
their strategies — only the data-plane timing is simulated:

* decode progress per instance follows the paper's cost model (Eq. 2 with
  the H20-profiled Table 4 coefficients by default),
* trajectory response lengths are drawn from the heavy-tail lognormal that
  reproduces Fig. 4's skewness,
* training occupies a dedicated trainer for ``train_time(batch_tokens)``,
* Pull stalls an instance for ``pull_time`` (Fig. 19 / Table 3); re-prefill
  after routing/migration stalls for ``tokens / prefill_tps`` (Table 3:
  prefill is 7.9% of step time),
* Push overlaps training (Appendix A) — the new version becomes pullable
  ``push_time`` after the optimizer step, without blocking the trainer.

This is the engine behind the Fig. 13/15/16/17/18 reproductions
(``benchmarks/``). StaleFlow vs the strict-staleness in-flight-limit
baseline (VeRL-Async) differ ONLY in the strategy suite — matching the
paper's observation (Fig. 16) that all-vanilla strategies reduce StaleFlow
to VeRL-Async. Sync (VeRL) and one-step (VeRL-Pipeline) baselines live in
``sim.baselines``.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core import (
    CostModel,
    FnVerifier,
    PAPER_H20_QWEN3_30B,
    RewardServer,
    RolloutCoordinator,
    StalenessManager,
    StrategyConfig,
    StrategySuite,
    TrajectoryLifecycle,
    TrajectoryServer,
)
from repro.core.lifecycle import LifecycleEvent, LifecycleEventKind
from repro.core.types import Trajectory
from repro.rollout.backend import (
    SimBackend,
    VersionSource,
    create_backend,
    execute_commands,
)


@dataclass
class SimConfig:
    n_instances: int = 8
    batch_size: int = 128            # groups per training step
    group_size: int = 16
    eta: int = 1
    prompt_len: int = 2048
    response_mean: float = 4000.0
    response_sigma: float = 1.0
    response_cap: int = 20000
    total_steps: int = 8
    seed: int = 0
    cost_model: CostModel = field(default_factory=lambda: PAPER_H20_QWEN3_30B)
    # training: time = train_fixed + train_per_token * batch_tokens
    train_fixed: float = 5.0
    train_per_token: float = 6e-6
    pull_time: float = 7.8 / 4       # Table 3 per-step pull cost, amortized
    push_time: float = 2.0
    prefill_tps: float = 50000.0     # re-prefill throughput (tokens/s)
    coordinator_interval: float = 2.0
    dt: float = 0.5
    suite: StrategySuite = field(default_factory=StrategySuite.staleflow)
    strategy_cfg: StrategyConfig = field(default_factory=StrategyConfig)
    group_redundancy: int = 0
    batch_redundancy: int = 0
    max_sim_time: float = 1e7
    # streaming pipeline mirror (same semantics as RuntimeConfig.streaming):
    # completions/aborts trigger RolloutCoordinator.route_instance on the
    # freed instance, and the trainer consumes partial batches — keeps the
    # sim's control plane exercising the exact live cost-model/verifier
    # code paths under streaming
    streaming: bool = False
    stream_min_fill: int = 1
    # observability mirror (same semantics as RuntimeConfig): a
    # TrajectoryTracer on the sim's lifecycle bus, clocked in sim seconds
    observability: bool = False
    trace_path: Optional[str] = None
    # reward-hub mirror (same semantics as RuntimeConfig.verifier): any
    # score/score_trajectory object — a RewardHub, a FaultInjectingVerifier
    # stack, ... — replaces the instant constant-1.0 verifier, and terminal
    # verification failures (VerificationAbort) release the protocol entry
    # through the coordinator exactly as the live runtime does
    verifier: Optional[object] = None


@dataclass
class SimResult:
    total_time: float
    total_tokens: int               # tokens consumed by training
    steps: int
    throughput: float               # tokens / s
    staleness_hists: List[List[int]]
    instance_load: List[Tuple[float, Dict[int, int]]]  # (t, inst -> n_run)
    sync_events: List[Tuple[float, int, int]]          # (t, inst, version)
    pull_total: float = 0.0
    interrupt_count: int = 0
    route_count: int = 0
    train_busy: float = 0.0
    decode_tokens: float = 0.0
    prefill_tokens: float = 0.0


# The simulator's data plane now lives behind the engine-backend contract
# (``repro.rollout.backend.SimBackend``); ``SimInstance`` remains as the
# historical name used throughout the sim/baseline modules and tests.
SimInstance = SimBackend


def _length_sampler(cfg: SimConfig):
    rng = np.random.default_rng(cfg.seed + 1)
    mu = np.log(cfg.response_mean) - cfg.response_sigma ** 2 / 2

    def sample() -> int:
        return int(np.clip(rng.lognormal(mu, cfg.response_sigma), 16, cfg.response_cap))

    return sample


def _prompt_source(cfg: SimConfig):
    proto = [0] * cfg.prompt_len
    return iter(lambda: list(proto), None)  # infinite


class StaleFlowSim:
    """StaleFlow (or, with ``suite=vanilla``, the in-flight-limit baseline)."""

    def __init__(self, cfg: SimConfig):
        self.cfg = cfg
        cm = cfg.cost_model
        self.manager = StalenessManager(
            batch_size=cfg.batch_size, eta=cfg.eta,
            batch_redundancy=cfg.batch_redundancy,
        )
        self.ts = TrajectoryServer(
            _prompt_source(cfg),
            capacity_groups=(cfg.eta + 1) * cfg.batch_size + cfg.batch_redundancy,
            group_size=cfg.group_size,
            group_redundancy=cfg.group_redundancy,
            max_new_tokens=cfg.response_cap,
        )
        # the same trajectory-lifecycle bus the live runtime runs on: the
        # TS, reward scoring (instant rule-based verifier), protocol
        # Occupy, and surplus aborts are all event subscribers here too
        self.lifecycle = TrajectoryLifecycle()
        self.ts.attach(self.lifecycle)
        self.reward_server = RewardServer(
            cfg.verifier
            if cfg.verifier is not None
            else FnVerifier(lambda prompt, response: 1.0),
            self.lifecycle,
            # hub on_failure="abort" mirrors the live runtime: release the
            # protocol entry + group-wide ABORTED (deferred: the
            # coordinator is constructed just below)
            on_abort=lambda traj: self.coordinator.abort_unverifiable(traj),
        )
        self.coordinator = RolloutCoordinator(
            self.manager, self.ts, cost_model=cm, cfg=cfg.strategy_cfg,
            suite=cfg.suite, group_sampling=cfg.group_size > 1,
            lifecycle=self.lifecycle,
        )
        self.lifecycle.subscribe(LifecycleEventKind.ABORTED, self._on_aborted)
        self.now = 0.0
        # optional tracer, driven by the sim clock: the exported trace has
        # the exact layout of a live run, just with sim-second timestamps
        self.tracer = None
        if cfg.observability or cfg.trace_path:
            from repro.obs import TrajectoryTracer

            self.tracer = TrajectoryTracer(
                self.lifecycle,
                clock=lambda: self.now,
                floor_source=lambda: self.manager.train_version,
            )
        self.instances: Dict[int, SimBackend] = {
            i: create_backend(
                "sim", i, cost_model=cm,
                prefill_tps=cfg.prefill_tps, pull_time=cfg.pull_time,
            )
            for i in range(cfg.n_instances)
        }
        if self.tracer is not None:
            for inst in self.instances.values():
                inst.on_admit = self.tracer.on_admit
        self._sample_len = _length_sampler(cfg)
        self._completed_len: Dict[int, int] = {}
        self.now = 0.0
        self.trainer_busy_until = 0.0
        self.pending_version: Optional[int] = None  # lands at push completion
        self.version_available_at = 0.0
        self.ps = VersionSource(0)
        self.result = SimResult(0, 0, 0, 0.0, [], [], [])

    @property
    def ps_version(self) -> int:
        return self.ps.version

    @ps_version.setter
    def ps_version(self, v: int) -> None:
        self.ps.version = v

    # ------------------------------------------------------------------ run
    def run(self) -> SimResult:
        cfg = self.cfg
        self.ts.refill()
        self._assign_targets()
        next_coord = 0.0
        next_load_sample = 0.0
        while (
            self.result.steps < cfg.total_steps and self.now < cfg.max_sim_time
        ):
            # 1) decode
            for inst in self.instances.values():
                for traj in inst.step(self.now, cfg.dt):
                    self._on_complete(traj)
            # 2) coordinator cycle
            if self.now >= next_coord:
                self._coordinate()
                next_coord = self.now + cfg.coordinator_interval
            # 3) trainer
            self._trainer()
            # 4) refill + assign target lengths to new trajectories
            self.ts.refill()
            self._assign_targets()
            # telemetry
            if self.now >= next_load_sample:
                self.result.instance_load.append(
                    (self.now, {i: len(inst.running) for i, inst in self.instances.items()})
                )
                if self.tracer is not None:
                    for i, inst in self.instances.items():
                        self.tracer.sample(
                            f"instance-{i}",
                            {
                                "active": len(inst.running),
                                "waiting": len(inst.waiting),
                                "kv_fill": inst.kv_bytes()
                                / max(cfg.cost_model.kv_budget, 1e-9),
                            },
                        )
                    self.tracer.sample(
                        "staleness-buffers",
                        {
                            "in_flight": self.manager.in_flight(),
                            "train_version": self.manager.train_version,
                        },
                    )
                next_load_sample = self.now + 10.0
            self.now += cfg.dt

        r = self.result
        r.total_time = self.now
        r.throughput = r.total_tokens / max(self.now, 1e-9)
        r.staleness_hists = [list(h) for h in self.manager.consumed_staleness]
        r.decode_tokens = sum(i.decode_tokens for i in self.instances.values())
        r.prefill_tokens = sum(i.prefill_tokens for i in self.instances.values())
        if self.tracer is not None and self.cfg.trace_path:
            from repro.obs import export_chrome_trace

            export_chrome_trace(self.tracer, self.cfg.trace_path)
        return r

    def _assign_targets(self) -> None:
        for t in self.ts.peek():
            if t.sim_target_len == 0:
                t.sim_target_len = self._sample_len()

    def _on_aborted(self, e: LifecycleEvent) -> None:
        """Protocol-initiated aborts (surplus/filtering) release sim
        residency; command-executed aborts (``inst`` set) already did."""
        if e.inst is not None:
            return
        freed = None
        for inst_id, inst in self.instances.items():
            if inst.abort([e.traj_id], self.now):
                freed = inst_id
        if self.cfg.streaming and freed is not None:
            self._stream_admit(freed)

    def _on_complete(self, traj: Trajectory) -> None:
        if self.ts.get(traj.traj_id) is None:
            return  # aborted earlier this tick (redundancy surplus)
        self._completed_len[traj.traj_id] = traj.sim_generated
        inst_id = traj.instance
        # the event fans out: TS marks GENERATED, the reward server scores
        # (instant rule-based verifier), protocol Occupy + surplus aborts
        # cascade off REWARDED — the sim and the live runtime share one
        # lifecycle write path
        self.lifecycle.completed(traj, inst_id)
        if self.cfg.streaming:
            # streaming mirror: the freed KV capacity is refilled by an
            # incremental single-instance routing decision, same fast path
            # the live runtime drives off this event
            self._stream_admit(inst_id)

    def _stream_admit(self, inst_id) -> None:
        inst = self.instances.get(inst_id)
        if inst is None or self.coordinator.in_cycle():
            return
        commands = self.coordinator.route_instance(
            inst.snapshot(), self.ps_version
        )
        if not commands:
            return
        res = execute_commands(
            commands, {inst_id: inst}, self.ts, self.ps, now=self.now,
            lifecycle=self.lifecycle,
        )
        self.result.route_count += res.routed

    def _coordinate(self) -> None:
        # new version becomes visible once Push lands
        if self.pending_version is not None and self.now >= self.version_available_at:
            self.ps_version = self.pending_version
            self.pending_version = None
        snaps = {i: inst.snapshot() for i, inst in self.instances.items()}
        commands = self.coordinator.step(snaps, self.ps_version)
        res = execute_commands(
            commands, self.instances, self.ts, self.ps, now=self.now,
            lifecycle=self.lifecycle,
        )
        self.result.route_count += res.routed
        self.result.interrupt_count += res.interrupted
        self.result.pull_total += self.cfg.pull_time * len(res.pulls)
        self.result.sync_events.extend(
            (self.now, inst_id, version) for inst_id, version in res.pulls
        )

    def _trainer(self) -> None:
        if self.now < self.trainer_busy_until:
            return
        min_fill = self.cfg.stream_min_fill if self.cfg.streaming else None
        if not self.manager.ready(min_fill):
            return
        ids = self.coordinator.try_consume(min_fill)
        if ids is None:
            return
        # batch token count: look up retired trajectories' final lengths
        tokens = 0
        for tid in ids:
            # retired from registry; approximate with target lengths stored
            # on the consumed trajectories via the groups' members
            tokens += self.cfg.prompt_len  # prompt
        # responses: consumed trajs are gone from the registry; track their
        # lengths through the completion hook instead
        tokens += self._consumed_response_tokens(ids)
        dur = self.cfg.train_fixed + self.cfg.train_per_token * tokens
        self.trainer_busy_until = self.now + dur
        self.result.train_busy += dur
        self.result.total_tokens += tokens
        self.result.steps += 1
        if self.tracer is not None:
            self.tracer.activity(
                "train_step", self.now, self.trainer_busy_until,
                track="trainer", args={"step": self.result.steps},
            )
        new_version = (
            self.ps_version + 1
            if self.pending_version is None
            else self.pending_version + 1
        )
        self.pending_version = new_version
        self.version_available_at = self.trainer_busy_until + self.cfg.push_time

    def _consumed_response_tokens(self, ids) -> int:
        # consume retires payloads from the TS registry; lengths were
        # recorded at completion time
        return sum(self._completed_len.pop(tid, 0) for tid in ids)
