"""Fault-tolerant checkpoint/restore.

Design points for 1000+-node deployments (DESIGN.md §3):

* **Mesh-agnostic**: leaves are gathered to host numpy before writing, and
  restore returns host arrays the launcher re-shards under whatever mesh the
  *restarted* job has — a restart may change topology (elastic scaling,
  failed pod excluded) without invalidating checkpoints.
* **Atomic**: written to ``<dir>.tmp`` then renamed, so a crash mid-write
  never corrupts the latest checkpoint; ``latest_step`` scans for the newest
  complete one.
* **Complete system state**: params + optimizer + model version + the
  staleness-protocol state (buffer entries and train_version) + TS payloads
  (in-flight trajectories), so an interrupted async run resumes with its
  staleness guarantees intact rather than dropping in-flight work.

Format: one ``.npz`` for array leaves (pytree paths as keys) + ``meta.json``
for structure and scalar state — serialized with ``orjson`` when available,
otherwise stdlib ``json`` (offline environments). Either reader loads either
writer's output; scalar state is expected to be finite (non-finite floats
are the one divergence: orjson writes ``null`` where stdlib writes ``NaN``).
"""
from __future__ import annotations

import os
import shutil
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np

try:
    import orjson

    def _json_dumps(obj: Any) -> bytes:
        return orjson.dumps(obj, option=orjson.OPT_SERIALIZE_NUMPY)

    def _json_loads(data: bytes) -> Any:
        return orjson.loads(data)

except ModuleNotFoundError:  # pragma: no cover - depends on environment
    import json

    def _np_default(o: Any):
        if isinstance(o, np.integer):
            return int(o)
        if isinstance(o, np.floating):
            return float(o)
        if isinstance(o, np.ndarray):
            return o.tolist()
        raise TypeError(f"not JSON serializable: {type(o)!r}")

    def _json_dumps(obj: Any) -> bytes:
        return json.dumps(
            obj, default=_np_default, separators=(",", ":")
        ).encode("utf-8")

    def _json_loads(data: bytes) -> Any:
        return json.loads(data)


def _flatten_with_paths(tree: Any, prefix: str = "") -> Dict[str, np.ndarray]:
    flat = {}
    paths_leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
    for path, leaf in paths_leaves:
        key = prefix + jax.tree_util.keystr(path)
        flat[key] = np.asarray(leaf)
    return flat


def save_checkpoint(
    directory: str,
    step: int,
    params: Any,
    opt_state: Any,
    *,
    extra_meta: Optional[Dict[str, Any]] = None,
    protocol_state: Optional[Dict[str, Any]] = None,
) -> str:
    """Write checkpoint for ``step``; returns the final path."""
    path = os.path.join(directory, f"step_{step:08d}")
    tmp = path + ".tmp"
    os.makedirs(tmp, exist_ok=True)

    arrays = _flatten_with_paths(params, "params")
    arrays.update(_flatten_with_paths(opt_state, "opt"))
    np.savez(os.path.join(tmp, "arrays.npz"), **arrays)

    treedefs = {
        "params_treedef": jax.tree_util.tree_structure(params),
        "opt_treedef": jax.tree_util.tree_structure(opt_state),
    }
    meta = {
        "step": step,
        "params_keys": sorted(_flatten_with_paths(params, "params")),
        "opt_keys": sorted(_flatten_with_paths(opt_state, "opt")),
        "extra": extra_meta or {},
        "protocol": protocol_state or {},
    }
    with open(os.path.join(tmp, "meta.json"), "wb") as f:
        f.write(_json_dumps(meta))
    # treedefs are reproducible from the same code version; store reprs for
    # sanity checking on restore
    with open(os.path.join(tmp, "treedef.txt"), "w") as f:
        f.write(str(treedefs["params_treedef"]) + "\n")
        f.write(str(treedefs["opt_treedef"]) + "\n")

    if os.path.exists(path):
        shutil.rmtree(path)
    os.rename(tmp, path)
    return path


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = []
    for name in os.listdir(directory):
        if name.startswith("step_") and not name.endswith(".tmp"):
            if os.path.exists(os.path.join(directory, name, "meta.json")):
                steps.append(int(name.split("_")[1]))
    return max(steps) if steps else None


def restore_checkpoint(
    directory: str,
    params_template: Any,
    opt_template: Any,
    *,
    step: Optional[int] = None,
) -> Tuple[Any, Any, Dict[str, Any]]:
    """Restore into the *templates'* tree structure (host numpy leaves).

    Templates come from ``init_params``/``init_opt_state`` under the NEW
    topology — leaf shapes must match, shardings need not.
    """
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {directory}")
    path = os.path.join(directory, f"step_{step:08d}")
    arrays = np.load(os.path.join(path, "arrays.npz"))
    with open(os.path.join(path, "meta.json"), "rb") as f:
        meta = _json_loads(f.read())

    def fill(template: Any, prefix: str) -> Any:
        paths_leaves, treedef = jax.tree_util.tree_flatten_with_path(template)
        leaves = []
        for p, leaf in paths_leaves:
            key = prefix + jax.tree_util.keystr(p)
            arr = arrays[key]
            if arr.shape != tuple(np.shape(leaf)):
                raise ValueError(
                    f"shape mismatch for {key}: ckpt {arr.shape} vs "
                    f"template {np.shape(leaf)}"
                )
            leaves.append(arr)
        return jax.tree_util.tree_unflatten(treedef, leaves)

    params = fill(params_template, "params")
    opt_state = fill(opt_template, "opt")
    return params, opt_state, meta


# ------------------------------------------------- protocol state (de)hydrate
def dump_protocol_state(manager) -> Dict[str, Any]:
    """Serialize a StalenessManager for exact-resume restarts."""
    with manager._lock:
        return {
            "batch_size": manager.batch_size,
            "eta": manager.eta,
            "batch_redundancy": manager.batch_redundancy,
            "train_version": manager.train_version,
            "buffers": {
                str(v): [
                    {"state": e.state.value, "key": e.key, "version": e.version}
                    for e in buf.entries
                ]
                for v, buf in manager._buffers.items()
            },
        }


def dump_service_state(
    manager,
    *,
    reward_server=None,
    retired=None,
    lifecycle=None,
) -> Dict[str, Any]:
    """Protocol state plus the service-layer in-flight picture.

    The staleness buffers remain the restart-critical payload
    (``load_protocol_state`` reads them); the ``services`` section records
    what was in flight across the reward queue, the retired-payload store,
    and the lifecycle bus when the checkpoint was cut — the restart
    aborts those trajectories (work is regenerated), so the dump is
    forensic: it tells an operator exactly how much in-flight work a
    restart at this checkpoint discards.
    """
    state = dump_protocol_state(manager)
    services: Dict[str, Any] = {}
    if reward_server is not None:
        services["reward"] = reward_server.stats()
    if retired is not None:
        services["retired_ids"] = sorted(retired.ids())
    if lifecycle is not None:
        services["lifecycle_counts"] = {
            k.value: v for k, v in lifecycle.counts.items()
        }
    state["services"] = services
    return state


def load_service_state(state: Dict[str, Any]):
    """Returns ``(StalenessManager, services_dict)`` from a service-shaped
    dump (``services`` is ``{}`` for pre-service checkpoints — the formats
    are mutually readable)."""
    return load_protocol_state(state), state.get("services", {})


def load_protocol_state(state: Dict[str, Any]):
    from repro.core.staleness import Entry, EntryState, StalenessBuffer, StalenessManager

    m = StalenessManager(
        batch_size=state["batch_size"],
        eta=state["eta"],
        batch_redundancy=state.get("batch_redundancy", 0),
    )
    m.train_version = state["train_version"]
    for v_str, entries in state["buffers"].items():
        v = int(v_str)
        buf = StalenessBuffer(v_buf=v, capacity=m.capacity)
        for slot, e in enumerate(entries):
            entry = Entry(EntryState(e["state"]), e["key"], e["version"])
            buf.entries[slot] = entry
            if entry.key is not None:
                m._index[entry.key] = (v, slot)
        m._buffers[v] = buf
    m.check_invariants()
    return m
