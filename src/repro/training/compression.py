"""Gradient compression for the DP all-reduce (distributed-optimization
trick for scale-out; DESIGN.md §3).

Two schemes, both with error feedback (the residual is carried and added to
the next step's gradient so compression bias does not accumulate):

* ``int8``  — per-tensor symmetric quantization: 4x wire reduction vs f32
              (2x vs bf16), cheap (one amax pass).
* ``topk``  — magnitude sparsification at rate ``k``: transmit only the
              top-k fraction (values + indices).

On the TPU target these run *inside* shard_map around the DP psum
(``repro.distributed.collectives``): quantize -> all-reduce int32-safe
accumulation -> dequantize. Host-level reference + error-feedback algebra
live here so they are unit-testable without a mesh.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp


# ----------------------------------------------------------------- int8
def quantize_int8(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    amax = jnp.max(jnp.abs(x)).astype(jnp.float32)
    scale = jnp.where(amax > 0, amax / 127.0, 1.0)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


# ----------------------------------------------------------------- top-k
def sparsify_topk(x: jax.Array, rate: float) -> Tuple[jax.Array, jax.Array]:
    """Returns (values, flat indices); keeps ceil(rate * size) entries."""
    flat = x.reshape(-1).astype(jnp.float32)
    k = max(1, int(flat.size * rate))
    vals, idx = jax.lax.top_k(jnp.abs(flat), k)
    return flat[idx], idx


def densify_topk(values: jax.Array, idx: jax.Array, shape) -> jax.Array:
    flat = jnp.zeros(int(jnp.prod(jnp.asarray(shape))), jnp.float32)
    return flat.at[idx].set(values).reshape(shape)


# -------------------------------------------------------- error feedback
class ErrorFeedback:
    """Carries per-leaf compression residuals across steps."""

    def __init__(self, params_template: Any):
        self.residual = jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params_template
        )

    def compress_grads(
        self, grads: Any, *, scheme: str = "int8", topk_rate: float = 0.01
    ) -> Any:
        """Compress+decompress grads (simulating the wire), tracking error."""

        def one(g, r):
            g32 = g.astype(jnp.float32) + r
            if scheme == "int8":
                q, s = quantize_int8(g32)
                out = dequantize_int8(q, s)
            elif scheme == "topk":
                v, i = sparsify_topk(g32, topk_rate)
                out = densify_topk(v, i, g32.shape)
            else:
                raise ValueError(scheme)
            return out, g32 - out

        flat_g, treedef = jax.tree_util.tree_flatten(grads)
        flat_r = treedef.flatten_up_to(self.residual)
        outs = [one(g, r) for g, r in zip(flat_g, flat_r)]
        self.residual = treedef.unflatten([o[1] for o in outs])
        return treedef.unflatten([o[0] for o in outs])


def compressed_bytes(grads: Any, *, scheme: str, topk_rate: float = 0.01) -> int:
    """Wire bytes for a compressed gradient pytree (for the roofline and
    sync-overhead accounting)."""
    total = 0
    for g in jax.tree_util.tree_leaves(grads):
        n = g.size
        if scheme == "int8":
            total += n + 4
        elif scheme == "topk":
            k = max(1, int(n * topk_rate))
            total += k * (4 + 4)
        else:
            total += n * 4
    return total
