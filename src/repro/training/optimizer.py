"""AdamW in pure pytree form (no optax dependency).

States are plain pytrees mirroring the params, so the distribution layer
shards them with the same PartitionSpecs as the parameters (ZeRO-3-style:
params are already fully sharded over the mesh, hence so are m/v)."""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 1e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.0
    grad_clip: float = 1.0


def init_opt_state(params: Any) -> Dict[str, Any]:
    zeros = jax.tree_util.tree_map(lambda p: jnp.zeros_like(p, jnp.float32), params)
    return {
        "m": zeros,
        "v": jax.tree_util.tree_map(jnp.copy, zeros),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree: Any) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves))


def adamw_update(
    params: Any, grads: Any, state: Dict[str, Any], cfg: AdamWConfig
) -> Tuple[Any, Dict[str, Any], Dict[str, jax.Array]]:
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9)) if cfg.grad_clip > 0 else 1.0
    step = state["step"] + 1
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m2 = cfg.b1 * m + (1 - cfg.b1) * g
        v2 = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        mh = m2 / b1c
        vh = v2 / b2c
        delta = mh / (jnp.sqrt(vh) + cfg.eps)
        if cfg.weight_decay:
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - cfg.lr * delta).astype(p.dtype), m2, v2

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return (
        new_p,
        {"m": new_m, "v": new_v, "step": step},
        {"grad_norm": gnorm},
    )
