"""Training-step factories.

``make_rl_train_step`` — the system's real step: DAPO/GRPO objective over a
consumed staleness-buffer batch (tokens + behavior logprobs + advantages +
response mask), grads, clip, AdamW. This is also what the multi-pod dry-run
lowers for every ``train_4k`` cell.

``make_lm_train_step`` — plain next-token cross-entropy (used by ablations
and as a pretraining-style baseline).

Both support gradient rematerialization (``remat=True`` checkpoints each
scanned block) and return (params, opt_state, metrics).
"""
from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import model as M
from repro.rl import losses
from repro.training.optimizer import AdamWConfig, adamw_update


def make_rl_train_step(
    cfg: ArchConfig,
    opt_cfg: AdamWConfig = AdamWConfig(),
    *,
    objective: str = "dapo",
    aux_coef: float = 0.01,
    remat: bool = False,
    impl: Optional[str] = None,
    accum_steps: int = 1,
) -> Callable:
    """Returns train_step(params, opt_state, batch) -> (params, opt, metrics).

    batch:
      tokens            (B, T) int32 — prompt + response, right padded
      behavior_logprobs (B, T) f32   — 0 outside response positions
      advantages        (B,)   f32
      mask              (B, T) f32   — 1 on response positions (shifted to
                                       align with next-token prediction)
      [frontend_embeds  (B, ...)     — vlm/audio stubs]

    ``accum_steps > 1`` splits the batch into microbatches scanned with
    f32 gradient accumulation — activation temp memory drops ~linearly
    (the lever that fits 76B/132B-class training under the 16 GB HBM gate;
    see EXPERIMENTS.md §Perf).
    """
    obj_fn = losses.dapo_objective if objective == "dapo" else losses.grpo_objective

    def loss_fn(params, batch):
        logits, aux = M.forward(
            cfg, params, batch["tokens"],
            frontend_embeds=batch.get("frontend_embeds"),
            impl=impl, remat=remat,
        )
        # next-token alignment: logits[:, t] predicts tokens[:, t+1]
        lp = losses.token_logprobs(
            logits[:, :-1], batch["tokens"][:, 1:]
        )                                           # (B, T-1)
        blp = batch["behavior_logprobs"][:, 1:]
        mask = batch["mask"][:, 1:]
        loss, metrics = obj_fn(lp, blp, batch["advantages"], mask, impl=impl) \
            if objective == "dapo" else obj_fn(lp, blp, batch["advantages"], mask)
        total = loss + aux_coef * aux["moe_aux"]
        metrics = dict(metrics)
        metrics["pg_loss"] = loss
        metrics["moe_aux"] = aux["moe_aux"]
        return total, metrics

    def train_step(params, opt_state, batch):
        if accum_steps == 1:
            (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                params, batch
            )
        else:
            micro = jax.tree_util.tree_map(
                lambda x: x.reshape((accum_steps, x.shape[0] // accum_steps)
                                    + x.shape[1:]),
                batch,
            )
            grads0 = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params
            )

            def body(acc, mb):
                g_acc, loss_acc, metric_acc = acc
                (l, m), g = jax.value_and_grad(loss_fn, has_aux=True)(
                    params, mb
                )
                g_acc = jax.tree_util.tree_map(
                    lambda a, b: a + b.astype(jnp.float32), g_acc, g
                )
                metric_acc = {
                    k: metric_acc[k] + jnp.asarray(v, jnp.float32)
                    for k, v in m.items()
                }
                return (g_acc, loss_acc + l, metric_acc), None

            metrics0 = {
                k: jnp.zeros((), jnp.float32)
                for k in ("mean_is_ratio", "pg_loss", "moe_aux")
            }
            (grads, loss, msum), _ = jax.lax.scan(
                body, (grads0, jnp.zeros((), jnp.float32), metrics0), micro
            )
            grads = jax.tree_util.tree_map(lambda g: g / accum_steps, grads)
            loss = loss / accum_steps
            metrics = {k: v / accum_steps for k, v in msum.items()}
        params, opt_state, opt_metrics = adamw_update(
            params, grads, opt_state, opt_cfg
        )
        metrics = dict(metrics)
        metrics.update(opt_metrics)
        metrics["loss"] = loss
        return params, opt_state, metrics

    return train_step


def make_lm_train_step(
    cfg: ArchConfig,
    opt_cfg: AdamWConfig = AdamWConfig(),
    *,
    remat: bool = False,
    impl: Optional[str] = None,
) -> Callable:
    def loss_fn(params, batch):
        logits, aux = M.forward(
            cfg, params, batch["tokens"],
            frontend_embeds=batch.get("frontend_embeds"),
            impl=impl, remat=remat,
        )
        lp = losses.token_logprobs(logits[:, :-1], batch["tokens"][:, 1:])
        mask = batch.get("mask")
        mask = mask[:, 1:] if mask is not None else jnp.ones_like(lp)
        nll = -(lp * mask).sum() / jnp.maximum(mask.sum(), 1.0)
        return nll + 0.01 * aux["moe_aux"], {"nll": nll}

    def train_step(params, opt_state, batch):
        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            params, batch
        )
        params, opt_state, opt_metrics = adamw_update(
            params, grads, opt_state, opt_cfg
        )
        metrics = dict(metrics)
        metrics.update(opt_metrics)
        metrics["loss"] = loss
        return params, opt_state, metrics

    return train_step
