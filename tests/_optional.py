"""Optional-dependency shims for the test suite.

``hypothesis`` powers the property tests but is not available in offline
environments; importing it at module scope used to kill collection of the
whole suite with ``ModuleNotFoundError``. Import ``given/settings/st`` from
here instead: with hypothesis installed they are the real thing, without it
they degrade to decorators that mark each property test as skipped while
keeping every non-hypothesis test in the same module collectible.
"""
from __future__ import annotations

__all__ = ["HAVE_HYPOTHESIS", "given", "settings", "st"]

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:  # offline environment
    import pytest

    HAVE_HYPOTHESIS = False

    class _AnyStrategy:
        """Stand-in for ``hypothesis.strategies``: every attribute is a
        callable returning an inert placeholder (only ever passed to the
        stub ``given`` below, which ignores it)."""

        def __getattr__(self, name):
            return lambda *args, **kwargs: None

    st = _AnyStrategy()

    def settings(*args, **kwargs):
        def deco(fn):
            return fn

        return deco

    def given(*args, **kwargs):
        return lambda fn: pytest.mark.skip(reason="hypothesis not installed")(fn)
