"""Shared fixtures for the test suite."""
import pytest

from repro.analysis import witness as lock_witness


@pytest.fixture
def lock_witnessed():
    """Run the test under the runtime lock-order witness.

    Enabling before the test body means every lock the test constructs
    (runtimes, reward hubs, schedulers) joins the tracked set; teardown
    fails the test if the acquisition graph recorded any order
    violation, cycle, or emit-under-lock — the threaded stress tests
    double as the race gate.
    """
    with lock_witness.enabled() as w:
        yield w
    w.assert_clean()
