"""Near-miss patterns that must produce zero diagnostics (no false
positives): emit under the emit-safe coordinator prefix, reentrant RLock
reentry, correctly ordered nesting, bare locks in single-role modules,
wall-clock outside deterministic modules, and an explicit suppression.
"""
import threading
import time

from repro.analysis.witness import make_lock, make_rlock


class Coordinator:
    def __init__(self, lifecycle):
        self.lifecycle = lifecycle
        self.lock = make_rlock("coordinator")
        self._ts_lock = make_lock("ts")
        # no roles directive: single-role modules may keep bare locks
        self._bare = threading.Lock()

    def consume(self, traj):
        with self.lock:
            # clean: the coordinator prefix is emit-safe by construction
            self.lifecycle.consumed(traj)

    def reentrant(self):
        with self.lock:
            with self.lock:  # clean: RLock reentry
                pass

    def ordered(self):
        with self.lock:
            with self._ts_lock:  # clean: 0 -> 30 respects the order
                pass

    def allowed_emit(self, traj):
        with self._ts_lock:
            # repro: allow[RPL001] reason=fixture demonstrates suppression
            self.lifecycle.aborted(traj)

    def stamp(self):
        # clean: not a deterministic module, wall-clock is fine here
        return time.time()
