"""Seeded RPL001: lifecycle dispatch while holding a non-emit-safe lock.

Reconstructs the PR 5 deadlock shape: a reward worker publishes REWARDED
while still holding its queue lock; the coordinator's INTERRUPTED
subscriber then blocks on that lock while holding the coordinator lock.
"""
from repro.analysis.witness import make_lock


class RewardWorker:
    def __init__(self, lifecycle):
        self.lifecycle = lifecycle
        self._lock = make_lock("reward")

    def score_one(self, traj):
        with self._lock:
            traj.reward = 1.0
            self.lifecycle.rewarded(traj)  # seeded RPL001 (direct emit)

    def score_indirect(self, traj):
        with self._lock:
            self._publish(traj)  # seeded RPL001 (transitive emit)

    def _publish(self, traj):
        self.lifecycle.rewarded(traj)

    def finish(self, event):
        # clean: dispatching with no lock held is the fixed shape
        self.lifecycle.emit(event)
