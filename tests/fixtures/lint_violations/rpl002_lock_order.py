"""Seeded RPL002: acquisitions that invert the declared partial order."""
from repro.analysis.witness import make_lock, make_rlock


class Coordinator:
    def __init__(self):
        self.lock = make_rlock("coordinator")
        self._ts_lock = make_lock("ts")
        self._stats_lock = make_lock("stats")

    def bad_inversion(self):
        with self._ts_lock:
            with self.lock:  # seeded RPL002: ts(30) -> coordinator(0)
                pass

    def bad_terminal(self):
        with self._stats_lock:
            with self._ts_lock:  # seeded RPL002: stats is a hard leaf
                pass

    def bad_reacquire(self):
        with self._ts_lock:
            with self._ts_lock:  # seeded RPL002: non-reentrant self-deadlock
                pass

    def good_nesting(self):
        # clean: coordinator(0) -> ts(30) -> stats(70) is the declared order
        with self.lock:
            with self._ts_lock:
                pass
        with self._stats_lock:
            pass
