# repro: roles=coordinator,decode,trainer
"""Seeded RPL003: the PR 7 unlocked-busy-dict shape.

Three loop threads bump a shared telemetry dict; one site skips the
lock. Facet A additionally flags the bare ``threading.Lock()`` that
bypasses the witness-aware factory.
"""
import threading

from repro.analysis.witness import make_lock


class BusyScheduler:
    def __init__(self):
        self._bare = threading.Lock()  # seeded RPL003 (facet A)
        self._busy_lock = make_lock("busy")
        self.busy = {"decode": 0.0, "train": 0.0, "coordinate": 0.0}

    def decode_loop(self, dt):
        self.busy["decode"] += dt  # seeded RPL003 (facet B: unguarded)

    def trainer_loop(self, dt):
        with self._busy_lock:
            self.busy["train"] += dt  # clean: guarded site

    def coordinate_locked(self, dt):
        # clean: '*_locked' names a caller-holds-the-lock contract
        self.busy["coordinate"] += dt
