# repro: deterministic
"""Seeded RPL004: wall-clock / unseeded randomness on a seed path."""
import random
import time


def sample_latency():
    jitter = random.random()  # seeded RPL004: global unseeded RNG
    stamp = time.time()  # seeded RPL004: wall-clock read
    return jitter, stamp


def seeded_ok(seed):
    # clean: explicit seeded generator + monotonic local duration
    rng = random.Random(seed)
    t0 = time.perf_counter()
    return rng.random(), time.perf_counter() - t0
