"""Seeded RPL005: Condition.notify must hold its own lock, alone."""
from repro.analysis.witness import make_condition, make_lock


class Gate:
    def __init__(self):
        self._cond = make_condition("gate")
        self._reward_lock = make_lock("reward")
        self._seq = 0

    def bad_unlocked(self):
        self._seq += 1
        self._cond.notify_all()  # seeded RPL005: no lock held (lost wakeup)

    def bad_wrong_lock(self):
        with self._reward_lock:
            self._cond.notify()  # seeded RPL005: holds the wrong lock

    def bad_extra_lock(self):
        with self._reward_lock:
            with self._cond:
                self._seq += 1
                self._cond.notify_all()  # seeded RPL005: extra lock held

    def good(self):
        with self._cond:
            self._seq += 1
            self._cond.notify_all()
