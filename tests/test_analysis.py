"""Concurrency-correctness tooling tests: the RPL lint, the runtime
lock-order witness, and the EventGate lost-wakeup contract.

The lint/witness regression pairs reconstruct the repo's two historical
races — the PR 5 emit-under-lock deadlock (reward worker dispatching
REWARDED while holding its lock vs the coordinator's INTERRUPTED emit)
and the PR 7 unlocked-busy-dict write — and prove the tooling catches
both shapes.
"""
import threading
import time
from pathlib import Path

from repro.analysis import lint, lock_order, witness
from repro.analysis.lint import ModuleLinter
from repro.analysis.witness import TrackedLock, TrackedRLock
from repro.core.lifecycle import (
    LifecycleEventKind as K,
    TrajectoryLifecycle,
)
from repro.core.types import Trajectory
from repro.runtime.schedulers import EventGate

FIXTURES = Path(__file__).parent / "fixtures" / "lint_violations"


def lint_src(source, relpath="mod.py"):
    return ModuleLinter(relpath, source).run()


# --------------------------------------------------------------------- lint
class TestLint:
    def test_selftest_catches_every_seeded_fixture_exactly(self):
        # every seeded RPL001-RPL005 hit at its exact file:line:col,
        # zero false positives on the clean fixtures
        assert lint.selftest(FIXTURES) == 0

    def test_repo_tree_is_clean_with_empty_baseline(self):
        assert lint.main(["--check"]) == 0

    def test_suppression_comment_silences_one_rule_with_reason(self):
        src = (
            "from repro.analysis.witness import make_lock\n"
            "class W:\n"
            "    def __init__(self, lifecycle):\n"
            "        self.lifecycle = lifecycle\n"
            "        self._lock = make_lock('reward')\n"
            "    def go(self, t):\n"
            "        with self._lock:\n"
            "            self.lifecycle.rewarded(t){}\n"
        )
        assert [d.rule for d in lint_src(src.format(""))] == ["RPL001"]
        ok = src.format("  # repro: allow[RPL001] reason=subs are lock-free")
        assert lint_src(ok) == []
        # a different rule in the allow bracket does not suppress
        other = src.format("  # repro: allow[RPL002] reason=wrong rule")
        assert [d.rule for d in lint_src(other)] == ["RPL001"]

    def test_unknown_lock_names_are_permissive_for_order(self):
        src = (
            "from repro.analysis.witness import make_lock\n"
            "class C:\n"
            "    def __init__(self):\n"
            "        self._a_lock = make_lock('zebra')\n"
            "        self._b_lock = make_lock('yak')\n"
            "    def go(self):\n"
            "        with self._a_lock:\n"
            "            with self._b_lock:\n"
            "                pass\n"
        )
        assert [d for d in lint_src(src) if d.rule == "RPL002"] == []

    def test_emit_safe_prefix_not_flagged(self):
        src = (
            "from repro.analysis.witness import make_rlock\n"
            "class C:\n"
            "    def __init__(self, lifecycle):\n"
            "        self.lifecycle = lifecycle\n"
            "        self.lock = make_rlock('coordinator')\n"
            "    def go(self, t):\n"
            "        with self.lock:\n"
            "            self.lifecycle.consumed(t)\n"
        )
        assert lint_src(src) == []

    def test_can_acquire_order_semantics(self):
        assert lock_order.can_acquire("coordinator", "ts")
        assert not lock_order.can_acquire("ts", "coordinator")
        # hard leaves admit nothing below them
        assert not lock_order.can_acquire("busy", "gate")
        # order-keyed same-name nesting must ascend
        assert lock_order.can_acquire(
            "instance", "instance", held_key=0, new_key=1
        )
        assert not lock_order.can_acquire(
            "instance", "instance", held_key=1, new_key=0
        )
        # unknown names are permissive (runtime witness still graphs them)
        assert lock_order.can_acquire("zebra", "coordinator")


# ------------------------------------------------------------------ witness
class TestWitness:
    def test_order_violation_reported_before_blocking(self):
        with witness.enabled() as w:
            ts = TrackedLock("ts")
            coord = TrackedLock("coordinator")
            with ts:
                with coord:  # ts(30) -> coordinator(0): inversion
                    pass
            assert w.violations()["order"] == 1
            sample = w.order_violations[0]
            assert sample["held"] == "ts" and sample["acquiring"] == "coordinator"
            assert sample["stack"]  # offending stack captured

    def test_opposite_order_threads_form_a_cycle_without_colliding(self):
        # the PR 5 detection property: two threads taking the same pair
        # in opposite orders are flagged even when they never deadlock
        with witness.enabled() as w:
            a = TrackedLock("zebra")  # unknown names: no order rank,
            b = TrackedLock("yak")    # the cycle check still applies

            def first():
                with a:
                    with b:
                        pass

            def second():
                with b:
                    with a:
                        pass

            for fn in (first, second):
                t = threading.Thread(target=fn)
                t.start()
                t.join()
            assert w.violations()["cycles"] == 1
            (cycle,) = w.cycles()
            assert set(cycle) == {"zebra", "yak"}

    def test_emit_under_non_safe_lock_flagged_with_stack(self):
        with witness.enabled() as w:
            reward = TrackedLock("reward")
            with reward:
                witness.on_emit("rewarded")
            assert w.violations()["emit_under_lock"] == 1
            sample = w.emit_under_lock[0]
            assert sample["held"] == ["reward"]
            assert sample["event"] == "rewarded"

    def test_emit_under_coordinator_prefix_is_clean(self):
        with witness.enabled() as w:
            coord = TrackedRLock("coordinator")
            with coord:
                witness.on_emit("consumed")
            witness.on_emit("rewarded")  # no lock held
            w.assert_clean()
            assert w.emits == 2

    def test_rlock_reentry_records_only_outermost(self):
        with witness.enabled() as w:
            coord = TrackedRLock("coordinator")
            with coord:
                with coord:
                    pass
            assert w.acquires == 1
            w.assert_clean()

    def test_condition_wait_flows_through_witness(self):
        with witness.enabled() as w:
            cond = witness.make_condition("gate")
            fired = threading.Event()

            def waiter():
                with cond:
                    cond.wait(timeout=5.0)
                fired.set()

            t = threading.Thread(target=waiter)
            t.start()
            time.sleep(0.05)
            with cond:
                cond.notify_all()
            t.join(timeout=5.0)
            assert fired.is_set()
            w.assert_clean()
            assert w.held_labels() == []

    def test_factories_return_plain_primitives_when_disabled(self):
        witness.disable()
        assert not isinstance(witness.make_lock("x"), TrackedLock)
        assert not isinstance(witness.make_rlock("x"), TrackedLock)
        cond = witness.make_condition("x")
        assert not isinstance(getattr(cond, "_lock", None), TrackedLock)
        witness.on_emit("rewarded")  # no-op, must not raise

    def test_pr5_regression_reward_dispatch_vs_coordinator_emit(self):
        # reconstruction of the PR 5 deadlock shape on a real lifecycle
        # bus: the coordinator path nests coordinator -> reward (legal),
        # while a reward worker dispatches REWARDED still holding its
        # lock — whose subscriber takes the coordinator lock. The
        # witness reports the emit and the coordinator<->reward cycle
        # without the threads ever needing to actually collide.
        with witness.enabled() as w:
            lifecycle = TrajectoryLifecycle()
            coord_lock = TrackedRLock("coordinator")
            reward_lock = TrackedLock("reward")
            lifecycle.subscribe(
                K.REWARDED, lambda e: coord_lock.acquire() or coord_lock.release()
            )

            def coordinator_path():
                with coord_lock:       # coordinator submits a score
                    with reward_lock:  # -> legal 0 -> 46 edge
                        pass

            def reward_worker():
                traj = Trajectory(traj_id=1, prompt=[1, 2, 3])
                with reward_lock:  # buggy: dispatch under the queue lock
                    lifecycle.rewarded(traj)

            for fn in (coordinator_path, reward_worker):
                t = threading.Thread(target=fn)
                t.start()
                t.join()
            v = w.violations()
            assert v["emit_under_lock"] >= 1
            assert v["cycles"] >= 1
            assert any(
                set(c) >= {"coordinator", "reward"} for c in w.cycles()
            )

    def test_fixed_shape_dispatch_outside_lock_is_clean(self):
        with witness.enabled() as w:
            lifecycle = TrajectoryLifecycle()
            coord_lock = TrackedRLock("coordinator")
            lifecycle.subscribe(
                K.REWARDED, lambda e: coord_lock.acquire() or coord_lock.release()
            )
            reward_lock = TrackedLock("reward")
            traj = Trajectory(traj_id=1, prompt=[1, 2, 3])
            with reward_lock:
                traj.reward = 1.0  # mutate under the lock ...
            lifecycle.rewarded(traj)  # ... dispatch after releasing
            w.assert_clean()


# ---------------------------------------------------------------- EventGate
class TestEventGate:
    def test_notify_between_seq_and_wait_returns_immediately(self):
        gate = EventGate()
        seen = gate.seq()
        gate.notify()  # lands in the seq()..wait() window
        t0 = time.perf_counter()
        assert gate.wait(seen, timeout=5.0)
        assert time.perf_counter() - t0 < 1.0

    def test_no_lost_wakeups_under_racing_notifier(self):
        gate = EventGate()
        stop = threading.Event()

        def hammer():
            while not stop.is_set():
                gate.notify()

        t = threading.Thread(target=hammer)
        t.start()
        try:
            misses = 0
            for _ in range(200):
                seen = gate.seq()
                if not gate.wait(seen, timeout=2.0):
                    misses += 1
            assert misses == 0
        finally:
            stop.set()
            t.join()

    def test_wait_times_out_false_when_idle(self):
        gate = EventGate()
        assert not gate.wait(gate.seq(), timeout=0.01)

    def test_subscribe_many_unsubscribe_many_symmetry(self):
        lifecycle = TrajectoryLifecycle()
        gate = EventGate()
        kinds = [K.REWARDED, K.ABORTED]
        before = {k: list(lifecycle._subs[k]) for k in K}
        lifecycle.subscribe_many(kinds, gate.notify)
        seen = gate.seq()
        traj = Trajectory(traj_id=1, prompt=[1, 2, 3])
        lifecycle.rewarded(traj)
        assert gate.seq() == seen + 1
        lifecycle.aborted(2)
        assert gate.seq() == seen + 2
        lifecycle.unsubscribe_many(kinds, gate.notify)
        lifecycle.rewarded(traj)  # no longer wired
        assert gate.seq() == seen + 2
        assert {k: list(lifecycle._subs[k]) for k in K} == before
