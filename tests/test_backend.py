"""EngineBackend conformance: the real JAX engine and the cost-model sim
backend must honor the same instance contract (`repro.rollout.backend`),
since the coordinator, runtime, simulator, and mixed clusters drive them
interchangeably through `execute_commands`."""
import jax
import numpy as np
import pytest

from repro.configs import get_arch
from repro.core import PAPER_H20_QWEN3_30B
from repro.core.commands import Abort, Interrupt, Pull, Route
from repro.core.snapshot import InstanceSnapshot
from repro.core.types import Trajectory, TrajStatus, reset_traj_ids
from repro.models import model as M
from repro.rollout.backend import (
    EngineBackend,
    SimBackend,
    VersionSource,
    create_backend,
    execute_commands,
)

CFG = get_arch("qwen2-1.5b").reduced()
PARAMS = M.init_params(CFG, jax.random.PRNGKey(0))


def mk_jax(inst_id=0, slots=2):
    return create_backend(
        "jax", inst_id, cfg=CFG, params=PARAMS, version=0,
        max_slots=slots, max_len=64, temperature=0.0,
    )


def mk_jax_paged(inst_id=0, slots=2):
    return create_backend(
        "jax", inst_id, cfg=CFG, params=PARAMS, version=0,
        max_slots=slots, max_len=64, temperature=0.0,
        paged=True, kv_block_size=16,
    )


def mk_sim(inst_id=0):
    return create_backend("sim", inst_id, cost_model=PAPER_H20_QWEN3_30B)


def mk_traj(tid, prompt_len=6, max_new=8):
    prompt = list(np.random.RandomState(tid).randint(3, 17, size=prompt_len))
    t = Trajectory(traj_id=tid, prompt=prompt, max_new_tokens=max_new)
    t.sim_target_len = max_new  # only the sim backend reads this
    return t


BACKENDS = {
    "jax": mk_jax,
    "jax_paged": mk_jax_paged,
    "sim": mk_sim,
}


def drive(inst, now=0.0, dt=5.0, rounds=200):
    done = []
    for i in range(rounds):
        done.extend(inst.step(now + i * dt, dt))
        if done:
            break
    return done


@pytest.mark.parametrize("kind", list(BACKENDS))
def test_backend_satisfies_protocol(kind):
    inst = BACKENDS[kind]()
    assert isinstance(inst, EngineBackend)
    for method in ("route", "interrupt", "abort", "pull", "step", "snapshot"):
        assert callable(getattr(inst, method))


@pytest.mark.parametrize("kind", list(BACKENDS))
def test_route_step_complete_cycle(kind):
    reset_traj_ids()
    inst = BACKENDS[kind]()
    t = mk_traj(1)
    inst.route(t, 0.0)
    assert t.instance == inst.inst_id
    snap = inst.snapshot()
    assert isinstance(snap, InstanceSnapshot)
    assert snap.resident() == {1}
    done = drive(inst)
    assert [d.traj_id for d in done] == [1]
    assert done[0].finished
    assert done[0].status == TrajStatus.GENERATED
    snap = inst.snapshot()
    assert snap.complete_trajs == {1}
    assert snap.resident() == set()


@pytest.mark.parametrize("kind", list(BACKENDS))
def test_route_many_admits_wave(kind):
    reset_traj_ids()
    inst = BACKENDS[kind]()
    trajs = [mk_traj(50 + i, max_new=100) for i in range(3)]
    inst.route_many(trajs, 0.0)
    snap = inst.snapshot()
    assert snap.resident() == {50, 51, 52}
    assert all(t.instance == inst.inst_id for t in trajs)


@pytest.mark.parametrize("kind", list(BACKENDS))
def test_interrupt_returns_and_detaches(kind):
    inst = BACKENDS[kind]()
    t = mk_traj(2, max_new=100)
    inst.route(t, 0.0)
    out = inst.interrupt([2], 1.0)
    assert [x.traj_id for x in out] == [2]
    assert out[0].status == TrajStatus.INTERRUPTED
    assert out[0].instance is None
    assert inst.snapshot().resident() == set()


@pytest.mark.parametrize("kind", list(BACKENDS))
def test_abort_marks_aborted(kind):
    inst = BACKENDS[kind]()
    t = mk_traj(3, max_new=100)
    inst.route(t, 0.0)
    out = inst.abort([3], 1.0)
    assert [x.traj_id for x in out] == [3]
    assert out[0].status == TrajStatus.ABORTED
    assert inst.snapshot().resident() == set()


@pytest.mark.parametrize("kind", list(BACKENDS))
def test_pull_bumps_version_and_clears_completions(kind):
    inst = BACKENDS[kind]()
    t = mk_traj(4)
    inst.route(t, 0.0)
    drive(inst)
    assert inst.snapshot().complete_trajs == {4}
    inst.pull(PARAMS if kind == "jax" else None, 5, 10.0)
    assert inst.inst_version == 5
    assert inst.snapshot().inst_version == 5
    assert inst.snapshot().complete_trajs == set()


@pytest.mark.parametrize("kind", list(BACKENDS))
def test_snapshot_kv_accounting_nonnegative(kind):
    inst = BACKENDS[kind]()
    t = mk_traj(5, max_new=100)
    inst.route(t, 0.0)
    snap = inst.snapshot()
    assert snap.kv_cache > 0
    assert snap.traj_lengths[5] >= len(t.prompt)
    inst.interrupt([5], 1.0)
    assert inst.snapshot().kv_cache == 0


def test_create_backend_rejects_unknown_kind():
    with pytest.raises(ValueError, match="unknown backend"):
        create_backend("cuda", 0)


class _StubTS:
    """Minimal trajectory-server facade for executor tests."""

    def __init__(self, trajs):
        self.registry = {t.traj_id: t for t in trajs}
        self.put_backs = []
        self.drops = []

    def take(self, tid):
        return self.registry[tid]

    def put_back(self, tid):
        self.put_backs.append(tid)

    def drop(self, tid):
        self.drops.append(tid)
        self.registry.pop(tid, None)


def test_execute_commands_mixed_backends():
    """One command batch, two backend kinds, one executor."""
    reset_traj_ids()
    instances = {0: mk_jax(0), 1: mk_sim(1)}
    trajs = [mk_traj(10), mk_traj(11, max_new=100)]
    ts = _StubTS(trajs)

    class _PS:
        version = 3

        def pull(self):
            return PARAMS, self.version

    ps = _PS()
    res = execute_commands(
        [
            Route(0, (10,), v_traj=3),
            Route(1, (11,), v_traj=3),
            Pull(0),
            Pull(1),
        ],
        instances,
        ts,
        ps,
        now=0.0,
    )
    assert res.routed == 2
    assert res.pulls == [(0, 3), (1, 3)]
    assert trajs[0].v_traj == 3 and trajs[1].v_traj == 3
    # Pull is issued post-interrupt by contract, but both backends must
    # still report the new version
    assert instances[0].inst_version == 3
    assert instances[1].inst_version == 3

    res2 = execute_commands(
        [Interrupt(1, (11,)), Abort(0, (10,)), Route(99, (10,))],
        instances,
        ts,
        ps,
    )
    assert res2.interrupted == 1 and res2.aborted == 1
    assert ts.put_backs == [11]
    assert ts.drops == [10]
    assert res2.routed == 0  # instance 99 doesn't exist: command skipped


def test_execute_commands_route_then_abort_stays_in_order():
    """Wave coalescing must not reorder a Route past a later Interrupt/
    Abort for the same trajectory: pending waves flush before any
    non-Route command executes."""
    inst = mk_sim(0)
    t = mk_traj(60, max_new=100)
    ts = _StubTS([t])
    res = execute_commands(
        [Route(0, (60,), v_traj=0), Abort(0, (60,))],
        {0: inst},
        ts,
        VersionSource(0),
    )
    assert res.routed == 1 and res.aborted == 1
    # the trajectory was routed, then aborted off the instance — it must
    # NOT still be resident (the engine never decodes a dropped traj)
    assert inst.snapshot().resident() == set()
    assert ts.drops == [60]
    assert t.status == TrajStatus.ABORTED


def test_execute_commands_timers_accumulate():
    instances = {0: mk_sim(0)}
    ts = _StubTS([mk_traj(20)])
    timers = {}
    execute_commands(
        [Route(0, (20,), v_traj=0), Pull(0)],
        instances,
        ts,
        VersionSource(1),
        timers=timers,
    )
    assert timers.get("route", 0) > 0
    assert timers.get("pull", 0) > 0


def test_sim_backend_respects_kv_budget():
    import dataclasses

    cm = dataclasses.replace(
        PAPER_H20_QWEN3_30B, kv_budget=PAPER_H20_QWEN3_30B.k5 * 100
    )
    inst = SimBackend(0, cm)
    a, b = mk_traj(30, prompt_len=20), mk_traj(31, prompt_len=20)
    inst.route(a, 0.0)
    inst.route(b, 0.0)
    snap = inst.snapshot()
    assert snap.run_trajs == {30}
    assert snap.wait_trajs == {31}


# ================================================ block-granular accounting
def test_sim_and_paged_engine_kv_accounting_parity():
    """SimBackend with a block-sized cost model must report the same
    ``snapshot().kv_cache`` as a paged RolloutInstance holding the same
    trajectories — the coordinator's routing math sees one memory picture
    across real and simulated replicas."""
    import dataclasses

    reset_traj_ids()
    bs = 16
    k5 = 2 * CFG.n_layers * CFG.n_kv_heads * CFG.hd * 4
    cm = dataclasses.replace(
        PAPER_H20_QWEN3_30B, k5=float(k5), block_size=bs, kv_budget=float("inf")
    )
    sim = SimBackend(0, cm)
    jaxp = create_backend(
        "jax", 1, cfg=CFG, params=PARAMS, version=0,
        max_slots=4, max_len=64, temperature=0.0,
        paged=True, kv_block_size=bs,
    )
    # 6 tokens -> 1 block, 20 tokens -> 2 blocks (lengths chosen off block
    # boundaries so the engine's +1 sampled token doesn't change the count)
    for tid, plen in ((70, 6), (71, 20)):
        t_sim, t_jax = mk_traj(tid, prompt_len=plen), mk_traj(tid, prompt_len=plen)
        sim.route(t_sim, 0.0)
        jaxp.route(t_jax, 0.0)
    expected = k5 * bs * (1 + 2)
    assert sim.snapshot().kv_cache == expected
    assert jaxp.snapshot().kv_cache == expected
    sim.interrupt([70, 71], 1.0)
    jaxp.interrupt([70, 71], 1.0)
    assert sim.snapshot().kv_cache == 0
    assert jaxp.snapshot().kv_cache == 0


def test_sim_and_engine_shared_prefix_kv_parity():
    """A freshly routed group must report identical snapshot kv_cache on a
    prefix-sharing paged engine and a SimBackend with the same block-sized
    cost model: shared prompt blocks charged once, exclusive tails per
    member — one memory picture for the coordinator."""
    import dataclasses

    reset_traj_ids()
    bs, plen, g = 16, 37, 3   # 2 full shared blocks + off-boundary tail
    k5 = 2 * CFG.n_layers * CFG.n_kv_heads * CFG.hd * 4
    cm = dataclasses.replace(
        PAPER_H20_QWEN3_30B, k5=float(k5), block_size=bs,
        kv_budget=float("inf"),
    )
    sim = SimBackend(0, cm, share_prefix=True)
    jaxp = create_backend(
        "jax", 1, cfg=CFG, params=PARAMS, version=0,
        max_slots=4, max_len=64, temperature=0.0,
        paged=True, kv_block_size=bs, share_prefix=True,
    )
    prompt = list(np.random.RandomState(7).randint(3, 17, size=plen))

    def group(base):
        return [
            Trajectory(traj_id=base + i, prompt=list(prompt), group_id=0,
                       max_new_tokens=50, sim_target_len=50)
            for i in range(g)
        ]

    sim.route_many(group(80), 0.0)
    jaxp.route_many(group(80), 0.0)
    n_full = plen // bs
    # lazy CoW (the default): shared prompt blocks once, plus ONE shared
    # tail block — nobody has decoded yet, so nobody owns a private copy
    expected = k5 * bs * (n_full + 1)
    assert sim.snapshot().kv_cache == expected
    assert jaxp.snapshot().kv_cache == expected
    # the coordinator's routing math prices the same group identically
    # when told every member is still undiverged (each engine member holds
    # prompt + 1 sampled token, same block count)
    assert cm.group_kv_bytes_for(
        plen, [plen + 1] * g, undiverged=g
    ) == expected
    # the default (eager/worst-case) view the admission decisions use
    assert cm.group_kv_bytes_for(plen, [plen + 1] * g) == (
        k5 * bs * (n_full + g)
    )
    assert sim.shared_prefix_hits == g - 1
    # snapshots agree on the prefix structure the discard math needs
    ssim, sjax = sim.snapshot(), jaxp.snapshot()
    assert list(ssim.prefix_tokens.values()) == [n_full * bs]
    assert list(sjax.prefix_tokens.values()) == [n_full * bs]
    assert set(map(frozenset, ssim.prefix_groups.values())) == set(
        map(frozenset, sjax.prefix_groups.values())
    )
    assert set(map(frozenset, ssim.prefix_tail_members.values())) == set(
        map(frozenset, sjax.prefix_tail_members.values())
    )
    # first decode write diverges the engine members (tail copied per
    # member); the sim mirrors at its first progress step
    jaxp.step()
    # past the prefill stall, under one token of progress: members diverge
    # without finishing (the divergence mirror fires at the first step)
    sim.step(0.0, 0.005)
    assert jaxp.snapshot().kv_cache == k5 * bs * (n_full + g)
    assert jaxp.block_copies == g - 1  # last owner wrote in place
    assert sim.block_copies == g - 1
    assert not jaxp.snapshot().prefix_tail_members
    assert not sim.snapshot().prefix_tail_members
    # members leave one by one: both release the tail only, then the
    # shared prefix with the last member
    sim.interrupt([80], 1.0)
    jaxp.interrupt([80], 1.0)
    assert sim.snapshot().kv_cache == jaxp.snapshot().kv_cache
    sim.interrupt([81, 82], 1.0)
    jaxp.interrupt([81, 82], 1.0)
    assert sim.snapshot().kv_cache == 0
    assert jaxp.snapshot().kv_cache == 0


def test_paged_engine_admits_more_than_dense_at_fixed_budget():
    """The acceptance property behind paging: at one fixed KV budget the
    paged engine runs strictly more concurrent trajectories than the dense
    engine, whose slots each reserve ``max_len`` rows."""
    reset_traj_ids()
    bs = 16
    max_len = 64
    k5 = 2 * CFG.n_layers * CFG.n_kv_heads * CFG.hd * 4
    budget = float(k5 * max_len * 2)  # HBM for 2 dense max_len slots

    dense = create_backend(
        "jax", 0, cfg=CFG, params=PARAMS, version=0,
        max_slots=2,  # budget // (k5 * max_len): dense reserves worst case
        max_len=max_len, temperature=0.0, kv_budget=budget,
    )
    paged = create_backend(
        "jax", 1, cfg=CFG, params=PARAMS, version=0,
        max_slots=8, max_len=max_len, temperature=0.0, kv_budget=budget,
        paged=True, kv_block_size=bs,
    )
    for inst in (dense, paged):
        reset_traj_ids()
        inst.route_many(
            [mk_traj(300 + i, prompt_len=6, max_new=100) for i in range(8)],
            0.0,
        )
    assert paged.n_active() > dense.n_active()
    assert paged.kv_bytes() <= budget and dense.kv_bytes() <= budget
