"""Unit tests: cost model (Eq. 2-4), strategies (Alg. 2-5), speculative
state (Eq. 1), TS/PS middleware, and the coordinator cycle (Alg. 1)."""
import threading

import pytest
from _optional import given, settings, st

from repro.core import (
    Abort,
    CostModel,
    InstanceSnapshot,
    Interrupt,
    ParameterServer,
    Pull,
    RolloutCoordinator,
    Route,
    SpeculativeState,
    StalenessManager,
    StalenessVerifier,
    StrategyConfig,
    Trajectory,
    TrajectoryServer,
    migration_strategy,
    plan_transfers,
    prefix_routing_strategy,
    routing_strategy,
    synchronization_strategy,
    vanilla_routing,
)
from repro.core.types import reset_traj_ids

CM = CostModel(k1=1e-9, k2=2e-3, k3=1e-4, k4=1e-2, k5=1000.0, kv_budget=1e9)


def snap(inst_id, *, kv=0.0, run=(), wait=(), complete=(), version=0, lengths=None):
    return InstanceSnapshot(
        inst_id=inst_id,
        kv_cache=kv,
        run_trajs=set(run),
        wait_trajs=set(wait),
        complete_trajs=set(complete),
        inst_version=version,
        traj_lengths=dict(lengths or {}),
    )


def traj(tid, length=100, v=None, group=-1):
    t = Trajectory(traj_id=tid, prompt=[1] * length, group_id=group)
    t.v_traj = v
    return t


# ------------------------------------------------------------- cost model
def test_cost_model_throughput_monotonic_in_load():
    s0 = snap(0)
    assert CM.throughput(s0) == 0.0
    s1 = snap(0, kv=1e6, run={1})
    s2 = snap(0, kv=2e6, run={1, 2})
    assert CM.throughput(s2) > CM.throughput(s1) > 0  # batching wins pre-knee


def test_cost_model_memory_vs_compute_regime():
    # knee at n = k2/k3 = 20
    lat_small = CM.step_latency(0, 10)
    lat_knee = CM.step_latency(0, 20)
    assert lat_small == lat_knee  # memory-bound floor
    assert CM.step_latency(0, 40) > lat_knee


def test_marginal_gain_zero_when_budget_exceeded():
    s = snap(0, kv=CM.kv_budget - 10.0, run={1})
    assert CM.marginal_gain(s, length=100) == 0.0
    assert not CM.admit(s, 100)


def test_marginal_gain_zero_when_waiters_exist():
    s = snap(0, wait={9})
    assert CM.marginal_gain(s, 10) == 0.0


def test_ideal_gain_matches_eq4():
    l = 123
    expect = 1.0 / (CM.k1 * CM.k5 * l + max(CM.k2, CM.k3) + CM.k4)
    assert CM.ideal_gain(l) == pytest.approx(expect)


def test_marginal_gain_discounted_by_preemptions():
    """Preemption-aware routing (ROADMAP): a replica thrashing its pool
    reports preemptions since the last snapshot, and its marginal gain is
    discounted so the coordinator stops feeding it."""
    calm = snap(0, kv=1e6, run={1}, lengths={1: 100})
    thrash = snap(1, kv=1e6, run={2}, lengths={2: 100})
    thrash.preemptions = 4
    g_calm = CM.marginal_gain(calm, 100)
    g_thrash = CM.marginal_gain(thrash, 100)
    assert g_calm > 0
    assert g_thrash == pytest.approx(
        g_calm / (1.0 + CM.preemption_penalty * 4)
    )
    # penalty 0 disables the discount
    cm0 = CM.scaled(preemption_penalty=0.0)
    assert cm0.marginal_gain(thrash, 100) == pytest.approx(
        cm0.marginal_gain(calm, 100)
    )


def test_coordinator_differences_cumulative_preemptions():
    """Snapshots report cumulative preemption counts (a pure read on the
    engine); the coordinator rewrites its local clone to the per-cycle
    delta before the strategies run, so the penalty tracks the live rate
    and decays once the pool stops churning."""
    mgr, ts, coord = _mk_coordinator()
    s = {0: snap(0)}
    s[0].preemptions = 5
    coord.spec.resync(s)
    coord.step(s, ps_version=0)
    assert coord._preempt_seen[0] == 5
    # caller's snapshot is untouched (clone-only rewrite)
    assert s[0].preemptions == 5
    # a later cycle with the same cumulative count = zero new thrash
    s2 = {0: snap(0)}
    s2[0].preemptions = 5
    coord.spec.resync(s2)
    coord.step(s2, ps_version=0)
    assert coord._preempt_seen[0] == 5


def test_routing_avoids_thrashing_instance():
    """Two otherwise-identical replicas: the one that preempted residents
    last window loses the waterfall."""
    s = {0: snap(0, kv=1e6, run={1}, lengths={1: 100}),
         1: snap(1, kv=1e6, run={2}, lengths={2: 100})}
    s[0].preemptions = 5
    routed = routing_strategy(s, [traj(10)], CM, _AlwaysYes())
    assert routed and routed[0][0] == 1


# ---------------------------------------------------- shared-prefix groups
def test_group_kv_bytes_charges_prefix_once():
    cm = CM.scaled(block_size=16)
    # P=40 -> 2 full blocks shared; each member len 45 -> 3 blocks total,
    # 1 exclusive beyond the shared prefix
    expect = cm.k5 * 16 * (2 + 4 * 1)
    assert cm.group_kv_bytes_for(40, [45] * 4) == expect
    # without paging there is no sharing: plain sum
    assert CM.group_kv_bytes_for(40, [45] * 4) == CM.k5 * 45 * 4


def test_prefix_routing_bundles_group_on_one_instance():
    """Group-affine routing: initial members of one sampling group land on
    a single instance (where the shared prefix will live), even when count
    balancing would scatter them."""
    reset_traj_ids()
    cm = CM.scaled(block_size=16)
    s = {0: snap(0), 1: snap(1, kv=1e5, run={99}, lengths={99: 100})}
    members = [traj(10 + i, length=40, group=7) for i in range(4)]
    routed = prefix_routing_strategy(s, members, cm, _AlwaysYes())
    assert len(routed) == 4
    assert len({inst for inst, _, _ in routed}) == 1
    # partial (already-versioned) members still route individually
    partial = traj(50, length=40, v=0, group=8)
    partial.response = [1] * 4
    routed2 = prefix_routing_strategy(
        s, [partial] + members, cm, _AlwaysYes()
    )
    assert len(routed2) == 5


def test_prefix_routing_splits_unplaceable_group_instead_of_stalling():
    """A group too big to EVER admit as one unit must not deadlock the
    waterfall: it splits into singleton units so members trickle in
    (remaining members then follow the standard Alg. 3 per-trajectory
    withhold semantics instead of freezing the cycle forever)."""
    cm = CM.scaled(block_size=16, kv_budget=CM.k5 * 16 * 5)  # 5-block pool
    s = {0: snap(0)}
    # 4 members x 37-token prompt: unit needs 2 shared + 4 tails = 6 > 5
    members = [traj(20 + i, length=37, group=9) for i in range(4)]
    routed = prefix_routing_strategy(s, members, cm, _AlwaysYes())
    routed_ids = {t.traj_id for _, t, _ in routed}
    assert 20 in routed_ids, "unplaceable group stalled the whole waterfall"
    # and with room for the whole group, nothing splits — all land together
    cm_big = CM.scaled(block_size=16)
    routed_all = prefix_routing_strategy(
        {0: snap(0)}, [traj(40 + i, length=37, group=9) for i in range(4)],
        cm_big, _AlwaysYes(),
    )
    assert len(routed_all) == 4
    assert len({i for i, _, _ in routed_all}) == 1


def test_prefix_routing_matches_plain_for_ungrouped():
    s = {0: snap(0), 1: snap(1)}
    ts = [traj(1), traj(2), traj(3)]
    a = prefix_routing_strategy(s, ts, CM, _AlwaysYes())
    b = routing_strategy(s, ts, CM, _AlwaysYes())
    assert [(i, t.traj_id, v) for i, t, v in a] == [
        (i, t.traj_id, v) for i, t, v in b
    ]


def test_snapshot_discard_releases_shared_prefix_once():
    """Prefix-aware discard: members release exclusive blocks only; the
    shared prompt blocks come off kv_cache with the last member."""
    k5, bs = 1000.0, 16
    n_full = 2                          # 32 shared prompt tokens
    # 3 members, each 45 tokens -> 3 blocks, 1 exclusive
    kv = k5 * bs * (n_full + 3 * 1)
    s = snap(0, kv=kv, run={1, 2, 3}, lengths={1: 45, 2: 45, 3: 45})
    s.prefix_groups = {0: {1, 2, 3}}
    s.prefix_tokens = {0: n_full * bs}
    s.discard([1], bytes_per_token=k5, block_size=bs)
    assert s.kv_cache == k5 * bs * (n_full + 2)
    s.discard([2, 3], bytes_per_token=k5, block_size=bs)
    assert s.kv_cache == 0.0
    assert s.prefix_groups == {} and s.prefix_tokens == {}


def test_with_routed_group_then_discard_roundtrips():
    cm = CM.scaled(block_size=16)
    s = snap(0)
    s2 = cm.with_routed_group(s, [1, 2, 3], 40, [45, 45, 45])
    assert s2.run_trajs == {1, 2, 3}
    assert s2.kv_cache == cm.group_kv_bytes_for(40, [45, 45, 45])
    s2.discard([1, 2, 3], bytes_per_token=cm.k5, block_size=16)
    assert s2.kv_cache == 0.0


# ------------------------------------------------------------- strategies
class _AlwaysYes:
    def can_assign(self, traj, version):
        return True


class _ManagerVerifier(StalenessVerifier):
    pass


def test_routing_prefers_emptier_instance():
    s = {0: snap(0, kv=5e8, run=set(range(30)), lengths={i: 100 for i in range(30)}),
         1: snap(1)}
    routed = routing_strategy(s, [traj(100)], CM, _AlwaysYes())
    assert routed and routed[0][0] == 1


def test_routing_mlq_prioritizes_staler_trajectories():
    s = {0: snap(0, version=3)}
    ts = [traj(1, v=None), traj(2, v=3), traj(3, v=1)]
    routed = routing_strategy(s, ts, CM, _AlwaysYes())
    order = [t.traj_id for _, t, _ in routed]
    assert order[:2] == [3, 2]  # v=1 first, then v=3, initial last


def test_routing_stops_entirely_when_front_is_unroutable():
    """Alg. 3 lines 13-15: an unroutable front trajectory halts the cycle
    (the synchronization strategy is responsible for unblocking it)."""
    s = {0: snap(0, version=0)}
    ts = [traj(3, v=1), traj(1, v=None)]
    assert routing_strategy(s, ts, CM, _AlwaysYes()) == []


def test_routing_waterfall_withholds_when_gain_low():
    # both instances heavily loaded -> marginal gain below mu * ideal
    heavy = set(range(200))
    lengths = {i: 5000 for i in heavy}
    s = {
        0: snap(0, kv=9.9e8, run=heavy, lengths=lengths),
        1: snap(1, kv=9.9e8, run=set(range(200, 400)),
                lengths={i: 5000 for i in range(200, 400)}),
    }
    routed = routing_strategy(s, [traj(1, length=50000)], CM, _AlwaysYes(),
                              StrategyConfig(mu=0.9))
    assert routed == []


def test_routing_respects_version_floor_for_partial_trajs():
    s = {0: snap(0, version=0), 1: snap(1, version=2)}
    t = traj(1, v=2)  # partially generated at version 2
    routed = routing_strategy(s, [t], CM, _AlwaysYes())
    assert routed and routed[0][0] == 1  # only instance 1 qualifies


def test_sync_strategy_only_when_starved_and_useful():
    mgr = StalenessManager(batch_size=4, eta=1)
    ver = StalenessVerifier(mgr, None)
    # instance 0 behind PS and starved (trajectory needs version >= 1)
    s = {0: snap(0, version=0)}
    t = traj(1, v=1)
    out = synchronization_strategy(s, [t], 1, CM, ver)
    assert out == [0]
    # not starved: an initial trajectory is routable at version 0
    out2 = synchronization_strategy(s, [traj(2, v=None)], 1, CM, ver)
    assert out2 == []
    # up to date: nothing to do
    out3 = synchronization_strategy({0: snap(0, version=1)}, [t], 1, CM, ver)
    assert out3 == []


def test_migration_wait_overflow():
    cfg = StrategyConfig(phi_wait=2)
    s = {0: snap(0, wait={1, 2, 3, 4}, lengths={1: 10, 2: 20, 3: 30, 4: 40}),
         1: snap(1)}
    out = migration_strategy(s, CM, cfg)
    insts = [i for i, _ in out]
    assert 0 in insts
    moved = [set(ts) for i, ts in out if i == 0][0]
    assert len(moved) == 2 and moved == {4, 3}  # longest waiters first


def test_migration_throughput_gap():
    cfg = StrategyConfig(phi_throughput=2.0)
    fast = snap(0, kv=1e6, run=set(range(10)), lengths={i: 100 for i in range(10)})
    slow = snap(1, kv=5e8, run={99}, lengths={99: 500000})
    out = migration_strategy({0: fast, 1: slow}, CM, cfg)
    assert out and out[0][0] == 0 and set(out[0][1]) == set(range(10))


def test_vanilla_routing_balances_counts():
    s = {0: snap(0, run={1, 2}), 1: snap(1)}
    routed = vanilla_routing(s, [traj(10), traj(11), traj(12)], CM, _AlwaysYes())
    targets = [i for i, _, _ in routed]
    assert targets.count(1) >= 2  # emptier instance takes more


# ------------------------------------------------------- speculative state
def test_speculative_state_eq1_cycle():
    p = SpeculativeState()
    s0 = {0: snap(0)}
    p.resync(s0)
    assert p.validate(s0)
    p.apply(Route(0, (1, 2)), ps_version=0)
    assert not p.validate(s0)  # commands not yet landed
    s1 = {0: snap(0, run={1, 2}, lengths={1: 1, 2: 1})}
    assert p.validate(s1)
    p.apply(Interrupt(0, (1,)), ps_version=0)
    s2 = {0: snap(0, run={2}, lengths={2: 1})}
    assert p.validate(s2)
    p.apply(Pull(0), ps_version=5)
    s3 = {0: snap(0, version=5)}
    assert p.validate(s3)


def test_speculative_counts_wait_and_complete():
    p = SpeculativeState()
    p.apply(Route(0, (1, 2, 3)), ps_version=0)
    # one running, one preempted to wait, one completed -> still accounted
    s = {0: snap(0, run={1}, wait={2}, complete={3}, lengths={1: 1, 2: 1})}
    assert p.validate(s)


# ----------------------------------------------------------------- TS / PS
def _prompts(n=100, length=8):
    return iter([[1] * length for _ in range(n)])


def test_ts_refill_respects_capacity_and_groups():
    reset_traj_ids()
    ts = TrajectoryServer(_prompts(), capacity_groups=3, group_size=2)
    assert ts.refill() == 3
    assert ts.n_available == 6  # 3 groups x 2 members
    assert ts.refill() == 0    # at capacity
    t = ts.peek()[0]
    ts.take(t.traj_id)
    assert ts.n_available == 5
    ts.put_back(t.traj_id)
    assert ts.n_available == 6


def test_ts_group_retirement_frees_capacity():
    reset_traj_ids()
    ts = TrajectoryServer(_prompts(), capacity_groups=1, group_size=2)
    ts.refill()
    ids = [t.traj_id for t in ts.peek()]
    for tid in ids:
        ts.take(tid)
        ts.complete(tid)
        ts.retire(tid)
    assert ts.refill() == 1  # slot freed -> new group sampled


def test_ps_push_pull_versioning():
    ps = ParameterServer()
    ps.push({"w": 1}, 0)
    ps.push({"w": 2}, 1)
    ps.push({"w": 0}, 0)  # stale push ignored
    params, v = ps.pull()
    assert v == 1 and params == {"w": 2}


def test_ps_rw_lock_concurrent_reads():
    ps = ParameterServer()
    ps.push({"w": 1}, 0)
    results = []
    barrier = threading.Barrier(4)

    def reader():
        barrier.wait(timeout=5)
        results.append(ps.pull()[1])

    threads = [threading.Thread(target=reader) for _ in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=5)
    assert results == [0, 0, 0, 0]


def test_comm_plan_balances_senders():
    required = [(f"s{i}", 100, "r", ["a", "b"]) for i in range(10)]
    plan = plan_transfers(required, lambda s, r: 100.0)
    lat = plan.per_sender_latency()
    assert set(lat) == {"a", "b"}
    assert abs(lat["a"] - lat["b"]) < 1.1 * (100 / 100.0 + 1e-4)
    assert plan.total_bytes == 1000


# ------------------------------------------------------------- coordinator
def _mk_coordinator(*, batch_size=2, eta=1, group_size=1, n_prompts=64):
    reset_traj_ids()
    mgr = StalenessManager(batch_size=batch_size, eta=eta)
    ts = TrajectoryServer(
        _prompts(n_prompts),
        capacity_groups=(eta + 1) * batch_size,
        group_size=group_size,
    )
    ts.refill()
    coord = RolloutCoordinator(
        mgr, ts, cost_model=CM, group_sampling=group_size > 1
    )
    return mgr, ts, coord


def test_coordinator_routes_and_reserves():
    mgr, ts, coord = _mk_coordinator()
    s = {0: snap(0), 1: snap(1)}
    coord.spec.resync(s)
    cmds = coord.step(s, ps_version=0)
    routes = [c for c in cmds if isinstance(c, Route)]
    assert routes, "expected routing commands"
    assert mgr.in_flight() == len(routes)
    for c in routes:
        assert c.v_traj == 0


def test_coordinator_rejects_unvalidated_snapshot():
    mgr, ts, coord = _mk_coordinator()
    s = {0: snap(0)}
    coord.spec.resync(s)
    coord.step(s, ps_version=0)          # issues routes -> P moves ahead
    cmds = coord.step(s, ps_version=0)   # same (stale) snapshot again
    assert cmds == []
    assert coord.stats.snapshots_rejected == 1


def test_coordinator_full_cycle_to_consume():
    mgr, ts, coord = _mk_coordinator(batch_size=2, eta=1)
    s = {0: snap(0)}
    coord.spec.resync(s)
    cmds = coord.step(s, ps_version=0)
    routed = [c for c in cmds if isinstance(c, Route)]
    # simulate instances finishing those trajectories
    for c in routed:
        for tid in c.traj_ids:
            t = ts.take(tid)
            t.response = [5] * 4
            ts.complete(tid)
            t.reward = 1.0
            coord.on_trajectory_rewarded(t)
    batch = coord.try_consume()
    assert batch is not None and len(batch) == 2
    assert mgr.train_version == 1


def test_coordinator_group_occupy_and_surplus_abort():
    mgr, ts, coord = _mk_coordinator(batch_size=1, eta=0, group_size=2)
    # group redundancy via TS config is separate; emulate surplus by marking
    # group complete after group_size rewards
    s = {0: snap(0)}
    coord.spec.resync(s)
    cmds = coord.step(s, ps_version=0)
    routed = [tid for c in cmds if isinstance(c, Route) for tid in c.traj_ids]
    group = ts.get(routed[0]).group_id
    members = [tid for tid in routed if ts.get(tid).group_id == group]
    assert len(members) >= 1
    done = 0
    for tid in members:
        t = ts.take(tid)
        t.response = [5]
        ts.complete(tid)
        t.reward = 1.0
        coord.on_trajectory_rewarded(t)
        done += 1
        if done == 2:
            break
    batch = coord.try_consume()
    assert batch is not None and len(batch) == 2


@settings(max_examples=30, deadline=None)
@given(
    batch_size=st.integers(1, 3),
    eta=st.integers(0, 2),
    n_inst=st.integers(1, 3),
)
def test_coordinator_never_violates_staleness(batch_size, eta, n_inst):
    """Drive full async cycles; the protocol invariant must hold throughout
    and consumed staleness never exceeds eta."""
    mgr, ts, coord = _mk_coordinator(batch_size=batch_size, eta=eta, n_prompts=200)
    snaps = {i: snap(i) for i in range(n_inst)}
    coord.spec.resync(snaps)
    ps_version = 0
    for _ in range(12):
        cmds = coord.step(snaps, ps_version)
        for c in cmds:
            if isinstance(c, Route):
                for tid in c.traj_ids:
                    t = ts.take(tid)
                    snaps[c.inst].run_trajs.add(tid)
                    snaps[c.inst].traj_lengths[tid] = t.length
                    snaps[c.inst].kv_cache += CM.k5 * t.length
            elif isinstance(c, Interrupt):
                snaps[c.inst].discard(c.traj_ids, bytes_per_token=CM.k5)
                for tid in c.traj_ids:
                    if ts.get(tid) is not None:
                        ts.put_back(tid)
            elif isinstance(c, Pull):
                snaps[c.inst].inst_version = ps_version
                snaps[c.inst].complete_trajs = set()
            elif isinstance(c, Abort):
                snaps[c.inst].discard(c.traj_ids, bytes_per_token=CM.k5)
        # instances finish everything they run
        for i, si in snaps.items():
            for tid in sorted(si.run_trajs):
                t = ts.get(tid)
                if t is None:
                    si.discard([tid], bytes_per_token=CM.k5)
                    continue
                t.response = [7] * 3
                ts.complete(tid)
                t.reward = 1.0
                coord.on_trajectory_rewarded(t)
                si.complete_trajs.add(tid)
                si.run_trajs.discard(tid)
            mgr.check_invariants()
        batch = coord.try_consume()
        if batch is not None:
            ps_version += 1
        ts.refill()
    for hist in mgr.consumed_staleness:
        assert all(0 <= x <= eta for x in hist)


# --------------------------------------- streaming incremental admission
def test_route_instance_routes_single_instance():
    """The event-driven fast path routes to the freed instance alone,
    reserving protocol entries exactly like a full cycle would."""
    mgr, ts, coord = _mk_coordinator()
    s0 = snap(0)
    coord.spec.resync({0: s0})
    cmds = coord.route_instance(s0, ps_version=0)
    routes = [c for c in cmds if isinstance(c, Route)]
    assert routes and all(isinstance(c, Route) for c in cmds)
    assert all(c.inst == 0 for c in routes)
    assert mgr.in_flight() == len(routes)
    assert coord.stats.stream_cycles == 1
    assert coord.stats.stream_routes == len(routes)
    # seed counters untouched: stream cycles are accounted separately
    assert coord.stats.cycles == 0
    assert coord.stats.snapshots_rejected == 0


def test_route_instance_validates_snapshot():
    """A stale single-instance snapshot (its Route effects not yet
    landed) is Eq. 1-rejected without disturbing the seed counters."""
    mgr, ts, coord = _mk_coordinator()
    s0 = snap(0)
    coord.spec.resync({0: s0})
    assert coord.route_instance(s0, ps_version=0)  # P moved ahead
    cmds = coord.route_instance(s0, ps_version=0)  # same stale snapshot
    assert cmds == []
    assert coord.stats.stream_rejected == 1
    assert coord.stats.snapshots_rejected == 0


def test_route_instance_noop_on_empty_ts():
    mgr, ts, coord = _mk_coordinator(n_prompts=0)
    s0 = snap(0)
    coord.spec.resync({0: s0})
    assert coord.route_instance(s0, ps_version=0) == []
    assert mgr.in_flight() == 0


def test_route_instance_respects_staleness_gate():
    """The verifier gate carries over: with protocol capacity exhausted,
    the fast path admits nothing."""
    mgr, ts, coord = _mk_coordinator(batch_size=1, eta=0)
    s0 = snap(0)
    coord.spec.resync({0: s0})
    first = coord.route_instance(s0, ps_version=0)
    assert len(first) == 1  # (eta+1)*batch_size = 1 protocol slot
    for c in first:
        t = ts.take(c.traj_ids[0])  # what execute_commands would do
        s0.run_trajs.add(c.traj_ids[0])
        s0.traj_lengths[c.traj_ids[0]] = t.length
        s0.kv_cache += CM.k5 * t.length
    # snapshot now validates, but no protocol slot is free
    assert coord.route_instance(s0, ps_version=0) == []
    assert mgr.in_flight() == 1


def test_route_instance_guarded_against_reentry():
    """A lifecycle subscriber firing inside a running cycle's dispatch
    must not recurse into admission (the coordinator lock is held)."""
    mgr, ts, coord = _mk_coordinator()
    s0 = snap(0)
    coord.spec.resync({0: s0})
    observed = []

    real_routing = coord.suite.routing

    def probing_routing(*a, **kw):
        # we are inside step() -> in_cycle() is True for this thread,
        # so a re-entrant fast-path call must bail out empty
        observed.append(coord.in_cycle())
        observed.append(coord.route_instance(s0, ps_version=0))
        return real_routing(*a, **kw)

    coord.suite = type(coord.suite)(
        routing=probing_routing,
        synchronization=coord.suite.synchronization,
        migration=coord.suite.migration,
    )
    cmds = coord.step({0: s0}, ps_version=0)
    assert [c for c in cmds if isinstance(c, Route)]
    assert observed[0] is True
    assert observed[1] == []  # re-entrant admission refused


def test_route_instance_then_full_cycle_consume():
    """Admission via the fast path feeds the same protocol pipeline: the
    routed trajectories complete, reward, and consume under the bound."""
    mgr, ts, coord = _mk_coordinator(batch_size=2, eta=1)
    s0 = snap(0)
    coord.spec.resync({0: s0})
    cmds = coord.route_instance(s0, ps_version=0)
    assert cmds
    for c in cmds:
        for tid in c.traj_ids:
            t = ts.take(tid)
            t.response = [5] * 4
            ts.complete(tid)
            t.reward = 1.0
            coord.on_trajectory_rewarded(t)
    batch = coord.try_consume(min_fill=1)
    assert batch is not None and 1 <= len(batch) <= 2
    assert mgr.train_version == 1
    for hist in mgr.consumed_staleness:
        assert all(0 <= s <= 1 for s in hist)
