"""Distribution layer tests.

Sharding rules are pure functions -> tested directly. Multi-device
semantics (compressed psum, mesh construction, small-scale lower+compile)
run in SUBPROCESSES with XLA_FLAGS=--xla_force_host_platform_device_count
so the main test process keeps its single CPU device (per the assignment:
the 512-device trick is dry-run-only)."""
import subprocess
import sys
import textwrap

from jax.sharding import PartitionSpec as P

from repro.configs import get_arch
from repro.distributed.sharding import param_spec


class FakeMesh:
    """Duck-typed mesh: only ``shape`` (axis sizes) is consulted by rules."""

    def __init__(self, **axes):
        self.shape = dict(axes)


MESH = FakeMesh(data=16, model=16)


def test_param_spec_attention_weights():
    # (L, D, H*hd): D -> data, heads -> model
    assert param_spec(MESH, "['blocks']['wq']", (48, 5120, 5120)) == P(None, "data", "model")
    assert param_spec(MESH, "['blocks']['wo']", (48, 5120, 5120)) == P(None, "model", "data")


def test_param_spec_embed_vocab_padding_divisible():
    cfg = get_arch("granite-3-8b")
    assert cfg.vocab_size % 16 != 0        # raw vocab does NOT divide
    assert cfg.padded_vocab % 256 == 0     # padded vocab shards cleanly
    spec = param_spec(MESH, "['embed']", (cfg.padded_vocab, cfg.d_model))
    assert spec == P("model", "data")


def test_param_spec_nondivisible_falls_back_to_replication():
    # head dim 100 does not divide model=16 -> replicated on that dim
    spec = param_spec(MESH, "['blocks']['wq']", (4, 128, 100))
    assert spec == P(None, "data", None)


def test_param_spec_moe_expert_parallel():
    spec = param_spec(MESH, "['blocks']['we_gate']", (40, 16, 6144, 10752))
    assert spec == P(None, "model", "data", None)


def test_param_spec_opt_state_mirrors_params():
    a = param_spec(MESH, "['m']['blocks']['wq']", (48, 5120, 5120))
    b = param_spec(MESH, "['blocks']['wq']", (48, 5120, 5120))
    assert a == b


def test_param_spec_norms_replicated():
    assert param_spec(MESH, "['blocks']['attn_norm']", (48, 5120)) == P()


def _run_subprocess(code: str, devices: int = 8) -> str:
    prog = (
        f"import os; os.environ['XLA_FLAGS']="
        f"'--xla_force_host_platform_device_count={devices}'\n"
        + textwrap.dedent(code)
    )
    import os

    out = subprocess.run(
        [sys.executable, "-c", prog],
        capture_output=True, text=True, timeout=480,
        env={
            "PYTHONPATH": "src",
            "PATH": "/usr/bin:/bin",
            # without this the child jax probes for a TPU backend (libtpu
            # ships in the image) and stalls minutes on metadata retries
            "JAX_PLATFORMS": os.environ.get("JAX_PLATFORMS", "cpu"),
        },
        cwd="/root/repo",
    )
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_production_mesh_shapes_subprocess():
    out = _run_subprocess(
        """
        import jax
        from repro.launch.mesh import make_production_mesh
        m = make_production_mesh()
        assert m.shape == {"data": 16, "model": 16}, m.shape
        m2 = make_production_mesh(multi_pod=True)
        assert m2.shape == {"pod": 2, "data": 16, "model": 16}
        assert m2.size == 512
        print("MESH_OK")
        """,
        devices=512,
    )
    assert "MESH_OK" in out


def test_compressed_psum_subprocess():
    out = _run_subprocess(
        """
        import jax, jax.numpy as jnp, numpy as np
        from functools import partial
        from repro.distributed.collectives import psum_compressed, shard_map
        mesh = jax.make_mesh((4,), ("data",))
        x = jnp.arange(32, dtype=jnp.float32).reshape(4, 8) / 7.0

        @partial(shard_map, mesh=mesh,
                 in_specs=jax.sharding.PartitionSpec("data"),
                 out_specs=jax.sharding.PartitionSpec("data"))
        def f(xs):
            return psum_compressed(xs, "data")

        got = f(x)
        want = jnp.broadcast_to(x.sum(0, keepdims=True), x.shape)
        err = float(jnp.abs(got - want).max()) / float(jnp.abs(want).max())
        assert err < 0.02, err   # int8 quantization tolerance
        print("PSUM_OK", err)
        """,
        devices=4,
    )
    assert "PSUM_OK" in out


def test_gpipe_matches_sequential_subprocess():
    """4-stage GPipe over a toy MLP stack == sequential application."""
    out = _run_subprocess(
        """
        import jax, jax.numpy as jnp, numpy as np
        from repro.distributed.pipeline import gpipe_apply
        S, M, mb, d = 4, 6, 2, 8
        mesh = jax.make_mesh((S,), ("stage",))
        key = jax.random.PRNGKey(0)
        w = jax.random.normal(key, (S, d, d)) * 0.3
        b = jax.random.normal(jax.random.fold_in(key, 1), (S, d)) * 0.1
        x = jax.random.normal(jax.random.fold_in(key, 2), (M, mb, d))

        def stage_fn(p, h):
            return jnp.tanh(h @ p["w"] + p["b"])

        got = gpipe_apply(stage_fn, {"w": w, "b": b}, x, mesh=mesh)
        ref = x
        for s in range(S):
            ref = jnp.tanh(ref @ w[s] + b[s])
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   rtol=1e-5, atol=1e-5)
        print("GPIPE_OK")
        """,
        devices=4,
    )
    assert "GPIPE_OK" in out


def test_small_mesh_train_step_executes_subprocess():
    """Numerically execute the sharded RL train step on an 8-device mesh
    (reduced arch) — proves in/out shardings are not just lowerable but
    runnable."""
    out = _run_subprocess(
        """
        import jax, jax.numpy as jnp
        from repro.configs import get_arch
        from repro.distributed import sharding as shd
        from repro.training.optimizer import AdamWConfig, init_opt_state
        from repro.training.train_step import make_rl_train_step
        from repro.models import model as M

        cfg = get_arch("qwen2-1.5b").reduced()
        mesh = jax.make_mesh((4, 2), ("data", "model"))
        params = M.init_params(cfg, jax.random.PRNGKey(0))
        opt = init_opt_state(params)
        b, t = 8, 32
        batch = {
            "tokens": jnp.ones((b, t), jnp.int32) * 5,
            "behavior_logprobs": jnp.full((b, t), -2.0),
            "mask": jnp.ones((b, t)),
            "advantages": jnp.linspace(-1, 1, b),
        }
        p_sh = shd.params_shardings(mesh, params)
        o_sh = shd.opt_shardings(mesh, opt)
        b_sh = shd.train_batch_shardings(mesh, batch)
        step = jax.jit(
            make_rl_train_step(cfg, AdamWConfig(lr=1e-3)),
            in_shardings=(p_sh, o_sh, b_sh),
            out_shardings=(p_sh, o_sh, None),
        )
        params = jax.device_put(params, p_sh)
        opt = jax.device_put(opt, o_sh)
        batch = jax.device_put(batch, b_sh)
        p2, o2, m = step(params, opt, batch)
        assert jnp.isfinite(m["loss"]), m
        print("TRAIN_OK", float(m["loss"]))
        """,
        devices=8,
    )
    assert "TRAIN_OK" in out
